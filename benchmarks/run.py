"""Benchmark harness — one entry per paper table/figure + infra perf.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig1,...]

Prints ``name,us_per_call,derived`` CSV per run (plus human-readable
logs) and writes JSON to experiments/bench/.  Every row is recorded
through a ``repro.obs.MetricsRegistry`` — the CSV and the ``metrics``
key in results.json are both rendered from its ``snapshot()``, so the
bench results share the exact schema the engines' telemetry emits.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.obs import MetricsRegistry

ALL = ("table1", "table2", "fig1", "fig3", "perf", "het", "cohort",
       "dist", "pipeline", "quant", "serve", "tier", "obs", "roofline")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list from: " + ",".join(ALL))
    ap.add_argument("--fresh", action="store_true",
                    help="ignore cached per-bench JSON results")
    args = ap.parse_args()
    which = args.only.split(",") if args.only else list(ALL)

    def cached(name, fn):
        path = f"experiments/bench/{name}.json"
        if not args.fresh and os.path.exists(path):
            print(f"[{name}] using cached results from {path}")
            with open(path) as f:
                return json.load(f)
        out = fn()
        with open(path, "w") as f:
            json.dump(out, f, indent=1, default=str)
        return out

    os.makedirs("experiments/bench", exist_ok=True)
    results = {}
    reg = MetricsRegistry()

    def record(name, us, derived):
        # one labeled series per bench row; the CSV below and the
        # results.json "metrics" key render from reg.snapshot()
        reg.gauge("bench/us_per_call").set(float(us), name=name,
                                           derived=str(derived))

    t00 = time.time()
    if "table1" in which:
        from benchmarks import table1_accuracy
        rows = cached("table1", table1_accuracy.run)
        results["table1"] = rows
        for r in rows:
            record(f"table1/{r['dataset']}/{r['method']}", r['wall_s']*1e6,
                   f"global_acc={r['global_acc']:.4f};"
                   f"local_acc={r['local_acc']:.4f}")
    if "table2" in which:
        from benchmarks import table2_rank
        rows = cached("table2", table2_rank.run)
        results["table2"] = rows
        for r in rows:
            record(f"table2/r{r['r']}xn{r['n']}", r['wall_s']*1e6,
                   f"acc={r['acc']:.4f};pct_params={r['pct_params']:.4f}")
    if "fig1" in which:
        from benchmarks import fig1_sensitivity
        rep = cached("fig1", fig1_sensitivity.run)
        results["fig1"] = rep
        record("fig1/sensitivity", rep['wall_s']*1e6,
               f"dirA_over_dirB={rep['obs1_dir_ratio_A_over_B']:.3f};"
               f"magB_over_magA={rep['obs2_mag_ratio_B_over_A']:.3f}")
    if "fig3" in which:
        from benchmarks import fig3_pipeline
        rows = cached("fig3", fig3_pipeline.run)
        results["fig3"] = rows
        for r in rows:
            tag = "post-serial" if r["pipeline"] else "pre-serial"
            record(f"fig3/{tag}", r['wall_s']*1e6,
                   f"local_acc={r['local_acc']:.4f}")
    if "perf" in which:
        from benchmarks import perf_micro
        rows = cached("perf", perf_micro.run)
        results["perf"] = rows
        for r in rows:
            record(f"perf/{r['arch']}/fwd", r['fwd_us'], "smoke_cpu")
            record(f"perf/{r['arch']}/decode", r['dec_us'], "smoke_cpu")
    if "het" in which:
        from benchmarks import perf_micro
        rows = cached("het", lambda: perf_micro.run_het_round()[0])
        results["het"] = rows
        for r in rows:
            record(f"perf/{r['arch']}", r['us'],
                   f"ratio_vs_uniform={r['ratio']:.2f}")
    if "cohort" in which:
        from benchmarks import perf_micro
        rows = cached("cohort", lambda: perf_micro.run_cohort()[0])
        results["cohort"] = rows
        for r in rows:
            record(f"perf/{r['arch']}", r['us'],
                   f"ratio_vs_full={r['ratio']:.2f}")
    if "dist" in which:
        from benchmarks import perf_micro
        rows = cached("dist", lambda: perf_micro.run_dist_round()[0])
        results["dist"] = rows
        for r in rows:
            record(f"perf/{r['arch']}", r['us'],
                   f"ratio_vs_engine={r['ratio']:.2f}")
    if "pipeline" in which:
        from benchmarks import perf_micro
        rows = cached("pipeline", lambda: perf_micro.run_pipeline()[0])
        results["pipeline"] = rows
        for r in rows:
            record(f"perf/{r['arch']}", r['us'],
                   f"ratio_vs_engine={r['ratio']:.2f}")
    if "quant" in which:
        from benchmarks import perf_micro
        rows = cached("quant", lambda: perf_micro.run_quant()[0])
        results["quant"] = rows
        for r in rows:
            extra = (f"bytes_ratio={r['bytes_ratio']:.2f}"
                     if "bytes_ratio" in r else "smoke_cpu")
            record(f"perf/{r['arch']}", r['us'], extra)
    if "serve" in which:
        from benchmarks import serve_multitenant
        rows = cached("serve", lambda: (serve_multitenant.run()[0]
                                        + serve_multitenant.run_quant()[0]))
        results["serve"] = rows
        for r in rows:
            record(r['arch'], r['us'], f"tokens_s={r['tokens_s']:.1f}")
    if "tier" in which:
        from benchmarks import serve_multitenant
        rows = cached("tier", lambda: serve_multitenant.run_churn()[0])
        results["tier"] = rows
        for r in rows:
            extra = (f";ratio={r['ratio']:.2f}" if "ratio" in r else "")
            record(r['arch'], r['us'],
                   f"tokens_s={r['tokens_s']:.1f}" + extra)
    if "obs" in which:
        from benchmarks import perf_micro
        rows = cached("obs", lambda: perf_micro.run_obs()[0])
        results["obs"] = rows
        for r in rows:
            record(f"perf/{r['arch']}", r['us'],
                   f"ratio_vs_disabled={r['ratio']:.3f}")
    if "roofline" in which:
        from benchmarks import roofline
        recs = roofline.load_records()
        results["roofline_n"] = len(recs)
        for line in roofline.quant_decode_table():
            print(line)
        for line in roofline.table(recs):
            print(line)
        for r in recs:
            if r.get("status") != "ok":
                continue
            ro = r["roofline"]
            step_s = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
            vtag = "" if r.get("variant", "baseline") == "baseline" \
                else f"+{r['variant']}"
            record(f"roofline/{r['arch']}{vtag}/{r['shape']}/{r['mesh']}",
                   step_s*1e6,
                   f"dom={ro['dominant']};fits={r['fits_16g']}")

    snap = reg.snapshot()
    results["metrics"] = snap
    csv_lines = ["name,us_per_call,derived"]
    for s in snap["gauges"].get("bench/us_per_call", []):
        csv_lines.append(f"{s['labels']['name']},{s['value']:.0f},"
                         f"{s['labels']['derived']}")
    with open("experiments/bench/results.json", "w") as f:
        json.dump(results, f, indent=1, default=str)
    print()
    print("\n".join(csv_lines))
    print(f"\n[benchmarks done in {time.time()-t00:.0f}s; "
          f"JSON -> experiments/bench/results.json]")


if __name__ == "__main__":
    main()
