"""Shared benchmark scaffolding: reduced model, cached pretrained base,
heterogeneous client datasets (paper setting: one downstream task per
client; causal / QA / IE like Table I)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.data.loader import eval_batches
from repro.data.synthetic import (SyntheticInstructionDataset,
                                  make_dataset_family, TASK_TYPES)
from repro.fed.pretrain import get_pretrained_base
from repro.models.config import ArchConfig

# ~1.6 M params — "llama-family" reduced model used across benchmarks
BENCH_CFG = ArchConfig(
    name="bench-llama", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=512, dtype="float32", lora_rank=8,
    lora_alpha=32.0, lora_dropout=0.0, source="reduced llama2 family")

# Paper Table I uses three downstream tasks; map to our generators.
PAPER_TASKS = ("causal", "qa", "ie")
SEQ = 48
EVAL_BATCH = 32
N_EVAL = 4


def task_probs(task: str):
    return [1.0 if t == task else 0.0 for t in TASK_TYPES]


def mixture_probs():
    return [1.0 / len(PAPER_TASKS) if t in PAPER_TASKS else 0.0
            for t in TASK_TYPES]


def build_setting(dataset_name: str, n_clients: int = 3, seed: int = 0,
                  pool_size: int = 64):
    """Returns (client_datasets, server_dataset, eval_global, eval_local).

    pool_size: finite per-client training shard (paper setting — Dolly-15k
    split across clients); eval batches are always fresh/held-out."""
    fam = make_dataset_family(dataset_name)
    cds = [SyntheticInstructionDataset(
        fam, task_probs(PAPER_TASKS[c % len(PAPER_TASKS)]),
        client_seed=seed,                      # shared world per family
        pool_size=pool_size, pool_seq_len=SEQ)
        for c in range(n_clients)]
    sds = SyntheticInstructionDataset(fam, mixture_probs(), client_seed=seed)
    eval_global = eval_batches(sds, EVAL_BATCH, SEQ, N_EVAL, seed=20_000)
    rng = np.random.default_rng(30_000)
    eval_local = []
    for _ in range(N_EVAL):
        # held-out per-task eval — sample_task_batch always generates
        # fresh examples (never the client's finite training pool)
        outs = [d.sample_task_batch(rng, EVAL_BATCH, SEQ,
                                    PAPER_TASKS[i % len(PAPER_TASKS)])
                for i, d in enumerate(cds)]
        eval_local.append({k: jnp.asarray(np.stack([o[k] for o in outs]))
                           for k in outs[0]})
    return cds, sds, eval_global, eval_local


def eval_per_task(sim_or_params_eval, fam_name: str, tasks=PAPER_TASKS):
    fam = make_dataset_family(fam_name)
    out = {}
    for t in tasks:
        ds = SyntheticInstructionDataset(fam, task_probs(t), client_seed=0)
        out[t] = eval_batches(ds, EVAL_BATCH, SEQ, N_EVAL, seed=40_000)
    return out


def bench_base(dataset_name: str, steps: int = 800, log=lambda s: None):
    fam = make_dataset_family(dataset_name)
    mix = SyntheticInstructionDataset(fam, mixture_probs(), client_seed=0)
    return get_pretrained_base(BENCH_CFG, mix, steps=steps, log=log)
