from repro.fed.simulate import FedSim, FedHyper  # noqa: F401
