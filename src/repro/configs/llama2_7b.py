"""LLaMA-2 7B — the paper\'s primary fine-tuning target
[arXiv:2307.09288]."""
from repro.models.config import ArchConfig, reduced

ARCH = ArchConfig(
    name="llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008,
    vocab_size=32000,
    source="arXiv:2307.09288",
)
SMOKE = reduced(ARCH)
