from repro.optim.optimizers import (  # noqa: F401
    adamw,
    sgd,
    OptState,
    Optimizer,
    masked,
    chain_clip,
)
from repro.optim.schedules import (  # noqa: F401
    constant_schedule,
    cosine_schedule,
    linear_warmup_cosine,
)
