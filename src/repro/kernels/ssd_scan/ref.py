"""Pure-jnp oracle for the Mamba-2 SSD chunked scan.

Reuses the model's XLA implementation (models/ssm._ssd_chunked) — itself
validated against a naive per-step recurrence in tests/test_ssm.py — so
kernel ⇄ model ⇄ naive recurrence form a three-way agreement check.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import _ssd_chunked


def ssd_ref(x, dt, A_log, B, C, chunk: int):
    """x (b,S,H,P); dt (b,S,H); B,C (b,S,G,N) → (y (b,S,H,P), state)."""
    return _ssd_chunked(x, dt, A_log, B, C, chunk)


def ssd_naive(x, dt, A_log, B, C):
    """O(S) sequential recurrence — ground truth for tiny shapes."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    a = jnp.exp(-jnp.exp(A_log.astype(jnp.float32)) * dt.astype(jnp.float32))
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    state = jnp.zeros((b, H, N, P), jnp.float32)
    ys = []
    for t in range(S):
        state = a[:, t, :, None, None] * state + jnp.einsum(
            "bhn,bhp->bhnp", Bh[:, t], xdt[:, t])
        ys.append(jnp.einsum("bhn,bhnp->bhp", Ch[:, t], state))
    y = jnp.stack(ys, axis=1)
    return y.astype(x.dtype), state.transpose(0, 1, 3, 2)
