"""Pallas TPU kernel: fused DoRA-LoRA linear.

On GPU the adapter path is two extra cuBLAS launches + elementwise ops,
each round-tripping through HBM.  On TPU we fuse: for every (M,N) output
tile the kernel streams K-tiles of x and W0 through VMEM, accumulating the
base matmul on the MXU, and *in the same K-loop* accumulates the rank-r
intermediate h = (x ⊙ A_mag) @ (A_dir + dA_dir) — A-factor columns ride
along with the W0 K-tiles, so x is read from HBM exactly once.  At the
final K step the tiny (bm × r) h tile is scaled by (B_mag + dB_mag) and
pushed through B_dir (r ≤ 128 ⇒ one MXU pass) into the output tile.

Grid: (M/bm, N/bn, K/bk)  — K innermost so the f32 scratch accumulators
live in VMEM across the K loop.

VMEM working set (bm=bn=256, bk=512, r=32, bf16):
  x(256·512) + w0(512·256) + a(512·32) + bdir(32·256) + acc(256·256·4)
  + h(256·32·4) ≈ 0.85 MB  « 16 MB v5e VMEM; MXU dims all multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w0_ref, adir_ref, amag_ref, bdir_ref, bmag_ref,
            o_ref, acc_ref, h_ref, *, scale: float, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...]
    # base path: acc += x @ w0   (MXU, f32 accumulate)
    acc_ref[...] += jax.lax.dot_general(
        x, w0_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    # adapter path: h += (x * a_mag) @ (a_dir + da_dir)
    xs = x * amag_ref[...][None, :].astype(x.dtype)
    h_ref[...] += jax.lax.dot_general(
        xs, adir_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        h = h_ref[...] * bmag_ref[...][None, :]
        delta = jax.lax.dot_general(
            h.astype(bdir_ref.dtype), bdir_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * delta).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk",
                                             "interpret"))
def fused_dora_matmul(x, w0, a_dir, a_mag, b_dir, b_mag, da_dir, db_mag,
                      *, scale: float = 1.0, bm: int = 256, bn: int = 256,
                      bk: int = 512, interpret: bool = False):
    """x (M,K) @ [W0 + scale·diag(A_mag)(A_dir+dA_dir)diag(B_mag+dB_mag)B_dir]."""
    M, K = x.shape
    N = w0.shape[1]
    r = a_dir.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    a_eff = (a_dir + da_dir).astype(x.dtype)
    b_eff_mag = (b_mag + db_mag).astype(jnp.float32)

    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # w0
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),    # a_eff
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),        # a_mag
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),    # b_dir
            pl.BlockSpec((r,), lambda i, j, k: (0,)),         # b_eff_mag
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),   # base accumulator
            pltpu.VMEM((bm, r), jnp.float32),    # adapter intermediate
        ],
        interpret=interpret,
    )(x, w0, a_eff, a_mag.astype(jnp.float32), b_dir.astype(x.dtype),
      b_eff_mag)
