"""Multi-device tests (8 host devices via subprocess — XLA locks device
count at first init, so these run in their own interpreter)."""
import os
import subprocess
import sys

import jax
import pytest

# The multi-device stack targets the jax.shard_map / jax.set_mesh /
# jax.sharding.AxisType APIs; on older jax (this container ships 0.4.x)
# those do not exist and these tests cannot run.
pytestmark = pytest.mark.skipif(
    not (hasattr(jax, "shard_map") and hasattr(jax, "set_mesh")),
    reason="multi-device stack requires jax.shard_map/jax.set_mesh "
           "(newer jax than installed)")

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(snippet: str, timeout=420):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_fed_train_step_dense_and_moe_debug_mesh():
    out = _run("""
import jax, jax.numpy as jnp
from repro.launch.mesh import make_debug_mesh, dp_size
from repro.launch.train import make_fed_train_step, TrainSettings
from repro.models.config import ArchConfig
from repro.models import model as M
from repro.core import peft, aggregation as agg

mesh = make_debug_mesh(4, 2)
for fam_kw in [dict(family="dense"), dict(family="moe", n_experts=4, top_k=2)]:
    cfg = ArchConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
                     lora_rank=4, lora_dropout=0.0, **fam_kw)
    C = dp_size(mesh)
    base = M.init_params(jax.random.PRNGKey(0), cfg)
    ad = peft.add_lora(base, cfg, jax.random.PRNGKey(1), decomposed=True)
    adapters = agg.broadcast_to_clients(ad, C)
    with jax.set_mesh(mesh):
        fn, opt_init = make_fed_train_step(cfg, mesh, TrainSettings(micro_batches=2))
        ost = opt_init(adapters)
        batch = {"tokens": jnp.ones((C, 4, 32), jnp.int32),
                 "loss_mask": jnp.ones((C, 4, 32), jnp.float32)}
        na, no, met = jax.jit(fn)(base, adapters, ost, jnp.zeros((), jnp.int32), batch)
        assert jnp.isfinite(met["ce"]), fam_kw
        # aggregation: shared components identical across clients
        leaf = jax.tree.leaves(na)[0]
        import numpy as np
        for c in range(1, C):
            np.testing.assert_allclose(np.asarray(leaf[c]), np.asarray(leaf[0]), rtol=1e-5)
    print("OK", fam_kw)
""")
    assert out.count("OK") == 2


def test_moe_ep_matches_local_math():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_debug_mesh
from repro.models.config import ArchConfig
from repro.models.layers import moe_ffn_ep, moe_ffn_local
cfg = ArchConfig(name="m", family="moe", n_layers=1, d_model=32, n_heads=2,
                 n_kv_heads=1, d_ff=64, vocab_size=64, dtype="float32",
                 n_experts=4, top_k=2, capacity_factor=8.0)
mesh = make_debug_mesh(4, 2)
k = jax.random.split(jax.random.PRNGKey(0), 4)
p = {"router": {"kernel": jax.random.normal(k[0], (32, 4)) * 0.2},
     "experts": {"gate": jax.random.normal(k[1], (4, 32, 64)) * 0.2,
                 "up": jax.random.normal(k[2], (4, 32, 64)) * 0.2,
                 "down": jax.random.normal(k[3], (4, 64, 32)) * 0.2}}
x = jax.random.normal(jax.random.PRNGKey(5), (8, 16, 32))
y_loc, _ = moe_ffn_local(p, x, cfg)
with jax.set_mesh(mesh):
    y_ep, _ = jax.jit(lambda p, x: moe_ffn_ep(p, x, cfg, mesh))(p, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_loc), rtol=2e-3, atol=2e-4)
# small-batch (decode-style) replicated path
x1 = jax.random.normal(jax.random.PRNGKey(6), (1, 3, 32))
y1_loc, _ = moe_ffn_local(p, x1, cfg)
with jax.set_mesh(mesh):
    y1_ep, _ = jax.jit(lambda p, x: moe_ffn_ep(p, x, cfg, mesh))(p, x1)
np.testing.assert_allclose(np.asarray(y1_ep), np.asarray(y1_loc), rtol=2e-3, atol=2e-4)
print("OK")
""")


def test_dryrun_tiny_mesh_smoke():
    """The dry-run machinery end-to-end on a small mesh with a reduced
    arch — exercises lower+compile+analysis without the 512-dev cost."""
    _run("""
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config, InputShape
from repro.launch import specs as SP
from repro.launch.mesh import make_debug_mesh, dp_size
from repro.launch.serve import make_decode_step
from repro.launch import analysis as AN

cfg = get_smoke_config("gemma3-1b")
mesh = make_debug_mesh(4, 2)
shape = InputShape("mini_decode", 64, 8, "decode")
with jax.set_mesh(mesh):
    abs_base = SP.abstract_params(cfg)
    base_sh = SP.param_specs(cfg, mesh, abs_base)
    args, sh = SP.decode_specs(cfg, shape, mesh)
    fn = make_decode_step(cfg, mesh)
    lw = jax.jit(fn, in_shardings=(base_sh, sh["new_token"], sh["cache"],
                                   sh["cache_index"]), out_shardings=None
                 ).lower(abs_base, args["new_token"], args["cache"],
                         args["cache_index"])
    c = lw.compile()
    mem = c.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
    colls = AN.parse_collectives(c.as_text(), (2,))
    fl = AN.analytic_step_flops(cfg, shape)
    assert fl["flops_global"] > 0
    print("OK", colls.get("total", 0) >= 0)
""")
