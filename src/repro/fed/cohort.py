"""Cross-device-scale federation: bank → cohort → round → bank.

The jitted round engines (``fed/simulate.FedSim`` and the production
shard_map step in ``launch/train.py``) are fixed-shape: one compiled
program over exactly C client slots.  Cross-device federation has
N ≫ C *registered* clients, of which each round samples a cohort.  This
module keeps the compiled round untouched and adds the three host-side
pieces around it:

  ClientBank      host-resident (numpy) state for all N registered
                  clients — adapter overlays, optimizer state, and the
                  round each client last synced.  ``gather`` stacks a
                  cohort into the engine's (C, ...) device layout;
                  ``scatter`` writes survivors back.  Nothing N-sized
                  ever touches the accelerator.
  CohortSampler   deterministic per-round cohort draw (distinct
                  indices, seeded by (seed, round) so any round is
                  reproducible in isolation).
  FaultPlan       per-round fault draw: dropouts (mid-round client
                  loss), stragglers (miss the round, deliver their
                  update d rounds late), corrupted-update adversaries
                  (inflate their round update) — all expressed through
                  the (C,) participation / update_scale / staleness
                  vectors both engines accept, so the fault layer needs
                  no engine changes and stays oracle-parity-exact.
  CohortSim       the driver: deliver matured straggler buffers, sample
                  a cohort, gather, run the faulted round, buffer new
                  stragglers, scatter participants, emit participation/
                  staleness telemetry through ``repro.obs``.

Staleness is bank state, not simulation fiction: a client's ``τ`` at
round r is ``r − last_sync``, and FedBuff-family aggregates
(``needs_staleness``) discount its contribution by ``(1+τ)^(−α)`` — a
cohort of never-before-sampled clients at round 40 aggregates very
differently from a fresh one, exactly as in buffered/async federation
(Nguyen et al.).

Comm billing follows participation: a dropped client uploads nothing; a
straggler is billed when its buffered update *arrives* (see
``CohortSim._deliver_due``), not in the round it missed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.utils import pytree as pt

# Bucket bounds for the fed/staleness_rounds histogram: staleness is a
# small integer (rounds since last sync), so the default latency-shaped
# bounds would pile everything below 1.0 — these are threaded through
# obs.observe(..., bounds=...) per the registry's first-creation-wins
# contract.
STALENESS_BOUNDS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0)


class ClientBank:
    """Host-resident state for ``n_total`` registered clients.

    Leaves are numpy arrays with a leading (N,) axis; the bank is pure
    host memory, sized by the fleet, never by the accelerator.  Cohort
    indices must be distinct (``CohortSampler`` draws without
    replacement) — scatter with duplicate indices would be
    last-write-wins.
    """

    def __init__(self, adapters, opt_state, n_total: int):
        self.n_total = int(n_total)
        if self.n_total < 1:
            raise ValueError(f"n_total must be >= 1, got {n_total}")

        def bank(leaf):
            arr = np.asarray(jax.device_get(leaf))
            return np.broadcast_to(arr, (self.n_total,) + arr.shape).copy()

        self.adapters = jax.tree.map(bank, adapters)
        self.opt_state = jax.tree.map(bank, opt_state)
        # round index of each client's last server sync; staleness at
        # round r is r - last_sync (0 for a fresh fleet at round 0)
        self.last_sync = np.zeros((self.n_total,), np.int64)

    @classmethod
    def from_sim(cls, sim, n_total: int) -> "ClientBank":
        """Bank whose every client starts at ``sim``'s initial state
        (same adapter template, same optimizer init — exactly what the
        sim's own C slots start as, so round 0 of a cohort run matches a
        full-participation run when the cohort covers the fleet)."""
        if sim._client_ranks is not None:
            raise ValueError(
                "ClientBank requires a uniform-rank fleet: per-client "
                "rank masks are bound to the sim's C slots, not to bank "
                "clients, so a mixed-rank bank would silently re-mask "
                "clients to whichever slot they land in")
        return cls(sim.adapter_template, sim.opt.init(sim.adapter_template),
                   n_total)

    # -- cohort movement ---------------------------------------------------

    def gather(self, idx):
        """Stack cohort ``idx`` into the engine's (C, ...) device trees."""
        idx = np.asarray(idx)

        def g(leaf):
            return jnp.asarray(leaf[idx])

        return jax.tree.map(g, self.adapters), jax.tree.map(g, self.opt_state)

    def scatter(self, idx, adapters, opt_state, round_idx: int,
                mask=None) -> None:
        """Write cohort slots back into the bank.  ``mask`` (C,) bool
        selects which slots actually synced this round (participants);
        unmasked slots keep their old bank state — a dropped client
        never heard from the server."""
        idx = np.asarray(idx)
        mask = (np.ones(idx.shape, bool) if mask is None
                else np.asarray(mask, bool))
        sel = idx[mask]
        if sel.size == 0:
            return
        host_ad = jax.device_get(adapters)
        host_ost = jax.device_get(opt_state)

        def put(bank_leaf, new_leaf):
            bank_leaf[sel] = np.asarray(new_leaf)[mask]

        jax.tree.map(put, self.adapters, host_ad)
        jax.tree.map(put, self.opt_state, host_ost)
        self.last_sync[sel] = int(round_idx)

    def deposit(self, client: int, adapters, opt_state,
                sync_round: int) -> None:
        """Write ONE client's (unbatched, host) state — the delayed
        straggler-delivery path."""
        def put(bank_leaf, new_leaf):
            bank_leaf[client] = np.asarray(new_leaf)

        jax.tree.map(put, self.adapters, adapters)
        jax.tree.map(put, self.opt_state, opt_state)
        self.last_sync[client] = int(sync_round)

    def staleness(self, idx, round_idx: int) -> np.ndarray:
        """Rounds since each cohort member last synced, as (C,) f32 —
        the τ vector FedBuff-family aggregates discount by."""
        return (int(round_idx)
                - self.last_sync[np.asarray(idx)]).astype(np.float32)

    # -- checkpointing -----------------------------------------------------

    def state_tree(self) -> dict:
        return {"adapters": self.adapters, "opt_state": self.opt_state,
                "last_sync": self.last_sync}

    def save(self, path: str, round_idx: int = 0) -> None:
        from repro.checkpoint.ckpt import save_checkpoint
        save_checkpoint(path, self.state_tree(), step=round_idx)

    def load(self, path: str) -> int:
        """Restore a bank saved by ``save`` (host-side: N× adapter bytes
        never touch the accelerator)."""
        from repro.checkpoint.ckpt import restore_checkpoint
        tree, round_idx = restore_checkpoint(path, self.state_tree(),
                                             to_host=True)
        self.adapters = tree["adapters"]
        self.opt_state = tree["opt_state"]
        self.last_sync = np.asarray(tree["last_sync"], np.int64)
        return round_idx


class CohortSampler:
    """Deterministic per-round cohort draw: C distinct client indices
    from N, seeded by (seed, round) so round r's cohort is reproducible
    without replaying rounds 0..r-1."""

    def __init__(self, n_total: int, cohort: int, seed: int = 0):
        if not 1 <= cohort <= n_total:
            raise ValueError(
                f"cohort size {cohort} must be in [1, n_total={n_total}]")
        self.n_total, self.cohort, self.seed = int(n_total), int(cohort), seed

    def sample(self, round_idx: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, int(round_idx)))
        return np.sort(rng.choice(self.n_total, size=self.cohort,
                                  replace=False))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Per-round fault distribution over the cohort.

    Each cohort slot independently draws one fate: dropout (probability
    ``dropout_rate`` — the client vanishes mid-round: its work is lost,
    it uploads nothing, it is not billed), straggler (``straggler_rate``
    — it misses the round but its trained update arrives
    ``straggler_delay``∈[lo,hi] rounds later), else it participates;
    participants are additionally corrupted with ``corrupt_rate``
    (their round update is inflated ×``corrupt_scale`` — the adversary
    the trimmed-mean aggregators are built for).  Draws are seeded by
    (seed, round): deterministic, replayable, engine-independent.
    """
    dropout_rate: float = 0.0
    straggler_rate: float = 0.0
    straggler_delay: tuple = (1, 3)
    # delay-draw distribution over [lo, hi]: "uniform" (the default),
    # or the heavy-tailed straggler models of arXiv 2410.22815 —
    # "lognormal" (delay ≈ lo·LogNormal(0, σ=straggler_tail)) and
    # "pareto" (delay ≈ lo·(1+Pareto(α=straggler_tail))), both clipped
    # into [lo, hi] so the host-side in-flight buffers stay bounded
    straggler_dist: str = "uniform"
    straggler_tail: float = 1.0
    corrupt_rate: float = 0.0
    corrupt_scale: float = 10.0
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.dropout_rate + self.straggler_rate <= 1.0:
            raise ValueError(
                "dropout_rate + straggler_rate must lie in [0, 1], got "
                f"{self.dropout_rate} + {self.straggler_rate}")
        lo, hi = self.straggler_delay
        if not 1 <= int(lo) <= int(hi):
            raise ValueError(
                f"straggler_delay range {self.straggler_delay} must "
                "satisfy 1 <= lo <= hi (a 0-round delay is just "
                "participation)")
        if self.straggler_dist not in ("uniform", "lognormal", "pareto"):
            raise ValueError(
                f"straggler_dist {self.straggler_dist!r} must be "
                "uniform | lognormal | pareto")
        if self.straggler_tail <= 0.0:
            raise ValueError(
                f"straggler_tail must be > 0 (σ for lognormal, α for "
                f"pareto), got {self.straggler_tail}")

    @property
    def any(self) -> bool:
        return (self.dropout_rate > 0 or self.straggler_rate > 0
                or self.corrupt_rate > 0)

    def draw(self, round_idx: int, n: int) -> dict:
        rng = np.random.default_rng((self.seed, int(round_idx), 727))
        u = rng.random(n)
        dropout = u < self.dropout_rate
        straggler = (~dropout) & (u < self.dropout_rate
                                  + self.straggler_rate)
        corrupt = ((~dropout) & (~straggler)
                   & (rng.random(n) < self.corrupt_rate))
        delays = self._draw_delays(rng, n)
        participation = (~(dropout | straggler)).astype(np.float32)
        update_scale = np.where(corrupt, self.corrupt_scale,
                                1.0).astype(np.float32)
        return {"participation": participation,
                "update_scale": update_scale, "dropout": dropout,
                "straggler": straggler, "corrupt": corrupt,
                "delays": delays}

    def _draw_delays(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Integer delays in [lo, hi].  Heavy-tailed draws scale the
        floor ``lo`` by a LogNormal/Pareto multiplier ≥ ~1 and clip at
        ``hi`` — the cap bounds the in-flight straggler buffers, so hi
        acts as a "declared dead after" horizon for the tail."""
        lo, hi = int(self.straggler_delay[0]), int(self.straggler_delay[1])
        if self.straggler_dist == "uniform":
            return rng.integers(lo, hi + 1, size=n)
        if self.straggler_dist == "lognormal":
            mult = rng.lognormal(mean=0.0, sigma=self.straggler_tail,
                                 size=n)
        else:                                  # pareto, α = straggler_tail
            mult = 1.0 + rng.pareto(self.straggler_tail, size=n)
        return np.clip(np.floor(lo * mult).astype(np.int64), lo, hi)


class CohortSim:
    """Drives a fixed-shape ``FedSim`` over a ``ClientBank`` fleet.

    Per round: matured straggler buffers deliver to the bank (billed at
    arrival), a cohort is sampled and gathered into the sim's C slots,
    the faulted round runs (``FedSim.run_cohort_round`` — the parity
    oracle of the production fault path), new stragglers' trained state
    is buffered host-side for delayed delivery, and participants scatter
    back with ``last_sync = round``.

    Checkpoint scope: the bank + round counter + comm bill + in-flight
    straggler buffers.  The buffers ride along as stacked host trees
    (one ``(P, ...)`` leaf per adapter/opt leaf, P = deliveries in
    flight) so a restart mid-delay still delivers — and bills — each
    buffered update at its original delivery round instead of silently
    converting stragglers into dropouts.  The stacked leaves are
    variable-length, so ``load`` reads them through the flat
    (template-free) checkpoint path; checkpoints written before this
    field existed restore with no pending deliveries, as before.
    """

    def __init__(self, sim, n_total: int, faults: FaultPlan | None = None,
                 seed: int = 0):
        self.sim = sim
        self.bank = ClientBank.from_sim(sim, n_total)
        self.sampler = CohortSampler(n_total, sim.hp.n_clients, seed)
        self.faults = faults if faults is not None else FaultPlan()
        self.round = 0
        self._pending: list[dict] = []   # in-flight straggler deliveries

    # -- straggler buffer --------------------------------------------------

    def _deliver_due(self) -> tuple[int, int]:
        """Deliver matured straggler buffers; returns (deposited, billed)
        — every matured upload is billed, but one that lost the race to a
        fresher sync is discarded rather than deposited."""
        due = [d for d in self._pending if d["deliver_at"] <= self.round]
        self._pending = [d for d in self._pending
                         if d["deliver_at"] > self.round]
        n, billed = 0, len(due)
        for d in due:
            # the upload happened regardless — bill the wire either way
            self.sim.comm_bytes += self.sim.client_comm_bytes()
            if self.bank.last_sync[d["client"]] > d["trained_round"]:
                # a fresher sync landed while this update was in flight;
                # the server keeps the newer state
                if obs.enabled():
                    obs.inc("fed/stale_deliveries_discarded",
                            method=self.sim.hp.method)
                continue
            self.bank.deposit(d["client"], d["adapters"], d["opt_state"],
                              d["trained_round"])
            n += 1
        if n and obs.enabled():
            obs.inc("fed/straggler_deliveries", n,
                    method=self.sim.hp.method)
        return n, billed

    def _buffer_stragglers(self, idx, fault) -> None:
        strag = np.nonzero(fault["straggler"])[0]
        if strag.size == 0 or self.sim.last_trained is None:
            return
        host_ad = jax.device_get(self.sim.last_trained["adapters"])
        host_ost = jax.device_get(self.sim.last_trained["opt_state"])
        for slot in strag:
            def take(leaf, s=int(slot)):
                return np.asarray(leaf[s])
            self._pending.append({
                "client": int(idx[slot]),
                "deliver_at": self.round + int(fault["delays"][slot]),
                "trained_round": self.round,
                "adapters": jax.tree.map(take, host_ad),
                "opt_state": jax.tree.map(take, host_ost)})

    # -- the round ---------------------------------------------------------

    def run_round(self, batches: list[dict], rng) -> dict:
        """One cohort round.  ``batches``: list (per local step) of
        stacked (C, B, S) dicts, exactly as ``FedSim.local_round``
        takes — the data pipeline feeds cohort slots, not bank ids."""
        sim, r = self.sim, self.round
        delivered, billed = self._deliver_due()
        idx = self.sampler.sample(r)
        C = sim.hp.n_clients
        ad, ost = self.bank.gather(idx)
        sim.client_adapters, sim.opt_state = ad, ost
        if sim.method.prox:
            sim._round_ref = sim.client_adapters
        stale = self.bank.staleness(idx, r)
        fault = self.faults.draw(r, C)
        use_faults = self.faults.any
        mets = sim.run_cohort_round(
            batches, rng,
            participation=fault["participation"] if use_faults else None,
            staleness=stale,
            update_scale=fault["update_scale"] if use_faults else None)
        live = (fault["participation"] > 0 if use_faults
                else np.ones((C,), bool))
        if use_faults:
            self._buffer_stragglers(idx, fault)
        self.bank.scatter(idx, sim.client_adapters, sim.opt_state, r,
                          mask=live)
        if obs.enabled():
            method = sim.hp.method
            obs.set_gauge("fed/participation_rate", float(live.mean()),
                          method=method)
            for v in stale[live]:
                obs.observe("fed/staleness_rounds", float(v),
                            bounds=STALENESS_BOUNDS, method=method)
            obs.inc("fed/dropouts", float(fault["dropout"].sum()),
                    method=method)
            obs.inc("fed/stragglers", float(fault["straggler"].sum()),
                    method=method)
            obs.inc("fed/corrupt_updates", float(fault["corrupt"].sum()),
                    method=method)
            obs.event(
                "fed_cohort", method=method, round=r,
                cohort=[int(i) for i in idx],
                participation=[int(v) for v in live],
                staleness=[float(v) for v in stale],
                dropouts=int(fault["dropout"].sum()),
                stragglers=int(fault["straggler"].sum()),
                corrupt=int(fault["corrupt"].sum()),
                delivered=delivered, pending=len(self._pending),
                comm_bytes=int(sim.comm_bytes))
        self.round = r + 1
        return {"metrics": mets, "cohort": idx, "participation": live,
                "staleness": stale, "delivered": delivered,
                "delivered_billed": billed, "pending": len(self._pending)}

    # -- checkpointing -----------------------------------------------------

    def state_tree(self) -> dict:
        return {"bank": self.bank.state_tree(),
                "round": np.asarray(self.round, np.int64),
                "comm_bytes": np.asarray(self.sim.comm_bytes, np.int64)}

    def save(self, path: str) -> None:
        from repro.checkpoint.ckpt import save_checkpoint
        tree = self.state_tree()
        if self._pending:
            # stack the in-flight deliveries on a lead P axis; P varies
            # between checkpoints, so load() reads these back through the
            # flat (template-free) path instead of state_tree()
            tree["pending"] = {
                "client": np.array([d["client"] for d in self._pending],
                                   np.int64),
                "deliver_at": np.array([d["deliver_at"]
                                        for d in self._pending], np.int64),
                "trained_round": np.array([d["trained_round"]
                                           for d in self._pending], np.int64),
                "adapters": jax.tree.map(
                    lambda *xs: np.stack(xs),
                    *[d["adapters"] for d in self._pending]),
                "opt_state": jax.tree.map(
                    lambda *xs: np.stack(xs),
                    *[d["opt_state"] for d in self._pending]),
            }
        save_checkpoint(path, tree, step=self.round)

    def load(self, path: str) -> int:
        from repro.checkpoint.ckpt import (load_checkpoint_flat,
                                           restore_checkpoint)
        tree, _ = restore_checkpoint(path, self.state_tree(), to_host=True,
                                     # pre-pending checkpoints lack these
                                     # leaves; extra ckpt leaves are also
                                     # ignored by the template restore
                                     strict=True)
        self.bank.adapters = tree["bank"]["adapters"]
        self.bank.opt_state = tree["bank"]["opt_state"]
        self.bank.last_sync = np.asarray(tree["bank"]["last_sync"], np.int64)
        self.round = int(tree["round"])
        self.sim.comm_bytes = int(tree["comm_bytes"])
        self._pending = self._load_pending(load_checkpoint_flat(path)[0])
        return self.round

    def _load_pending(self, flat: dict) -> list[dict]:
        """Rebuild the in-flight straggler list from a checkpoint's flat
        leaves (empty for checkpoints written before pending persisted).
        The bank's own trees template the structure — optimizer state is
        a namedtuple pytree, which flat paths alone can't reconstruct."""
        if "pending/client" not in flat:
            return []
        clients = np.asarray(flat["pending/client"], np.int64)
        deliver = np.asarray(flat["pending/deliver_at"], np.int64)
        trained = np.asarray(flat["pending/trained_round"], np.int64)

        def unstack(template, head):
            return pt.tree_map_with_path(
                lambda p, _leaf: np.asarray(flat[head + p]), template)

        stacked_ad = unstack(self.bank.adapters, "pending/adapters/")
        stacked_ost = unstack(self.bank.opt_state, "pending/opt_state/")
        pending = []
        for i in range(clients.shape[0]):
            def take(leaf, i=i):
                return np.asarray(leaf[i])
            pending.append({
                "client": int(clients[i]),
                "deliver_at": int(deliver[i]),
                "trained_round": int(trained[i]),
                "adapters": jax.tree.map(take, stacked_ad),
                "opt_state": jax.tree.map(take, stacked_ost)})
        return pending
