"""Reference quantization + dequant-matmul oracle (weight-only int8/int4).

Symmetric, zero-preserving layouts shared by the Pallas kernel, the XLA
serving fallback, and the compressed federated uplink:

  int8   q (..., d_in, d_out) int8 in [-127, 127]
  int4   q (..., d_in/2, d_out) uint8 — two nibbles packed along d_in,
         stored biased (v = q + 8, q in [-7, 7]) so the sign survives
         the pack; zero quantizes to the exact zero code either way.
  scale  (..., G, d_out) float32 — per output channel (G = 1, the
         default) or per group of ``group_size`` input rows
         (G = d_in / group_size).

The storage dtype IS the format tag: int8 leaves are plain int8, packed
int4 leaves are uint8 — consumers recover d_in from the activation and
the group size from the scale shape, so no side metadata travels with
the param tree.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-8          # scale floor: an all-zero channel dequantizes to zero


def _grouped(w, group_size):
    *lead, d_in, d_out = w.shape
    g = d_in if group_size is None else int(group_size)
    if d_in % g:
        raise ValueError(f"group_size {g} does not divide d_in {d_in}")
    return w.reshape(*lead, d_in // g, g, d_out)


def quantize_int8(w, *, group_size=None):
    """w (..., d_in, d_out) f32 → (q int8, scale f32 (..., G, d_out))."""
    w = jnp.asarray(w, jnp.float32)
    wg = _grouped(w, group_size)
    scale = jnp.maximum(jnp.max(jnp.abs(wg), axis=-2), _EPS) / 127.0
    q = jnp.clip(jnp.round(wg / scale[..., None, :]), -127, 127)
    return q.reshape(w.shape).astype(jnp.int8), scale


def quantize_int4(w, *, group_size=None):
    """w (..., d_in, d_out) f32, d_in even →
    (packed uint8 (..., d_in/2, d_out), scale f32 (..., G, d_out))."""
    w = jnp.asarray(w, jnp.float32)
    if w.shape[-2] % 2:
        raise ValueError(f"int4 packing needs even d_in, got {w.shape[-2]}")
    wg = _grouped(w, group_size)
    scale = jnp.maximum(jnp.max(jnp.abs(wg), axis=-2), _EPS) / 7.0
    q = jnp.clip(jnp.round(wg / scale[..., None, :]), -7, 7)
    v = (q.reshape(w.shape) + 8.0).astype(jnp.uint8)       # biased nibbles
    return v[..., 0::2, :] | (v[..., 1::2, :] << 4), scale


def unpack_int4(packed):
    """(..., d_in/2, d_out) uint8 → (..., d_in, d_out) int8 in [-7, 7]."""
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    *lead, p, d_out = packed.shape
    return jnp.stack([lo, hi], axis=-2).reshape(*lead, 2 * p, d_out)


def dequantize(q, scale):
    """Recover the f32 weight from an int8 or packed-int4 leaf."""
    if q.dtype == jnp.uint8:
        q = unpack_int4(q)
    *lead, d_in, d_out = q.shape
    G = scale.shape[-2]
    wg = q.astype(jnp.float32).reshape(*lead, G, d_in // G, d_out)
    return (wg * scale[..., None, :]).reshape(*lead, d_in, d_out)


def quant_matmul_ref(x, q, scale):
    """x (..., d_in) @ dequant(q, scale) → (..., d_out): the oracle the
    Pallas kernel must match, and the XLA fallback off-TPU.  XLA fuses
    the dequant into the dot's operand read, so even the fallback never
    keeps a second f32 copy of the weights live across calls."""
    w = dequantize(q, scale).astype(x.dtype)
    return jnp.einsum("...k,kn->...n", x, w)
