#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): the full test suite with src on PYTHONPATH.
#
#   scripts/ci.sh              # full suite (includes the serving tests)
#   scripts/ci.sh --serve      # fast path: multi-tenant serving subsystem
#                              # only (BGMV kernel, AdapterStore, engine)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--serve" ]]; then
  shift
  exec python -m pytest -x -q tests/test_batched_lora.py \
    tests/test_adapter_store.py tests/test_serve_engine.py "$@"
fi
exec python -m pytest -x -q "$@"
