.PHONY: test test-serve test-het test-dist test-quant test-obs test-scale test-tier test-lint test-fast lint-fed perf serve-bench bench-smoke

# tier-1 verify (ROADMAP.md)
test:
	bash scripts/ci.sh

# multi-tenant serving subsystem only (BGMV kernel, store, engine)
test-serve:
	bash scripts/ci.sh --serve

# heterogeneous-rank subsystem (aggregation properties, mixed-rank
# round/serving parity, het checkpoints)
test-het:
	bash scripts/ci.sh --het

# distributed subsystem (shard_map collective round vs FedSim parity on
# 8 virtual host devices)
test-dist:
	bash scripts/ci.sh --dist

# quantized hot paths (int8/int4 codecs + dequant-fused matmul +
# quantized serving, compressed-uplink aggregation + billing)
test-quant:
	bash scripts/ci.sh --quant

# telemetry layer (registry/events/tracing, disabled-sink invariance,
# report round-trip, checkpoint migration shim)
test-obs:
	bash scripts/ci.sh --obs

# cross-device-scale federation (client bank, cohort sampling, fault
# injection + straggler billing, faulted/async engine-vs-oracle parity)
test-scale:
	bash scripts/ci.sh --scale

# tiered adapter pool (T2→T1→T0 promotion parity, queue-informed
# eviction, async prefetch determinism, tier checkpoints + base pool)
test-tier:
	bash scripts/ci.sh --tier

# static-analysis lane (repro.lint R1–R5 over src/repro + its tests)
test-lint:
	bash scripts/ci.sh --lint

# just the analyzer, no test suite — the quick pre-commit check
lint-fed:
	PYTHONPATH=src python -m repro.lint src/repro

# tier-1 minus the slow sweeps and the multi-device dist tests
test-fast:
	bash scripts/ci.sh --fast

# fed-round + per-arch microbenchmarks
perf:
	PYTHONPATH=src python -m benchmarks.perf_micro

# mixed-tenant batch vs naive merge-per-tenant serving loop
serve-bench:
	PYTHONPATH=src python -m benchmarks.serve_multitenant

# the CI benchmark smoke job, locally: micro entries + regression check
# against the checked-in trajectory (benchmarks/baselines/); the obs
# entry also leaves its telemetry JSONL artifact at
# experiments/bench/obs_telemetry.jsonl
bench-smoke:
	PYTHONPATH=src python -m benchmarks.run --only perf,het,cohort,dist,pipeline,quant,obs,tier --fresh
	PYTHONPATH=src python scripts/check_bench.py
