"""FedLoRA-Optimizer — the paper's pipeline (Fig. 2).

Per round:
  stage 1  every client LoRA-fine-tunes locally (D-M-decomposed adapters,
           base components trainable, pipeline deltas frozen);
  agg      decomposed FedAvg of (Ā_D, Ā_M, B̄_M, B̄_D)          (Eqs. 5–8)
  stage 2  global optimizer trains ΔA_D on the global task mix  (Eq. 9)
After the final round:
  stage 3  local optimizer trains ΔB_M per client with the
           λ/2‖ΔM‖²_F regularizer                               (Eqs. 10–12)

``pipeline=False`` reproduces the Fig.-3 "non-pipeline" ablation: the
LoRA-tuned client models go *straight* to the local optimizer — no
aggregation, no global stage (the paper: "the personalized model is
adapted directly from the initial LoRA model").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import numpy as np

from repro.core.methods import get_method
from repro.data.loader import client_batch
from repro.data.synthetic import SyntheticInstructionDataset
from repro.fed.simulate import FedSim, FedHyper
from repro.models.config import ArchConfig


@dataclasses.dataclass
class RunResult:
    global_acc: float
    local_acc: float
    per_client: list
    history: list
    comm_bytes: int


def run_federated(cfg: ArchConfig, hp: FedHyper,
                  client_datasets: Sequence[SyntheticInstructionDataset],
                  server_dataset: SyntheticInstructionDataset,
                  eval_global_batches: list[dict],
                  eval_local_stacked: list[dict],
                  log: Callable[[str], None] = lambda s: None,
                  base=None) -> RunResult:
    """Run any method (ours or baseline) through the same round loop so the
    comparisons in benchmarks/table1 are apples-to-apples."""
    sim = FedSim(cfg, hp, base=base)
    method = get_method(hp.method)
    rng = np.random.default_rng(hp.seed + 1)
    history = []
    aggregated = None
    for rnd in range(hp.rounds):
        jrng = jax.random.PRNGKey(hp.seed * 1000 + rnd)
        batches = [client_batch(client_datasets, rng, hp.batch, hp.seq_len)
                   for _ in range(hp.local_steps)]
        mets = sim.local_round(batches, jrng)
        if hp.pipeline or not method.pipeline:
            aggregated = sim.aggregate()
        else:
            # non-pipeline ablation: clients keep their own adapters
            aggregated = jax.tree.map(lambda x: x[0], sim.client_adapters)
        if hp.pipeline and method.pipeline:
            sbatches = [
                {k: jax.numpy.asarray(v) for k, v in
                 server_dataset.sample_batch(rng, hp.batch, hp.seq_len).items()}
                for _ in range(hp.global_steps)]
            # stage 1 consumes split(fold_in(jrng, step)) children, stage 2
            # the unsplit parent — split's domain separation keeps the streams
            # disjoint, and this chain is the sim↔engine parity contract
            # lint: ok[R3] stage-2 parent key is disjoint from stage-1 split children
            aggregated = sim.global_stage(aggregated, sbatches, jrng)
        ev = sim.eval_global(aggregated, eval_global_batches)
        history.append({"round": rnd, "train_ce": float(np.mean(mets["ce"])),
                        **ev})
        log(f"[{hp.method}] round {rnd}: train_ce="
            f"{history[-1]['train_ce']:.3f} global_acc={ev['acc']:.3f}")

    # final personalization (stage 3 for ours; plain local fine-tune for
    # baselines — their standard personalization recipe)
    pbatches = [client_batch(client_datasets, rng, hp.batch, hp.seq_len)
                for _ in range(hp.personal_steps)]
    sim.personalize(pbatches, jax.random.PRNGKey(hp.seed * 77 + 5))
    loc = sim.eval_personalized(eval_local_stacked)
    glob = sim.eval_global(aggregated, eval_global_batches)
    return RunResult(global_acc=glob["acc"], local_acc=loc["acc"],
                     per_client=loc["per_client"], history=history,
                     comm_bytes=sim.comm_bytes)
