from repro.core import dora, peft, aggregation, sensitivity  # noqa: F401

# NOTE: repro.core.fedlora imports repro.fed (which imports this package);
# import it directly — from repro.core.fedlora import run_federated.
