"""Structured JSONL event sink with size-based rotation.

One event per line: ``{"ts": <unix seconds>, "kind": "...", ...fields}``.
Kinds emitted by the instrumented engines (catalog in
docs/observability.md):

    fed_round          per-round summary from FedSim / FedPipeline
    fed_stage          stage-2 / stage-3 summaries
    serve_run          end-of-run serving summary
    serve_admit        request admitted to a batch row
    pool_register / pool_evict     AdapterStore slot churn
    ckpt_save / ckpt_restore       checkpoint traffic
    compile            first execution of a named jitted program
    metrics_snapshot   full MetricsRegistry dump (run epilogue)

Values must be JSON-serializable; engines convert device arrays to
plain floats/lists before emitting (no jax imports here — the sink is
pure host code and usable from any process).

Rotation: when the live file would exceed ``max_bytes`` the sink
renames ``path -> path.1`` (shifting ``path.1 -> path.2`` ... up to
``keep``) and starts fresh, so long serve runs cannot fill a disk.
``read_events`` re-joins rotated segments oldest-first.
"""
from __future__ import annotations

import json
import os
import time


class EventLog:
    def __init__(self, path: str, *, max_bytes: int = 8 * 1024 * 1024,
                 keep: int = 3):
        self.path = str(path)
        self.max_bytes = int(max_bytes)
        self.keep = int(keep)
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._fh = open(self.path, "a", encoding="utf-8")
        self._size = self._fh.tell()

    def emit(self, kind: str, **fields) -> None:
        rec = {"ts": round(time.time(), 3), "kind": kind}
        rec.update(fields)
        line = json.dumps(rec, sort_keys=False, default=_coerce) + "\n"
        if self._size + len(line) > self.max_bytes and self._size > 0:
            self._rotate()
        # no flush here: the file object's block buffering batches the
        # write syscalls (per-event flush is measurable on the serve hot
        # loop); close()/rotation/``flush()`` drain the buffer, and
        # ``emit_snapshot`` flushes as the run epilogue
        self._fh.write(line)
        self._size += len(line)

    def flush(self) -> None:
        self._fh.flush()

    def _rotate(self) -> None:
        self._fh.close()
        for i in range(self.keep - 1, 0, -1):
            src, dst = f"{self.path}.{i}", f"{self.path}.{i + 1}"
            if os.path.exists(src):
                os.replace(src, dst)
        if self.keep > 0:
            os.replace(self.path, f"{self.path}.1")
        else:
            os.remove(self.path)
        self._fh = open(self.path, "a", encoding="utf-8")
        self._size = 0

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()


class NullEventLog:
    """Disabled-telemetry sink: ``emit`` is a no-op."""

    path = None

    def emit(self, kind: str, **fields) -> None:
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


def _coerce(obj):
    """JSON fallback for numpy scalars/arrays that slip through."""
    if hasattr(obj, "tolist"):
        return obj.tolist()
    if hasattr(obj, "item"):
        return obj.item()
    raise TypeError(f"not JSON-serializable: {type(obj)!r}")


def read_events(path: str, *, kind: str | None = None) -> list[dict]:
    """All events at ``path`` (rotated segments first), oldest-first."""
    segments = []
    i = 1
    while os.path.exists(f"{path}.{i}"):
        segments.append(f"{path}.{i}")
        i += 1
    segments.reverse()  # path.N is oldest
    if os.path.exists(path):
        segments.append(path)
    out = []
    for seg in segments:
        with open(seg, encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                if kind is None or rec.get("kind") == kind:
                    out.append(rec)
    return out
