"""Fig. 3 — pipeline (global→local) vs non-pipeline (local-only) ablation.

Paper: DeepSeek-7B on Dolly; pipeline-structured (global optimizer stage
before personalization) beats feeding the LoRA-tuned model straight to
the local optimizer, on all three tasks.
"""
from __future__ import annotations

import time

from benchmarks.common import BENCH_CFG, bench_base, build_setting, PAPER_TASKS
from repro.core.fedlora import run_federated
from repro.fed.simulate import FedHyper


def run(rounds: int = 6, log=print) -> list[dict]:
    base = bench_base("ni", log=lambda s: log(f"  {s}"))
    cds, sds, eg, el = build_setting("ni")
    rows = []
    for pipeline in (True, False):
        hp = FedHyper(method="fedlora_opt", n_clients=len(cds),
                      rounds=rounds, local_steps=3, batch=8, seq_len=48,
                      lr=3e-3, server_lr=5e-4, global_steps=2,
                      personal_steps=10, lam=1e-3, pipeline=pipeline, seed=0)
        t0 = time.time()
        res = run_federated(BENCH_CFG, hp, cds, sds, eg, el, base=base)
        # per-client == per-task accuracies (client c specializes task c)
        per_task = {PAPER_TASKS[i % len(PAPER_TASKS)]: float(a)
                    for i, a in enumerate(res.per_client)}
        row = {"pipeline": pipeline, "local_acc": res.local_acc,
               "global_acc": res.global_acc, "per_task": per_task,
               "wall_s": time.time() - t0}
        rows.append(row)
        log(f"[fig3] pipeline={pipeline}: local={res.local_acc:.3f} "
            f"per-task={ {k: round(v,3) for k,v in per_task.items()} }")
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        tag = "post-serial" if r["pipeline"] else "pre-serial"
        per = ";".join(f"{k}={v:.4f}" for k, v in r["per_task"].items())
        print(f"fig3/{tag},{r['wall_s']*1e6:.0f},local_acc={r['local_acc']:.4f};{per}")
    return rows


if __name__ == "__main__":
    main()
