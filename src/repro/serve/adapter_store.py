"""AdapterStore: slot-pooled per-tenant adapters for mixed-batch serving.

The store owns, per target projection, stacked pools with an ``L =
n_slots + 1`` slot axis the BGMV kernel gathers over (slot ``n_slots``
is the permanent all-zero null adapter — rows without a tenant adapter
point there).  Targets under the model's scanned ``blocks`` keep their
leading superblock axis *ahead of* the slot axis — ``(n_sb, L, ...)`` —
so ``lax.scan`` slices off ``n_sb`` and every layer sees a clean
``(L, ...)`` pool.  Two pool layouts:

  kind="pairs"     pool_A (L, d_in, r) + pool_B (L, r, d_out): one
                   effective LoRA pair per tenant.  Raw-LoRA adapters
                   pack as-is; decomposed-DoRA adapters collapse to
                   their effective pair (A_mag·(A_dir+dA_dir),
                   (B_mag+dB_mag)·B_dir).

  kind="dora_mag"  the paper's deployment shape: every tenant shares the
                   direction/magnitude factors (A_dir+dA_dir, A_mag,
                   B_dir, B_mag) and differs only in its RAW per-rank
                   magnitude delta ΔB_M — pool_dB_mag (L, r); the
                   effective magnitude B_mag+ΔB_M is formed inside the
                   BGMV kernel.  Bytes per tenant = 4·r per target (a
                   few hundred bytes total), so one host holds millions
                   of personalized variants.

Heterogeneous tenants: one pool serves adapters of mixed ranks.  The
store's ``rank`` is the pool allocation — pass the fleet's server rank
to serve a server-rank fleet (it may exceed cfg.lora_rank; for
kind='dora_mag' it defaults to the shared tree's own rank).  A tenant
may register any rank ≤ the pool rank — its leaves are zero-padded into
the slot and its true rank is recorded in the slot-rank table (saved
with the tenant table, exposed as a ``pool_ranks`` leaf for BOTH kinds
so the BGMV kernel masks each row at its slot's own rank).  Storing the
dora_mag delta RAW is what makes that mask correct for magnitudes too:
a rank-r tenant's federated model is the first r rank rows of the
server model plus its ΔB_M (FedSim's rebroadcast re-mask), so serving
must mask the shared rows above r as well — and the null/evicted slot
(rank 0) masks everything, serving the bare backbone.

Register/evict is LRU over slots; ``save``/``load`` round-trip the pools
plus the tenant table through ``checkpoint/ckpt.py`` (tenant ids are
encoded as fixed-width uint8 rows so every checkpoint leaf stays a plain
numeric array).

``TieredAdapterStore`` grows the same pool into a three-tier cache for
fleets far larger than the device pool (the ROADMAP's million-tenant
north star — at 4·r bytes of ΔB_M per tenant, host RAM holds millions):

    T0  the fixed-shape device pool above (n_slots hot tenants)
    T1  host-RAM cache: packed numpy leaves keyed by tenant id,
        capacity-bounded with its own LRU eviction (spill → T2)
    T2  per-tenant checkpoint shards on disk (``checkpoint.save_shard``)

Promotion on a T0 miss is T2→T1→T0; ``install_batch`` installs every
adapter the next batcher chunk needs in ONE donated device scatter per
pool leaf between decode chunks (pools stay fixed-shape — nothing
recompiles), and an async prefetcher (background thread + double-
buffered host staging) pulls queued tenants' shards toward T1 while the
decode scan runs, so by install time the promotion is a host-memory hit
instead of a blocking disk read.  Victim choice is queue-informed:
active-row tenants are hard-pinned, tenants sitting in the batcher
queue are only evicted when no unqueued victim exists, and LRU recency
breaks the remaining ties.
"""
from __future__ import annotations

import os
import queue
import threading
import time
import warnings
from collections import OrderedDict
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.ckpt import (checkpoint_leaf_paths,
                                   list_shards, load_checkpoint_flat,
                                   load_shard_flat, restore_checkpoint,
                                   save_checkpoint, save_shard)
from repro.core.peft import _target_kernels
from repro.models.config import ArchConfig
from repro.utils import pytree as pt

Params = Any

_ID_BYTES = 64

_DECOMPOSED = ("A_dir", "A_mag", "B_dir", "B_mag")

# pool leaves carrying a slot axis (cleared on evict); the bgmv_* leaves
# are shared across tenants and never change per slot
_SLOT_KEYS = ("pool_A", "pool_B", "pool_dB_mag")


def _encode_id(tenant: str) -> np.ndarray:
    raw = tenant.encode("utf-8")
    if not raw or len(raw) > _ID_BYTES:
        raise ValueError(f"tenant id must be 1..{_ID_BYTES} utf-8 bytes, "
                         f"got {tenant!r}")
    return np.frombuffer(raw.ljust(_ID_BYTES, b"\0"), np.uint8).copy()


def _decode_id(row: np.ndarray) -> str:
    return bytes(np.asarray(row, np.uint8)).rstrip(b"\0").decode("utf-8")


_get = pt.tree_get


class AdapterStore:
    """Pools per-tenant adapters behind integer slots for BGMV serving."""

    def __init__(self, base: Params, cfg: ArchConfig, *, n_slots: int = 8,
                 kind: str = "pairs", rank: int = 0,
                 shared: Optional[Params] = None):
        if kind not in ("pairs", "dora_mag"):
            raise ValueError(f"unknown AdapterStore kind {kind!r}")
        if kind == "dora_mag" and shared is None:
            raise ValueError("kind='dora_mag' needs the shared decomposed "
                             "adapter tree (direction factors)")
        self.cfg = cfg
        self.kind = kind
        if not rank and kind == "dora_mag":
            # the pool allocation follows the shared model's own rank —
            # a fleet trained at server_rank > cfg.lora_rank serves
            # without truncation
            rank = int(jax.tree.leaves(pt.filter_tree(
                shared, lambda p: p.endswith("A_dir")))[0].shape[-1])
        self.rank = rank or cfg.lora_rank
        self.n_slots = n_slots
        self.null_slot = n_slots                      # all-zero identity slot
        # target prefix (".../q_proj") → (lead_dims, d_in, d_out); lead is
        # () for tail/unstacked params, (n_sb,) under the scanned blocks
        self.targets: dict[str, tuple[tuple, int, int]] = {}
        for path, kern in _target_kernels(base, cfg.lora_targets):
            *lead, d_in, d_out = kern.shape
            if len(lead) > 1:
                raise ValueError(f"unsupported kernel layout at {path}: "
                                 f"{kern.shape}")
            self.targets[path.rsplit("/", 1)[0]] = (tuple(lead), d_in, d_out)
        if not self.targets:
            raise ValueError(f"no lora_targets {cfg.lora_targets} in base")

        L, r = n_slots + 1, self.rank
        self._pools: dict[str, dict[str, jnp.ndarray]] = {}
        for prefix, (lead, d_in, d_out) in self.targets.items():
            if kind == "pairs":
                self._pools[prefix] = {
                    "pool_A": jnp.zeros((*lead, L, d_in, r), jnp.float32),
                    "pool_B": jnp.zeros((*lead, L, r, d_out), jnp.float32),
                }
            else:
                sh = {k: _get(shared, f"{prefix}/{k}") for k in _DECOMPOSED}
                if any(v is None for v in sh.values()):
                    raise ValueError(f"shared tree missing decomposed leaves "
                                     f"under {prefix}")
                if sh["A_dir"].shape != (*lead, d_in, r):
                    raise ValueError(
                        f"shared rank mismatch at {prefix}: "
                        f"{sh['A_dir'].shape} vs {(*lead, d_in, r)}")
                da = _get(shared, f"{prefix}/dA_dir")
                a_dir = sh["A_dir"] + (da if da is not None else 0.0)
                self._pools[prefix] = {
                    "bgmv_A_dir": jnp.asarray(a_dir, jnp.float32),
                    "bgmv_A_mag": jnp.asarray(sh["A_mag"], jnp.float32),
                    "bgmv_B_dir": jnp.asarray(sh["B_dir"], jnp.float32),
                    "bgmv_B_mag": jnp.asarray(sh["B_mag"], jnp.float32),
                    # RAW ΔB_M per slot — the kernel adds the shared
                    # B_mag and rank-masks the product, so slots above a
                    # tenant's rank (and the null slot) contribute zero
                    "pool_dB_mag": jnp.zeros((*lead, L, r), jnp.float32),
                }

        self._slot_of: dict[str, int] = {}            # tenant → slot
        self._tenant_of: dict[int, str] = {}          # slot → tenant
        self._last_used = np.zeros((n_slots,), np.int64)
        self._counter = 0
        # per-slot adapter ranks (null slot stays 0: an all-zero rank-0
        # identity); tenants below r_max are zero-padded into their slot
        self._slot_ranks = np.zeros((n_slots + 1,), np.int32)
        # bumped on every pool/rank-table mutation — ServeEngine keys its
        # merged-params cache on this so unchanged pools skip the merge
        self.version = 0

    # ------------------------------------------------------------------
    # slot management
    # ------------------------------------------------------------------

    def __contains__(self, tenant: str) -> bool:
        return tenant in self._slot_of

    @property
    def tenants(self) -> list[str]:
        return sorted(self._slot_of)

    def slot_of(self, tenant: str) -> int:
        """Slot for a registered tenant; bumps LRU recency."""
        slot = self._slot_of[tenant]
        self._touch(slot)
        obs.inc("pool/lookups", kind=self.kind)
        return slot

    def rank_of(self, tenant: str) -> int:
        """The tenant's own adapter rank (≤ the pool's r_max)."""
        return int(self._slot_ranks[self._slot_of[tenant]])

    def _touch(self, slot: int) -> None:
        self._counter += 1
        self._last_used[slot] = self._counter

    def _alloc(self, tenant: str) -> int:
        if tenant in self._slot_of:
            return self._slot_of[tenant]
        for slot in range(self.n_slots):
            if slot not in self._tenant_of:
                return slot
        lru = min(self._tenant_of, key=lambda s: self._last_used[s])
        self.evict(self._tenant_of[lru])
        return lru

    def _set_slot(self, prefix: str, key: str, slot: int, val):
        pool = self._pools[prefix]
        lead, _, _ = self.targets[prefix]
        idx = (slice(None), slot) if lead else (slot,)
        pool[key] = pool[key].at[idx].set(val)
        self.version += 1

    def evict(self, tenant: str) -> None:
        slot = self._slot_of.pop(tenant)
        del self._tenant_of[slot]
        self._last_used[slot] = 0
        self._slot_ranks[slot] = 0
        for prefix, pool in self._pools.items():
            for key in _SLOT_KEYS:
                if key in pool:
                    self._set_slot(prefix, key, slot, 0.0)
        if obs.enabled():
            obs.inc("pool/evictions", kind=self.kind)
            obs.set_gauge("pool/occupancy",
                          len(self._tenant_of) / self.n_slots, kind=self.kind)
            obs.event("pool_evict", tenant=tenant, slot=slot, pool=self.kind)

    # ------------------------------------------------------------------
    # register
    # ------------------------------------------------------------------

    def register(self, tenant: str, adapter: Params, rank: int = 0) -> int:
        """Pack one tenant's adapter tree into a pool slot (LRU evict when
        full).  Accepts raw-LoRA {lora_A, lora_B} or decomposed-DoRA
        leaves for kind='pairs'; a dB_mag overlay (or full decomposed
        tree) for kind='dora_mag'.  The tenant's rank may be anything
        ≤ the pool's r_max — lower ranks are zero-padded into the slot
        and recorded in the slot-rank table.  ``rank``: the tenant's TRUE
        rank when it differs from the leaves' allocation — a server-rank
        fleet pads every client's adapters to the server rank (rows above
        the client's own rank are zero), so the shape alone over-states
        the rank and the BGMV mask would not truncate.  Raises ValueError
        on rank/target mismatch."""
        packed, r_t = self._pack_adapter(tenant, adapter, rank)
        slot = self._alloc(tenant)
        for prefix, leaves in packed.items():
            for key, val in leaves.items():
                self._set_slot(prefix, key, slot, val)
        self._slot_of[tenant] = slot
        self._tenant_of[slot] = tenant
        self._slot_ranks[slot] = r_t
        self._touch(slot)
        if obs.enabled():
            obs.inc("pool/registers", kind=self.kind)
            obs.set_gauge("pool/occupancy",
                          len(self._tenant_of) / self.n_slots, kind=self.kind)
            obs.event("pool_register", tenant=tenant, slot=slot,
                      rank=int(self._slot_ranks[slot]), pool=self.kind)
        return slot

    # ------------------------------------------------------------------
    # batch install / prefetch — the tier-aware surface ServeEngine uses
    # ------------------------------------------------------------------

    def install_batch(self, tenants, *, pinned=(), queued=()) -> dict[str, int]:
        """Make every tenant resident in the device pool and return
        ``{tenant: slot}``.  The flat store has exactly one tier, so this
        is a recency-bumping lookup (a never-registered tenant raises
        KeyError); ``pinned``/``queued`` are victim-selection hints for
        the tiered override and are ignored here."""
        return {t: self.slot_of(t) for t in tenants}

    def prefetch(self, tenants) -> None:
        """Hint that ``tenants`` will be needed by an upcoming chunk.
        No-op for the flat store; ``TieredAdapterStore`` hands them to
        its background shard loader."""

    def drain_prefetch(self) -> None:
        """Fold completed prefetches into the host cache (tier store);
        no-op here."""

    def _pack_adapter(self, tenant: str, adapter: Params,
                      rank: int = 0) -> tuple[dict, int]:
        """Validate + pack one tenant's adapter into HOST numpy leaves,
        keyed ``{target_prefix: {pool_key: array}}``; returns (packed,
        true_rank).  Pure host work — no device dispatch — so bulk
        registration (the tiered store's 10k-tenant fleets) never blocks
        on the accelerator."""
        _encode_id(tenant)                            # validate early
        packed, t_ranks = {}, set()
        for p in self.targets:
            packed[p], r_t = self._pack_one(p, adapter)
            t_ranks.add(r_t)
        if len(t_ranks) != 1:
            raise ValueError(f"adapter rank mismatch across targets: "
                             f"{sorted(t_ranks)}")
        if rank:
            if not 1 <= rank <= min(t_ranks):
                raise ValueError(
                    f"explicit rank {rank} mismatch: outside [1, "
                    f"{min(t_ranks)}] (the adapter leaves' own rank)")
            t_ranks = {rank}
        extra = [p for p in pt.tree_paths(adapter)
                 if not any(p.startswith(t + "/") for t in self.targets)]
        if extra:
            raise ValueError(f"adapter has leaves outside the store's "
                             f"targets: {extra[:3]}")
        return packed, t_ranks.pop()

    def _pad_rank(self, x: np.ndarray, axis: int) -> np.ndarray:
        """Zero-pad a rank-``r_t`` leaf up to the pool's r_max along
        ``axis`` (negative).  Raises (with 'mismatch' in the message) when
        the leaf exceeds the pool allocation."""
        r_t = x.shape[axis]
        if not 1 <= r_t <= self.rank:
            raise ValueError(f"rank mismatch: adapter rank {r_t} outside "
                             f"[1, r_max={self.rank}]")
        if r_t == self.rank:
            return x
        pad = [(0, 0)] * x.ndim
        pad[x.ndim + axis] = (0, self.rank - r_t)
        return np.pad(x, pad)

    def _pack_one(self, prefix: str, adapter: Params) -> tuple[dict, int]:
        """Pack one target's leaves for a slot; returns (leaves, rank)."""
        lead, d_in, d_out = self.targets[prefix]
        r = self.rank
        sub = _get(adapter, prefix)
        if sub is None:
            raise ValueError(f"adapter missing target {prefix} "
                             f"(store targets: {list(self.targets)})")
        if self.kind == "dora_mag":
            db = sub.get("dB_mag")
            if db is None:
                raise ValueError(f"{prefix}: kind='dora_mag' needs a dB_mag "
                                 f"leaf per target")
            r_t = db.shape[-1]
            if db.shape != (*lead, r_t) or r_t > r:
                raise ValueError(f"{prefix}: dB_mag rank mismatch "
                                 f"{db.shape} vs {(*lead, f'<={r}')}")
            # stored RAW: the kernel forms B_mag + ΔB_M itself and its
            # rank mask covers the magnitude rows too — padded rows,
            # stale rows, and the null slot all contribute exactly zero
            return {"pool_dB_mag": self._pad_rank(
                np.asarray(db, np.float32), -1)}, r_t
        if "lora_A" in sub:
            A = np.asarray(sub["lora_A"], np.float32)
            B = np.asarray(sub["lora_B"], np.float32)
        elif "A_dir" in sub:
            da = sub.get("dA_dir")
            db = sub.get("dB_mag")
            a_dir = np.asarray(sub["A_dir"], np.float32)
            if da is not None:
                a_dir = a_dir + np.asarray(da, np.float32)
            b_mag = np.asarray(sub["B_mag"], np.float32)
            if db is not None:
                b_mag = b_mag + np.asarray(db, np.float32)
            A = np.asarray(sub["A_mag"], np.float32)[..., None] * a_dir
            B = b_mag[..., None] * np.asarray(sub["B_dir"], np.float32)
        else:
            raise ValueError(f"{prefix}: no lora_A/A_dir leaves in adapter")
        r_t = A.shape[-1]
        if (r_t > r or A.shape != (*lead, d_in, r_t)
                or B.shape != (*lead, r_t, d_out)):
            raise ValueError(f"{prefix}: shape mismatch A{A.shape} B{B.shape} "
                             f"vs {(*lead, d_in, f'<={r}')} / "
                             f"{(*lead, f'<={r}', d_out)}")
        A = self._pad_rank(np.asarray(A, np.float32), -1)
        B = self._pad_rank(np.asarray(B, np.float32), -2)
        return {"pool_A": A, "pool_B": B}, r_t

    # ------------------------------------------------------------------
    # serving views
    # ------------------------------------------------------------------

    def overlay(self) -> Params:
        """Pooled overlay pytree to merge into the backbone params —
        ``layers.linear`` consults these leaves when adapter_idx is set.
        Both kinds carry the per-slot rank table as a ``pool_ranks`` leaf
        (broadcast over any scanned-block lead axis) so the BGMV kernel
        masks each row at its slot's own rank — for kind='dora_mag' the
        mask covers the magnitude rows (shared B_mag + raw ΔB_M), which
        is what serves a rank-r tenant its own rank-r slice of the shared
        model and the null slot (rank 0) the bare backbone."""
        slot_ranks = jnp.asarray(self._slot_ranks)
        out: dict = {}
        for prefix, pool in self._pools.items():
            keys = prefix.split("/")
            cur = out
            for k in keys:
                cur = cur.setdefault(k, {})
            cur.update(pool)
            lead, _, _ = self.targets[prefix]
            cur["pool_ranks"] = jnp.broadcast_to(
                slot_ranks, (*lead, self.n_slots + 1))
        return out

    def bytes_per_tenant(self, tenant: str | None = None) -> int:
        """Marginal pool bytes one registered tenant occupies (at the
        tenant's own rank when given; at the pool's r_max otherwise —
        padding rows are zero and compress away at rest, but they do
        occupy pool memory)."""
        r = self.rank if tenant is None else self.rank_of(tenant)
        total = 0
        for prefix, (lead, d_in, d_out) in self.targets.items():
            n = int(np.prod(lead)) if lead else 1
            if self.kind == "dora_mag":
                total += 4 * r * n
            else:
                total += 4 * r * (d_in + d_out) * n
        return total

    # ------------------------------------------------------------------
    # checkpointing
    # ------------------------------------------------------------------

    def _meta_arrays(self) -> dict:
        ids = np.zeros((self.n_slots, _ID_BYTES), np.uint8)
        for slot, tenant in self._tenant_of.items():
            ids[slot] = _encode_id(tenant)
        return {"tenant_ids": ids,
                "last_used": self._last_used.copy(),
                "counter": np.asarray(self._counter, np.int64),
                "slot_ranks": self._slot_ranks.copy()}

    def state_tree(self) -> dict:
        return {"pools": {p.replace("/", "."): dict(v)
                          for p, v in self._pools.items()},
                "meta": self._meta_arrays()}

    def save(self, path: str, step: int = 0) -> None:
        save_checkpoint(path, self.state_tree(), step=step)

    def load(self, path: str) -> int:
        """Restore pools + tenant table saved by ``save`` into this store
        (must be constructed with the same base/cfg/n_slots/kind and the
        same pool rank).  Checkpoints written before the slot-rank table
        existed restore every occupied slot at the pool's full rank
        (their pools were never padded).  kind='dora_mag' checkpoints
        from the pre-raw-delta layout (a ``pool_B_mag`` pool of MERGED
        magnitudes ``B_mag + ΔB_M`` per slot) are migrated best-effort:
        the shared magnitude is subtracted back out per occupied slot
        (see ``_load_legacy_b_mag``); the conversion is rejected with a
        ValueError when it is genuinely non-invertible — the checkpoint's
        shared ``B_mag`` differs from this store's, or the pool shapes
        don't match this allocation."""
        if self.kind == "dora_mag":
            try:
                old_paths = checkpoint_leaf_paths(path)
            except Exception:
                old_paths = []
            if any(p.endswith("/pool_B_mag") for p in old_paths):
                return self._load_legacy_b_mag(path)
        like = self.state_tree()
        like["meta"]["slot_ranks"] = np.full((self.n_slots + 1,), self.rank,
                                             np.int32)
        tree, step = restore_checkpoint(path, like,
                                        allow_missing=r"^meta/slot_ranks$")
        for p in self._pools:
            self._pools[p] = {k: jnp.asarray(v) for k, v in
                              tree["pools"][p.replace("/", ".")].items()}
        self._restore_meta(tree["meta"])
        self.version += 1
        return step

    def _restore_meta(self, meta: dict) -> None:
        ids = np.asarray(meta["tenant_ids"], np.uint8)
        self._last_used = np.asarray(meta["last_used"], np.int64).copy()
        self._counter = int(meta["counter"])
        self._slot_ranks = np.asarray(meta["slot_ranks"], np.int32).copy()
        self._slot_of, self._tenant_of = {}, {}
        for slot in range(self.n_slots):
            tenant = _decode_id(ids[slot])
            if tenant:
                self._slot_of[tenant] = slot
                self._tenant_of[slot] = tenant
        for slot in range(self.n_slots + 1):          # empty/null slots: rank 0
            if slot not in self._tenant_of:
                self._slot_ranks[slot] = 0

    def _load_legacy_b_mag(self, path: str) -> int:
        """Migration shim: restore a pre-raw-delta kind='dora_mag'
        checkpoint whose per-slot pool held MERGED magnitudes
        (``pool_B_mag[slot] = B_mag + ΔB_M``, zero-padded above the
        tenant's rank) instead of today's raw ``pool_dB_mag``.

        Best-effort inversion: ``ΔB_M = pool_B_mag[slot] − B_mag`` for
        every occupied slot (empty and null slots reset to zero).  That
        subtraction is only valid against the shared magnitude the
        checkpoint was WRITTEN with — when the checkpoint carries its
        ``bgmv_B_mag`` leaf and it disagrees with this store's shared
        tree, or the pool shapes don't match this allocation, the merge
        is genuinely non-invertible here and a ValueError is raised
        (re-register the tenants instead)."""
        warnings.warn(
            f"{path}: legacy pre-raw-delta AdapterStore checkpoint "
            "(merged pool_B_mag layout) — converting to raw pool_dB_mag "
            "by subtracting the shared B_mag per occupied slot",
            stacklevel=3)
        like = self.state_tree()
        like["meta"]["slot_ranks"] = np.full((self.n_slots + 1,), self.rank,
                                             np.int32)
        for p, pool in self._pools.items():
            legacy = {k: v for k, v in pool.items() if k != "pool_dB_mag"}
            legacy["pool_B_mag"] = jnp.zeros_like(pool["pool_dB_mag"])
            like["pools"][p.replace("/", ".")] = legacy
        try:
            # old checkpoints may predate the shared bgmv_* leaves — the
            # caller's own shared tree is then the only candidate
            tree, step = restore_checkpoint(
                path, like,
                allow_missing=r"^meta/slot_ranks$|/bgmv_")
        except AssertionError as e:
            raise ValueError(
                f"legacy pool_B_mag checkpoint {path} is not convertible "
                f"into this store: pool shape mismatch {e.args[0]!r} — the "
                "merge is non-invertible here; re-register the tenants"
            ) from e
        self._restore_meta(tree["meta"])
        occupied = np.zeros((self.n_slots + 1,), bool)
        for slot in self._tenant_of:
            occupied[slot] = True
        for p, pool in self._pools.items():
            ck = tree["pools"][p.replace("/", ".")]
            b_mag = np.asarray(pool["bgmv_B_mag"])     # (lead, r) shared
            ck_b_mag = np.asarray(ck["bgmv_B_mag"])
            if not np.allclose(ck_b_mag, b_mag, rtol=1e-6, atol=1e-7):
                raise ValueError(
                    f"legacy pool_B_mag checkpoint {path} was written "
                    f"against a different shared B_mag at {p!r} — the merge "
                    "is non-invertible with this store's shared tree; "
                    "re-register the tenants")
            merged = np.asarray(ck["pool_B_mag"])       # (lead, L, r)
            db = merged - ck_b_mag[..., None, :]
            # empty/null slots and rank rows above each slot's own rank
            # carry no delta (the old layout zero-padded them)
            occ = occupied.reshape((-1, 1))
            rows = np.arange(self.rank) < self._slot_ranks[:, None]
            db = db * (occ & rows)
            self._pools[p] = {k: jnp.asarray(v) for k, v in ck.items()
                              if k != "pool_B_mag"}
            self._pools[p]["pool_dB_mag"] = jnp.asarray(db, jnp.float32)
        self.version += 1
        if obs.enabled():
            obs.event("ckpt_migrate", path=str(path),
                      layout="pool_B_mag->pool_dB_mag",
                      tenants=len(self._tenant_of))
        return step


# ---------------------------------------------------------------------------
# tiered store: device pool (T0) + host-RAM cache (T1) + disk shards (T2)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("lead",), donate_argnums=(0,))
def _scatter_rows(pool, idx, vals, lead: bool):
    """Batched multi-slot install: scatter ``k`` packed slot rows into a
    pool leaf in one donated device put (the pool buffer is reused in
    place — no reallocation, and pool shapes are static so nothing
    recompiles; compiled variants are bounded by distinct (leaf shape,
    k)).  ``vals`` stacks the rows on the slot axis — axis 1 under a
    scanned-block lead axis, axis 0 otherwise."""
    if lead:
        return pool.at[:, idx].set(vals)
    return pool.at[idx].set(vals)


class _Prefetcher:
    """Background T2→staging loader for the tiered store.

    One daemon thread drains a work queue of tenant ids, loads each
    tenant's shard into packed host leaves, and deposits the result in
    the BACK staging buffer.  ``drain`` — always called from the serving
    thread, between decode chunks — flips back→front under the lock (an
    O(1) pointer swap) and returns the front buffer for the store to
    fold into T1 lock-free.  Only the staging buffers are shared; the
    thread never touches T0/T1 state, so the store needs no locking.

    Each work item carries the tenant's registration generation at
    submit time; the store discards a completed load whose generation is
    stale (the tenant re-registered while the shard read was in flight),
    so a prefetch can never resurrect an outdated adapter."""

    def __init__(self, load_fn):
        self._load = load_fn                  # tenant → (packed, rank)
        self._lock = threading.Lock()
        self._work: queue.Queue = queue.Queue()
        self._inflight: set[str] = set()
        self._back: dict[str, tuple] = {}     # tenant → (packed, rank, gen)
        self._thread: Optional[threading.Thread] = None

    def submit(self, tenant: str, gen: int) -> None:
        with self._lock:
            if tenant in self._inflight or tenant in self._back:
                return
            self._inflight.add(tenant)
        self._work.put((tenant, gen))
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._run, name="adapter-prefetch", daemon=True)
            self._thread.start()

    def _run(self) -> None:
        while True:
            tenant, gen = self._work.get()
            try:
                packed, rank = self._load(tenant)
            except Exception:
                # missing/corrupt shard: drop the prefetch — the install
                # path's synchronous load raises the real error clearly
                packed, rank = None, 0
            with self._lock:
                self._inflight.discard(tenant)
                if packed is not None:
                    self._back[tenant] = (packed, rank, gen)

    def drain(self) -> dict[str, tuple]:
        """Flip the double buffer; returns completed loads."""
        with self._lock:
            front, self._back = self._back, {}
        return front

    def wait(self, timeout: float = 5.0) -> bool:
        """Block until no load is in flight (True) or timeout (False)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if not self._inflight:
                    return True
            time.sleep(0.001)
        return False


class TieredAdapterStore(AdapterStore):
    """Three-tier adapter store: device pool (T0) ⊇ host cache (T1) →
    per-tenant disk shards (T2).

    T1 is an INCLUSIVE host-RAM cache of packed numpy leaves keyed by
    tenant id: promotion into T0 keeps the T1 copy, so demotion out of
    T0 is pure bookkeeping (no device read-back, no row zeroing — the
    victim row is overwritten by the incoming scatter) and every
    registered tenant always lives in T1 or a T2 shard.  T1 is
    capacity-bounded with its own LRU; evicting a DIRTY entry (packed
    since its last shard write) spills it to ``shard_dir`` first, so no
    adapter is ever lost.

    ``register`` packs into T1 only — bulk fleet registration never
    touches the device.  Residency comes from ``install_batch`` (or
    ``slot_of``, which promotes on demand): every missing tenant is
    promoted T2→T1→T0 with ONE donated device scatter per pool leaf.
    Victim selection is queue-informed: ``pinned`` tenants (active batch
    rows) are never evicted — a pool with every slot pinned raises
    RuntimeError rather than corrupt an active row — and ``queued``
    tenants (sitting in the batcher queue) are evicted only when no
    unqueued victim exists; LRU recency orders the rest.  Sizing rule:
    give the pool at least as many slots as the engine has batch rows
    (``n_slots >= max_rows``) — an admitted batch can need one slot per
    row, all pinned at once.

    ``prefetch``/``drain_prefetch`` bound the async prefetcher: submit
    upcoming tenants before launching a decode chunk, drain after it
    returns — completed shard loads fold into T1 so the next
    ``install_batch`` hits host memory instead of disk.  Determinism
    contract: a promoted adapter's bytes are identical whether they
    arrived via the prefetcher or a synchronous T2 load, so served
    tokens never depend on thread timing.

    ``save`` flushes dirty T1 entries to their shards, then writes the
    base (T0) state plus a tier directory table; ``load`` accepts both
    tiered checkpoints and legacy flat-store checkpoints (the directory
    then starts as the resident set), and adopts any shards already in
    ``shard_dir``."""

    def __init__(self, base: Params, cfg: ArchConfig, *, shard_dir: str,
                 host_capacity: int = 1024, n_slots: int = 8,
                 kind: str = "pairs", rank: int = 0,
                 shared: Optional[Params] = None):
        super().__init__(base, cfg, n_slots=n_slots, kind=kind, rank=rank,
                         shared=shared)
        if not shard_dir:
            raise ValueError("TieredAdapterStore needs a shard_dir (the T2 "
                             "spill/restore target)")
        if host_capacity < 1:
            raise ValueError(f"host_capacity must be >= 1, got "
                             f"{host_capacity}")
        self.shard_dir = str(shard_dir)
        os.makedirs(self.shard_dir, exist_ok=True)
        self.host_capacity = int(host_capacity)
        # T1: tenant → (packed leaves, rank, dirty), insertion = LRU order
        self._t1: OrderedDict[str, tuple] = OrderedDict()
        # tier directory: every tenant in ANY tier → rank (-1 = unknown
        # yet; shard-only tenants adopted from disk resolve lazily)
        self._dir: dict[str, int] = {}
        self._gen: dict[str, int] = {}        # re-registration generations
        self._prefetcher = _Prefetcher(self._read_shard)
        for t in list_shards(self.shard_dir):  # warm-start against a
            self._dir[t] = -1                  # pre-existing shard set

    # -- membership is directory-wide, not resident-set ----------------

    def __contains__(self, tenant: str) -> bool:
        return tenant in self._dir

    @property
    def tenants(self) -> list[str]:
        return sorted(self._dir)

    @property
    def resident_tenants(self) -> list[str]:
        """Tenants currently holding a T0 slot (the base class's notion
        of membership)."""
        return sorted(self._slot_of)

    def rank_of(self, tenant: str) -> int:
        r = self._dir[tenant]
        if r < 0:                             # shard-only: resolve lazily
            _packed, r = self._read_shard(tenant)
            self._dir[tenant] = int(r)
        return int(r)

    # -- registration goes to T1 ---------------------------------------

    def register(self, tenant: str, adapter: Params, rank: int = 0) -> int:
        """Pack one tenant's adapter into the host cache (T1, dirty).
        Unlike the flat store, registration does NOT claim a device slot
        — residency comes from ``install_batch``/``slot_of``.  Returns
        the tenant's T0 slot when it is already resident (the device row
        is refreshed in place), else -1."""
        packed, r_t = self._pack_adapter(tenant, adapter, rank)
        self._gen[tenant] = self._gen.get(tenant, 0) + 1
        self._dir[tenant] = r_t
        self._t1_put(tenant, packed, r_t, dirty=True)
        slot = self._slot_of.get(tenant, -1)
        if slot >= 0:
            self._install_rows([(slot, tenant, packed, r_t)])
        if obs.enabled():
            obs.inc("pool/registers", kind=self.kind)
            obs.set_gauge("pool/t1_occupancy",
                          len(self._t1) / self.host_capacity)
            obs.event("pool_register", tenant=tenant, slot=slot,
                      rank=int(r_t), pool=self.kind, tier="t1")
        return slot

    # -- promotion ------------------------------------------------------

    def slot_of(self, tenant: str) -> int:
        """Slot for a known tenant, promoting T2→T1→T0 on a miss."""
        if tenant in self._slot_of:
            return super().slot_of(tenant)
        return self.install_batch([tenant])[tenant]

    def install_batch(self, tenants, *, pinned=(), queued=()) -> dict[str, int]:
        """Make every tenant T0-resident and return ``{tenant: slot}``.
        All missing tenants are promoted (T2→T1→T0) and installed with
        one donated device scatter per pool leaf — between decode chunks
        this is the batched hot-swap.  Tenants in ``tenants`` that are
        already resident are implicitly pinned (they are needed by the
        same chunk)."""
        order = list(dict.fromkeys(tenants))
        out: dict[str, int] = {}
        missing: list[str] = []
        for t in order:
            slot = self._slot_of.get(t)
            if slot is not None:
                self._touch(slot)
                out[t] = slot
                obs.inc("pool/tier_hits", tier="t0")
            else:
                missing.append(t)
        if order:
            obs.inc("pool/lookups", len(order), kind=self.kind)
        if not missing:
            return out
        self.drain_prefetch()                 # fold completed prefetches
        incoming = []
        for t in missing:
            if t not in self._dir:
                raise KeyError(f"unknown tenant {t!r}: register it first")
            entry = self._t1.get(t)
            if entry is not None:
                self._t1.move_to_end(t)
                packed, r_t, _dirty = entry
                src = "t1"
                obs.inc("pool/tier_hits", tier="t1")
            else:
                obs.inc("pool/tier_misses", tier="t1")
                packed, r_t = self._read_shard(t)
                self._t1_put(t, packed, r_t, dirty=False)
                src = "t2"
            obs.inc("pool/promotions", src=src)
            incoming.append((t, packed, r_t, src))
        slots = self._alloc_slots(len(incoming), pinned=set(pinned) | set(out),
                                  queued=set(queued))
        self._install_rows([(s, t, p, r)
                            for s, (t, p, r, _src) in zip(slots, incoming)])
        for (t, _p, r_t, src), s in zip(incoming, slots):
            out[t] = s
            if obs.enabled():
                obs.event("pool_promote", tenant=t, slot=s, src=src,
                          rank=int(r_t), pool=self.kind)
        if obs.enabled():
            obs.set_gauge("pool/occupancy",
                          len(self._tenant_of) / self.n_slots, kind=self.kind)
            obs.set_gauge("pool/t1_occupancy",
                          len(self._t1) / self.host_capacity)
        return out

    def _alloc_slots(self, k: int, *, pinned: set, queued: set) -> list[int]:
        """Pick ``k`` free-or-evictable slots.  Preference order: free
        slots, then LRU over unpinned+unqueued residents, then LRU over
        unpinned queued residents (queue-informed eviction).  Raises
        RuntimeError when fewer than ``k`` slots are evictable (every
        resident is pinned) — active rows are never corrupted."""
        slots = [s for s in range(self.n_slots)
                 if s not in self._tenant_of][:k]
        need = k - len(slots)
        if need > 0:
            ranked = sorted(
                (self._tenant_of[s] in queued, int(self._last_used[s]), s)
                for s in self._tenant_of
                if self._tenant_of[s] not in pinned)
            if len(ranked) < need:
                raise RuntimeError(
                    f"adapter pool exhausted: need {need} more slots but "
                    f"only {len(ranked)} of {self.n_slots} residents are "
                    f"evictable (rest pinned by active rows) — raise "
                    f"n_slots or shrink the admitted batch")
            for was_queued, _lu, s in ranked[:need]:
                self._demote(s, bool(was_queued))
                slots.append(s)
        return slots

    def _demote(self, slot: int, was_queued: bool) -> None:
        """Bookkeeping-only T0 eviction: the adapter's bytes stay in T1
        (or its spilled shard) and the device row itself is overwritten
        by the incoming scatter — no zeroing write."""
        tenant = self._tenant_of.pop(slot)
        del self._slot_of[tenant]
        self._last_used[slot] = 0
        self._slot_ranks[slot] = 0
        if obs.enabled():
            obs.inc("pool/evictions", kind=self.kind)
            obs.event("pool_evict", tenant=tenant, slot=slot, pool=self.kind,
                      tier="t0", queued=was_queued)

    def _install_rows(self, rows) -> None:
        """Install packed host rows into T0 — one donated device scatter
        per pool leaf, shared by every row in ``rows``."""
        idx = jnp.asarray(np.array([s for s, *_ in rows], np.int32))
        for prefix, (lead, _d_in, _d_out) in self.targets.items():
            pool = self._pools[prefix]
            axis = 1 if lead else 0
            for key in _SLOT_KEYS:
                if key not in pool:
                    continue
                vals = np.stack([p[prefix][key] for _s, _t, p, _r in rows],
                                axis=axis)
                pool[key] = _scatter_rows(pool[key], idx,
                                          jnp.asarray(vals), bool(lead))
        for slot, tenant, _packed, r_t in rows:
            self._slot_of[tenant] = slot
            self._tenant_of[slot] = tenant
            self._slot_ranks[slot] = int(r_t)
            self._touch(slot)
        self.version += 1

    # -- T1 cache -------------------------------------------------------

    def _t1_put(self, tenant: str, packed: dict, rank: int,
                *, dirty: bool) -> None:
        self._t1[tenant] = (packed, int(rank), bool(dirty))
        self._t1.move_to_end(tenant)
        while len(self._t1) > self.host_capacity:
            victim, (vp, vr, vdirty) = self._t1.popitem(last=False)
            if vdirty:
                save_shard(self.shard_dir, victim,
                           self._shard_tree(vp, vr))
                obs.inc("pool/t1_spills")
            obs.inc("pool/t1_evictions")

    # -- T2 shard codec -------------------------------------------------

    def _shard_tree(self, packed: dict, rank: int) -> dict:
        return {"leaves": {p.replace("/", "."): dict(v)
                           for p, v in packed.items()},
                "rank": np.asarray(rank, np.int32)}

    def _read_shard(self, tenant: str) -> tuple[dict, int]:
        flat, _step = load_shard_flat(self.shard_dir, tenant)
        rank = int(flat.pop("rank"))
        packed: dict = {}
        for p in self.targets:
            head = "leaves/" + p.replace("/", ".") + "/"
            leaves = {path[len(head):]: np.asarray(arr, np.float32)
                      for path, arr in flat.items() if path.startswith(head)}
            if not leaves:
                raise KeyError(f"shard for tenant {tenant!r} is missing "
                               f"target {p}")
            packed[p] = leaves
        return packed, rank

    # -- async prefetch -------------------------------------------------

    def prefetch(self, tenants) -> None:
        """Queue background shard loads for tenants not yet in T0/T1.
        Called before launching a decode chunk; loads overlap the scan."""
        for t in tenants:
            if t in self._slot_of or t in self._t1 or t not in self._dir:
                continue
            self._prefetcher.submit(t, self._gen.get(t, 0))
            obs.inc("pool/prefetch_submits")

    def drain_prefetch(self) -> None:
        """Fold completed prefetches into T1 (the buffer flip).  Loads
        superseded by a re-registration while in flight are discarded."""
        for tenant, (packed, rank, gen) in self._prefetcher.drain().items():
            if gen != self._gen.get(tenant, 0) or tenant in self._t1:
                continue
            self._t1_put(tenant, packed, rank, dirty=False)
            if obs.enabled():
                obs.inc("pool/prefetched")
                obs.event("pool_prefetch", tenant=tenant, rank=int(rank))

    def wait_prefetch(self, timeout: float = 5.0) -> bool:
        """Block until the prefetcher is quiet.  Tests/benchmarks use
        this as a barrier; serving never needs it — a missed prefetch
        just falls back to the synchronous T2 path, with identical
        bytes (the determinism contract)."""
        return self._prefetcher.wait(timeout)

    # -- checkpointing --------------------------------------------------

    def flush(self) -> None:
        """Spill every dirty T1 entry to its T2 shard (clean entries are
        already byte-identical on disk)."""
        for t, (packed, r, dirty) in list(self._t1.items()):
            if dirty:
                save_shard(self.shard_dir, t, self._shard_tree(packed, r))
                self._t1[t] = (packed, r, False)
                obs.inc("pool/t1_spills")

    def save(self, path: str, step: int = 0) -> None:
        """Flush dirty T1 → shards, then write the base (T0) state plus
        the tier directory table (ids + ranks, variable-length — read
        back via the flat loader, never shape-asserted)."""
        self.flush()
        tree = self.state_tree()
        names = sorted(self._dir)
        ids = np.zeros((len(names), _ID_BYTES), np.uint8)
        ranks = np.zeros((len(names),), np.int32)
        for i, t in enumerate(names):
            ids[i] = _encode_id(t)
            ranks[i] = self._dir[t]
        tree["tier"] = {"ids": ids, "ranks": ranks}
        save_checkpoint(path, tree, step=step)

    def load(self, path: str) -> int:
        """Restore T0 state — legacy flat-store checkpoints load
        unchanged (the directory then starts as the resident set) — plus
        the tier directory when present.  T1 restarts from the restored
        resident rows (kept inclusive so demotion stays bookkeeping-
        only) and refills from shards on demand."""
        step = super().load(path)
        self._t1.clear()
        self._gen.clear()
        self._dir = {}
        flat, _ = load_checkpoint_flat(path)
        ids = flat.get("tier/ids")
        if ids is not None:
            for row, r in zip(np.asarray(ids, np.uint8),
                              np.asarray(flat["tier/ranks"], np.int32)):
                t = _decode_id(row)
                if t:
                    self._dir[t] = int(r)
        for slot, t in self._tenant_of.items():
            self._dir.setdefault(t, int(self._slot_ranks[slot]))
        for t in list_shards(self.shard_dir):
            self._dir.setdefault(t, -1)
        # resident rows become T1 entries too (inclusive cache): without
        # a host copy, a bookkeeping-only demotion would lose the bytes
        for slot, t in sorted(self._tenant_of.items()):
            self._t1_put(t, self._extract_slot(slot),
                         int(self._slot_ranks[slot]), dirty=True)
        return step

    def _extract_slot(self, slot: int) -> dict:
        """Copy one resident row back to packed host leaves."""
        packed: dict = {}
        for prefix, (lead, _di, _do) in self.targets.items():
            pool = self._pools[prefix]
            idx = (slice(None), slot) if lead else (slot,)
            packed[prefix] = {k: np.asarray(pool[k][idx])
                              for k in _SLOT_KEYS if k in pool}
        return packed
