"""Learning-rate schedules (pure fns of an int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def fn(step):
        return jnp.asarray(lr, jnp.float32)

    return fn


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1.0 - final_frac) * cos)

    return fn


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.0):
    def fn(step):
        warm = lr * jnp.minimum(1.0, step / max(warmup, 1))
        t = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = lr * (final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return fn
