"""End-to-end behaviour tests for the paper's system.

Small-scale but real: federated rounds, the paper pipeline, serving with
personalized adapters.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedlora import run_federated
from repro.data.loader import eval_batches
from repro.data.partition import specialist_partition
from repro.data.synthetic import SyntheticInstructionDataset, make_dataset_family
from repro.fed.simulate import FedHyper
from repro.models import model as M
from repro.models.config import ArchConfig

CFG = ArchConfig(name="sys", family="dense", n_layers=2, d_model=96,
                 n_heads=4, n_kv_heads=2, d_ff=192, vocab_size=512,
                 dtype="float32", lora_rank=4, lora_dropout=0.0)


@pytest.fixture(scope="module")
def setting():
    fam = make_dataset_family("dolly")
    C = 3
    probs = specialist_partition(C, 4)
    cds = [SyntheticInstructionDataset(fam, probs[c], client_seed=0)
           for c in range(C)]
    sds = SyntheticInstructionDataset(fam, [0.25] * 4, client_seed=0)
    eg = eval_batches(sds, 16, 48, 2)
    rng = np.random.default_rng(5)
    el = []
    for _ in range(2):
        outs = [d.sample_batch(rng, 16, 48) for d in cds]
        el.append({k: jnp.asarray(np.stack([o[k] for o in outs]))
                   for k in outs[0]})
    return cds, sds, eg, el


def test_full_pipeline_runs_and_reports(setting):
    cds, sds, eg, el = setting
    hp = FedHyper(method="fedlora_opt", n_clients=3, rounds=2, local_steps=2,
                  batch=8, seq_len=48, personal_steps=3, global_steps=2)
    res = run_federated(CFG, hp, cds, sds, eg, el)
    assert len(res.history) == 2
    assert res.comm_bytes > 0
    assert 0.0 <= res.global_acc <= 1.0
    assert len(res.per_client) == 3


def test_pipeline_flag_changes_behavior(setting):
    cds, sds, eg, el = setting
    r1 = run_federated(CFG, FedHyper(method="fedlora_opt", n_clients=3,
                                     rounds=1, local_steps=1, batch=4,
                                     seq_len=48, personal_steps=1,
                                     global_steps=1, pipeline=True),
                       cds, sds, eg, el)
    r2 = run_federated(CFG, FedHyper(method="fedlora_opt", n_clients=3,
                                     rounds=1, local_steps=1, batch=4,
                                     seq_len=48, personal_steps=1,
                                     global_steps=1, pipeline=False),
                       cds, sds, eg, el)
    assert r1.history[0]["ce"] != r2.history[0]["ce"]


def test_serve_generates_with_personalized_adapters():
    from repro.core import peft
    from repro.launch.serve import greedy_generate, merge_adapters
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    ad = peft.add_lora(params, CFG, jax.random.PRNGKey(1), decomposed=True)
    # personalize only dB_mag (a few scalars per tenant)
    ad["blocks"]["sub0"]["attn"]["q_proj"]["dB_mag"] = \
        ad["blocks"]["sub0"]["attn"]["q_proj"]["dB_mag"] + 0.5
    merged = merge_adapters(params, ad)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        5, CFG.vocab_size, size=(2, 16)), jnp.int32)
    out = greedy_generate(merged, {"tokens": toks}, CFG, n_new=4)
    assert out.shape == (2, 4)
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < CFG.vocab_size))
