"""Direction–Magnitude (D-M) decomposition (paper Eq. 1 / Eq. 4).

For a kernel in (d_in, d_out) layout the DoRA "column" is the per-input-
feature vector over outputs, so

    mag(X) = ||X||_c           shape (..., d_in)    [norm over last axis]
    dir(X) = X / ||X||_c       shape (..., d_in, d_out)
    X      = dir * mag[..., None]                   (Eq. 1)

Leading stacked dims (the scan-over-superblocks layer axis, or a vmapped
client axis) pass straight through.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def magnitude(x):
    return jnp.linalg.norm(x.astype(jnp.float32), axis=-1)


def decompose(x):
    """x (..., d_in, d_out) → (mag (..., d_in), dir (..., d_in, d_out))."""
    m = magnitude(x)
    d = x.astype(jnp.float32) / (m[..., None] + _EPS)
    return m.astype(x.dtype), d.astype(x.dtype)


def recompose(mag, dir_):
    """(Eq. 1)  X = mag ⊙ dir  (broadcast over the output axis)."""
    return (dir_.astype(jnp.float32)
            * mag.astype(jnp.float32)[..., None]).astype(dir_.dtype)


def decompose_lora_pair(lora_A, lora_B):
    """LoRA factors → paper Eq. 4 components.

    lora_A: (..., d_in, r) → (A_mag (..., d_in), A_dir)
    lora_B: (..., r, d_out) → (B_mag (..., r),  B_dir)
    """
    A_mag, A_dir = decompose(lora_A)
    B_mag, B_dir = decompose(lora_B)
    return {"A_mag": A_mag, "A_dir": A_dir, "B_mag": B_mag, "B_dir": B_dir}


def recompose_lora_pair(c):
    """Inverse of decompose_lora_pair, honouring the trained deltas
    (paper Eq. 9 / Eq. 10):

        A = (A_dir + dA_dir) · diag(A_mag)
        B = diag(B_mag + dB_mag) · B_dir
    """
    a_dir = c["A_dir"] + c.get("dA_dir", 0.0)
    b_mag = c["B_mag"] + c.get("dB_mag", 0.0)
    return recompose(c["A_mag"], a_dir), recompose(b_mag, c["B_dir"])


def effective_delta_w(c, scale: float):
    """Materialized ΔW = scale · A · B for analysis/tests (not the compute
    path — the model applies the factors without forming ΔW)."""
    A, B = recompose_lora_pair(c)
    return scale * jnp.einsum("...ir,...ro->...io", A.astype(jnp.float32),
                              B.astype(jnp.float32))
