"""R5 — dead-mask detection.

Historical bug class: a ``FedMethod`` whose ``stage_global_mask`` /
``stage_local_mask`` / ``keep_local`` / ``server_zero_rx`` regex
matches *zero* leaves of the adapter tree.  Nothing crashes — the
stage silently trains nothing (or shares everything), and only a
downstream parity test catches it, if one exists for that method ×
architecture combination.  As the registry grows per-layer selective
sharing (SDFLoRA-style mask families), regex↔tree drift becomes the
dominant failure mode.

Unlike R1–R4 this is a *project* rule: it imports the live registry
(``repro.core.methods``), builds abstract adapter trees via
``jax.eval_shape`` (no FLOPs, no device memory) for at least
``llama2_7b`` and one MoE config, and evaluates every regex of every
registered method against the real leaf paths.  A regex matching zero
leaves on a config where the method has a non-empty adapter tree is a
finding anchored at the method's ``name=`` line in core/methods.py.

Methods whose adapter overlay is legitimately empty on a config (e.g.
a dense-only method on a pure-MoE architecture) are skipped for that
config.  Every ``stage_mask`` stage (local_pretrain / global / local)
must select at least one leaf — a non-pipeline method's global/local
stages fall back to ``train_mask``, so this cannot over-fire.
``keep_local=None`` is fine (nothing kept local is a valid choice),
but a *non-None* pattern matching nothing is dead by definition; the
server-zero pattern is resolved through
``aggregation.aggregate_zero_rx`` so inferred patterns are checked
too.
"""
from __future__ import annotations

import ast
import re

from .base import Finding, ProjectContext, Rule

_CONFIGS = (
    ("llama2_7b", "repro.configs.llama2_7b"),
    ("qwen3_moe_30b_a3b", "repro.configs.qwen3_moe_30b_a3b"),
)


def evaluate_registry(configs=_CONFIGS) -> list[dict]:
    """Evaluate every registered method against abstract adapter trees
    (``jax.eval_shape`` — no FLOPs) of ``configs``: each of the three
    ``stage_mask`` stages must select ≥ 1 leaf, and each non-None
    ``keep_local`` / ``aggregate_zero_rx`` regex must match ≥ 1 leaf
    path.  Returns problem dicts ``{method, config, field, detail}``.
    Importable on its own so tests can call it without the lint
    runner."""
    import jax

    from repro.core import aggregation as agg
    from repro.core import methods as M
    from repro.launch import train as T
    from repro.utils import pytree as pt

    problems: list[dict] = []
    for cfg_name, cfg_mod in configs:
        mod = __import__(cfg_mod, fromlist=["SMOKE"])
        cfg = mod.SMOKE
        base = T.abstract_base(cfg)
        for name in M.available_methods():
            method = M.get_method(name)
            try:
                ad = jax.eval_shape(
                    lambda m=method, c=cfg, b=base: m.make_adapter(
                        b, c, jax.random.PRNGKey(0)))
            except Exception as e:             # config/method mismatch
                problems.append(dict(
                    method=name, config=cfg_name, field="make_adapter",
                    detail=f"make_adapter failed: {e!r}"))
                continue
            paths = pt.tree_paths(ad)
            if not paths:
                continue                       # method n/a on this config
            # stage masks are path-predicate functions — they evaluate
            # fine on abstract trees (only leaf *paths* are consulted)
            for stage in ("local_pretrain", "global", "local"):
                mask = method.stage_mask(ad, stage)
                n = sum(1 for v in jax.tree_util.tree_leaves(mask) if v)
                if n == 0:
                    problems.append(dict(
                        method=name, config=cfg_name,
                        field=f"stage_mask[{stage}]",
                        detail=(f"selects 0 of {len(paths)} adapter "
                                f"leaves on {cfg_name} — the stage "
                                f"would silently train nothing")))
            for field, pattern in (
                    ("keep_local", method.keep_local),
                    ("server_zero_rx", agg.aggregate_zero_rx(method))):
                if pattern is None:
                    continue
                rx = re.compile(pattern)
                if not any(rx.search(p) for p in paths):
                    problems.append(dict(
                        method=name, config=cfg_name, field=field,
                        detail=(f"regex {pattern!r} matches 0 of "
                                f"{len(paths)} adapter leaf paths on "
                                f"{cfg_name} — dead pattern")))
    return problems


class DeadMask(Rule):
    code = "R5"
    name = "dead-mask"
    description = ("FedMethod mask/keep-local regex matches zero leaves "
                   "of the real adapter tree for llama2_7b or the MoE "
                   "config (stage silently trains/shares nothing)")

    # tests can point the rule at a different evaluator
    evaluate = staticmethod(evaluate_registry)

    def check_project(self, ctx: ProjectContext) -> list[Finding]:
        mod = ctx.module("core/methods.py")
        if mod is None:
            return []                          # partial lint run
        try:
            problems = type(self).evaluate()
        except ImportError as e:
            # jax (or the repo itself) not importable — static-only run
            return [mod.finding(
                "R5", mod.tree.body[0],
                f"dead-mask evaluation skipped: {e!r} (run with "
                f"PYTHONPATH=src and jax installed)")]
        anchors = self._name_lines(mod)
        out: list[Finding] = []
        for p in problems:
            anchor = anchors.get(p["method"], mod.tree.body[0])
            out.append(mod.finding(
                "R5", anchor,
                f"method `{p['method']}` {p['field']}: {p['detail']}"))
        return out

    def _name_lines(self, mod) -> dict[str, ast.AST]:
        """Map method name -> the ``name="..."`` keyword node of its
        register()/FedMethod(...) call in core/methods.py."""
        anchors: dict[str, ast.AST] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == "name" and isinstance(
                            kw.value, ast.Constant) and isinstance(
                            kw.value.value, str):
                        anchors.setdefault(kw.value.value, kw.value)
        return anchors
