"""The repro.lint runner: file discovery, suppression comments, the
checked-in baseline, and human/JSON reporting.

Usage (also via ``python -m repro.lint``)::

    python -m repro.lint src/repro              # lint a tree
    python -m repro.lint --json src/repro       # machine output
    python -m repro.lint --rules R1,R3 path     # subset of rules
    python -m repro.lint --write-baseline path  # accept current findings

Suppression: append ``# lint: ok[R1] reason`` (or ``ok[R1,R3]``) to the
finding line, or put it on its own line directly above.  The reason is
mandatory — a bare ``ok[R1]`` does not suppress.

Baseline: ``.lint-baseline.json`` at the repo root (next to
pyproject.toml) holds accepted findings as ``{rule, path, line_text,
note}``.  Entries match on content, not line numbers, so they survive
unrelated edits; every entry MUST carry a non-empty ``note`` — the
one-line justification reviewers read.  Stale entries (no longer
produced by the analyzer) are reported as warnings so the file shrinks
over time.

Exit codes: 0 clean, 1 unsuppressed findings, 2 config error (bad
baseline, unjustified entries, unknown rule).
"""
from __future__ import annotations

import argparse
import json
import os
import re

from . import rules as R
from .rules.base import Finding, ModuleInfo, ProjectContext

_SUPPRESS_RX = re.compile(
    r"#\s*lint:\s*ok\[([A-Z0-9, ]+)\]\s*(\S.*)?$")


def find_repo_root(start: str) -> str:
    """Nearest ancestor holding pyproject.toml (fallback: start)."""
    cur = os.path.abspath(start)
    if os.path.isfile(cur):
        cur = os.path.dirname(cur)
    while True:
        if os.path.exists(os.path.join(cur, "pyproject.toml")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start)
        cur = parent


def discover(paths: list[str]) -> list[str]:
    files: list[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            files.append(p)
        elif os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if d != "__pycache__"
                               and not d.startswith(".")]
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        files.append(os.path.join(dirpath, fn))
    return sorted(set(files))


def parse_modules(files: list[str], root: str) \
        -> tuple[list[ModuleInfo], list[Finding]]:
    mods: list[ModuleInfo] = []
    errors: list[Finding] = []
    for path in files:
        rel = os.path.relpath(os.path.abspath(path), root).replace(
            os.sep, "/")
        try:
            with open(path, encoding="utf-8") as f:
                source = f.read()
            mods.append(ModuleInfo(path=path, rel=rel, source=source))
        except (OSError, SyntaxError) as e:
            errors.append(Finding(
                rule="E0", path=rel, line=getattr(e, "lineno", 1) or 1,
                col=0, message=f"could not parse: {e}", line_text=""))
    return mods, errors


def run_rules(mods: list[ModuleInfo], root: str,
              codes: list[str]) -> list[Finding]:
    findings: list[Finding] = []
    ctx = ProjectContext(root=root, modules=mods)
    for code in codes:
        rule = R.get_rule(code)
        for mod in mods:
            findings.extend(rule.check_module(mod))
        findings.extend(rule.check_project(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def suppressed(mod_by_rel: dict[str, ModuleInfo], f: Finding) -> bool:
    """True if the finding line (or the line above) carries a justified
    ``# lint: ok[<rule>] reason`` comment."""
    mod = mod_by_rel.get(f.path)
    if mod is None:
        return False
    for lineno in (f.line, f.line - 1):
        text = mod.line_text(lineno)
        m = _SUPPRESS_RX.search(text)
        if m and m.group(2):                   # reason is mandatory
            codes = {c.strip() for c in m.group(1).split(",")}
            if f.rule in codes:
                return True
    return False


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def load_baseline(path: str) -> tuple[list[dict], list[str]]:
    """Returns (entries, config_errors)."""
    if not os.path.exists(path):
        return [], []
    try:
        with open(path, encoding="utf-8") as f:
            entries = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [], [f"baseline {path}: unreadable ({e})"]
    errs: list[str] = []
    if not isinstance(entries, list):
        return [], [f"baseline {path}: expected a JSON list"]
    for i, e in enumerate(entries):
        missing = {"rule", "path", "line_text", "note"} - set(e)
        if missing:
            errs.append(f"baseline entry {i}: missing {sorted(missing)}")
        elif not str(e["note"]).strip() or \
                str(e["note"]).startswith("TODO"):
            errs.append(
                f"baseline entry {i} ({e['rule']} {e['path']}): every "
                f"entry needs a one-line justification in `note`")
    return entries, errs


def write_baseline(path: str, findings: list[Finding]) -> None:
    entries = [dict(rule=f.rule, path=f.path, line_text=f.line_text,
                    note="TODO: justify") for f in findings]
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(entries, fh, indent=2, ensure_ascii=False)
        fh.write("\n")


def apply_baseline(findings: list[Finding], entries: list[dict]) \
        -> tuple[list[Finding], list[Finding], list[dict]]:
    """Split into (new, baselined, stale-entries).  Matching is by
    (rule, path, line_text) with multiplicity."""
    pool: dict[tuple, int] = {}
    for e in entries:
        k = (e["rule"], e["path"], e["line_text"])
        pool[k] = pool.get(k, 0) + 1
    new: list[Finding] = []
    matched: list[Finding] = []
    for f in findings:
        k = f.sig
        if pool.get(k, 0) > 0:
            pool[k] -= 1
            matched.append(f)
        else:
            new.append(f)
    stale = []
    for e in entries:
        k = (e["rule"], e["path"], e["line_text"])
        if pool.get(k, 0) > 0:
            pool[k] -= 1
            stale.append(e)
    return new, matched, stale


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="repo-aware JAX static analyzer (rules R1–R5)")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to lint")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable JSON report on stdout")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule codes (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--baseline", default=None,
                    help="baseline file (default: <root>/.lint-baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline "
                         "(notes start as TODO and must be filled in)")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code in R.available_rules():
            rule = R.get_rule(code)
            print(f"{code}  {rule.name}: {rule.description}")
        return 0
    if not args.paths:
        ap.error("no paths given (try: python -m repro.lint src/repro)")

    codes = R.available_rules()
    if args.rules:
        codes = [c.strip() for c in args.rules.split(",") if c.strip()]
        for c in codes:
            R.get_rule(c)                      # raises on unknown

    root = find_repo_root(args.paths[0])
    files = discover(args.paths)
    mods, parse_errors = parse_modules(files, root)
    findings = parse_errors + run_rules(mods, root, codes)

    mod_by_rel = {m.rel: m for m in mods}
    findings = [f for f in findings if not suppressed(mod_by_rel, f)]

    baseline_path = args.baseline or os.path.join(
        root, ".lint-baseline.json")
    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(f"wrote {len(findings)} entries to {baseline_path} — fill "
              f"in every `note` before committing")
        return 0

    entries: list[dict] = []
    config_errors: list[str] = []
    if not args.no_baseline:
        entries, config_errors = load_baseline(baseline_path)
    new, matched, stale = apply_baseline(findings, entries)

    if args.json:
        print(json.dumps(dict(
            findings=[f.to_dict() for f in new],
            baselined=[f.to_dict() for f in matched],
            stale_baseline=stale,
            config_errors=config_errors,
            files=len(files), rules=codes), indent=2))
    else:
        for f in new:
            print(f.render())
        for e in stale:
            print(f"warning: stale baseline entry {e['rule']} "
                  f"{e['path']}: {e['line_text']!r} — remove it")
        for err in config_errors:
            print(f"error: {err}")
        n = len(new)
        print(f"repro.lint: {len(files)} files, rules "
              f"{','.join(codes)}: {n} finding(s), "
              f"{len(matched)} baselined, {len(stale)} stale")
    if config_errors:
        return 2
    return 1 if new else 0
