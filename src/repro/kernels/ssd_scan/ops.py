"""jit'd public wrapper: model-layout SSD scan (b,S,H,P)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_bh
from repro.kernels.ssd_scan.ref import ssd_ref, ssd_naive  # noqa: F401  (re-exported via repro.kernels)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd_scan(x, dt, A_log, B, C, *, chunk: int = 256,
             interpret: bool | None = None):
    """x (b,S,H,P); dt (b,S,H); A_log (H,); B,C (b,S,G,N).
    Returns (y (b,S,H,P), final_state (b,H,P,N))."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    if interpret is None:
        interpret = not _on_tpu()
    xf = x.transpose(0, 2, 1, 3).reshape(b * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(b * H, S)
    Bf = B.transpose(0, 2, 1, 3).reshape(b * G, S, N)
    Cf = C.transpose(0, 2, 1, 3).reshape(b * G, S, N)
    alog = jnp.broadcast_to(A_log[None, :], (b, H)).reshape(b * H).astype(jnp.float32)
    y, st = ssd_scan_bh(xf, dtf, alog, Bf, Cf, chunk=chunk,
                        interpret=interpret)
    y = y.reshape(b, H, S, P).transpose(0, 2, 1, 3)
    st = st.reshape(b, H, N, P).transpose(0, 1, 3, 2)     # → (b,H,P,N)
    return y, st


__all__ = ["ssd_scan", "ssd_ref", "ssd_naive"]
