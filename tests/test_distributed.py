"""Multi-device tests (8 host devices via subprocess — XLA locks device
count at first init, so these run in their own interpreter).

Two compatibility tiers (see launch/mesh.shard_map_compat):

  · data-only client meshes (make_client_mesh) run the *fully manual*
    shard_map region — available on every supported jax, including the
    0.4.x this container ships (jax.experimental.shard_map);
  · meshes with a tensor-parallel 'model' axis need partial-auto
    shard_map (jax.shard_map / jax.set_mesh, jax >= 0.6) — those tests
    skip on older jax.

The collective-parity sweeps are the acceptance gate for the
distributed aggregation engine: for every method in the registry, one
production shard_map round must produce the same client adapters as
``FedSim.run_round`` (mixed-rank and weighted fleets included).
"""
import os
import subprocess
import sys

import jax
import pytest

pytestmark = pytest.mark.dist

# Partial-auto shard_map (manual data axes + auto 'model' axis) targets
# the jax.shard_map / jax.set_mesh APIs; on older jax (this container
# ships 0.4.x) those do not exist and the model-parallel tests cannot run.
NEEDS_PARTIAL_AUTO = pytest.mark.skipif(
    not (hasattr(jax, "shard_map") and hasattr(jax, "set_mesh")),
    reason="partial-auto shard_map requires jax.shard_map/jax.set_mesh "
           "(newer jax than installed)")

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(snippet: str, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# collective-parity sweep: shard_map round == FedSim.run_round
# ---------------------------------------------------------------------------

# Shared harness, exec'd inside the 8-device subprocess.  ``run_case``
# drives ROUNDS production train_step calls against the FedSim oracle on
# identical initial state/batches and compares final client adapters in
# f32 (the two paths fuse differently, so ~ulp drift accumulates; the
# exact method is compared on the product A·B — truncated-SVD *factors*
# are sign-sensitive to that drift, the aggregate itself is not).
PARITY_HARNESS = r"""
import numpy as np
import jax, jax.numpy as jnp
from repro.launch.mesh import make_client_mesh
from repro.launch.train import make_fed_train_step, TrainSettings
from repro.fed.simulate import FedHyper, FedSim
from repro.core.methods import available_methods, get_method
from repro.models.config import ArchConfig
from repro.utils import pytree as pt

C, T, B, S, ROUNDS = 4, 2, 2, 16, 2
cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=32, n_heads=2,
                 n_kv_heads=1, d_ff=64, vocab_size=64, dtype="float32",
                 lora_rank=4, lora_dropout=0.0)
mesh = make_client_mesh(C)
rng = np.random.default_rng(0)


def reseed(name):
    # every case draws from its own name-keyed data stream: sweep
    # results must not depend on registry order/size (a method added
    # earlier in the alphabet would otherwise shift every later case's
    # batches, and the ~ulp parity tolerances are marginal enough for
    # that to matter)
    import zlib
    global rng
    rng = np.random.default_rng(zlib.crc32(name.encode()))


def make_batches():
    return [{"tokens": jnp.asarray(
                 rng.integers(5, cfg.vocab_size, size=(C, B, S)), jnp.int32),
             "loss_mask": jnp.ones((C, B, S), jnp.float32)}
            for _ in range(T)]


def compare(name, prod, ref):
    prod = dict(zip(pt.tree_paths(prod), map(np.asarray, jax.tree.leaves(prod))))
    ref = dict(zip(pt.tree_paths(ref), map(np.asarray, jax.tree.leaves(ref))))
    assert set(prod) == set(ref), name
    if name == "lora_exact":
        for pref in sorted(p.rsplit("/", 1)[0] for p in prod
                           if p.endswith("lora_A")):
            pa, pb = pref + "/lora_A", pref + "/lora_B"
            np.testing.assert_allclose(
                np.einsum("...ir,...ro->...io", prod.pop(pa), prod.pop(pb)),
                np.einsum("...ir,...ro->...io", ref.pop(pa), ref.pop(pb)),
                rtol=5e-4, atol=5e-5, err_msg=f"{name}:{pref}")
    for p in sorted(prod):
        if name == "lora_fedavg_q8":
            # the engines agree to ~ulp, and a stochastic-rounding draw
            # whose fractional part sits within that drift of its uniform
            # sample can legitimately flip between them — allow isolated
            # diffs up to one SR bin, but still demand near-total strict
            # agreement: a broken rounding-key chain flips ~half the
            # draws on every leaf and fails the 99% gate
            bin_ = max(np.abs(prod[p]).max(), np.abs(ref[p]).max()) / 127.0
            np.testing.assert_allclose(prod[p], ref[p], rtol=2e-4,
                                       atol=2 * bin_ + 2e-5,
                                       err_msg=f"{name}:{p}")
            close = np.isclose(prod[p], ref[p], rtol=2e-4, atol=2e-5)
            assert close.mean() > 0.99, (name, p, float(close.mean()))
        else:
            np.testing.assert_allclose(prod[p], ref[p], rtol=2e-4, atol=2e-5,
                                       err_msg=f"{name}:{p}")


def run_case(name, ranks=None, weights=None, prox_mu=0.0):
    reseed(name)
    hp = FedHyper(method=name, n_clients=C, local_steps=T, batch=B,
                  seq_len=S, lr=1e-2, prox_mu=prox_mu, client_ranks=ranks,
                  client_weights=weights)
    sim = FedSim(cfg, hp)
    st = TrainSettings(lr=hp.lr, micro_batches=1, clip=hp.clip, remat=False,
                       method=name, local_steps=T, prox_mu=prox_mu,
                       client_ranks=ranks, client_weights=weights)
    step_fn, _ = make_fed_train_step(cfg, mesh, st)
    na, no = sim.client_adapters, sim.opt_state
    step0 = jnp.zeros((), jnp.int32)
    for r in range(ROUNDS):
        batches = make_batches()
        big = {k: jnp.concatenate([b[k] for b in batches], axis=1)
               for k in batches[0]}
        # production first: FedSim.local_round donates its buffers, and
        # round 1 shares them with the production call
        na, no, met = step_fn(sim.base, na, no, step0, big)
        sim.run_round(batches, jax.random.PRNGKey(r))
        step0 = step0 + T
        assert np.isfinite(float(met["ce"])), (name, r)
    compare(name, na, sim.client_adapters)
    print("OK", name, "ranks" if ranks else "", "weights" if weights else "")


# ---- full three-stage pipeline: shard_map == FedSim stage by stage ----
TG, TP = 2, 2          # stage-2 / stage-3 steps per pipeline iteration


def make_server_batches(n):
    return [{"tokens": jnp.asarray(
                 rng.integers(5, cfg.vocab_size, size=(B, S)), jnp.int32),
             "loss_mask": jnp.ones((B, S), jnp.float32)}
            for _ in range(n)]


def flat(bs, axis):
    return {k: jnp.concatenate([b[k] for b in bs], axis=axis)
            for k in bs[0]}


def keep_leaves(method, tree):
    import re
    if not method.keep_local:
        return {}
    rx = re.compile(method.keep_local)
    return {p: np.asarray(x) for p, x in
            zip(pt.tree_paths(tree), jax.tree.leaves(tree)) if rx.search(p)}


def run_pipeline_case(name, ranks=None, weights=None, prox_mu=0.0):
    from repro.launch.train import make_fed_pipeline_step
    reseed(name)
    method = get_method(name)
    hp = FedHyper(method=name, n_clients=C, local_steps=T, batch=B,
                  seq_len=S, lr=1e-2, server_lr=5e-3, global_steps=TG,
                  personal_steps=TP, lam=1e-2, prox_mu=prox_mu,
                  client_ranks=ranks, client_weights=weights)
    sim = FedSim(cfg, hp)
    st = TrainSettings(lr=hp.lr, micro_batches=1, clip=hp.clip, remat=False,
                       method=name, local_steps=T, prox_mu=prox_mu,
                       client_ranks=ranks, client_weights=weights,
                       server_lr=hp.server_lr, global_steps=TG,
                       personal_steps=TP, lam=hp.lam)
    pipe = make_fed_pipeline_step(cfg, mesh, st)
    na, no = sim.client_adapters, sim.opt_state
    step0 = jnp.zeros((), jnp.int32)
    anchor = None
    agg_p = None
    for r in range(ROUNDS):
        cb, sb = make_batches(), make_server_batches(TG)
        pb = (make_batches() + make_batches())[:TP]
        na, no, agg_p, met = pipe.round_step(
            sim.base, na, no, step0, flat(cb, 1), anchor)
        anchor = na if method.prox else None
        kept = keep_leaves(method, na)
        agg_p, na, _ = pipe.global_step(sim.base, agg_p, na, flat(sb, 0))
        # keep-local leaves must pass through stage 2 untouched
        for p, want in kept.items():
            node = na
            for k in p.split("/"):
                node = node[k]
            np.testing.assert_array_equal(np.asarray(node), want,
                                          err_msg=f"{name}:stage2-kept:{p}")
        na, _ = pipe.personal_step(sim.base, na, flat(pb, 1))

        sim.local_round(cb, jax.random.PRNGKey(r))
        agg_s = sim.aggregate()
        agg_s = sim.global_stage(agg_s, sb, jax.random.PRNGKey(100 + r))
        sim.personalize(pb, jax.random.PRNGKey(200 + r))
        step0 = step0 + T
        assert np.isfinite(float(met["ce"])), (name, r)
    compare(name, na, sim.client_adapters)
    compare(name, agg_p, agg_s)
    print("PIPE-OK", name, "ranks" if ranks else "",
          "weights" if weights else "")
"""


@pytest.mark.slow
def test_collective_parity_all_methods():
    """Every registry method: production shard_map round == FedSim round
    on a uniform fleet (2 rounds, so optimizer state and the FedProx
    anchor survive the round boundary)."""
    out = _run(PARITY_HARNESS + r"""
names = available_methods()
for name in names:
    m = get_method(name)
    run_case(name, prox_mu=0.05 if m.prox else 0.0)
print("SWEPT", len(names))
""")
    assert "SWEPT 14" in out, out


@pytest.mark.slow
def test_collective_parity_het_and_weighted_fleets():
    """Mixed-rank fleets (rank-aware aggregation family + the paper
    pipeline + FedALT) and data-size-weighted clients run identically on
    the production path."""
    out = _run(PARITY_HARNESS + r"""
run_case("fedlora_opt", ranks=(1, 2, 3, 4))
run_case("lora_zeropad", ranks=(1, 2, 3, 4))
run_case("lora_replication", ranks=(1, 2, 3, 4), weights=(1., 2., 3., 4.))
run_case("lora_exact", ranks=(1, 2, 3, 4), weights=(4., 3., 2., 1.))
run_case("fedalt", ranks=(2, 4, 4, 2))
run_case("lora", weights=(1., 2., 3., 4.))
run_case("lora_fedavg_q8", ranks=(1, 2, 3, 4), weights=(1., 2., 3., 4.))
print("HET-OK")
""")
    assert "HET-OK" in out, out


@pytest.mark.slow
def test_round_parity_with_adapter_dropout():
    """cfg.lora_dropout > 0 on the production path: threading ``rng``
    into the round draws the simulator's exact per-step/per-client
    dropout keys (micro_batches=1), so the round parity gate extends to
    dropout-on training — including over the compressed q8 uplink."""
    out = _run(PARITY_HARNESS + r"""
import dataclasses as _dc
cfg = _dc.replace(cfg, lora_dropout=0.3)


def run_dropout_case(name):
    hp = FedHyper(method=name, n_clients=C, local_steps=T, batch=B,
                  seq_len=S, lr=1e-2)
    sim = FedSim(cfg, hp)
    st = TrainSettings(lr=hp.lr, micro_batches=1, clip=hp.clip, remat=False,
                       method=name, local_steps=T)
    step_fn, _ = make_fed_train_step(cfg, mesh, st)
    na, no = sim.client_adapters, sim.opt_state
    step0 = jnp.zeros((), jnp.int32)
    for r in range(ROUNDS):
        batches = make_batches()
        big = {k: jnp.concatenate([b[k] for b in batches], axis=1)
               for k in batches[0]}
        na, no, met = step_fn(sim.base, na, no, step0, big,
                              rng=jax.random.PRNGKey(r))
        sim.run_round(batches, jax.random.PRNGKey(r))
        step0 = step0 + T
        assert np.isfinite(float(met["ce"])), (name, r)
    compare(name, na, sim.client_adapters)
    print("DROPOUT-OK", name)


run_dropout_case("lora")
run_dropout_case("lora_fedavg_q8")
""")
    assert out.count("DROPOUT-OK") == 2, out


@pytest.mark.slow
def test_pipeline_parity_with_dropout():
    """cfg.lora_dropout > 0 through ALL THREE pipeline stages: stage 1
    takes ``rng`` in round_step, stages 2/3 take their own rng (the
    simulator's ``global_stage`` / ``personalize`` key chains —
    ``fold_in(rng, step)`` unsplit and ``split(fold_in(rng, 31+step),
    C)[client]`` respectively), so the full-pipeline parity gate extends
    to dropout-on training.  A stage-2 rng also forces the replicated
    stage-2 path (sharded rows would redraw different masks)."""
    out = _run(PARITY_HARNESS + r"""
import dataclasses as _dc
cfg = _dc.replace(cfg, lora_dropout=0.3)


def run_pipeline_dropout_case(name):
    from repro.launch.train import make_fed_pipeline_step
    method = get_method(name)
    hp = FedHyper(method=name, n_clients=C, local_steps=T, batch=B,
                  seq_len=S, lr=1e-2, server_lr=5e-3, global_steps=TG,
                  personal_steps=TP, lam=1e-2)
    sim = FedSim(cfg, hp)
    st = TrainSettings(lr=hp.lr, micro_batches=1, clip=hp.clip, remat=False,
                       method=name, local_steps=T, server_lr=hp.server_lr,
                       global_steps=TG, personal_steps=TP, lam=hp.lam)
    pipe = make_fed_pipeline_step(cfg, mesh, st)
    na, no = sim.client_adapters, sim.opt_state
    step0 = jnp.zeros((), jnp.int32)
    anchor = None
    for r in range(ROUNDS):
        cb, sb = make_batches(), make_server_batches(TG)
        pb = (make_batches() + make_batches())[:TP]
        na, no, agg_p, met = pipe.round_step(
            sim.base, na, no, step0, flat(cb, 1), anchor,
            jax.random.PRNGKey(r))
        anchor = na if method.prox else None
        agg_p, na, _ = pipe.global_step(sim.base, agg_p, na, flat(sb, 0),
                                        jax.random.PRNGKey(100 + r))
        na, _ = pipe.personal_step(sim.base, na, flat(pb, 1),
                                   jax.random.PRNGKey(200 + r))

        sim.local_round(cb, jax.random.PRNGKey(r))
        agg_s = sim.aggregate()
        agg_s = sim.global_stage(agg_s, sb, jax.random.PRNGKey(100 + r))
        sim.personalize(pb, jax.random.PRNGKey(200 + r))
        step0 = step0 + T
        assert np.isfinite(float(met["ce"])), (name, r)
    compare(name, na, sim.client_adapters)
    compare(name, agg_p, agg_s)
    print("PIPE-DROPOUT-OK", name)


run_pipeline_dropout_case("lora")
run_pipeline_dropout_case("fedlora_opt")
""", timeout=1800)
    assert out.count("PIPE-DROPOUT-OK") == 2, out


@pytest.mark.slow
def test_pipeline_stage2_sharded_server_batch():
    """When the replicated server batch divides evenly over the client
    axis, stage 2 shards rows across clients and recovers the full-batch
    gradient with a token-weighted psum — the pipeline must still match
    the simulator's replicated stage-2 math (dp× fewer FLOPs is a pure
    layout change)."""
    out = _run(PARITY_HARNESS + r"""
# widen the server batches so TG·B_srv (= 8) divides over C=4 shards
# and the sharded stage-2 path engages (the default B=2 batches leave
# it on the replicated fallback)
def make_server_batches(n):
    return [{"tokens": jnp.asarray(
                 rng.integers(5, cfg.vocab_size, size=(4, S)), jnp.int32),
             "loss_mask": jnp.ones((4, S), jnp.float32)}
            for _ in range(n)]


run_pipeline_case("lora")
run_pipeline_case("fedlora_opt")
print("STAGE2-SHARD-OK")
""", timeout=1800)
    assert "STAGE2-SHARD-OK" in out, out


@pytest.mark.slow
def test_pipeline_parity_all_methods():
    """The full three-stage pipeline (stage-1 round → stage-2 global
    optimizer on replicated server batches → stage-3 per-client
    personalization) matches the FedSim sequence ``run_round →
    global_stage → personalize`` for every registry method over 2 full
    iterations — final client adapters AND the aggregated server model;
    keep-local leaves are verified untouched by stage 2."""
    out = _run(PARITY_HARNESS + r"""
names = available_methods()
for name in names:
    m = get_method(name)
    run_pipeline_case(name, prox_mu=0.05 if m.prox else 0.0)
print("PIPE-SWEPT", len(names))
""", timeout=1800)
    assert "PIPE-SWEPT 14" in out, out


@pytest.mark.slow
def test_pipeline_parity_het_and_weighted_fleets():
    """Mixed-rank and data-size-weighted fleets through the full
    pipeline: stage 2 trains the server model at the full allocated rank
    and the rebroadcast re-masks each client to its own rank; stage 3
    masks every personalization update the same way the simulator
    does."""
    out = _run(PARITY_HARNESS + r"""
run_pipeline_case("fedlora_opt", ranks=(1, 2, 3, 4))
run_pipeline_case("lora_zeropad", ranks=(1, 2, 3, 4))
run_pipeline_case("lora_replication", ranks=(1, 2, 3, 4),
                  weights=(1., 2., 3., 4.))
run_pipeline_case("lora_exact", ranks=(1, 2, 3, 4), weights=(4., 3., 2., 1.))
run_pipeline_case("fedalt", ranks=(2, 4, 4, 2))
run_pipeline_case("lora", weights=(1., 2., 3., 4.))
print("PIPE-HET-OK")
""", timeout=1800)
    assert "PIPE-HET-OK" in out, out


@pytest.mark.slow
def test_collective_parity_faulted_and_async_rounds():
    """Cohort-fault parity: the production round with participation /
    staleness / update_scale vectors matches ``FedSim.run_cohort_round``
    on identical state across three aggregation classes — weighted
    FedAvg with dropouts, trimmed-mean with corrupted-update
    adversaries, and FedBuff staleness-discounted (async/buffered)
    rounds.  Fault vectors change per round, so the static ``use_faults``
    gate and the call-time weight threading both get exercised across a
    retrace boundary."""
    out = _run(PARITY_HARNESS + r"""
def run_fault_case(name, weights=None, fault_rounds=()):
    reseed(name)
    hp = FedHyper(method=name, n_clients=C, local_steps=T, batch=B,
                  seq_len=S, lr=1e-2, client_weights=weights)
    sim = FedSim(cfg, hp)
    st = TrainSettings(lr=hp.lr, micro_batches=1, clip=hp.clip, remat=False,
                       method=name, local_steps=T, client_weights=weights)
    step_fn, _ = make_fed_train_step(cfg, mesh, st)
    na, no = sim.client_adapters, sim.opt_state
    step0 = jnp.zeros((), jnp.int32)
    bytes_before = sim.comm_bytes
    for r, f in enumerate(fault_rounds):
        batches = make_batches()
        big = {k: jnp.concatenate([b[k] for b in batches], axis=1)
               for k in batches[0]}
        def arr(k):
            v = f.get(k)
            return None if v is None else jnp.asarray(v, jnp.float32)
        na, no, met = step_fn(sim.base, na, no, step0, big,
                              participation=arr("participation"),
                              staleness=arr("staleness"),
                              update_scale=arr("update_scale"))
        sim.run_cohort_round(batches, jax.random.PRNGKey(r),
                             participation=f.get("participation"),
                             staleness=f.get("staleness"),
                             update_scale=f.get("update_scale"))
        step0 = step0 + T
        assert np.isfinite(float(met["ce"])), (name, r)
    compare(name, na, sim.client_adapters)
    # billing followed participation: only live clients paid the wire
    live = sum(sum(1 for p in f.get("participation", (1.,) * C) if p > 0)
               for f in fault_rounds)
    assert sim.comm_bytes - bytes_before == live * sim.client_comm_bytes(), \
        (name, sim.comm_bytes - bytes_before, live)
    print("FAULT-OK", name)


run_fault_case("lora", weights=(1., 2., 3., 4.),
               fault_rounds=[{"participation": (1., 0., 1., 1.)},
                             {"participation": (0., 1., 1., 0.)}])
run_fault_case("lora_trimmed",
               fault_rounds=[{"participation": (1., 1., 1., 1.),
                              "update_scale": (1., 25., 1., 1.)},
                             {"participation": (1., 0., 1., 1.),
                              "update_scale": (1., 1., 40., 1.)}])
run_fault_case("lora_fedbuff",
               fault_rounds=[{"participation": (1., 1., 0., 1.),
                              "staleness": (0., 2., 5., 1.)},
                             {"participation": (1., 1., 1., 0.),
                              "staleness": (3., 0., 0., 7.)}])
""")
    assert out.count("FAULT-OK") == 3, out


def test_fed_train_step_rejects_bad_fleets():
    """Fleet-shape validation fires at construction (shared with FedSim
    via peft.fleet_alloc_rank), and aggregators without a collective form
    are rejected before tracing."""
    from repro.core import aggregation as fedagg
    from repro.core.methods import FedMethod
    from repro.core.peft import fleet_alloc_rank
    from repro.launch.mesh import make_client_mesh
    from repro.launch.train import make_fed_train_step, TrainSettings
    from repro.models.config import ArchConfig

    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=32,
                     n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
                     dtype="float32", lora_rank=4, lora_dropout=0.0)
    mesh = make_client_mesh(1)
    with pytest.raises(ValueError, match="entries for"):
        make_fed_train_step(cfg, mesh, TrainSettings(
            method="lora", client_ranks=(2, 4)))
    with pytest.raises(ValueError, match="entries for"):
        make_fed_train_step(cfg, mesh, TrainSettings(
            method="lora", client_weights=(1.0, 2.0)))
    with pytest.raises(ValueError, match="het_ranks=False"):
        make_fed_train_step(cfg, mesh, TrainSettings(
            method="prompt", client_ranks=(4,)))
    with pytest.raises(ValueError, match="below the fleet max"):
        fleet_alloc_rank((2, 8), 2, server_rank=4)
    custom = FedMethod(name="custom", make_adapter=lambda *a, **k: {},
                       train_mask=lambda t: t, aggregate=lambda t: t)
    with pytest.raises(ValueError, match="no shard_map collective form"):
        fedagg.collective_form(custom)
    # fedavg_excluding is only WMEAN-expressible when the excluded leaves
    # are exactly the keep-local set (the restore overwrites them); any
    # other exclude_rx would silently average leaves the simulator zeroes
    import functools
    mismatched = FedMethod(
        name="excl", make_adapter=lambda *a, **k: {},
        train_mask=lambda t: t,
        aggregate=functools.partial(fedagg.fedavg_excluding,
                                    exclude_rx=r"foo$"),
        keep_local=r"bar$")
    with pytest.raises(ValueError, match="no shard_map collective form"):
        fedagg.collective_form(mismatched)


# ---------------------------------------------------------------------------
# model-parallel tests (partial-auto shard_map; jax >= 0.6 only)
# ---------------------------------------------------------------------------


@NEEDS_PARTIAL_AUTO
def test_fed_train_step_dense_and_moe_debug_mesh():
    out = _run("""
import jax, jax.numpy as jnp
from repro.launch.mesh import make_debug_mesh, dp_size
from repro.launch.train import make_fed_train_step, TrainSettings
from repro.models.config import ArchConfig
from repro.models import model as M
from repro.core import peft, aggregation as agg

mesh = make_debug_mesh(4, 2)
for fam_kw in [dict(family="dense"), dict(family="moe", n_experts=4, top_k=2)]:
    cfg = ArchConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
                     lora_rank=4, lora_dropout=0.0, **fam_kw)
    C = dp_size(mesh)
    base = M.init_params(jax.random.PRNGKey(0), cfg)
    ad = peft.add_lora(base, cfg, jax.random.PRNGKey(1), decomposed=True)
    adapters = agg.broadcast_to_clients(ad, C)
    with jax.set_mesh(mesh):
        fn, opt_init = make_fed_train_step(cfg, mesh, TrainSettings(micro_batches=2))
        ost = opt_init(adapters)
        batch = {"tokens": jnp.ones((C, 4, 32), jnp.int32),
                 "loss_mask": jnp.ones((C, 4, 32), jnp.float32)}
        na, no, met = jax.jit(fn)(base, adapters, ost, jnp.zeros((), jnp.int32), batch)
        assert jnp.isfinite(met["ce"]), fam_kw
        # aggregation: shared components identical across clients
        leaf = jax.tree.leaves(na)[0]
        import numpy as np
        for c in range(1, C):
            np.testing.assert_allclose(np.asarray(leaf[c]), np.asarray(leaf[0]), rtol=1e-5)
    print("OK", fam_kw)
""")
    assert out.count("OK") == 2


@NEEDS_PARTIAL_AUTO
def test_moe_ep_matches_local_math():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_debug_mesh
from repro.models.config import ArchConfig
from repro.models.layers import moe_ffn_ep, moe_ffn_local
cfg = ArchConfig(name="m", family="moe", n_layers=1, d_model=32, n_heads=2,
                 n_kv_heads=1, d_ff=64, vocab_size=64, dtype="float32",
                 n_experts=4, top_k=2, capacity_factor=8.0)
mesh = make_debug_mesh(4, 2)
k = jax.random.split(jax.random.PRNGKey(0), 4)
p = {"router": {"kernel": jax.random.normal(k[0], (32, 4)) * 0.2},
     "experts": {"gate": jax.random.normal(k[1], (4, 32, 64)) * 0.2,
                 "up": jax.random.normal(k[2], (4, 32, 64)) * 0.2,
                 "down": jax.random.normal(k[3], (4, 64, 32)) * 0.2}}
x = jax.random.normal(jax.random.PRNGKey(5), (8, 16, 32))
y_loc, _ = moe_ffn_local(p, x, cfg)
with jax.set_mesh(mesh):
    y_ep, _ = jax.jit(lambda p, x: moe_ffn_ep(p, x, cfg, mesh))(p, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_loc), rtol=2e-3, atol=2e-4)
# small-batch (decode-style) replicated path
x1 = jax.random.normal(jax.random.PRNGKey(6), (1, 3, 32))
y1_loc, _ = moe_ffn_local(p, x1, cfg)
with jax.set_mesh(mesh):
    y1_ep, _ = jax.jit(lambda p, x: moe_ffn_ep(p, x, cfg, mesh))(p, x1)
np.testing.assert_allclose(np.asarray(y1_ep), np.asarray(y1_loc), rtol=2e-3, atol=2e-4)
print("OK")
""")


@NEEDS_PARTIAL_AUTO
def test_dryrun_tiny_mesh_smoke():
    """The dry-run machinery end-to-end on a small mesh with a reduced
    arch — exercises lower+compile+analysis without the 512-dev cost."""
    _run("""
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config, InputShape
from repro.launch import specs as SP
from repro.launch.mesh import make_debug_mesh, dp_size
from repro.launch.serve import make_decode_step
from repro.launch import analysis as AN

cfg = get_smoke_config("gemma3-1b")
mesh = make_debug_mesh(4, 2)
shape = InputShape("mini_decode", 64, 8, "decode")
with jax.set_mesh(mesh):
    abs_base = SP.abstract_params(cfg)
    base_sh = SP.param_specs(cfg, mesh, abs_base)
    args, sh = SP.decode_specs(cfg, shape, mesh)
    fn = make_decode_step(cfg, mesh)
    lw = jax.jit(fn, in_shardings=(base_sh, sh["new_token"], sh["cache"],
                                   sh["cache_index"]), out_shardings=None
                 ).lower(abs_base, args["new_token"], args["cache"],
                         args["cache_index"])
    c = lw.compile()
    mem = c.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
    colls = AN.parse_collectives(c.as_text(), (2,))
    fl = AN.analytic_step_flops(cfg, shape)
    assert fl["flops_global"] > 0
    print("OK", colls.get("total", 0) >= 0)
""")
