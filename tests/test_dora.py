"""Property tests for the D-M decomposition + decomposed aggregation.

``hypothesis`` is optional: without it the property tests run over a
deterministic sample of random matrices instead of generated cases.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dora
from repro.core import aggregation as agg

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=30,
        suppress_health_check=list(hypothesis.HealthCheck))
    hypothesis.settings.load_profile("ci")

    mats = hnp.arrays(
        np.float32, st.tuples(st.integers(2, 8), st.integers(2, 8)),
        elements=st.floats(-4, 4, width=32).filter(lambda v: abs(v) > 1e-3))

    def given_mats(check):
        return hypothesis.given(mats)(check)
else:
    def _fallback_mats(n=12):
        rng = np.random.default_rng(42)
        out = []
        for i in range(n):
            shape = (int(rng.integers(2, 9)), int(rng.integers(2, 9)))
            x = rng.uniform(-4, 4, size=shape).astype(np.float32)
            x[np.abs(x) <= 1e-3] = 1e-2
            out.append(x)
        return out

    def given_mats(check):
        return pytest.mark.parametrize(
            "x", _fallback_mats(),
            ids=[f"mat{i}" for i in range(12)])(check)


@given_mats
def test_decompose_recompose_identity(x):
    m, d = dora.decompose(jnp.asarray(x))
    back = dora.recompose(m, d)
    np.testing.assert_allclose(np.asarray(back), x, rtol=2e-5, atol=2e-5)


@given_mats
def test_direction_unit_norm(x):
    _, d = dora.decompose(jnp.asarray(x))
    norms = np.linalg.norm(np.asarray(d), axis=-1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)


@given_mats
def test_magnitude_nonnegative(x):
    m, _ = dora.decompose(jnp.asarray(x))
    assert np.all(np.asarray(m) >= 0)


def test_decompose_stacked_leading_dims():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, 5, 4, 6)),
                    jnp.float32)
    m, d = dora.decompose(x)
    assert m.shape == (3, 5, 4)
    np.testing.assert_allclose(np.asarray(dora.recompose(m, d)),
                               np.asarray(x), rtol=1e-5, atol=1e-5)


def test_eq9_composition_matches_factor_apply():
    """Eq. 9/10: composing (A_dir+dA)·A_mag and B_dir·(B_mag+dB) as a
    materialized ΔW must equal the factor-wise model compute path."""
    rng = np.random.default_rng(1)
    K, r, N, M = 12, 4, 10, 7
    comp = {
        "A_dir": jnp.asarray(rng.normal(size=(K, r)), jnp.float32),
        "A_mag": jnp.asarray(rng.uniform(0.5, 2, size=(K,)), jnp.float32),
        "B_dir": jnp.asarray(rng.normal(size=(r, N)), jnp.float32),
        "B_mag": jnp.asarray(rng.uniform(0.1, 1, size=(r,)), jnp.float32),
        "dA_dir": jnp.asarray(rng.normal(size=(K, r)) * 0.1, jnp.float32),
        "dB_mag": jnp.asarray(rng.normal(size=(r,)) * 0.1, jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    dw = dora.effective_delta_w(comp, scale=2.0)
    y_mat = x @ dw
    from repro.models.layers import lora_delta
    y_fac = lora_delta(comp, x, 2.0)
    np.testing.assert_allclose(np.asarray(y_mat), np.asarray(y_fac),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# aggregation properties (Eqs. 5-8)
# ---------------------------------------------------------------------------

def _client_tree(seed, C=4):
    rng = np.random.default_rng(seed)
    return {"q": {"A_dir": jnp.asarray(rng.normal(size=(C, 6, 3)), jnp.float32),
                  "B_mag": jnp.asarray(rng.uniform(0.2, 1, size=(C, 3)), jnp.float32)}}


def test_fedavg_identical_clients_is_identity():
    t = _client_tree(0)
    same = jax.tree.map(lambda x: jnp.broadcast_to(x[:1], x.shape), t)
    out = agg.decomposed_fedavg(same)
    np.testing.assert_allclose(np.asarray(out["q"]["A_dir"]),
                               np.asarray(same["q"]["A_dir"][0]), rtol=1e-6)


def test_fedavg_linearity():
    a, b = _client_tree(1), _client_tree(2)
    lhs = agg.fedavg(jax.tree.map(lambda x, y: x + y, a, b))
    rhs = jax.tree.map(lambda x, y: x + y, agg.fedavg(a), agg.fedavg(b))
    for lv, rv in zip(jax.tree.leaves(lhs), jax.tree.leaves(rhs)):
        np.testing.assert_allclose(np.asarray(lv), np.asarray(rv), rtol=1e-5)


def test_fedavg_weighted():
    t = _client_tree(3)
    w = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    out = agg.fedavg(t, weights=w)
    np.testing.assert_allclose(np.asarray(out["q"]["A_dir"]),
                               np.asarray(t["q"]["A_dir"][0]), rtol=1e-6)


def test_paper_averages_directions_without_renormalizing():
    """Pinned behaviour: Eqs. 5-8 are plain means — the averaged direction
    is generally NOT unit norm (the paper does not renormalize)."""
    rng = np.random.default_rng(4)
    dirs = rng.normal(size=(4, 5, 3)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=-1, keepdims=True)
    out = np.asarray(agg.decomposed_fedavg(
        {"d": jnp.asarray(dirs)})["d"])
    norms = np.linalg.norm(out, axis=-1)
    assert not np.allclose(norms, 1.0, atol=1e-3)
