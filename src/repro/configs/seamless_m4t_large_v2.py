"""SeamlessM4T-Large v2 — speech/text encoder-decoder transformer backbone
[arXiv:2308.11596].  The conformer/mel frontend is a STUB per the
assignment: input_specs provides precomputed frame embeddings; we build
the 24+24 enc-dec transformer that consumes them.  Assigned vocab 256206
is padded to 256256 (divisible by the 16-way model axis) — noted in
DESIGN.md §10."""
from repro.models.config import ArchConfig, reduced

ARCH = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256256,
    frontend="audio", frontend_tokens=1024,
    source="arXiv:2308.11596",
)
SMOKE = reduced(ARCH)
