from repro.data.synthetic import (  # noqa: F401
    TaskSpec,
    SyntheticInstructionDataset,
    make_dataset_family,
    TASK_TYPES,
)
from repro.data.partition import dirichlet_task_partition  # noqa: F401
from repro.data.loader import batch_iterator, eval_batches  # noqa: F401
from repro.data.tokenizer import HashTokenizer  # noqa: F401
