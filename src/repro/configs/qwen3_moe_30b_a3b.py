"""Qwen3-30B-A3B — 128-expert top-8 MoE, GQA kv=4
[hf:Qwen/Qwen3-30B-A3B].  d_ff=768 is the per-expert FFN width; every
layer's FFN is MoE.  qk-norm per the Qwen3 family."""
from repro.models.config import ArchConfig, reduced

ARCH = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
    vocab_size=151936,
    n_experts=128, top_k=8, qk_norm=True, d_head=128,
    source="hf:Qwen/Qwen3-30B-A3B",
)
SMOKE = reduced(ARCH)
