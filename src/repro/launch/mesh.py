"""Production mesh construction.

Defined as functions (not module constants) so importing never touches
jax device state — smoke tests must keep seeing 1 CPU device; only
dryrun.py sets XLA_FLAGS for 512 placeholder devices before any import.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}
except ImportError:      # older jax: no explicit axis types — meshes are
    _AXIS_KW = lambda n: {}          # Auto by default, importing must work
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod slice: 16×16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: 'data' carries batch + federated clients + expert parallelism;
    'model' is tensor parallel; 'pod' is the cross-silo boundary (only
    adapter aggregation crosses it).  With 512 placeholder devices the
    single-pod mesh uses the first 256.
    """
    import numpy as np
    from jax.sharding import Mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))
    assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes,
                **_AXIS_KW(len(axes)))


def make_debug_mesh(n_data: int = 4, n_model: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for CI-scale distributed tests (8 host devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data // 2, n_model),
                             ("pod", "data", "model"), **_AXIS_KW(3))
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         **_AXIS_KW(2))


def make_client_mesh(n_clients: int):
    """Data-only mesh: one shard per federated client, no tensor-parallel
    axis.  Because every mesh axis is a client axis, the federated train
    step's shard_map region is *fully* manual over it — the layout that
    runs on every jax this repo supports (partial-auto shard_map needs
    ``jax.shard_map``; see ``shard_map_compat``).  The CI --dist lane and
    the 8-virtual-device parity sweep run on this mesh."""
    return jax.make_mesh((n_clients,), ("data",), **_AXIS_KW(1))


def data_axes(mesh) -> tuple[str, ...]:
    from repro.utils.sharding import data_axis_names
    return data_axis_names(mesh)


def dp_size(mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out


# ---------------------------------------------------------------------------
# jax-version compat: the production train step targets jax.shard_map /
# jax.set_mesh (jax >= 0.6); this container ships 0.4.x, where shard_map
# lives in jax.experimental and partial-auto (manual data axes + auto
# model axis) aborts in the SPMD partitioner.  Fully-manual regions work
# on both — so data-only meshes (make_client_mesh) run everywhere, and
# meshes with a model axis require the newer API.
# ---------------------------------------------------------------------------


def shard_map_compat(f, mesh, *, in_specs, out_specs, manual_axes):
    """``jax.shard_map`` manual over ``manual_axes`` (auto elsewhere),
    falling back to ``jax.experimental.shard_map`` on older jax — where
    only fully-manual meshes are supported (partial-auto crashes XLA's
    partitioner on 0.4.x)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=set(manual_axes), check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - frozenset(manual_axes)
    if auto:
        raise NotImplementedError(
            f"partial-auto shard_map (auto axes {sorted(auto)}) requires "
            "jax.shard_map (jax >= 0.6); on this jax use a data-only mesh "
            "(make_client_mesh) so the region is fully manual")
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
