"""Pure-jnp oracles for the batched-LoRA (BGMV) kernels.

These double as the fast vectorized fallback on non-TPU backends (the
Pallas interpreter is an emulator — fine for validation, far too slow
for the serving hot path).  Op order deliberately mirrors
``models.layers.lora_delta`` so a mixed-tenant batch through the pooled
path reproduces the per-tenant merged-adapter path bit-for-bit in
float32:

  pairs      y[i] = (x[i] @ A[idx[i]]) @ B[idx[i]] · scale
  magnitude  y[i] = (((x[i] ⊙ A_mag) @ A_dir) ⊙ (B_mag + Δmag[idx[i]]))
                     @ B_dir · scale

Heterogeneous pools: ``ranks`` (L,) int32 masks the low-rank
intermediate at columns ≥ the row's slot rank (same op position as the
Pallas kernels' mask), so padded or stale rows above a tenant's own rank
contribute exactly nothing — on the magnitude path that includes the
shared B_mag rows, serving each tenant its own rank-slice of the shared
model (and the rank-0 null slot nothing).
"""
from __future__ import annotations

import jax.numpy as jnp


def _rank_keep(h, idx, ranks):
    """(B, S, r) keep-mask for per-row slot ranks."""
    rr = jnp.take(jnp.asarray(ranks, jnp.int32), idx, axis=0)    # (B,)
    return jnp.arange(h.shape[-1])[None, None, :] < rr[:, None, None]


def bgmv_ref(x, a_pool, b_pool, idx, scale: float = 1.0, ranks=None):
    """x (B, S, d_in), a_pool (L, d_in, r), b_pool (L, r, d_out),
    idx (B,) → (B, S, d_out)."""
    a = jnp.take(a_pool, idx, axis=0).astype(x.dtype)     # (B, d_in, r)
    b = jnp.take(b_pool, idx, axis=0).astype(x.dtype)     # (B, r, d_out)
    h = jnp.einsum("bsd,bdr->bsr", x, a)
    if ranks is not None:
        h = jnp.where(_rank_keep(h, idx, ranks), h, 0.0)
    return jnp.einsum("bsr,bro->bso", h, b) * scale


def bgmv_mag_ref(x, a_dir, a_mag, b_mag, dmag_pool, b_dir, idx,
                 scale: float = 1.0, ranks=None):
    """Decomposed-DoRA magnitude path; shared directions + magnitudes,
    per-row raw-delta gather.  Shapes as in bgmv_mag_matmul."""
    h = (x * a_mag.astype(x.dtype)) @ a_dir.astype(x.dtype)   # (B, S, r)
    m = b_mag[None] + jnp.take(dmag_pool, idx, axis=0)        # (B, r)
    h = h * m[:, None, :].astype(x.dtype)
    if ranks is not None:
        h = jnp.where(_rank_keep(h, idx, ranks), h, 0.0)
    return (h @ b_dir.astype(x.dtype)) * scale
