"""Table II — LoRA hyperparameters: rank r × number of adapted modules n.

Paper sweeps r×n on the Causal task (Dolly); n is the number of adapted
attention projections (n=1: Q; n=2: Q,V — the paper's default; n=4:
Q,K,V,O).  Reports Causal-task accuracy + trainable-parameter fraction.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks.common import BENCH_CFG, bench_base, build_setting
from repro.core.fedlora import run_federated
from repro.fed.simulate import FedHyper
from repro.utils import pytree as pt
from repro.core import peft

GRID = [(4, 1), (8, 1), (16, 1), (8, 2), (4, 4)]
N_TARGETS = {1: ("q_proj",), 2: ("q_proj", "v_proj"),
             4: ("q_proj", "k_proj", "v_proj", "o_proj")}


def run(rounds: int = 5, log=print) -> list[dict]:
    base = bench_base("dolly", log=lambda s: log(f"  {s}"))
    cds, sds, eg, el = build_setting("dolly")
    n_base = pt.tree_count_params(base)
    rows = []
    for r, n in GRID:
        cfg = dataclasses.replace(BENCH_CFG, lora_rank=r,
                                  lora_targets=N_TARGETS[n])
        ad = peft.add_lora(base, cfg, jax.random.PRNGKey(0), decomposed=True)
        # count only live factor params (exclude the dA/dB pipeline deltas)
        n_ad = sum(x.size for p, x in
                   zip(pt.tree_paths(ad), jax.tree.leaves(ad))
                   if not p.endswith(("dA_dir", "dB_mag")))
        hp = FedHyper(method="fedlora_opt", n_clients=len(cds),
                      rounds=rounds, local_steps=3, batch=8, seq_len=48,
                      lr=3e-3, personal_steps=8, global_steps=2, seed=0)
        t0 = time.time()
        res = run_federated(cfg, hp, cds, sds, eg, el, base=base)
        row = {"r": r, "n": n, "acc": res.local_acc,
               "global_acc": res.global_acc,
               "pct_params": 100.0 * n_ad / n_base,
               "wall_s": time.time() - t0}
        rows.append(row)
        log(f"[table2] r={r} n={n}: local_acc={row['acc']:.3f} "
            f"%params={row['pct_params']:.3f}")
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"table2/r{r['r']}xn{r['n']},{r['wall_s']*1e6:.0f},"
              f"acc={r['acc']:.4f};pct_params={r['pct_params']:.4f}")
    return rows


if __name__ == "__main__":
    main()
