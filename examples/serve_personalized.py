"""Multi-tenant personalized serving demo.

    PYTHONPATH=src python examples/serve_personalized.py

One frozen backbone + per-tenant DoRA-decomposed adapters where only the
ΔB_M magnitude vectors differ per tenant (the paper's local-optimizer
output — a few hundred *scalars* per tenant).  Batched prefill + greedy
decode; shows tenants produce different continuations from identical
prompts while sharing every backbone byte.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import peft  # noqa: E402
from repro.launch.serve import greedy_generate, merge_adapters  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models.config import ArchConfig  # noqa: E402
from repro.utils.pytree import (tree_bytes, tree_map_with_path,  # noqa: E402
                                tree_paths)

CFG = ArchConfig(name="serve-demo", family="dense", n_layers=4, d_model=256,
                 n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=1024,
                 dtype="float32", lora_rank=8, lora_dropout=0.0)


def main():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    shared = peft.add_lora(params, CFG, jax.random.PRNGKey(1),
                           decomposed=True)
    backbone_b = tree_bytes(params)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(5, CFG.vocab_size, size=(4, 24)),
                          jnp.int32)
    print(f"backbone: {backbone_b/1e6:.1f} MB shared across tenants")
    for tenant in range(3):
        # per-tenant personalization = only the dB_mag leaves
        ad = tree_map_with_path(
            lambda p, x: x + 0.3 * (tenant + 1) * jnp.sign(
                jnp.sin(jnp.arange(x.size, dtype=jnp.float32) + tenant)
            ).reshape(x.shape) if p.endswith("dB_mag") else x, shared)
        per_tenant_b = sum(
            x.size * 4 for p, x in zip(tree_paths(ad), jax.tree.leaves(ad))
            if p.endswith("dB_mag"))
        merged = merge_adapters(params, ad)
        out = greedy_generate(merged, {"tokens": prompts}, CFG, n_new=8)
        print(f"tenant {tenant}: ΔB_M payload={per_tenant_b} B  "
              f"first-request tokens: {np.asarray(out[0]).tolist()}")


if __name__ == "__main__":
    main()
