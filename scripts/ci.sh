#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): the full test suite with src on PYTHONPATH.
#
#   scripts/ci.sh              # full suite (includes serving + het + dist)
#   scripts/ci.sh --serve      # fast path: multi-tenant serving subsystem
#                              # only (BGMV kernel, AdapterStore, engine)
#   scripts/ci.sh --het        # heterogeneous-rank subsystem: aggregation
#                              # property suite, mixed-rank round/serving
#                              # parity, het checkpoint coverage
#   scripts/ci.sh --dist       # distributed subsystem: shard_map collective
#                              # round + three-stage pipeline vs FedSim
#                              # parity sweeps on 8 virtual host devices
#                              # (tests spawn their own subprocess with the
#                              # XLA flag)
#   scripts/ci.sh --quant      # quantized hot paths: int8/int4 codecs +
#                              # dequant-fused matmul + quantized serving
#                              # (test_quant.py), compressed-uplink
#                              # aggregation laws + comm billing
#   scripts/ci.sh --obs        # telemetry layer: registry/event-log units,
#                              # disabled-sink engine invariance, report
#                              # round-trip (test_obs.py) + the checkpoint
#                              # migration shim tests
#   scripts/ci.sh --scale      # cross-device-scale federation: client
#                              # bank + cohort sampling + fault injection
#                              # + straggler billing (test_cohort.py),
#                              # plus the faulted/async production-vs-
#                              # oracle parity case from the dist suite
#   scripts/ci.sh --tier       # tiered adapter pool: T2→T1→T0 promotion
#                              # parity, queue-informed eviction, async
#                              # prefetch determinism, tier checkpoints
#                              # (test_tiered_store.py) + the flat-pool
#                              # base suite it extends
#   scripts/ci.sh --lint       # repo-aware static analyzer: repro.lint
#                              # rules R1–R5 over src/repro (zero
#                              # unsuppressed findings beyond the
#                              # justified .lint-baseline.json) + the
#                              # rule/runner/sanitizer test suite
#                              # (test_lint_rules.py)
#   scripts/ci.sh --fast       # tier-1 minus the slow sweeps and the
#                              # multi-device dist tests
#                              # (-m 'not slow and not dist')
#
# Markers (slow, dist) are registered in pyproject.toml.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
case "${1:-}" in
  --serve)
    shift
    exec python -m pytest -x -q tests/test_batched_lora.py \
      tests/test_adapter_store.py tests/test_serve_engine.py "$@"
    ;;
  --het)
    shift
    exec python -m pytest -x -q tests/test_aggregation_properties.py \
      tests/test_het_ckpt.py tests/test_methods.py \
      tests/test_batched_lora.py tests/test_serve_engine.py "$@"
    ;;
  --dist)
    shift
    # the multi-device tests re-exec themselves in a subprocess under
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 (XLA locks the
    # device count at first init, and conftest keeps the parent process
    # single-device on purpose)
    exec python -m pytest -x -q -m dist tests/test_distributed.py "$@"
    ;;
  --quant)
    shift
    # serving quant (codecs, kernel-vs-oracle, quantize_backbone,
    # quantized engine) + the compressed-uplink side (codec property
    # laws in test_aggregation_properties.py, billing + round behaviour
    # in test_fed.py)
    exec python -m pytest -x -q tests/test_quant.py \
      tests/test_aggregation_properties.py tests/test_fed.py "$@"
    ;;
  --obs)
    shift
    # the telemetry suite owns the zero-cost-when-disabled contract;
    # the adapter-store file rides along for the pool_B_mag migration
    # shim (its warning path emits ckpt_migrate events)
    exec python -m pytest -x -q tests/test_obs.py \
      tests/test_adapter_store.py "$@"
    ;;
  --scale)
    shift
    # host-side orchestration suite + the one dist-suite case that pins
    # the faulted/async cohort numerics to the shard_map engine (selected
    # by node id, so the module's dist marker doesn't gate it here; it
    # re-execs itself under the 8-device XLA flag like the rest of the
    # dist lane)
    exec python -m pytest -x -q tests/test_cohort.py \
      "tests/test_distributed.py::test_collective_parity_faulted_and_async_rounds" \
      "$@"
    ;;
  --tier)
    shift
    # the tiered store subclasses the flat pool, so the base suite rides
    # along: a base-class regression (slot math, packing, eviction) is a
    # tier regression even when the tiered file still passes
    exec python -m pytest -x -q tests/test_tiered_store.py \
      tests/test_adapter_store.py "$@"
    ;;
  --lint)
    shift
    # the analyzer must exit 0 on the merged tree (ISSUE 10 acceptance
    # criterion) before the fixture/runner suite runs
    python -m repro.lint src/repro
    exec python -m pytest -x -q tests/test_lint_rules.py "$@"
    ;;
  --fast)
    shift
    # dist excluded too: the multi-device subprocess tests are the dist
    # lane's job (on new jax they compile multi-device programs for
    # minutes and would double up the matrix's heaviest work)
    exec python -m pytest -x -q -m "not slow and not dist" "$@"
    ;;
esac
exec python -m pytest -x -q "$@"
