"""Shared infrastructure for repro.lint rules: findings, the rule base
class, and the per-module AST index (imports, scopes, jit reachability).

Every rule is a class with a unique ``code`` (R1..R5), registered in
``repro.lint.rules`` exactly like a ``FedMethod`` in ``core.methods``.
A rule implements either or both hooks:

  check_module(mod)   called once per parsed source file (AST rules)
  check_project(ctx)  called once per lint run (whole-repo rules, e.g.
                      R5's live-registry dead-mask evaluation)

The jit-reachability index is module-local on purpose: a function is
"jit-reachable" when it is (a) passed to ``jax.jit`` / ``jax.vmap`` /
``jax.pmap`` / ``shard_map`` / ``shard_map_compat`` / ``jax.lax.scan``
(possibly through ``functools.partial`` or ``obs.annotate(...)(...)``),
(b) decorated with a jit wrapper, or (c) referenced by name from the
body of another jit-reachable function in the same module.  Cross-module
tracing (``model.forward`` called from a jitted round body) is out of
scope — the callee module's own ``lax.scan`` entry points cover the hot
paths there.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer hit.  ``path`` is repo-relative (posix separators);
    ``line``/``col`` are 1-based/0-based as in CPython's ast."""
    rule: str
    path: str
    line: int
    col: int
    message: str
    # the stripped source line the finding sits on — baseline entries
    # match on (rule, path, line_text) so they survive line-number drift
    line_text: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def sig(self) -> tuple:
        return (self.rule, self.path, self.line_text)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


class Rule:
    """Base class for lint rules (see module docstring for the hooks)."""
    code: str = "R0"
    name: str = ""
    description: str = ""

    def check_module(self, mod: "ModuleInfo") -> list[Finding]:
        return []

    def check_project(self, ctx: "ProjectContext") -> list[Finding]:
        return []


@dataclasses.dataclass
class ProjectContext:
    """Whole-run context handed to ``Rule.check_project``."""
    root: str                      # repo root (directory of pyproject.toml)
    modules: list                  # every parsed ModuleInfo in the run

    def module(self, rel_suffix: str) -> Optional["ModuleInfo"]:
        """Find a parsed module by repo-relative path suffix."""
        for m in self.modules:
            if m.rel.endswith(rel_suffix):
                return m
        return None


# ---------------------------------------------------------------------------
# AST helpers
# ---------------------------------------------------------------------------

def dotted_name(node) -> str:
    """``jax.lax.scan`` for an Attribute chain, ``jit`` for a Name,
    '' for anything dynamic."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def last_seg(node) -> str:
    d = dotted_name(node)
    return d.rsplit(".", 1)[-1] if d else ""


def func_operand(node) -> Optional[ast.Name]:
    """Unwrap an expression to the function-valued Name it forwards:
    ``f`` / ``partial(f, ...)`` / ``jax.jit(f)`` / ``annotate(..)(jit(f))``."""
    if isinstance(node, ast.Name):
        return node
    if isinstance(node, ast.Call) and node.args:
        nm = last_seg(node.func)
        if nm in ("partial", "jit", "vmap", "pmap", "checkpoint", "remat"):
            return func_operand(node.args[0])
        if isinstance(node.func, ast.Call):        # annotate(...)(inner)
            return func_operand(node.args[0])
    return None


_JIT_WRAPPERS = ("jit", "vmap", "pmap", "shard_map", "shard_map_compat")
FunctionNode = (ast.FunctionDef, ast.AsyncFunctionDef)


def jit_entry_operands(call: ast.Call) -> list:
    """Expressions passed as traced bodies to this call, if it is a jit
    wrapper / scan; [] otherwise."""
    nm = last_seg(call.func)
    dotted = dotted_name(call.func)
    if nm in _JIT_WRAPPERS and call.args:
        return [call.args[0]]
    if nm == "scan" and call.args and ("lax" in dotted or dotted == "scan"):
        return [call.args[0]]
    return []


def is_jit_decorator(dec) -> bool:
    if isinstance(dec, (ast.Name, ast.Attribute)):
        return last_seg(dec) in ("jit", "vmap", "pmap")
    if isinstance(dec, ast.Call):
        nm = last_seg(dec.func)
        if nm in ("jit", "vmap", "pmap"):
            return True
        if nm == "partial" and dec.args:
            return last_seg(dec.args[0]) in ("jit", "vmap", "pmap")
    return False


def walk_skip_nested(fn) -> list:
    """All descendant nodes of a function def, not descending into nested
    function/class defs (their bodies are separate analysis units)."""
    out: list = []
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        out.append(node)
        if isinstance(node, FunctionNode + (ast.ClassDef,)):
            continue
        stack.extend(ast.iter_child_nodes(node))
    return out


class _Scope:
    """One lexical scope (module or function): its immediate function
    defs and its simple function aliases (``x = partial(f, ...)``)."""

    def __init__(self, node, parent: Optional["_Scope"]):
        self.node = node
        self.parent = parent
        self.defs: dict[str, ast.AST] = {}
        self.aliases: dict[str, str] = {}

    def resolve(self, name: str):
        scope: Optional[_Scope] = self
        seen = set()
        while scope is not None:
            if name in scope.defs:
                return scope.defs[name]
            if name in scope.aliases and name not in seen:
                seen.add(name)
                name = scope.aliases[name]
                continue
            scope = scope.parent
        return None


class ModuleInfo:
    """One parsed source file plus lazily-built analysis indexes."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.parents: dict[int, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[id(child)] = node
        # import aliases: {"np": "numpy", "jnp": "jax.numpy", ...}
        self.imports: dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.imports[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.imports[a.asname or a.name] = \
                        f"{node.module}.{a.name}"
        self._scopes: Optional[dict[int, _Scope]] = None
        self._reachable: Optional[list] = None

    # -- plumbing ---------------------------------------------------------

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, node, message: str) -> Finding:
        return Finding(rule=rule, path=self.rel, line=node.lineno,
                       col=node.col_offset, message=message,
                       line_text=self.line_text(node.lineno))

    def enclosing_function(self, node):
        cur = self.parents.get(id(node))
        while cur is not None and not isinstance(cur, FunctionNode):
            cur = self.parents.get(id(cur))
        return cur

    def numpy_aliases(self) -> set[str]:
        return {alias for alias, mod in self.imports.items()
                if mod == "numpy" or mod.startswith("numpy.")}

    # -- scopes -----------------------------------------------------------

    def scopes(self) -> dict[int, _Scope]:
        if self._scopes is not None:
            return self._scopes
        scopes: dict[int, _Scope] = {}

        def build(node, parent_scope):
            scope = _Scope(node, parent_scope)
            scopes[id(node)] = scope
            for sub in walk_skip_nested(node) if isinstance(
                    node, FunctionNode) else ast.walk(node):
                if sub is node:
                    continue
                if isinstance(sub, FunctionNode):
                    owner = self.enclosing_function(sub)
                    owner_scope = scopes.get(id(owner)) if owner else \
                        scopes[id(self.tree)]
                    if owner_scope is scope or (owner is None
                                                and node is self.tree):
                        scope.defs[sub.name] = sub
                elif isinstance(sub, ast.Assign) and len(sub.targets) == 1 \
                        and isinstance(sub.targets[0], ast.Name):
                    op = func_operand(sub.value)
                    if op is not None and op.id != sub.targets[0].id:
                        scope.aliases[sub.targets[0].id] = op.id

        # module scope first (walks everything for module-level defs is
        # wrong — restrict to statement-level recursion)
        def build_exact(node, parent_scope):
            scope = _Scope(node, parent_scope)
            scopes[id(node)] = scope
            for sub in walk_skip_nested(node) if isinstance(
                    node, FunctionNode) else self._walk_module_level(node):
                if isinstance(sub, FunctionNode):
                    scope.defs[sub.name] = sub
                elif isinstance(sub, ast.Assign) and len(sub.targets) == 1 \
                        and isinstance(sub.targets[0], ast.Name):
                    op = func_operand(sub.value)
                    if op is not None and op.id != sub.targets[0].id:
                        scope.aliases[sub.targets[0].id] = op.id
            for name, fn in scope.defs.items():
                build_exact(fn, scope)

        build_exact(self.tree, None)
        self._scopes = scopes
        return scopes

    def _walk_module_level(self, node) -> list:
        """Module/class statements, not descending into function defs
        (class bodies are transparent: methods resolve like module-level
        defs for reachability purposes)."""
        out: list = []
        stack = list(ast.iter_child_nodes(node))
        while stack:
            sub = stack.pop()
            out.append(sub)
            if isinstance(sub, FunctionNode):
                continue
            stack.extend(ast.iter_child_nodes(sub))
        return out

    def scope_of(self, node) -> _Scope:
        scopes = self.scopes()
        fn = node if isinstance(node, FunctionNode) else \
            self.enclosing_function(node)
        while fn is not None:
            s = scopes.get(id(fn))
            if s is not None:
                return s
            fn = self.enclosing_function(fn)
        return scopes[id(self.tree)]

    # -- jit reachability -------------------------------------------------

    def jit_reachable(self) -> list:
        """Function defs traced under jit/vmap/shard_map/scan (see module
        docstring for the exact contract)."""
        if self._reachable is not None:
            return self._reachable
        entries: list = []
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                for operand in jit_entry_operands(node):
                    op = func_operand(operand)
                    if op is None:
                        continue
                    target = self.scope_of(node).resolve(op.id)
                    if isinstance(target, FunctionNode):
                        entries.append(target)
            elif isinstance(node, FunctionNode):
                if any(is_jit_decorator(d) for d in node.decorator_list):
                    entries.append(node)
        reachable: dict[int, ast.AST] = {}
        stack = entries
        while stack:
            fn = stack.pop()
            if id(fn) in reachable:
                continue
            reachable[id(fn)] = fn
            scope = self.scopes().get(id(fn))
            for node in walk_skip_nested(fn):
                if isinstance(node, ast.Name) and isinstance(
                        node.ctx, ast.Load) and scope is not None:
                    target = scope.resolve(node.id)
                    if isinstance(target, FunctionNode):
                        stack.append(target)
        self._reachable = sorted(reachable.values(), key=lambda f: f.lineno)
        return self._reachable
