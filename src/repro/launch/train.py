"""Production federated train step.

TPU-native mapping of the paper's round (DESIGN.md §4):

  · clients ↔ slices of the ('pod','data') axes — ONE client per data
    shard; each client's decomposed-LoRA adapters live only on its shard;
  · local SGD ↔ per-shard grad/update inside a shard_map that is MANUAL
    over ('pod','data') and AUTO over 'model' (XLA still does tensor
    parallelism inside each client);
  · aggregation (Eqs. 5–8) ↔ an explicit jax.lax.pmean over the data axes
    of the decomposed components — the only cross-client (and the only
    cross-pod) traffic, a few MB of adapter state;
  · ΔB_M stays client-local (personalization is never averaged).

Gradient accumulation: the per-client batch is split into micro-batches
(a lax.scan, so HLO stays one body deep) so scan-boundary activations of
an 88-layer model fit HBM; LoRA grads are accumulated in f32.
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import functools

from repro.core import aggregation as fedagg
from repro.core.methods import get_method
from repro.launch.mesh import data_axes, dp_size
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim import adamw, masked
from repro.optim.optimizers import apply_updates, clip_by_global_norm
from repro.utils import pytree as pt

Params = Any


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    lr: float = 1e-4
    micro_batches: int = 1
    clip: float = 1.0
    remat: object = True          # True (full) | "dots" | False
    # stage: which components train (paper pipeline stages)
    stage: str = "local_pretrain"   # | "global" | "local"
    # federated method (core.methods registry) — drives the adapter
    # factory, the per-stage trainable mask, and the keep-local leaves
    method: str = "fedlora_opt"


def pick_micro_batches(cfg: ArchConfig, per_client_batch: int,
                       seq_len: int, budget_bytes: float = 1.0e9) -> int:
    """Choose grad-accumulation depth so scan-boundary activations
    (n_superblocks × mb × S × D × 2B) stay under budget."""
    n_sb, tail, pattern = cfg.blocks_layout()
    per_mb = (n_sb + 1) * seq_len * cfg.d_model * 2 * len(pattern)
    mb_max = max(1, int(budget_bytes // max(per_mb, 1)))
    micro = max(1, -(-per_client_batch // mb_max))
    while per_client_batch % micro:
        micro += 1
    return min(micro, per_client_batch)


def _pmean_equivalent(method) -> bool:
    """True when the method's aggregate is a plain client mean (what the
    shard_map pmean computes) — directly, or via fedavg_excluding whose
    excluded leaves the keep-local restore keeps per-client anyway.
    ``zeropad_fedavg`` qualifies too: mixed-rank adapters live zero-padded
    at r_max, so the pmean over padded trees IS zero-pad averaging."""
    a = method.aggregate
    if a in (fedagg.fedavg, fedagg.decomposed_fedavg, fedagg.zeropad_fedavg):
        return True
    return (isinstance(a, functools.partial)
            and a.func is fedagg.fedavg_excluding
            and a.keywords.get("exclude_rx") == method.keep_local)


def _stage_mask(method, adapters, stage: str):
    if stage == "global":
        return method.stage_global_mask(adapters)
    if stage == "local":
        return method.stage_local_mask(adapters)
    return method.train_mask(adapters)


def make_fed_train_step(cfg: ArchConfig, mesh, settings: TrainSettings):
    """Returns (train_step, opt_init).  train_step signature:

        train_step(base, adapters, opt_state, step, batch)
            → (adapters, opt_state, metrics)

    base: global param tree (model-sharded, replicated over data axes).
    adapters: leading client axis C = dp_size(mesh), sharded 1-per-shard.
    batch: {"tokens": (C, B_c, S), ...} sharded likewise.
    """
    if cfg.use_fused_dora:
        raise ValueError(
            "use_fused_dora is forward/serving-only (the Pallas kernel "
            "defines no VJP); the train step requires the jnp adapter path")
    daxes = data_axes(mesh)
    dp = dp_size(mesh)
    bspec = daxes if len(daxes) > 1 else daxes[0]
    micro = settings.micro_batches
    is_moe = cfg.n_experts > 0
    method = get_method(settings.method)
    keep_rx = re.compile(method.keep_local) if method.keep_local else None
    # this step's cross-client collective is a pmean with keep-local
    # leaves restored — i.e. client-weighted FedAvg.  Refuse methods whose
    # aggregation or loss semantics that collective cannot express, so a
    # method never silently trains with different math than the simulator.
    if method.prox or not _pmean_equivalent(method):
        raise ValueError(
            f"method {method.name!r} needs aggregation/loss semantics "
            "(custom aggregate or proximal term) that the pmean-based "
            "production train step does not implement; use fed/simulate.py "
            "or extend make_fed_train_step")

    def client_body(base, adapters, opt_state, step, batch):
        # ---- inside the manual region: one client per shard -------------
        adapters = jax.tree.map(lambda x: x[0], adapters)   # drop C axis
        opt_state = jax.tree.map(lambda x: x[0], opt_state)
        batch = {k: v[0] for k, v in batch.items()}
        mesh_tag = ("manual", mesh.shape["data"]) if is_moe else None

        def loss_fn(ad, mb):
            params = pt.merge_trees(base, ad)
            loss, met = M.loss_and_metrics(params, mb, cfg,
                                           mesh=mesh_tag,
                                           remat=settings.remat)
            return loss, met

        # gradient accumulation over micro-batches via lax.scan: one HLO
        # body regardless of depth (an unrolled loop made 88-layer compiles
        # explode), forward-only carry (grads), no cross-step residuals.
        B_c = batch["tokens"].shape[0]
        mb_sz = B_c // micro
        mbatch = {k: v.reshape((micro, mb_sz) + v.shape[1:])
                  for k, v in batch.items()}
        g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                          adapters)

        def acc_body(g_acc, mb):
            (_, met), g = jax.value_and_grad(loss_fn, has_aux=True)(
                adapters, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
            return g_acc, met

        g_acc, mets = jax.lax.scan(acc_body, g0, mbatch)
        met_acc = jax.tree.map(lambda x: jnp.sum(x, axis=0), mets)
        g_acc = jax.tree.map(lambda x: x / micro, g_acc)
        g_acc = clip_by_global_norm(g_acc, settings.clip)

        upd, opt_state = opt.update(g_acc, opt_state, adapters, step)
        adapters = apply_updates(adapters, upd)

        # ---- decomposed aggregation (Eqs. 5-8): pmean of every component
        # EXCEPT the method's keep-local leaves (the paper: personal ΔB_M)
        # — the only cross-client collective.
        agg = jax.tree.map(lambda x: jax.lax.pmean(x, daxes), adapters)
        adapters = (_select_personal(adapters, agg, keep_rx)
                    if keep_rx is not None else agg)
        met_acc = jax.tree.map(lambda x: jax.lax.pmean(x / micro, daxes),
                               met_acc)

        adapters = jax.tree.map(lambda x: x[None], adapters)
        opt_state = jax.tree.map(lambda x: x[None], opt_state)
        return adapters, opt_state, met_acc

    def _select_personal(local, agg, rx):
        return pt.tree_map_with_path(
            lambda p, leaf_agg: _pick(local, p) if rx.search(p) else leaf_agg,
            agg)

    def _pick(tree, path):
        node = tree
        for k in path.split("/"):
            node = node[k]
        return node

    # trainable mask from an abstract adapter tree
    abs_ad = jax.eval_shape(
        lambda: method.make_adapter(abstract_base(cfg), cfg,
                                    jax.random.PRNGKey(0)))
    mask = _stage_mask(method, abs_ad, settings.stage)
    opt = masked(adamw(settings.lr), mask)

    ad_spec = jax.tree.map(lambda _: P(bspec), abs_ad)
    ost_abs = jax.eval_shape(opt.init, abs_ad)
    ost_spec = jax.tree.map(lambda _: P(bspec), ost_abs)

    def batch_spec_of(batch):
        return {k: P(bspec) for k in batch}

    def train_step(base, adapters, opt_state, step, batch):
        body = jax.shard_map(
            partial(client_body),
            mesh=mesh,
            in_specs=(base_manual_specs(base, cfg), ad_spec, ost_spec, P(),
                      batch_spec_of(batch)),
            out_specs=(ad_spec, ost_spec, P()),
            axis_names=set(daxes),
            check_vma=False,
        )
        return body(base, adapters, opt_state, step, batch)

    def opt_init(adapters_c):
        return jax.vmap(opt.init)(adapters_c)

    return train_step, opt_init


def abstract_base(cfg: ArchConfig):
    return jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def base_manual_specs(base, cfg: ArchConfig):
    """Manual specs for the base tree over the DATA axes only: MoE expert
    slots are expert-parallel (manual over 'data'); everything else is
    replicated across clients ('model'-axis sharding stays auto)."""
    def fn(path, x):
        if cfg.n_experts and re.search(r"moe/experts/", path):
            # (n_sb, E_slots, D, F) — E_slots manual over 'data'
            lead = [None] * (len(x.shape) - 3)
            return P(*lead, "data", None, None)
        return P(*([None] * len(x.shape)))

    return pt.tree_map_with_path(fn, base)
