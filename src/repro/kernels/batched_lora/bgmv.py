"""Pallas TPU kernel: batched-gather LoRA (BGMV, Punica / S-LoRA style).

One mixed batch crosses many tenants: row i of ``x`` carries the tokens
of the tenant whose adapter occupies pool slot ``idx[i]``.  The kernel
computes, per row,

    y[i] = scale · (x[i] @ A[idx[i]]) @ B[idx[i]]

without ever merging an adapter into the backbone and without
materializing gathered per-row adapter copies: the index vector rides in
scalar-prefetch memory, so each grid step's BlockSpec index map selects
the right pool slot and the DMA engine streams exactly one
(d_in, r) + (r, d_out) adapter pair per row into VMEM.

Grid: (B, S/bs) — token blocks innermost, so a row's adapter pair keeps
the same block index across its token blocks and Pallas skips the
re-fetch (revisiting an unchanged block index is a no-op DMA).

A second entry point covers the paper's decomposed-DoRA deployment
shape, where tenants share every direction/magnitude factor and differ
only in their RAW per-rank magnitude delta (ΔB_M — a few hundred bytes
per tenant); the effective magnitude forms inside the kernel:

    y[i] = scale · (((x[i] ⊙ A_mag) @ A_dir) ⊙ (B_mag + Δmag[idx[i]])) @ B_dir

Here only the tiny (1, r) delta block is gathered per row; the shared
factors load once and stay VMEM-resident across the whole grid.

Heterogeneous pools: slots may hold adapters of different ranks, padded
to the pool's r_max.  A second scalar-prefetch vector carries each row's
rank and the kernel masks intermediate columns ≥ that rank before the
up-projection — so a freed slot re-registered at a lower rank can never
leak its previous occupant's high-rank rows, and the masked result is
bit-identical to running the tenant's own-rank adapter unpadded.
Because the magnitude pool stores the delta raw, the same mask covers
the magnitude path: a rank-r tenant is served the first r rank rows of
the *shared* model plus its delta (exactly the federated re-mask
semantics), and a rank-0 slot — the null slot, or a freed one —
contributes nothing at all.

VMEM working set (bs=256, d=1024, r=16, f32): x(256·1024) + a(1024·16)
+ b(16·1024) + out(256·1024) ≈ 2.2 MB « 16 MB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _imap(block):
    """Adapt a BlockSpec index map to absorb trailing scalar-prefetch
    refs: index maps see every prefetch operand, and the ranked kernels
    add a per-row rank vector that block selection never consults."""
    def f(i, s, idx_ref, *rest):
        return block(i, s, idx_ref)
    return f


def _bgmv_kernel(idx_ref, x_ref, a_ref, b_ref, o_ref, *, scale: float):
    del idx_ref  # consumed by the BlockSpec index maps
    x = x_ref[0]                                          # (bs, d_in)
    h = jax.lax.dot_general(
        x, a_ref[0].astype(x.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bs, r)
    y = jax.lax.dot_general(
        h.astype(x.dtype), b_ref[0].astype(x.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bs, d_out)
    o_ref[0] = (y * scale).astype(o_ref.dtype)


def _bgmv_ranked_kernel(idx_ref, rank_ref, x_ref, a_ref, b_ref, o_ref, *,
                        scale: float):
    """Mixed-rank variant: a second scalar-prefetch vector carries this
    row's adapter rank; intermediate columns at or above it are masked
    before the up-projection, so a slot padded to r_max — or holding
    stale rows from a previous higher-rank occupant — contributes exactly
    its own rank."""
    del idx_ref
    i = pl.program_id(0)
    x = x_ref[0]                                          # (bs, d_in)
    h = jax.lax.dot_general(
        x, a_ref[0].astype(x.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bs, r_max)
    keep = (jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
            < rank_ref[i])
    h = jnp.where(keep, h, 0.0)
    y = jax.lax.dot_general(
        h.astype(x.dtype), b_ref[0].astype(x.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bs, d_out)
    o_ref[0] = (y * scale).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "bs", "interpret"))
def bgmv_matmul(x, a_pool, b_pool, idx, ranks=None, *, scale: float = 1.0,
                bs: int = 256, interpret: bool = False):
    """x (B, S, d_in), pools (n_slots, d_in, r) / (n_slots, r, d_out),
    idx (B,) int32 → (B, S, d_out) per-row adapter deltas.  ``ranks``
    (n_slots,) int32: per-slot adapter ranks for heterogeneous pools —
    rank rows ≥ ranks[idx[i]] are masked out of row i."""
    B, S, d_in = x.shape
    r = a_pool.shape[-1]
    d_out = b_pool.shape[-1]
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    grid = (B, S // bs)
    ranked = ranks is not None
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2 if ranked else 1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, d_in),
                         _imap(lambda i, s, idx_ref: (i, s, 0))),
            pl.BlockSpec((1, d_in, r),
                         _imap(lambda i, s, idx_ref: (idx_ref[i], 0, 0))),
            pl.BlockSpec((1, r, d_out),
                         _imap(lambda i, s, idx_ref: (idx_ref[i], 0, 0))),
        ],
        out_specs=pl.BlockSpec((1, bs, d_out),
                               _imap(lambda i, s, idx_ref: (i, s, 0))),
    )
    kernel = (functools.partial(_bgmv_ranked_kernel, scale=scale) if ranked
              else functools.partial(_bgmv_kernel, scale=scale))
    args = (idx.astype(jnp.int32),)
    if ranked:
        # gather per-row ranks host-side of the grid: rank_ref[i] in the
        # kernel is then a plain scalar-prefetch load
        args = args + (jnp.take(jnp.asarray(ranks, jnp.int32),
                                idx.astype(jnp.int32), axis=0),)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, d_out), x.dtype),
        interpret=interpret,
    )(*args, x, a_pool, b_pool)


def _bgmv_mag_kernel(idx_ref, x_ref, adir_ref, amag_ref, bmag_ref, dmag_ref,
                     bdir_ref, o_ref, *, scale: float):
    del idx_ref
    x = x_ref[0]                                          # (bs, d_in)
    xs = x * amag_ref[...][None, :].astype(x.dtype)
    h = jax.lax.dot_general(
        xs, adir_ref[...].astype(x.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bs, r)
    # effective magnitude: shared B_mag + this row's raw ΔB_M — the same
    # single addition the merged lora_delta path performs
    h = h * (bmag_ref[...] + dmag_ref[0])[None, :]
    y = jax.lax.dot_general(
        h.astype(x.dtype), bdir_ref[...].astype(x.dtype),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bs, d_out)
    o_ref[0] = (y * scale).astype(o_ref.dtype)


def _bgmv_mag_ranked_kernel(idx_ref, rank_ref, x_ref, adir_ref, amag_ref,
                            bmag_ref, dmag_ref, bdir_ref, o_ref, *,
                            scale: float):
    """Mixed-rank magnitude variant: intermediate columns at or above
    this row's rank are masked AFTER the magnitude product, so a rank-r
    tenant is served the first r rank rows of the shared model plus its
    delta — and a rank-0 (null/freed) slot contributes nothing."""
    del idx_ref
    i = pl.program_id(0)
    x = x_ref[0]                                          # (bs, d_in)
    xs = x * amag_ref[...][None, :].astype(x.dtype)
    h = jax.lax.dot_general(
        xs, adir_ref[...].astype(x.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bs, r)
    h = h * (bmag_ref[...] + dmag_ref[0])[None, :]
    keep = (jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
            < rank_ref[i])
    h = jnp.where(keep, h, 0.0)
    y = jax.lax.dot_general(
        h.astype(x.dtype), bdir_ref[...].astype(x.dtype),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bs, d_out)
    o_ref[0] = (y * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bs", "interpret"))
def bgmv_mag_matmul(x, a_dir, a_mag, b_mag, dmag_pool, b_dir, idx,
                    ranks=None, *, scale: float = 1.0, bs: int = 256,
                    interpret: bool = False):
    """Decomposed-DoRA magnitude path: shared a_dir (d_in, r) /
    a_mag (d_in,) / b_mag (r,) / b_dir (r, d_out); raw-delta pool
    dmag_pool (n_slots, r) gathered per row via idx (B,).
    x (B, S, d_in) → (B, S, d_out).  ``ranks`` (n_slots,) int32 masks
    the magnitude product ≥ the slot's rank (shared rows included)."""
    B, S, d_in = x.shape
    r = a_dir.shape[-1]
    d_out = b_dir.shape[-1]
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    grid = (B, S // bs)
    ranked = ranks is not None
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2 if ranked else 1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, d_in),
                         _imap(lambda i, s, idx_ref: (i, s, 0))),
            pl.BlockSpec((d_in, r), _imap(lambda i, s, idx_ref: (0, 0))),
            pl.BlockSpec((d_in,), _imap(lambda i, s, idx_ref: (0,))),
            pl.BlockSpec((r,), _imap(lambda i, s, idx_ref: (0,))),
            pl.BlockSpec((1, r),
                         _imap(lambda i, s, idx_ref: (idx_ref[i], 0))),
            pl.BlockSpec((r, d_out), _imap(lambda i, s, idx_ref: (0, 0))),
        ],
        out_specs=pl.BlockSpec((1, bs, d_out),
                               _imap(lambda i, s, idx_ref: (i, s, 0))),
    )
    kernel = (functools.partial(_bgmv_mag_ranked_kernel, scale=scale)
              if ranked else functools.partial(_bgmv_mag_kernel, scale=scale))
    args = (idx.astype(jnp.int32),)
    if ranked:
        args = args + (jnp.take(jnp.asarray(ranks, jnp.int32),
                                idx.astype(jnp.int32), axis=0),)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, d_out), x.dtype),
        interpret=interpret,
    )(*args, x, a_dir, a_mag.astype(jnp.float32),
      b_mag.astype(jnp.float32), dmag_pool.astype(jnp.float32), b_dir)
