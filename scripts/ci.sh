#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): the full test suite with src on PYTHONPATH.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
