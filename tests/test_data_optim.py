"""Synthetic-data correctness + optimizer unit tests."""
try:                                  # optional dep: deterministic fallback
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.partition import dirichlet_task_partition, specialist_partition
from repro.data.synthetic import (ANS, SyntheticInstructionDataset,
                                  TASK_TYPES, make_dataset_family)
from repro.optim import adamw, masked, sgd
from repro.optim.optimizers import apply_updates, clip_by_global_norm


@pytest.mark.parametrize("family", ["dolly", "ni"])
@pytest.mark.parametrize("task", TASK_TYPES)
def test_answer_is_recoverable(family, task):
    """The answer token must follow the ANS marker and be marked by the
    loss mask one position earlier (next-token alignment)."""
    fam = make_dataset_family(family)
    ds = SyntheticInstructionDataset(fam, [0.25] * 4, client_seed=3)
    rng = np.random.default_rng(0)
    b = ds.sample_task_batch(rng, 16, 48, task)
    toks, mask = b["tokens"], b["loss_mask"]
    aux = SyntheticInstructionDataset.AUX_LM_WEIGHT
    for i in range(16):
        full = np.where(mask[i] >= 0.999)[0]
        assert len(full) == 1               # exactly one answer position
        pos = int(full[0])
        assert toks[i, pos] == ANS          # mask position predicts next tok
        assert toks[i, pos + 1] >= 4        # the answer token
        # context carries only the auxiliary LM weight; padding none
        near = np.abs(mask[i][:, None]
                      - np.asarray([0.0, aux, 1.0], np.float32)[None, :])
        assert np.all(near.min(axis=1) < 1e-6)


def test_causal_task_consistent_mapping():
    fam = make_dataset_family("dolly")
    ds = SyntheticInstructionDataset(fam, [1, 0, 0, 0], client_seed=5)
    rng = np.random.default_rng(0)
    qa = {}
    for _ in range(200):
        toks, mask, _ = ds.sample(rng, 48)
        pos = int(np.argmax(mask))
        q, a = int(toks[pos - 1]), int(toks[pos + 1])
        assert qa.setdefault(q, a) == a     # same client ⇒ same mapping


def test_dirichlet_partition_rows_stochastic():
    p = dirichlet_task_partition(8, 4, 0.5, seed=1)
    assert p.shape == (8, 4)
    np.testing.assert_allclose(p.sum(1), 1.0, rtol=1e-6)


def test_specialist_partition_one_hot():
    p = specialist_partition(8, 4)
    assert (p.sum(1) == 1).all() and (p.max(1) == 1).all()


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.asarray([3.0, -2.0])}
    ost = opt.init(params)
    for i in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, ost = opt.update(g, ost, params, jnp.asarray(i))
        params = apply_updates(params, upd)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_masked_optimizer_freezes_and_saves_memory():
    params = {"a": jnp.ones((4, 4)), "b": jnp.ones((4, 4))}
    mask = {"a": True, "b": False}
    opt = masked(adamw(0.1), mask)
    ost = opt.init(params)
    # frozen leaf carries zero-size moments
    assert ost.mu["b"].size == 0 and ost.mu["a"].size == 16
    g = {"a": jnp.ones((4, 4)), "b": jnp.ones((4, 4))}
    upd, _ = opt.update(g, ost, params, jnp.asarray(0))
    assert float(jnp.max(jnp.abs(upd["b"]))) == 0.0
    assert float(jnp.max(jnp.abs(upd["a"]))) > 0.0


def test_clip_by_global_norm():
    g = {"x": jnp.full((10,), 10.0)}
    c = clip_by_global_norm(g, 1.0)
    n = float(jnp.linalg.norm(c["x"]))
    assert abs(n - 1.0) < 1e-4


def _check_sgd_step_is_lr_scaled_gradient(lr):
    opt = sgd(lr)
    params = {"w": jnp.asarray([1.0])}
    ost = opt.init(params)
    g = {"w": jnp.asarray([2.0])}
    upd, _ = opt.update(g, ost, params, jnp.asarray(0))
    np.testing.assert_allclose(float(upd["w"][0]), -lr * 2.0, rtol=1e-5)


if HAVE_HYPOTHESIS:
    @hypothesis.given(st.floats(1e-4, 1e-1))
    @hypothesis.settings(deadline=None, max_examples=10)
    def test_sgd_step_is_lr_scaled_gradient(lr):
        _check_sgd_step_is_lr_scaled_gradient(lr)
else:
    @pytest.mark.parametrize("lr", [1e-4, 1e-3, 1e-2, 1e-1])
    def test_sgd_step_is_lr_scaled_gradient(lr):
        _check_sgd_step_is_lr_scaled_gradient(lr)
