"""R4 — recompile hazards.

A jitted function recompiles whenever the treedef / static parts of its
inputs or closure change.  Two mechanically detectable shapes:

(a) **mutated closure scalar** — a Python int/float/str closed over by
    a jit-traced inner function and *mutated* in the enclosing scope
    (``n += 1``, or reassigned lexically after the jitted def).  Every
    mutation silently retriggers a trace; worse, if the mutation
    happens after the first call the compiled program keeps the stale
    value.  The fix is to pass the value as an argument (dynamic) or
    mark it static explicitly.

(b) **unhashable static args** — a dict/list/set literal passed at a
    ``static_argnums`` position of a known-jitted callable: unhashable
    statics raise at call time, and fresh literals would defeat the
    compile cache even if hashable.

Suppress with ``# lint: ok[R4] <reason>`` when the rebind provably
happens before the first trace (e.g. config resolution above the jit).
"""
from __future__ import annotations

import ast

from .base import Finding, ModuleInfo, Rule, last_seg, walk_skip_nested


class RecompileHazards(Rule):
    code = "R4"
    name = "recompile-hazards"
    description = ("python scalar closed over by a jitted fn is mutated "
                   "in the enclosing scope, or an unhashable literal is "
                   "passed as a static arg (retrace/recompile every call)")

    def check_module(self, mod: ModuleInfo) -> list[Finding]:
        out: list[Finding] = []
        reachable = {id(f): f for f in mod.jit_reachable()}
        for fn in reachable.values():
            owner = mod.enclosing_function(fn)
            if owner is None:
                continue
            out.extend(self._closure_mutations(mod, fn, owner))
        out.extend(self._unhashable_statics(mod))
        return out

    # -- (a) mutated closure scalars --------------------------------------

    def _closure_mutations(self, mod: ModuleInfo, fn, owner) \
            -> list[Finding]:
        bound = {a.arg for a in fn.args.args + fn.args.posonlyargs
                 + fn.args.kwonlyargs}
        local_stores = {n.id for n in walk_skip_nested(fn)
                        if isinstance(n, ast.Name)
                        and not isinstance(n.ctx, ast.Load)}
        freevars = {n.id for n in walk_skip_nested(fn)
                    if isinstance(n, ast.Name)
                    and isinstance(n.ctx, ast.Load)
                    and n.id not in bound and n.id not in local_stores}
        if not freevars:
            return []
        out: list[Finding] = []
        for node in walk_skip_nested(owner):
            if isinstance(node, ast.AugAssign) and isinstance(
                    node.target, ast.Name) and node.target.id in freevars:
                out.append(mod.finding(
                    "R4", node,
                    f"`{node.target.id}` is closed over by jit-reachable "
                    f"`{fn.name}` and mutated here — each mutation "
                    f"retraces (or is silently ignored after the first "
                    f"compile); pass it as an argument instead"))
            elif isinstance(node, ast.Assign) and node.lineno > fn.lineno:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and tgt.id in freevars \
                            and self._is_scalar(node.value):
                        out.append(mod.finding(
                            "R4", node,
                            f"`{tgt.id}` is closed over by jit-reachable "
                            f"`{fn.name}` (defined above) and reassigned "
                            f"here — the traced program keeps the old "
                            f"value; pass it as an argument instead"))
        return out

    def _is_scalar(self, node) -> bool:
        return isinstance(node, ast.Constant) and isinstance(
            node.value, (int, float, str, bool))

    # -- (b) unhashable static args ---------------------------------------

    def _unhashable_statics(self, mod: ModuleInfo) -> list[Finding]:
        # name -> static positional indices, from jax.jit(f, static_argnums=…)
        statics: dict[str, tuple[int, ...]] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Call) \
                    and last_seg(node.value.func) == "jit":
                for kw in node.value.keywords:
                    if kw.arg == "static_argnums":
                        idx = self._ints(kw.value)
                        if idx:
                            statics[node.targets[0].id] = idx
        if not statics:
            return []
        out: list[Finding] = []
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and isinstance(
                    node.func, ast.Name) and node.func.id in statics):
                continue
            for i in statics[node.func.id]:
                if i < len(node.args) and isinstance(
                        node.args[i], (ast.Dict, ast.List, ast.Set)):
                    kind = type(node.args[i]).__name__.lower()
                    out.append(mod.finding(
                        "R4", node.args[i],
                        f"{kind} literal at static_argnums position {i} "
                        f"of jitted `{node.func.id}` — unhashable statics "
                        f"raise at call time; use a tuple or a hashable "
                        f"config object"))
        return out

    def _ints(self, node) -> tuple[int, ...]:
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return (node.value,)
        if isinstance(node, (ast.Tuple, ast.List)):
            return tuple(e.value for e in node.elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, int))
        return ()
