"""Pallas TPU kernel: Mamba-2 SSD chunked scan.

CUDA Mamba implements the selective scan with warp-level prefix products;
the TPU-native decomposition is the SSD block form: per chunk a Q×Q
lower-triangular decay-weighted C·Bᵀ matmul (MXU) plus a small recurrent
(N×P) state carried across chunks.  The chunk loop is the innermost grid
dimension, so the state lives in VMEM scratch for the whole sequence —
one HBM read of x/B/C, no state spills.

Grid: (B·H, S/Q) — chunk index innermost/sequential.

Per-(head,chunk) VMEM (Q=256, N=128, P=64, f32 scratch):
  x(Q·P) + B,C(2·Q·N) + decay(Q·Q) + state(N·P) ≈ 0.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(alog_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, st_ref,
            state_ref, *, nc: int, Q: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)               # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)             # (Q,)
    Bm = b_ref[0].astype(jnp.float32)              # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)              # (Q, N)
    a = -jnp.exp(alog_ref[0]) * dt                 # (Q,) log-decay
    ld = jnp.cumsum(a)                             # (Q,)
    xdt = x * dt[:, None]

    # intra-chunk: (C Bᵀ ∘ L) xdt   with L[i,j] = exp(l_i − l_j)·[i ≥ j]
    li = ld[:, None]
    lj = ld[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(tri, jnp.exp(li - lj), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(cb * decay, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += (C ∘ exp(l)) @ state_prev      state: (N, P)
    y += jax.lax.dot_general(Cm * jnp.exp(ld)[:, None], state_ref[...],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update: state = exp(l_Q)·state + (B ∘ exp(l_Q − l))ᵀ @ xdt
    lQ = ld[Q - 1]
    seg = jnp.exp(lQ - ld)
    state_ref[...] = jnp.exp(lQ) * state_ref[...] + jax.lax.dot_general(
        Bm * seg[:, None], xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(c == nc - 1)
    def _emit_state():
        st_ref[0] = state_ref[...].astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_bh(x, dt, a_log, B, C, *, chunk: int = 256,
                interpret: bool = False):
    """x (BH,S,P); dt (BH,S); a_log (BH,); B,C (BG,S,N) with BH = BG·rep.
    Returns (y (BH,S,P), final_state (BH,N,P))."""
    BH, S, P = x.shape
    BG, _, N = B.shape
    assert BH % BG == 0
    rep = BH // BG
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    grid = (BH, nc)
    y, st = pl.pallas_call(
        functools.partial(_kernel, nc=nc, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda h, c: (h,)),            # a_log
            pl.BlockSpec((1, Q, P), lambda h, c: (h, c, 0)),  # x
            pl.BlockSpec((1, Q), lambda h, c: (h, c)),        # dt
            pl.BlockSpec((1, Q, N), lambda h, c: (h // rep, c, 0)),  # B
            pl.BlockSpec((1, Q, N), lambda h, c: (h // rep, c, 0)),  # C
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, N, P), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(a_log, x, dt, B, C)
    return y, st
