import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) combination on the production mesh, with no real allocation, and
record memory/cost/collective analysis for the roofline.

MUST be run as its own process (the XLA_FLAGS above lock in 512 host
devices before jax initializes):

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
        --shape train_4k [--multipod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, get_config, shape_supported)
from repro.launch import analysis as AN
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh, dp_size, data_axes
from repro.launch.serve import make_prefill_step, make_decode_step
from repro.launch.train import (TrainSettings, make_fed_train_step,
                                pick_micro_batches)
from repro.utils import pytree as pt


def _mem_dict(mem) -> dict:
    return {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        # donated buffers alias inputs — don't double-count them
        "peak_estimate_bytes": mem.argument_size_in_bytes
        + mem.temp_size_in_bytes + mem.output_size_in_bytes
        - mem.alias_size_in_bytes,
    }


def _loop_trips(cfg, shape) -> tuple[int, ...]:
    n_sb, tail, pattern = cfg.blocks_layout()
    if cfg.n_enc_layers:
        n_sb = cfg.n_layers
    trips = [max(n_sb, 1)]
    if shape.kind in ("train", "prefill") and shape.seq_len >= 2048:
        trips.append(shape.seq_len // 512)     # chunked-attention q scan
    return tuple(trips)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            variant: str = "baseline") -> dict:
    import dataclasses
    cfg = get_config(arch)
    seq_shard_kv = False
    remat = True
    if variant == "seqshard_kv":
        seq_shard_kv = True
    elif variant == "cf1":
        cfg = dataclasses.replace(cfg, capacity_factor=1.0)
    elif variant == "remat_dots":
        remat = "dots"
    elif variant == "swa_global":     # beyond-paper: window the attn layers
        cfg = dataclasses.replace(cfg, sliding_window=4096)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "n_devices": n_dev, "variant": variant}
    t0 = time.time()

    with jax.set_mesh(mesh):
        abs_base = SP.abstract_params(cfg)
        base_shardings = SP.param_specs(cfg, mesh, abs_base)

        if shape.kind == "train":
            C = dp_size(mesh)
            settings = TrainSettings(
                micro_batches=pick_micro_batches(
                    cfg, shape.global_batch // C, shape.seq_len),
                remat=remat)
            rec["n_clients"] = C
            rec["micro_batches"] = settings.micro_batches
            step_fn, opt_init = make_fed_train_step(cfg, mesh, settings)
            abs_ad = SP.abstract_adapters(cfg, n_clients=C)
            ad_shardings = SP.adapter_specs(mesh, abs_ad, client_axis=True)
            abs_ost = jax.eval_shape(opt_init, abs_ad)
            ost_shardings = jax.tree.map(
                lambda x, s: s if False else NamedSharding(
                    mesh, P(_bax(mesh), *([None] * (len(x.shape) - 1)))),
                abs_ost, abs_ost)
            batch_args, batch_shardings = SP.train_batch_specs(
                cfg, shape, mesh, C)
            step_abs = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(
                step_fn,
                in_shardings=(base_shardings, ad_shardings, ost_shardings,
                              NamedSharding(mesh, P()), batch_shardings),
                out_shardings=(ad_shardings, ost_shardings, None),
                donate_argnums=(1, 2),   # adapters/opt state update in place
            ).lower(abs_base, abs_ad, abs_ost, step_abs, batch_args)
        elif shape.kind == "prefill":
            fn = make_prefill_step(cfg, mesh)
            batch_args, batch_shardings = SP.serve_batch_specs(cfg, shape, mesh)
            lowered = jax.jit(
                fn, in_shardings=(base_shardings, batch_shardings),
                out_shardings=None,
            ).lower(abs_base, batch_args)
        else:
            fn = make_decode_step(cfg, mesh)
            args, shardings = SP.decode_specs(cfg, shape, mesh,
                                              seq_shard_kv=seq_shard_kv)
            in_sh = [base_shardings, shardings["new_token"],
                     shardings["cache"], shardings["cache_index"]]
            in_args = [abs_base, args["new_token"], args["cache"],
                       args["cache_index"]]
            if cfg.n_enc_layers:
                in_sh.append(shardings["enc_out"])
                in_args.append(args["enc_out"])
            lowered = jax.jit(
                fn, in_shardings=tuple(in_sh), out_shardings=None,
                donate_argnums=(2,),     # KV cache updates in place
            ).lower(*in_args)

        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = _mem_dict(mem)
        rec["fits_16g"] = rec["memory"]["peak_estimate_bytes"] < 16e9
        ca = compiled.cost_analysis() or {}
        rec["cost_analysis"] = {
            "flops_per_device_raw": ca.get("flops", 0.0),
            "bytes_accessed_raw": ca.get("bytes accessed", 0.0),
            "note": "XLA counts while bodies once; see analysis.py",
        }
        txt = compiled.as_text()
        rec["hlo_lines"] = len(txt.splitlines())
        colls = AN.parse_collectives(txt, _loop_trips(cfg, shape))
        rec["collectives"] = colls
        # archive the HLO (gzip) so collective accounting can be re-derived
        # without recompiling
        import gzip
        hdir = os.path.join("experiments", "hlo")
        os.makedirs(hdir, exist_ok=True)
        tagname = (f"{arch}__{shape_name}__"
                   f"{'2x16x16' if multi_pod else '16x16'}"
                   + ("" if variant == "baseline" else f"__{variant}"))
        with gzip.open(os.path.join(hdir, tagname + ".hlo.gz"), "wt") as fh:
            fh.write(txt)

        # analytic roofline
        fl = AN.analytic_step_flops(cfg, shape)
        pc = AN.param_counts(cfg, abs_base)
        cache_bytes = 0
        if shape.kind == "decode":
            cache = SP.abstract_cache(
                cfg, shape.global_batch,
                shape.seq_len // 2 if cfg.n_enc_layers else shape.seq_len)
            cache_bytes = pt.tree_bytes(cache)
        by = AN.analytic_step_bytes(cfg, shape, pc["n_params"], n_dev,
                                    cache_bytes)
        terms = AN.roofline_terms(fl["flops_global"], by["hbm_bytes_dev"],
                                  colls["total"] / n_dev, n_dev)
        # MODEL_FLOPS: body params see every token; the lm_head sees every
        # token only in training (serve computes last-position logits), and
        # the embedding gather is not FLOPs.
        head_p = cfg.d_model * cfg.vocab_size
        factor = 6 if shape.kind == "train" else 2
        head_tokens = fl["tokens"] if shape.kind == "train" \
            else shape.global_batch
        model_flops = factor * pc["n_active_body"] * fl["tokens"] \
            + factor * head_p * head_tokens
        rec.update({
            "params": pc,
            "analytic": {**fl, **by, "cache_bytes_global": cache_bytes},
            "roofline": {
                "compute_s": terms.compute_s,
                "memory_s": terms.memory_s,
                "collective_s": terms.collective_s,
                "dominant": terms.dominant,
                "model_flops": model_flops,
                "useful_flops_ratio":
                    model_flops / max(fl["flops_global"], 1.0),
            },
        })
    return rec


def _bax(mesh):
    ax = data_axes(mesh)
    return ax if len(ax) > 1 else ax[0]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = []
    if args.all:
        for mp in (False, True):   # full single-pod table first
            for a in ARCH_IDS:
                if a == "llama2-7b":
                    continue       # paper target, not an assigned pair
                for s in SHAPES:
                    if not shape_supported(a, s):
                        continue
                    combos.append((a, s, mp))
    else:
        meshes = [False, True] if args.both_meshes else [args.multipod]
        for mp in meshes:
            combos.append((args.arch, args.shape, mp))
    variant = getattr(args, "variant", "baseline")

    results = []
    for arch, shape, mp in combos:
        tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
        if variant != "baseline":
            tag += f"__{variant}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            rec = run_one(arch, shape, mp, variant=variant)
            rec["status"] = "ok"
        except Exception as e:
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            print(rec["error"][:400])
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"  ok: compile={rec['compile_s']}s "
                  f"mem={rec['memory']['peak_estimate_bytes']/1e9:.2f}GB "
                  f"terms(c/m/coll)={r['compute_s']:.2e}/{r['memory_s']:.2e}/"
                  f"{r['collective_s']:.2e} dom={r['dominant']}", flush=True)
        results.append(rec)
    return results


if __name__ == "__main__":
    main()
