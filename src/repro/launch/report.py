"""Generate EXPERIMENTS.md §Dry-run / §Roofline sections from the
dry-run JSON artifacts.

    PYTHONPATH=src python -m repro.launch.report > experiments/roofline.md
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load() -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_bytes(b):
    return f"{b/1e9:.2f} GB"


def dryrun_section(recs) -> str:
    out = ["## §Dry-run", "",
           "Per (arch × shape × mesh): compile status, per-device memory "
           "from `compiled.memory_analysis()`, collective bytes parsed from "
           "HLO (loop-aware, see launch/analysis.py).", "",
           "| arch | shape | mesh | status | args/dev | temps/dev | "
           "fits 16G | collective bytes/step (global) | top collectives |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"ERROR: {str(r.get('error'))[:60]} | | | | | |")
            continue
        m = r["memory"]
        colls = r["collectives"]
        tops = sorted(((k, v) for k, v in colls.items()
                       if k not in ("total", "op_counts")),
                      key=lambda kv: -kv[1])[:2]
        tops_s = ", ".join(f"{k}:{v/1e9:.2f}GB" for k, v in tops)
        name = r['arch'] + ("" if r.get('variant', 'baseline') == 'baseline'
                            else f" +{r['variant']}")
        out.append(
            f"| {name} | {r['shape']} | {r['mesh']} | ok "
            f"({r['compile_s']}s) | {fmt_bytes(m['argument_bytes'])} | "
            f"{fmt_bytes(m['temp_bytes'])} | "
            f"{'yes' if r['fits_16g'] else '**NO**'} | "
            f"{fmt_bytes(colls['total'])} | {tops_s} |")
    return "\n".join(out)


def roofline_section(recs) -> str:
    out = ["## §Roofline (single-pod 16×16, 256 chips)", "",
           "Terms in seconds/step — compute = analytic FLOPs/dev ÷ 197e12; "
           "memory = modeled HBM bytes/dev ÷ 819e9; collective = parsed "
           "bytes/dev ÷ 50e9.  `useful` = MODEL_FLOPS (6·N_active·tokens "
           "train / 2·N·tokens serve) ÷ total analytic FLOPs.", "",
           "| arch | shape | compute s | memory s | collective s | "
           "dominant | useful | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|"]
    advice = {
        ("compute", "train"): "more chips or lower remat factor (3× fwd)",
        ("compute", "prefill"): "flash-kernel MXU util / larger per-core tiles",
        ("compute", "decode"): "batch more requests per step",
        ("memory", "train"): "re-use param reads across micro-batches",
        ("memory", "prefill"): "KV-cache write coalescing, bf16 cache",
        ("memory", "decode"): "weight/cache quantization, larger batch to "
                              "amortize weight reads",
        ("collective", "train"): "overlap adapter pmean with backward; "
                                 "bf16 collective payloads",
        ("collective", "prefill"): "reshard to cut activation all-gathers",
        ("collective", "decode"): "collective-permute ring for cache-sharded "
                                  "attention; fewer a2a hops",
    }
    for r in recs:
        if r.get("status") != "ok" or r["mesh"] != "16x16":
            continue
        ro = r["roofline"]
        kind = ("train" if r["shape"].startswith("train") else
                "prefill" if "prefill" in r["shape"] else "decode")
        name = r['arch'] + ("" if r.get('variant', 'baseline') == 'baseline'
                            else f" +{r['variant']}")
        out.append(
            f"| {name} | {r['shape']} | {ro['compute_s']:.3e} | "
            f"{ro['memory_s']:.3e} | {ro['collective_s']:.3e} | "
            f"**{ro['dominant']}** | {ro['useful_flops_ratio']:.2f} | "
            f"{advice[(ro['dominant'], kind)]} |")
    return "\n".join(out)


def summarize(recs) -> str:
    ok = [r for r in recs if r.get("status") == "ok"]
    bad = [r for r in recs if r.get("status") != "ok"]
    by_dom = defaultdict(int)
    for r in ok:
        if r["mesh"] == "16x16":
            by_dom[r["roofline"]["dominant"]] += 1
    return (f"{len(ok)} ok / {len(bad)} failed; single-pod dominants: "
            + ", ".join(f"{k}={v}" for k, v in sorted(by_dom.items())))


def main():
    recs = load()
    print(f"<!-- {summarize(recs)} -->\n")
    print(dryrun_section(recs))
    print()
    print(roofline_section(recs))


if __name__ == "__main__":
    main()
