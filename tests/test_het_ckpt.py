"""Heterogeneous-rank checkpoint coverage: msgpack roundtrips of a
mixed-rank adapter pool and of FedSim state must preserve per-tenant /
per-client ranks, and pre-het checkpoints (no slot-rank table) must
restore with sane defaults instead of crashing."""
import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
from repro.core import peft
from repro.fed.simulate import FedHyper, FedSim
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.serve import AdapterStore
from repro.utils import pytree as pt

CFG = ArchConfig(name="hetck-t", family="dense", n_layers=2, d_model=32,
                 n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                 dtype="float32", lora_rank=8, lora_dropout=0.0)


@pytest.fixture(scope="module")
def base():
    return M.init_params(jax.random.PRNGKey(0), CFG)


def _adapter(base, seed, rank):
    return peft.add_lora(base, CFG, jax.random.PRNGKey(seed), rank=rank)


def _batches(C, n, seed=0):
    rng = np.random.default_rng(seed)
    return [{"tokens": jnp.asarray(rng.integers(5, 64, size=(C, 2, 16)),
                                   jnp.int32),
             "loss_mask": jnp.ones((C, 2, 16), jnp.float32)}
            for _ in range(n)]


# ---------------------------------------------------------------------------
# heterogeneous adapter pool
# ---------------------------------------------------------------------------

def test_het_pool_roundtrip_preserves_ranks(base, tmp_path):
    path = str(tmp_path / "pool.msgpack")
    store = AdapterStore(base, CFG, n_slots=4, kind="pairs", rank=8)
    ranks = {"alice": 2, "bob": 4, "carol": 8}
    for i, (tenant, r) in enumerate(ranks.items()):
        store.register(tenant, _adapter(base, i + 1, r))
    store.save(path, step=11)

    fresh = AdapterStore(base, CFG, n_slots=4, kind="pairs", rank=8)
    assert fresh.load(path) == 11
    assert fresh.tenants == store.tenants
    for tenant, r in ranks.items():
        assert fresh.rank_of(tenant) == r, tenant
    # overlays (pools + the pool_ranks table) are leaf-identical
    for (pa, la), (pb, lb) in zip(
            zip(pt.tree_paths(store.overlay()),
                jax.tree.leaves(store.overlay())),
            zip(pt.tree_paths(fresh.overlay()),
                jax.tree.leaves(fresh.overlay()))):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_pre_het_pool_checkpoint_defaults_to_full_rank(base, tmp_path):
    """A checkpoint written before the slot-rank table existed (simulated
    by stripping the slot_ranks leaf) restores occupied slots at the
    pool's full rank — their pools were never padded — and empty/null
    slots at 0."""
    path = str(tmp_path / "old.msgpack")
    store = AdapterStore(base, CFG, n_slots=3, kind="pairs", rank=8)
    store.register("legacy", _adapter(base, 1, 8))
    store.save(path, step=2)
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    del payload["leaves"]["meta/slot_ranks"]
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))

    fresh = AdapterStore(base, CFG, n_slots=3, kind="pairs", rank=8)
    assert fresh.load(path) == 2
    assert fresh.rank_of("legacy") == 8
    empties = [s for s in range(4) if s != fresh.slot_of("legacy")]
    assert all(fresh._slot_ranks[s] == 0 for s in empties)


def test_restore_checkpoint_missing_leaf_policy(tmp_path):
    path = os.path.join(tmp_path, "t.msgpack")
    save_checkpoint(path, {"a": jnp.ones((2,))}, step=1)
    like = {"a": jnp.zeros((2,)), "b": jnp.full((3,), 7.0)}
    with pytest.raises(KeyError, match="allow_missing"):
        restore_checkpoint(path, like)
    # a non-matching allow_missing regex still raises for 'b'
    with pytest.raises(KeyError):
        restore_checkpoint(path, like, allow_missing=r"^zzz$")
    for kwargs in ({"strict": False}, {"allow_missing": r"^b$"}):
        tree, _ = restore_checkpoint(path, like, **kwargs)
        np.testing.assert_array_equal(np.asarray(tree["a"]), np.ones((2,)))
        np.testing.assert_array_equal(np.asarray(tree["b"]),
                                      np.full((3,), 7.0))


def test_restore_checkpoint_preserves_int64(tmp_path):
    """int64 counters must not wrap through jnp's x64-disabled asarray
    (comm accounting over thousands of rounds crosses 2^31)."""
    path = os.path.join(tmp_path, "t.msgpack")
    big = np.asarray(5_000_000_000, np.int64)
    save_checkpoint(path, {"n": big}, step=0)
    tree, _ = restore_checkpoint(path, {"n": np.asarray(0, np.int64)})
    assert int(tree["n"]) == 5_000_000_000


def test_cross_kind_pool_load_still_raises(base, tmp_path):
    """Only the slot-rank table is allowed to be missing: loading a
    kind='dora_mag' checkpoint into a kind='pairs' store must raise, not
    silently serve zero adapters."""
    shared = peft.add_lora(base, CFG, jax.random.PRNGKey(9), decomposed=True)
    mag = AdapterStore(base, CFG, n_slots=2, kind="dora_mag", shared=shared)
    path = str(tmp_path / "mag.msgpack")
    mag.save(path, step=5)
    pairs = AdapterStore(base, CFG, n_slots=2, kind="pairs", rank=8)
    with pytest.raises(KeyError, match="pool_A"):
        pairs.load(path)


# ---------------------------------------------------------------------------
# FedSim state
# ---------------------------------------------------------------------------

def test_fedsim_het_state_roundtrip(tmp_path):
    path = str(tmp_path / "sim.msgpack")
    hp = FedHyper(method="lora_exact", n_clients=3, local_steps=1,
                  client_ranks=(2, 3, 4))
    sim = FedSim(CFG, hp)
    sim.local_round(_batches(3, 1), jax.random.PRNGKey(0))
    sim.aggregate()
    sim.save(path, round_idx=4)

    sim2 = FedSim(CFG, hp)
    assert sim2.load(path) == 4
    assert sim2.comm_bytes == sim.comm_bytes
    assert int(sim2._step) == int(sim._step)
    for p, a, b in zip(pt.tree_paths(sim.client_adapters),
                       jax.tree.leaves(sim.client_adapters),
                       jax.tree.leaves(sim2.client_adapters)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=p)
    for a, b in zip(jax.tree.leaves(sim.opt_state),
                    jax.tree.leaves(sim2.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the restored fleet keeps training (and stays masked)
    sim2.local_round(_batches(3, 1, seed=2), jax.random.PRNGKey(1))


def test_fedsim_load_rejects_rank_permutation(tmp_path):
    """Same r_max, different per-client assignment — shapes all match, so
    only the recorded rank vector can catch the mismatch."""
    path = str(tmp_path / "sim.msgpack")
    hp = FedHyper(method="lora", n_clients=3, local_steps=1,
                  client_ranks=(2, 3, 4))
    sim = FedSim(CFG, hp)
    sim.save(path)
    other = FedSim(CFG, FedHyper(method="lora", n_clients=3, local_steps=1,
                                 client_ranks=(4, 3, 2)))
    with pytest.raises(ValueError, match="ranks"):
        other.load(path)


def test_fedsim_prox_anchor_survives_midcycle_save(tmp_path):
    """A fedprox checkpoint taken after local_round but BEFORE aggregate
    must restore the previous round's proximal anchor, not alias the
    current adapters (which would zero the prox term on resume)."""
    path = str(tmp_path / "sim.msgpack")
    hp = FedHyper(method="fedprox", n_clients=2, local_steps=2, lr=1e-2,
                  prox_mu=0.1)
    sim = FedSim(CFG, hp)
    sim.local_round(_batches(2, 2), jax.random.PRNGKey(0))
    # mid-cycle: anchor != adapters
    anchor = jax.tree.leaves(sim._round_ref)
    adapters = jax.tree.leaves(sim.client_adapters)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(anchor, adapters))
    sim.save(path)
    sim2 = FedSim(CFG, hp)
    sim2.load(path)
    for a, b in zip(anchor, jax.tree.leaves(sim2._round_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # resumed training matches the uninterrupted run exactly
    b2 = _batches(2, 1, seed=5)
    sim.local_round(b2, jax.random.PRNGKey(1))
    sim2.local_round(b2, jax.random.PRNGKey(1))
    for a, b in zip(jax.tree.leaves(sim.client_adapters),
                    jax.tree.leaves(sim2.client_adapters)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fedsim_uniform_state_roundtrip(tmp_path):
    """Uniform fleets record the flat rank vector too."""
    path = str(tmp_path / "sim.msgpack")
    hp = FedHyper(method="lora", n_clients=2, local_steps=1)
    sim = FedSim(CFG, hp)
    sim.local_round(_batches(2, 1), jax.random.PRNGKey(0))
    sim.save(path, round_idx=1)
    sim2 = FedSim(CFG, hp)
    assert sim2.load(path) == 1
    ranks = np.asarray(sim2.state_tree()["client_ranks"])
    np.testing.assert_array_equal(ranks, [CFG.lora_rank] * 2)
