"""Generate EXPERIMENTS.md §Dry-run / §Roofline / §Telemetry sections
from the dry-run JSON artifacts and the obs event log.

    PYTHONPATH=src python -m repro.launch.report > experiments/roofline.md

Pass a telemetry JSONL path via REPRO_TELEMETRY to append §Telemetry.
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")
TELEMETRY = os.environ.get("REPRO_TELEMETRY", "")


def load() -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_bytes(b):
    return f"{b/1e9:.2f} GB"


def dryrun_section(recs) -> str:
    out = ["## §Dry-run", "",
           "Per (arch × shape × mesh): compile status, per-device memory "
           "from `compiled.memory_analysis()`, collective bytes parsed from "
           "HLO (loop-aware, see launch/analysis.py).", "",
           "| arch | shape | mesh | status | args/dev | temps/dev | "
           "fits 16G | collective bytes/step (global) | top collectives |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"ERROR: {str(r.get('error'))[:60]} | | | | | |")
            continue
        m = r["memory"]
        colls = r["collectives"]
        tops = sorted(((k, v) for k, v in colls.items()
                       if k not in ("total", "op_counts")),
                      key=lambda kv: -kv[1])[:2]
        tops_s = ", ".join(f"{k}:{v/1e9:.2f}GB" for k, v in tops)
        name = r['arch'] + ("" if r.get('variant', 'baseline') == 'baseline'
                            else f" +{r['variant']}")
        out.append(
            f"| {name} | {r['shape']} | {r['mesh']} | ok "
            f"({r['compile_s']}s) | {fmt_bytes(m['argument_bytes'])} | "
            f"{fmt_bytes(m['temp_bytes'])} | "
            f"{'yes' if r['fits_16g'] else '**NO**'} | "
            f"{fmt_bytes(colls['total'])} | {tops_s} |")
    return "\n".join(out)


def roofline_section(recs) -> str:
    out = ["## §Roofline (single-pod 16×16, 256 chips)", "",
           "Terms in seconds/step — compute = analytic FLOPs/dev ÷ 197e12; "
           "memory = modeled HBM bytes/dev ÷ 819e9; collective = parsed "
           "bytes/dev ÷ 50e9.  `useful` = MODEL_FLOPS (6·N_active·tokens "
           "train / 2·N·tokens serve) ÷ total analytic FLOPs.", "",
           "| arch | shape | compute s | memory s | collective s | "
           "dominant | useful | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|"]
    advice = {
        ("compute", "train"): "more chips or lower remat factor (3× fwd)",
        ("compute", "prefill"): "flash-kernel MXU util / larger per-core tiles",
        ("compute", "decode"): "batch more requests per step",
        ("memory", "train"): "re-use param reads across micro-batches",
        ("memory", "prefill"): "KV-cache write coalescing, bf16 cache",
        ("memory", "decode"): "weight/cache quantization, larger batch to "
                              "amortize weight reads",
        ("collective", "train"): "overlap adapter pmean with backward; "
                                 "bf16 collective payloads",
        ("collective", "prefill"): "reshard to cut activation all-gathers",
        ("collective", "decode"): "collective-permute ring for cache-sharded "
                                  "attention; fewer a2a hops",
    }
    for r in recs:
        if r.get("status") != "ok" or r["mesh"] != "16x16":
            continue
        ro = r["roofline"]
        kind = ("train" if r["shape"].startswith("train") else
                "prefill" if "prefill" in r["shape"] else "decode")
        name = r['arch'] + ("" if r.get('variant', 'baseline') == 'baseline'
                            else f" +{r['variant']}")
        out.append(
            f"| {name} | {r['shape']} | {ro['compute_s']:.3e} | "
            f"{ro['memory_s']:.3e} | {ro['collective_s']:.3e} | "
            f"**{ro['dominant']}** | {ro['useful_flops_ratio']:.2f} | "
            f"{advice[(ro['dominant'], kind)]} |")
    return "\n".join(out)


def _mean(xs):
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


def telemetry_section(events) -> str:
    """Render obs event-log JSONL (a path, rotation-aware, or an
    already-loaded list of event dicts) into EXPERIMENTS-style tables:
    one federated-rounds table (per-round loss/drift/comm/wall split)
    and one serving table (per-run throughput + pool behaviour)."""
    if isinstance(events, (str, os.PathLike)):
        from repro.obs import read_events
        events = read_events(str(events))
    by_kind = defaultdict(list)
    for e in events:
        by_kind[e.get("kind", "?")].append(e)
    out = ["## §Telemetry", ""]

    rounds = by_kind["fed_round"]
    if rounds:
        out += ["### Federated rounds", "",
                "| engine | method | step | clients | ce mean | spread | "
                "grad-norm | drift mean | comm bytes (class) | "
                "wall split (s) |",
                "|---|---|---|---|---|---|---|---|---|---|"]
        for e in rounds:
            wall = e.get("wall", {})
            split = ", ".join(f"{k}:{v:.3f}" for k, v in wall.items())
            out.append(
                f"| {e.get('engine', 'sim')} | {e.get('method', '?')} | "
                f"{e.get('step', 0)} | {e.get('clients', 0)} | "
                f"{_mean(e.get('ce', [])):.4f} | "
                f"{e.get('loss_spread', 0.0):.4f} | "
                f"{_mean(e.get('grad_norm', [])):.4f} | "
                f"{_mean(e.get('drift', [])):.4f} | "
                f"{e.get('comm_bytes', 0):,} ({e.get('comm_class', '?')}) | "
                f"{split} |")
        out.append("")

    cohorts = by_kind["fed_cohort"]
    if cohorts:
        out += ["### Cohort rounds (partial participation)", "",
                "| method | round | cohort | part. rate | staleness "
                "mean/max | drop | strag | corrupt | delivered | "
                "in-flight | comm bytes |",
                "|---|---|---|---|---|---|---|---|---|---|---|"]
        for e in cohorts:
            part = e.get("participation", [])
            stale = e.get("staleness", []) or [0.0]
            rate = _mean(part)
            out.append(
                f"| {e.get('method', '?')} | {e.get('round', 0)} | "
                f"{len(part)} | {rate:.2f} | "
                f"{_mean(stale):.1f}/{max(stale):.0f} | "
                f"{e.get('dropouts', 0)} | {e.get('stragglers', 0)} | "
                f"{e.get('corrupt', 0)} | {e.get('delivered', 0)} | "
                f"{e.get('pending', 0)} | {e.get('comm_bytes', 0):,} |")
        out.append("")

    stages = by_kind["fed_stage"]
    if stages:
        out += ["### Pipeline stages", "",
                "| engine | stage | method | ce | wall s |",
                "|---|---|---|---|---|"]
        for e in stages:
            ce = e.get("ce", 0.0)
            out.append(f"| {e.get('engine', 'sim')} | {e['stage']} | "
                       f"{e.get('method', '?')} | {ce:.4f} | "
                       f"{e.get('wall', 0.0):.3f} |")
        out.append("")

    runs = by_kind["serve_run"]
    if runs:
        admits = by_kind["serve_admit"]
        waits = [a.get("wait", 0.0) for a in admits]
        depth = max((a.get("queue_depth", 0) for a in admits), default=0)
        out += ["### Serving", "",
                "| requests | tokens | wall s | tokens/s | chunks | "
                "prefills | rows |",
                "|---|---|---|---|---|---|---|"]
        for e in runs:
            out.append(f"| {e.get('requests', 0)} | {e.get('tokens', 0)} | "
                       f"{e.get('wall', 0.0):.3f} | "
                       f"{e.get('tokens_per_s', 0.0):,.1f} | "
                       f"{e.get('chunks', 0)} | {e.get('prefills', 0)} | "
                       f"{e.get('rows', 0)} |")
        out += ["",
                f"admission wait mean {_mean(waits)*1e3:.2f} ms / max "
                f"{max(waits, default=0.0)*1e3:.2f} ms over {len(admits)} "
                f"admits; peak queue depth {depth}; pool registers "
                f"{len(by_kind['pool_register'])}, evictions "
                f"{len(by_kind['pool_evict'])}", ""]

    snaps = by_kind["metrics_snapshot"]
    if snaps:
        counters = snaps[-1].get("snapshot", {}).get("counters", {})
        total = lambda n: sum(s.get("value", 0.0)  # noqa: E731
                              for s in counters.get(n, []))
        lookups, regs = total("pool/lookups"), total("pool/registers")
        if lookups or regs:
            out += [f"pool hit-rate {lookups / max(lookups + regs, 1):.2%} "
                    f"({int(lookups)} lookups / {int(regs)} registers)", ""]
        hists = snaps[-1].get("snapshot", {}).get("histograms", {})
        if hists:
            # bucket-resolved view: with the sub-ms default/latency
            # bounds, an 80 µs and a 600 µs span show up as *different*
            # rows here instead of one collapsed "< 1 ms" bucket
            out += ["### Histograms", "",
                    "| metric | labels | count | mean | min | max | "
                    "buckets (le: n) |",
                    "|---|---|---|---|---|---|---|"]
            for name, series in sorted(hists.items()):
                for s in series:
                    labels = ", ".join(
                        f"{k}={v}" for k, v in
                        sorted(s.get("labels", {}).items())) or "-"
                    bk = s.get("buckets", {})

                    def le(k):
                        return (float("inf") if k == "le_inf"
                                else float(k[3:]))
                    buckets = ", ".join(
                        f"{k[3:]}:{bk[k]}" for k in sorted(bk, key=le))
                    out.append(
                        f"| {name} | {labels} | {s.get('count', 0)} | "
                        f"{s.get('mean', 0.0):.3g} | "
                        f"{s.get('min', 0.0):.3g} | "
                        f"{s.get('max', 0.0):.3g} | {buckets} |")
            out.append("")

    if len(out) == 2:
        out += ["_no telemetry events_", ""]
    return "\n".join(out).rstrip()


def summarize(recs) -> str:
    ok = [r for r in recs if r.get("status") == "ok"]
    bad = [r for r in recs if r.get("status") != "ok"]
    by_dom = defaultdict(int)
    for r in ok:
        if r["mesh"] == "16x16":
            by_dom[r["roofline"]["dominant"]] += 1
    return (f"{len(ok)} ok / {len(bad)} failed; single-pod dominants: "
            + ", ".join(f"{k}={v}" for k, v in sorted(by_dom.items())))


def main():
    recs = load()
    print(f"<!-- {summarize(recs)} -->\n")
    print(dryrun_section(recs))
    print()
    print(roofline_section(recs))
    if TELEMETRY and os.path.exists(TELEMETRY):
        print()
        print(telemetry_section(TELEMETRY))


if __name__ == "__main__":
    main()
