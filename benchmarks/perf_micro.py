"""CPU micro-benchmarks: wall time of one forward/train/decode step per
reduced architecture (real measured numbers on this container; the TPU
numbers live in the roofline table, which is analytic by necessity)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M

B, S = 2, 64


def _batch(cfg, rng):
    S_tok = S
    extras = {}
    if cfg.frontend and not cfg.n_enc_layers:
        S_tok = S - cfg.frontend_tokens
        extras["frontend_emb"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)), jnp.float32)
    if cfg.n_enc_layers:
        extras["frontend_emb"] = jnp.asarray(
            rng.normal(size=(B, 16, cfg.d_model)), jnp.float32)
    return {"tokens": jnp.asarray(rng.integers(5, cfg.vocab_size,
                                               size=(B, S_tok)), jnp.int32),
            "loss_mask": jnp.ones((B, S_tok), jnp.float32), **extras}


def _time(fn, *args, reps=5):
    fn(*args)                                  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(log=print):
    rng = np.random.default_rng(0)
    rows = []
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg, rng)
        fwd = jax.jit(lambda p, b: M.loss_and_metrics(p, b, cfg)[0])
        us_f = _time(fwd, params, batch)
        cache = M.init_cache(cfg, B, S)
        dec = jax.jit(lambda p, t, c, i: M.decode_step(
            p, t, c, i, cfg,
            enc_out=jnp.zeros((B, 16, cfg.d_model)) if cfg.n_enc_layers else None)[0])
        us_d = _time(dec, params, jnp.ones((B,), jnp.int32), cache,
                     jnp.asarray(5))
        rows.append({"arch": arch, "fwd_us": us_f, "dec_us": us_d})
        log(f"[perf] {arch:24s} fwd={us_f:9.0f}us decode={us_d:9.0f}us")
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"perf/{r['arch']}/fwd,{r['fwd_us']:.0f},smoke_cpu")
        print(f"perf/{r['arch']}/decode,{r['dec_us']:.0f},smoke_cpu")
    return rows


if __name__ == "__main__":
    main()
