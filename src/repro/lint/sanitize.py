"""Runtime sanitizers — the dynamic half of repro.lint.

``nan_guard`` walks a pytree on the host and raises on the first
non-finite leaf, naming every offending path (a NaN that surfaces five
ops downstream of where it was born is the classic week-long hunt).
``tracked`` wraps a JAX PRNG key in a reuse detector: deriving
(``split`` / ``fold_in``) is free, but *consuming* the same key twice
(passing it to two samplers) raises ``KeyReuseError`` — the runtime
twin of static rule R3.

Both are host-side tools for tests and debugging sessions; neither is
jit-compatible and neither should appear in engine hot paths.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np

from repro.utils import pytree as pt


class NonFiniteError(ValueError):
    """A guarded pytree contained NaN/Inf leaves."""

    def __init__(self, name: str, bad: list[str]):
        self.name = name
        self.bad_paths = bad
        super().__init__(
            f"nan_guard({name!r}): non-finite values in {len(bad)} "
            f"leaf/leaves: " + ", ".join(bad[:8])
            + (" …" if len(bad) > 8 else ""))


def nan_guard(tree: Any, name: str = "tree") -> Any:
    """Raise ``NonFiniteError`` if any array leaf of ``tree`` holds
    NaN/Inf; returns ``tree`` unchanged otherwise (so it chains:
    ``params = nan_guard(step(params), "params")``)."""
    bad: list[str] = []

    def check(path: str, leaf: Any) -> Any:
        try:
            arr = np.asarray(leaf)
        except TypeError:
            return leaf                        # non-array leaf (config &c)
        if arr.dtype.kind in "fc" and not np.isfinite(arr).all():
            bad.append(path)
        return leaf

    pt.tree_map_with_path(check, tree)
    if bad:
        raise NonFiniteError(name, sorted(bad))
    return tree


def guard(name: str = "result") -> Callable:
    """Decorator form: ``@guard("grads")`` nan-guards the return value."""
    def deco(fn: Callable) -> Callable:
        def wrapped(*args, **kwargs):
            return nan_guard(fn(*args, **kwargs), name)
        wrapped.__name__ = getattr(fn, "__name__", "wrapped")
        return wrapped
    return deco


# ---------------------------------------------------------------------------
# key-reuse tracking
# ---------------------------------------------------------------------------

class KeyReuseError(RuntimeError):
    """A tracked PRNG key was consumed twice without re-derivation."""


class TrackedKey:
    """A PRNG key that raises on its second *consumption*.

    Deriving is free and returns fresh tracked keys::

        k = tracked(jax.random.PRNGKey(0))
        k1, k2 = k.split(2)
        x = jax.random.normal(k1.use(), (3,))   # fine
        y = jax.random.normal(k1.use(), (3,))   # KeyReuseError

    ``use()`` (or letting jax convert the object via ``__jax_array__``)
    marks the key consumed.  ``split``/``fold_in`` mirror
    ``jax.random`` and do not consume — deriving many children from one
    parent is exactly the hygienic pattern R3 enforces statically.
    """

    def __init__(self, key, label: str = "key"):
        self._key = key
        self.label = label
        self.consumed_at: str | None = None

    # -- derivation (never consumes) --------------------------------------

    def split(self, num: int = 2) -> list["TrackedKey"]:
        ks = jax.random.split(self._key, num)
        return [TrackedKey(ks[i], f"{self.label}.split[{i}]")
                for i in range(num)]

    def fold_in(self, data: int) -> "TrackedKey":
        return TrackedKey(jax.random.fold_in(self._key, data),
                          f"{self.label}.fold_in({data})")

    # -- consumption -------------------------------------------------------

    def use(self, site: str = "use()") -> Any:
        if self.consumed_at is not None:
            raise KeyReuseError(
                f"PRNG key {self.label!r} consumed twice: first at "
                f"{self.consumed_at}, now at {site} — derive a fresh key "
                f"with split()/fold_in() instead (lint rule R3)")
        self.consumed_at = site
        return self._key

    def __jax_array__(self):
        return self.use("__jax_array__ (implicit conversion)")

    def __repr__(self) -> str:
        state = f"consumed at {self.consumed_at}" \
            if self.consumed_at else "fresh"
        return f"TrackedKey({self.label}, {state})"


def tracked(key, label: str = "key") -> TrackedKey:
    """Wrap a raw JAX PRNG key (or another TrackedKey's raw key) in a
    reuse tracker."""
    if isinstance(key, TrackedKey):
        return key
    return TrackedKey(key, label)
