"""jit'd public wrapper for the fused DoRA-LoRA linear.

``fused_dora(...)`` dispatches to the Pallas TPU kernel on TPU backends
and to interpret mode elsewhere (this container is CPU-only; interpret
mode executes the same kernel body for validation).  Batched inputs
(..., K) are flattened to (M, K) and padded to tile boundaries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_dora.fused_dora import fused_dora_matmul
from repro.kernels.fused_dora.ref import fused_dora_ref  # noqa: F401  (re-exported via repro.kernels)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_dora(x, w0, a_dir, a_mag, b_dir, b_mag, da_dir=None, db_mag=None,
               *, scale: float = 1.0, interpret: bool | None = None):
    if da_dir is None:
        da_dir = jnp.zeros_like(a_dir)
    if db_mag is None:
        db_mag = jnp.zeros_like(b_mag)
    batch_shape = x.shape[:-1]
    K = x.shape[-1]
    N = w0.shape[1]
    xm = x.reshape(-1, K)
    M = xm.shape[0]
    if interpret is None:
        interpret = not _on_tpu()

    # tile sizes: shrink for small problems, keep MXU-aligned when possible
    bm = 256 if M % 256 == 0 else (128 if M % 128 == 0 else M)
    bn = 256 if N % 256 == 0 else (128 if N % 128 == 0 else N)
    bk = 512 if K % 512 == 0 else (128 if K % 128 == 0 else K)
    y = fused_dora_matmul(xm, w0, a_dir, a_mag, b_dir, b_mag, da_dir, db_mag,
                          scale=scale, bm=bm, bn=bn, bk=bk,
                          interpret=interpret)
    return y.reshape(*batch_shape, N)


__all__ = ["fused_dora", "fused_dora_ref"]
