"""Gemma 3 1B — dense, 5:1 local(SWA-512):global interleave, 128k-class
context, MQA kv=1, head_dim 256 [hf:google/gemma-3-1b-pt]."""
from repro.models.config import ArchConfig, reduced

ARCH = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab_size=262144, d_head=256,
    local_global=5, sliding_window=512, rope_theta=1e6,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
SMOKE = reduced(ARCH)
