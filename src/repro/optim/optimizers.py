"""From-scratch optimizers (no optax offline).

An ``Optimizer`` is a pair of pure functions:

  init(params) -> opt_state
  update(grads, opt_state, params, step) -> (updates, new_opt_state)

``updates`` are *deltas* to add to params.  ``masked`` wraps an optimizer so
that leaves where the bool-mask pytree is False get zero updates and carry no
optimizer state (crucial for LoRA: frozen base params must not allocate
AdamW moments — that is the PEFT memory story).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import pytree as pt

Pytree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[..., tuple[Pytree, Pytree]]


class AdamWState(NamedTuple):
    mu: Pytree
    nu: Pytree


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return AdamWState(
            mu=jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), params),
            nu=jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), params),
        )

    def update(grads, state, params, step):
        step = step + 1  # bias correction uses 1-indexed step
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = sched(step)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


class SGDState(NamedTuple):
    mom: Pytree


def sgd(lr, momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return SGDState(mom=())
        return SGDState(mom=jax.tree.map(
            lambda x: jnp.zeros_like(x, dtype=jnp.float32), params))

    def update(grads, state, params, step):
        lr_t = sched(step)
        g = grads
        if weight_decay:
            g = jax.tree.map(lambda gi, p: gi + weight_decay * p, g, params)
        if momentum == 0.0:
            updates = jax.tree.map(lambda gi, p: (-lr_t * gi).astype(p.dtype), g, params)
            return updates, state
        mom = jax.tree.map(lambda m, gi: momentum * m + gi.astype(jnp.float32),
                           state.mom, g)
        updates = jax.tree.map(lambda m, p: (-lr_t * m).astype(p.dtype), mom, params)
        return updates, SGDState(mom=mom)

    return Optimizer(init=init, update=update)


def masked(inner: Optimizer, mask: Pytree) -> Optimizer:
    """Apply ``inner`` only where the bool-mask pytree is True.

    Masked-out leaves are replaced by zero-size sentinel arrays before the
    inner optimizer sees them, so frozen params carry **zero bytes** of
    optimizer state (the PEFT memory story) while pytree structure stays
    intact for jit/pjit.
    """
    _sent = lambda: jnp.zeros((0,), jnp.float32)

    def init(params):
        selected = jax.tree.map(lambda m, p: p if m else _sent(), mask, params)
        return inner.init(selected)

    def update(grads, state, params, step):
        g_sel = jax.tree.map(lambda m, g: g if m else _sent(), mask, grads)
        p_sel = jax.tree.map(lambda m, p: p if m else _sent(), mask, params)
        upd, new_state = inner.update(g_sel, state, p_sel, step)
        full_upd = jax.tree.map(
            lambda m, u, p: u if m else jnp.zeros_like(p), mask, upd, params)
        return full_upd, new_state

    return Optimizer(init=init, update=update)


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Pytree:
    norm = pt.global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


def chain_clip(inner: Optimizer, max_norm: float) -> Optimizer:
    def update(grads, state, params, step):
        return inner.update(clip_by_global_norm(grads, max_norm), state,
                            params, step)

    return Optimizer(init=inner.init, update=update)


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(lambda p, u: p + u, params, updates)


OptState = Any
