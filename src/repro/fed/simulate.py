"""Generic federated PEFT engine.

Clients are a leading vmapped axis on the adapter overlay; the frozen
backbone is shared.  On a multi-device mesh the client axis is sharded
over ('pod','data') so aggregation lowers to an all-reduce carrying only
adapter bytes (see launch/train.py for the pjit'd variant); on CPU this
same code runs on one device for the paper-scale benchmarks.

The engine is method-agnostic: every method — the paper's
FedLoRA-Optimizer and all baselines — is a ``FedMethod`` strategy from
``core/methods.py`` (adapter factory, stage masks, aggregate fn, loss
extras, keep-local regex).  Adding a baseline is one ``register(...)``
call; this module contains zero per-method branches.

Hot loops (stage-1 local round, stage-2 global, stage-3 personalize)
are each ONE jitted ``lax.scan`` over local steps with the adapter /
optimizer-state buffers donated — no per-step Python dispatch and no
per-step device→host sync.  ``local_round_reference`` keeps the
per-step host-synced loop as the parity oracle and the perf baseline
(see benchmarks/perf_micro.py).
"""
from __future__ import annotations

import dataclasses
import re
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import aggregation as agg
from repro.core import peft
from repro.core.methods import get_method
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim import adamw, masked, chain_clip
from repro.optim.optimizers import apply_updates
from repro.utils import pytree as pt

Params = Any


@dataclasses.dataclass(frozen=True)
class FedHyper:
    method: str = "fedlora_opt"   # any name in core.methods.available_methods()
    n_clients: int = 4
    rounds: int = 10
    local_steps: int = 5
    batch: int = 8
    seq_len: int = 64
    lr: float = 1e-3
    server_lr: float = 5e-4
    global_steps: int = 5          # stage-2 ΔA_D steps per round (pipeline)
    personal_steps: int = 20       # stage-3 ΔB_M steps
    lam: float = 1e-3              # Eq. 11 Frobenius regularizer
    prox_mu: float = 0.0           # FedProx proximal coefficient
    pipeline: bool = True          # global→local staging (Fig. 3 ablation)
    clip: float = 1.0
    seed: int = 0
    # Heterogeneous fleet: one LoRA rank per client (len == n_clients).
    # None → every client at cfg.lora_rank.  Mixed-rank fleets allocate
    # adapters at r_server = server_rank or max(client_ranks) and mask
    # every update above each client's own rank, so the whole fleet still
    # runs the single jitted lax.scan round (the client axis stays
    # stackable).
    client_ranks: tuple = None
    # Server-side adapter rank for a heterogeneous fleet (0 → the fleet's
    # max).  Raising it widens the allocation so exact_fedavg's truncated
    # re-factorization can hold more of Σ wᵢ·AᵢBᵢ — at r_server ≥ Σ rᵢ
    # it is exact.  Ignored on uniform fleets.
    server_rank: int = 0
    # Per-client data-size aggregation weights (len == n_clients); None →
    # uniform.  Threaded into the method's aggregate fn (every aggregator
    # accepts ``weights``; trimmed-mean ignores them by contract).
    client_weights: tuple = None

    def __post_init__(self):
        """Validate + normalize the fleet vectors at the dataclass
        boundary: lists/ndarrays become plain tuples, and length/value
        errors surface here — not as a shape mismatch deep inside jit."""
        if self.client_ranks is not None:
            ranks = tuple(int(r) for r in self.client_ranks)
            object.__setattr__(self, "client_ranks", ranks)
            peft.fleet_alloc_rank(ranks, self.n_clients, self.server_rank)
        if self.client_weights is not None:
            weights = tuple(float(w) for w in self.client_weights)
            object.__setattr__(self, "client_weights", weights)
            peft.validate_client_weights(weights, self.n_clients)


class FedSim:
    """Federated simulation over one ArchConfig + per-client datasets."""

    def __init__(self, cfg: ArchConfig, hp: FedHyper, base=None):
        if cfg.use_fused_dora:
            raise ValueError(
                "use_fused_dora is forward/serving-only (the Pallas kernel "
                "defines no VJP); training through FedSim requires the jnp "
                "adapter path — construct with use_fused_dora=False")
        self.cfg, self.hp = cfg, hp
        self.method = get_method(hp.method)
        rng = jax.random.PRNGKey(hp.seed)
        r_base, r_ad = jax.random.split(rng)
        self.base = M.init_params(r_base, cfg) if base is None else base

        if hp.client_ranks is not None:
            if not self.method.het_ranks:
                raise ValueError(
                    f"method {self.method.name!r} has no rank dimension "
                    "(het_ranks=False); client_ranks requires a "
                    "LoRA-family method")
            self.alloc_rank = peft.fleet_alloc_rank(
                hp.client_ranks, hp.n_clients, hp.server_rank)
            self._client_ranks = jnp.asarray(hp.client_ranks, jnp.int32)
            ad = self.method.make_adapter(self.base, cfg, r_ad,
                                          rank=self.alloc_rank)
        else:
            self.alloc_rank = cfg.lora_rank
            self._client_ranks = None
            ad = self.method.make_adapter(self.base, cfg, r_ad)
        self.adapter_template = ad
        # per-client rank masks (None on uniform fleets: the masked and
        # unmasked programs are then byte-identical, so the uniform path
        # pays nothing)
        self.rank_mask = (peft.client_rank_masks(ad, self._client_ranks)
                          if self._client_ranks is not None else None)
        self.train_mask = self.method.train_mask(ad)
        self.global_mask = self.method.stage_global_mask(ad)
        self.local_mask = self.method.stage_local_mask(ad)
        self.reg_mask = (self.method.personal_reg(ad)
                         if self.method.personal_reg else None)
        self._keep_rx = (re.compile(self.method.keep_local)
                         if self.method.keep_local else None)
        # the comm class the method's aggregation moves on the wire
        # (psum: 2·|adapters|; all_gather: (C+1)·|adapters|; q8/topk:
        # compressed uplink + dense downlink — see comm_bytes_per_round)
        self._comm_class = agg.comm_class(self.method)
        self._topk_ratio = 0.01
        try:
            self._topk_ratio = agg.collective_form(self.method).topk_ratio
        except ValueError:
            pass                  # simulator-only aggregate: psum billing

        C = hp.n_clients
        self.client_adapters = agg.broadcast_to_clients(ad, C)
        if self.rank_mask is not None:
            self.client_adapters = peft.apply_rank_masks(
                self.client_adapters, self.rank_mask)
        self._build_steps()
        self.opt_state = jax.vmap(self.opt.init)(self.client_adapters)
        self._step = jnp.zeros((), jnp.int32)
        self.comm_bytes = 0
        # post-scale / pre-revert client state of the last faulted round
        # (what a straggler actually computed) — see run_cohort_round
        self.last_trained: dict | None = None
        # round reference for the FedProx proximal term (aliases the
        # current client adapters; prox methods never donate them)
        self._round_ref = self.client_adapters if self.method.prox else None

    # ------------------------------------------------------------------
    def _loss(self, base, adapters, batch, rng, lam, prox_ref, prox_mu):
        params = pt.merge_trees(base, adapters)
        loss, met = M.loss_and_metrics(params, batch, self.cfg, rng=rng)
        if lam:
            reg = sum(jnp.sum(jnp.square(x)) for m, x in zip(
                jax.tree.leaves(self.reg_mask), jax.tree.leaves(adapters))
                if m)
            loss = loss + 0.5 * lam * reg
        if prox_mu and prox_ref is not None:
            prox = pt.tree_dot(pt.tree_sub(adapters, prox_ref),
                               pt.tree_sub(adapters, prox_ref))
            loss = loss + 0.5 * prox_mu * prox
        return loss, met

    def _build_steps(self):
        hp, cfg, method = self.hp, self.cfg, self.method
        C = hp.n_clients
        self.opt = chain_clip(masked(adamw(hp.lr), self.train_mask), hp.clip)
        self.opt_global = chain_clip(masked(adamw(hp.server_lr),
                                            self.global_mask), hp.clip)
        self.opt_local = chain_clip(masked(adamw(hp.lr), self.local_mask),
                                    hp.clip)

        def one_client_step(base, adapters, opt_state, batch, rng, step,
                            prox_ref, rmask, *, opt, lam, prox_mu):
            (loss, met), g = jax.value_and_grad(
                self._loss, argnums=1, has_aux=True)(
                base, adapters, batch, rng, lam, prox_ref, prox_mu)
            upd, opt_state = opt.update(g, opt_state, adapters, step)
            if rmask is not None:
                # heterogeneous fleet: zero the update rows above this
                # client's rank (adapters are allocated at r_max)
                upd = jax.tree.map(jnp.multiply, upd, rmask)
            # grad_norm rides the metrics unconditionally (not gated on
            # telemetry) so the compiled program is identical with obs
            # on and off — the no-op-invariance contract of repro.obs
            met = dict(met, grad_norm=pt.global_norm(g))
            return apply_updates(adapters, upd), opt_state, met

        prox_mu = hp.prox_mu if method.prox else 0.0
        lam_pers = hp.lam if method.personal_reg is not None else 0.0
        mask_ax = 0 if self.rank_mask is not None else None
        step_train = partial(one_client_step, opt=self.opt, lam=0.0,
                             prox_mu=prox_mu)
        vstep = jax.vmap(step_train, in_axes=(None, 0, 0, 0, 0, 0, 0,
                                              mask_ax))
        self._vstep = jax.jit(vstep)          # per-step oracle / perf baseline
        step_pers = partial(one_client_step, opt=self.opt_local, lam=lam_pers,
                            prox_mu=0.0)
        vstep_pers = jax.vmap(step_pers, in_axes=(None, 0, 0, 0, 0, 0, 0,
                                                  mask_ax))
        step_glob = partial(one_client_step, opt=self.opt_global, lam=0.0,
                            prox_mu=0.0)

        # ---- jitted lax.scan over local steps ------------------------
        # Per-step rng folds the *traced* step counter, so host sync is
        # gone yet the key sequence matches the reference loop exactly.
        # Short rounds (the paper setting: 5 local steps) are fully
        # unrolled inside the jit — XLA fuses across steps and reuses
        # activation buffers; long stages keep a rolled scan so compile
        # time stays bounded.
        def _unroll(batches):
            t = jax.tree.leaves(batches)[0].shape[0]
            return t if t <= 8 else 1

        def make_scan(vstep_fn, fold_offset, with_prox):
            def scan_fn(base, adapters, opt_state, step0, batches, rng,
                        rmask, *prox):
                def body(carry, b):
                    ad, ost, step = carry
                    rngs = jax.random.split(
                        jax.random.fold_in(rng, fold_offset + step), C)
                    steps = jnp.full((C,), step, jnp.int32)
                    ref = prox[0] if with_prox else ad
                    ad, ost, met = vstep_fn(base, ad, ost, b, rngs, steps,
                                            ref, rmask)
                    return (ad, ost, step + 1), met
                (ad, ost, step), mets = jax.lax.scan(
                    body, (adapters, opt_state, step0), batches,
                    unroll=_unroll(batches))
                return ad, ost, step, jax.tree.map(lambda m: m[-1], mets)
            return scan_fn

        # prox methods keep the round reference aliased to the adapters,
        # so only the optimizer state is donated for them.  obs.annotate
        # names each jitted program in profiler traces (host-side wrapper
        # only — the compiled computation is untouched).
        self._round_scan = obs.annotate("fed/round_scan")(jax.jit(
            make_scan(vstep, 0, method.prox),
            donate_argnums=(2,) if method.prox else (1, 2)))
        self._pers_scan = obs.annotate("fed/stage3_personalize")(
            jax.jit(make_scan(vstep_pers, 31, False), donate_argnums=(2,)))

        def global_fn(base, aggregated, opt_state, batches, rng):
            # the server model trains at the full allocated rank — no mask
            def body(carry, b):
                ad, ost, step = carry
                ad, ost, _ = step_glob(base, ad, ost, b,
                                       jax.random.fold_in(rng, step), step,
                                       ad, None)
                return (ad, ost, step + 1), None
            (ad, ost, _), _ = jax.lax.scan(
                body, (aggregated, opt_state, jnp.zeros((), jnp.int32)),
                batches)
            return ad, ost
        self._global_scan = obs.annotate("fed/stage2_global")(
            jax.jit(global_fn, donate_argnums=(2,)))

        def eval_fn(base, adapters, batch):
            params = pt.merge_trees(base, adapters)
            _, met = M.loss_and_metrics(params, batch, cfg)
            return met
        self._eval = jax.jit(eval_fn)
        self._veval = jax.jit(jax.vmap(eval_fn, in_axes=(None, 0, 0)))
        agg_fn = method.aggregate
        if method.rank_aware:
            # rank-aware aggregators take the fleet's ranks; a uniform
            # fleet is the degenerate all-r_max case
            ranks = (self._client_ranks if self._client_ranks is not None
                     else jnp.full((C,), self.alloc_rank, jnp.int32))
            agg_fn = partial(agg_fn, ranks=ranks)
        # fleet weights stay a *call-time* argument of the jitted
        # aggregate (not baked): cohort rounds mask them per round with
        # participation flags, with no recompile beyond the one
        # structural weights-None ↔ weights-array retrace
        self._base_weights = (jnp.asarray(hp.client_weights, jnp.float32)
                              if hp.client_weights is not None else None)
        self._agg = jax.jit(agg_fn)
        self._drift_fn = None           # built on first telemetry-enabled
        self._obs_wall: dict = {}       # last round's wall-clock split

    def _client_drift(self, clients, aggregated):
        """Per-client aggregate drift ‖clientᵢ − aggregate‖ over the
        *shared* leaves (keep-local leaves are personal by contract and
        excluded; heterogeneous fleets mask the diff to each client's own
        rank rows).  Telemetry-only — built lazily so the disabled path
        never compiles it."""
        if self._drift_fn is None:
            keep_rx, rmask = self._keep_rx, self.rank_mask

            def fn(clients, aggregated):
                cl = jax.tree_util.tree_leaves_with_path(clients)
                ag = jax.tree.leaves(aggregated)
                rm = (jax.tree.leaves(rmask) if rmask is not None
                      else [None] * len(ag))
                tot = jnp.zeros((), jnp.float32)
                for (p, x), y, m in zip(cl, ag, rm):
                    if keep_rx is not None and keep_rx.search(pt.path_str(p)):
                        continue
                    d = x - y[None]
                    if m is not None:
                        d = d * m
                    tot = tot + jnp.sum(jnp.square(d),
                                        axis=tuple(range(1, x.ndim)))
                return jnp.sqrt(tot)
            self._drift_fn = jax.jit(fn)
        return self._drift_fn(clients, aggregated)

    # ------------------------------------------------------------------
    @staticmethod
    def _stack_batches(batches: list[dict]) -> dict:
        return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)

    def local_round(self, batches: list[dict], rng) -> dict:
        """One round of stage-1 local training: a single jitted lax.scan
        over local steps.  batches: list (per local step) of stacked
        (C, B, S) dicts."""
        stacked = self._stack_batches(batches)
        args = (self.base, self.client_adapters, self.opt_state, self._step,
                stacked, rng, self.rank_mask)
        if self.method.prox:
            args = args + (self._round_ref,)
        enabled = obs.enabled()
        t0 = time.perf_counter() if enabled else 0.0
        self.client_adapters, self.opt_state, self._step, mets = \
            self._round_scan(*args)
        if enabled:
            # block so the span covers device work; the disabled path
            # keeps async dispatch (no sync is added there)
            jax.block_until_ready(self.client_adapters)
            dt = time.perf_counter() - t0
            obs.observe("span_seconds", dt, span="fed/round_scan",
                        method=self.hp.method)
            self._obs_wall["scan"] = dt
        return {k: np.asarray(v) for k, v in mets.items()}

    def local_round_reference(self, batches: list[dict], rng) -> dict:
        """Seed-style per-step loop (host-synced step counter, Python
        dispatch per step).  Produces the same adapters as local_round —
        kept as the parity oracle and the perf_micro baseline."""
        C = self.hp.n_clients
        mets = None
        for b in batches:
            rngs = jax.random.split(
                jax.random.fold_in(rng, int(self._step)), C)
            steps = jnp.full((C,), self._step, jnp.int32)
            ref = self._round_ref if self.method.prox else self.client_adapters
            self.client_adapters, self.opt_state, mets = self._vstep(
                self.base, self.client_adapters, self.opt_state, b, rngs,
                steps, ref, self.rank_mask)
            self._step = self._step + 1
        return {k: np.asarray(v) for k, v in (mets or {}).items()}

    def aggregate(self, *, weights=None, staleness=None,
                  participation=None) -> Params:
        """Method aggregation (Eqs. 5–8 for ours, FedAvg/trimmed-mean for
        baselines) + comm accounting; broadcasts the aggregate back with
        keep-local leaves (e.g. dB_mag) preserved per client.

        Cohort/fault arguments (all optional, None → the synchronous
        full-participation round, byte-identical to the pre-cohort path):

          weights        per-round (C,) override of ``hp.client_weights``
          staleness      per-client rounds-since-sync (C,) — threaded to
                         ``needs_staleness`` aggregates (FedBuff family)
          participation  per-client 0/1 flags (C,): non-participants get
                         aggregation weight 0 and are not billed (a
                         dropped client uploads nothing)
        """
        enabled = obs.enabled()
        t0 = time.perf_counter() if enabled else 0.0
        C = self.hp.n_clients
        w = weights if weights is not None else self._base_weights
        if participation is not None:
            part = jnp.asarray(participation, jnp.float32)
            base_w = (w if w is not None
                      else jnp.ones((C,), jnp.float32))
            w = base_w * part
        kwargs = {}
        if w is not None:
            kwargs["weights"] = jnp.asarray(w, jnp.float32)
        if getattr(self.method.aggregate, "needs_step", False):
            # compressed codecs derive their stochastic-rounding keys
            # from the round counter (post-round, = the step the
            # production round_body passes), so both engines draw
            # identical masks
            kwargs["step"] = self._step
        if getattr(self.method.aggregate, "needs_staleness", False):
            kwargs["staleness"] = (
                jnp.zeros((C,), jnp.float32) if staleness is None
                else jnp.asarray(staleness, jnp.float32))
        aggregated = self._agg(self.client_adapters, **kwargs)
        if enabled:
            jax.block_until_ready(aggregated)
            dt = time.perf_counter() - t0
            obs.observe("span_seconds", dt, span="fed/aggregate",
                        method=self.hp.method)
            self._obs_wall["aggregate"] = dt
        prev_bytes = self.comm_bytes
        # billing is participation-masked: a dropped/straggling client
        # uploads nothing this round (stragglers bill at delivery — see
        # fed/cohort.CohortSim)
        live = (np.asarray(jax.device_get(participation)) > 0
                if participation is not None else np.ones((C,), bool))
        if self._client_ranks is None:
            self.comm_bytes += int(live.sum()) * agg.comm_bytes_per_round(
                self.adapter_template, exclude_rx=self.method.keep_local,
                comm=self._comm_class, n_clients=C,
                topk_ratio=self._topk_ratio)
        else:
            # heterogeneous fleet: each client moves only its own rank rows
            for r, on in zip(self.hp.client_ranks, live):
                if not on:
                    continue
                self.comm_bytes += agg.comm_bytes_per_round(
                    self.adapter_template, exclude_rx=self.method.keep_local,
                    rank=int(r), comm=self._comm_class, n_clients=C,
                    topk_ratio=self._topk_ratio)
        if enabled:
            obs.inc("fed/comm_bytes", self.comm_bytes - prev_bytes,
                    method=self.hp.method, comm=self._comm_class)
            self._obs_wall["comm_bytes"] = self.comm_bytes - prev_bytes
            # drift is measured pre-rebroadcast (the client models as
            # they finished the round, vs the server aggregate)
            self._obs_wall["drift"] = np.asarray(
                self._client_drift(self.client_adapters, aggregated),
                np.float64).reshape(-1)
            t0 = time.perf_counter()
        bcast = self._rebroadcast_keep_personal(aggregated)
        if enabled:
            jax.block_until_ready(bcast)
            dt = time.perf_counter() - t0
            obs.observe("span_seconds", dt, span="fed/rebroadcast",
                        method=self.hp.method)
            self._obs_wall["rebroadcast"] = dt
        self.client_adapters = bcast
        if self.method.prox:
            self._round_ref = bcast
        return aggregated

    def run_round(self, batches: list[dict], rng) -> dict:
        """One full federated round — stage-1 local training followed by
        the method's aggregation/rebroadcast.  This is the parity oracle
        the distributed tests compare the production shard_map round
        (launch/train.make_fed_train_step) against: after this call,
        ``self.client_adapters`` must match the train step's output
        adapters for the same initial state and batches."""
        if not obs.enabled():
            mets = self.local_round(batches, rng)
            self.aggregate()
            return mets
        self._obs_wall = {}
        t0 = time.perf_counter()
        mets = self.local_round(batches, rng)
        self.aggregate()
        total = time.perf_counter() - t0
        obs.observe("span_seconds", total, span="fed/round",
                    method=self.hp.method)
        obs.inc("fed/rounds", method=self.hp.method)
        w = self._obs_wall
        ce = np.asarray(mets["ce"], np.float64).reshape(-1)
        gn = np.asarray(mets.get("grad_norm", np.zeros_like(ce)),
                        np.float64).reshape(-1)
        drift = np.asarray(w.get("drift", np.zeros_like(ce))).reshape(-1)
        spread = float(ce.max() - ce.min()) if ce.size else 0.0
        obs.set_gauge("fed/loss_spread", spread, method=self.hp.method)
        for c in range(ce.size):
            obs.observe("fed/client_ce", float(ce[c]),
                        method=self.hp.method, client=c)
        obs.event(
            "fed_round", method=self.hp.method, step=int(self._step),
            clients=int(ce.size),
            ce=[round(float(v), 6) for v in ce],
            grad_norm=[round(float(v), 6) for v in gn],
            drift=[round(float(v), 6) for v in drift],
            loss_spread=round(spread, 6),
            comm_bytes=int(w.get("comm_bytes", 0)),
            comm_class=self._comm_class,
            wall={"scan": round(w.get("scan", 0.0), 6),
                  "aggregate": round(w.get("aggregate", 0.0), 6),
                  "rebroadcast": round(w.get("rebroadcast", 0.0), 6),
                  "total": round(total, 6)})
        return mets

    def client_comm_bytes(self, client: int | None = None) -> int:
        """One client's wire bytes for a single round of this method's
        collective (the unit ``aggregate`` bills per participant) —
        cohort drivers use it to bill straggler deliveries at arrival."""
        rank = (int(self.hp.client_ranks[client])
                if self._client_ranks is not None and client is not None
                else None)
        return agg.comm_bytes_per_round(
            self.adapter_template, exclude_rx=self.method.keep_local,
            rank=rank, comm=self._comm_class, n_clients=self.hp.n_clients,
            topk_ratio=self._topk_ratio)

    def run_cohort_round(self, batches: list[dict], rng, *,
                         participation=None, staleness=None,
                         update_scale=None, weights=None) -> dict:
        """One federated round under cohort faults — the parity oracle
        for the production round with the same fault arguments
        (``launch/train.round_step``).  All fault inputs are (C,) arrays:

          participation  0/1 flags; a 0-client's adapters AND optimizer
                         state revert to their round-start values (its
                         mid-round work is lost), it contributes weight 0
                         to the aggregate, and it is not billed.
          update_scale   multiplies each client's round *update*
                         (corrupted-update adversaries inflate theirs);
                         honest clients pass 1.
          staleness      rounds-since-last-sync, consumed by
                         ``needs_staleness`` aggregates (FedBuff family).
          weights        per-round override of ``hp.client_weights``.

        Fault transforms are statically gated: with every argument None
        this is byte-identical to ``run_round`` (the transforms would
        otherwise perturb f32 bit patterns — ``old + 1·(new−old) ≠ new``).
        When active, BOTH engines apply the identical expressions to ALL
        clients (identity values for honest ones), so parity holds bit
        for bit through the fault layer.

        After a faulted round ``self.last_trained`` holds the post-scale,
        pre-revert client state — what a straggler actually computed —
        for delayed delivery (see ``fed/cohort.CohortSim``)."""
        use_faults = participation is not None or update_scale is not None
        C = self.hp.n_clients
        if use_faults:
            # jnp.copy: the round scan donates the live buffers
            snap_ad = jax.tree.map(jnp.copy, self.client_adapters)
            snap_ost = jax.tree.map(jnp.copy, self.opt_state)
        mets = self.local_round(batches, rng)
        self.last_trained = None
        if use_faults:
            s = (jnp.ones((C,), jnp.float32) if update_scale is None
                 else jnp.asarray(update_scale, jnp.float32))
            p = (jnp.ones((C,), jnp.float32) if participation is None
                 else jnp.asarray(participation, jnp.float32))

            def scaled(new, old):
                sb = s.reshape((C,) + (1,) * (new.ndim - 1))
                return old + sb * (new - old)

            def revert(new, old):
                pb = p.reshape((C,) + (1,) * (new.ndim - 1))
                return jnp.where(pb > 0, new, old)

            self.client_adapters = jax.tree.map(
                scaled, self.client_adapters, snap_ad)
            self.last_trained = {"adapters": self.client_adapters,
                                 "opt_state": self.opt_state}
            self.client_adapters = jax.tree.map(
                revert, self.client_adapters, snap_ad)
            self.opt_state = jax.tree.map(revert, self.opt_state, snap_ost)
        if participation is not None and not np.any(
                np.asarray(jax.device_get(participation)) > 0):
            # every cohort client dropped: nothing uploads, nothing
            # aggregates, nothing is billed — the round is a no-op (for
            # prox methods the reverted adapters equal the round-start
            # anchor bitwise, so the aliased round reference stays valid)
            if self.method.prox:
                self._round_ref = self.client_adapters
            return mets
        self.aggregate(weights=weights, staleness=staleness,
                       participation=participation)
        return mets

    @staticmethod
    def _leaf(tree, path):
        node = tree
        for k in path.split("/"):
            node = node[k]
        return node

    def _rebroadcast_keep_personal(self, aggregated):
        """Broadcast the aggregate to every client; leaves matching the
        method's keep-local regex retain each client's own value, and on
        a heterogeneous fleet each client re-masks the broadcast down to
        its own rank: a rank-r client receives the first r rank rows of
        the server model (for ``lora_exact`` those are the top-r singular
        directions of the exact aggregate).  The logic itself lives in
        ``core.aggregation.rebroadcast_keep_personal`` — shared with the
        production shard_map pipeline (launch/train.py), so the two paths
        cannot diverge."""
        return agg.rebroadcast_keep_personal(
            aggregated, self.client_adapters, self._keep_rx, self.rank_mask)

    def global_stage(self, aggregated: Params, server_batches: list[dict],
                     rng) -> Params:
        """Stage 2 — train the global-stage leaves (ΔA_D for the paper,
        Eq. 9) on the server task mixture, as one jitted scan."""
        opt_state = self.opt_global.init(aggregated)
        enabled = obs.enabled()
        t0 = time.perf_counter() if enabled else 0.0
        aggregated, _ = self._global_scan(
            self.base, aggregated, opt_state,
            self._stack_batches(server_batches), rng)
        self.client_adapters = self._rebroadcast_keep_personal(aggregated)
        if enabled:
            jax.block_until_ready(self.client_adapters)
            dt = time.perf_counter() - t0
            obs.observe("span_seconds", dt, span="fed/stage2_global",
                        method=self.hp.method)
            obs.event("fed_stage", stage="global", method=self.hp.method,
                      steps=len(server_batches), wall=round(dt, 6))
        return aggregated

    def personalize(self, batches: list[dict], rng) -> None:
        """Stage 3 — per-client fine-tune of the local-stage leaves
        (ΔB_M with the Eq. 11 regularizer for the paper)."""
        opt_state = jax.vmap(self.opt_local.init)(self.client_adapters)
        enabled = obs.enabled()
        t0 = time.perf_counter() if enabled else 0.0
        self.client_adapters, _, _, _ = self._pers_scan(
            self.base, self.client_adapters, opt_state,
            jnp.zeros((), jnp.int32), self._stack_batches(batches), rng,
            self.rank_mask)
        if enabled:
            jax.block_until_ready(self.client_adapters)
            dt = time.perf_counter() - t0
            obs.observe("span_seconds", dt, span="fed/stage3_personalize",
                        method=self.hp.method)
            obs.event("fed_stage", stage="personalize",
                      method=self.hp.method, steps=len(batches),
                      wall=round(dt, 6))

    # ------------------------------------------------------------------
    # checkpointing
    # ------------------------------------------------------------------

    def state_tree(self) -> dict:
        """Round-resumable simulation state.  ``client_ranks`` is always
        recorded (uniform fleets store the flat rank) so a heterogeneous
        checkpoint can never silently load into a mismatched fleet."""
        ranks = (self._client_ranks if self._client_ranks is not None
                 else jnp.full((self.hp.n_clients,), self.alloc_rank,
                               jnp.int32))
        tree = {"client_adapters": self.client_adapters,
                "opt_state": self.opt_state,
                "step": self._step,
                "comm_bytes": np.asarray(self.comm_bytes, np.int64),
                "client_ranks": ranks}
        if self.method.prox:
            # the proximal anchor is its own state: mid-cycle (after a
            # round, before aggregate) it is NOT the current adapters
            tree["round_ref"] = self._round_ref
        return tree

    def save(self, path: str, round_idx: int = 0) -> None:
        from repro.checkpoint.ckpt import save_checkpoint
        save_checkpoint(path, self.state_tree(), step=round_idx)

    def load(self, path: str) -> int:
        """Restore state saved by ``save`` into this sim (same cfg/hp).
        Raises if the checkpoint's per-client ranks don't match this
        fleet's — rank layout is state, not a detail."""
        from repro.checkpoint.ckpt import restore_checkpoint
        tree, round_idx = restore_checkpoint(path, self.state_tree())
        want = np.asarray(self.state_tree()["client_ranks"])
        got = np.asarray(tree["client_ranks"])
        if not np.array_equal(want, got):
            raise ValueError(
                f"checkpoint fleet ranks {got.tolist()} do not match this "
                f"sim's {want.tolist()}")
        self.client_adapters = tree["client_adapters"]
        self.opt_state = tree["opt_state"]
        self._step = jnp.asarray(tree["step"])
        self.comm_bytes = int(tree["comm_bytes"])
        if self.method.prox:
            self._round_ref = tree["round_ref"]
        return round_idx

    # ------------------------------------------------------------------
    def eval_global(self, aggregated: Params, batches: list[dict]) -> dict:
        accs, ces = [], []
        for b in batches:
            met = self._eval(self.base, aggregated, b)
            accs.append(float(met["acc"]))
            ces.append(float(met["ce"]))
        return {"acc": float(np.mean(accs)), "ce": float(np.mean(ces))}

    def eval_personalized(self, batches_stacked: list[dict]) -> dict:
        """batches_stacked: list of (C,B,S) dicts, each client evaluated on
        its own task distribution."""
        accs = []
        for b in batches_stacked:
            met = self._veval(self.base, self.client_adapters, b)
            accs.append(np.asarray(met["acc"]))
        per_client = np.mean(np.stack(accs), axis=0)
        return {"acc": float(np.mean(per_client)),
                "per_client": per_client.tolist()}
