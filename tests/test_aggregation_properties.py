"""Property-based tests for the aggregation family (guarded-hypothesis
pattern from tests/conftest.py: generative with hypothesis installed, a
deterministic seed sweep without it).

Properties, checked for EVERY aggregator reachable through the FedMethod
registry (so a new ``register(...)`` call is automatically under test):

  * client-axis permutation invariance — an aggregation must not care
    about client order;
  * fixed point on identical clients — aggregating C copies of one
    adapter returns that adapter;
  * weight convexity — the (weighted) aggregate lies inside the
    per-coordinate client envelope;

plus the heterogeneous-rank separation result: ``exact_fedavg``
reconstructs Σ wᵢ·AᵢBᵢ to f32 tolerance on mixed-rank fleets where
zero-pad averaging provably does not (Nguyen et al.: the mean of the
factors is not the mean of the products).

Aggregators whose output factors are only defined up to re-factorization
(``lora_exact``: SVD sign/order) are compared in *delta space*
(A @ B), which is the quantity federated averaging is about.

Compressed-uplink aggregators (``lora_fedavg_q8``/``lora_fedavg_topk``)
are intentionally lossy — stochastic rounding is keyed per client index
(not permutation-equivariant) and both codecs break exact fixed points —
so they are exempt from the exact-equality sweep and instead obey their
own codec laws below: SR stays within one quantization bin and is
unbiased in expectation, and both compressed aggregates stay within a
provable noise envelope of exact FedAvg.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given_seeds

from repro.core import aggregation as agg
from repro.core import methods

C = 4                                  # clients per generated fleet

# aggregators compared by effective delta, not leaf-wise (re-factorization
# makes leaves non-unique)
_DELTA_ONLY = {"lora_exact"}

# lossy-codec aggregators: exempt from the exact-equality properties
# (they satisfy the bounded-error laws in the codec section instead)
_LOSSY = {"lora_fedavg_q8", "lora_fedavg_topk"}


def _registry_aggregators():
    """name → (callable(tree, weights), delta_only) for every registered
    method, with rank-aware aggregators closed over the fleet's ranks."""
    out = {}
    for name in methods.available_methods():
        if name in _LOSSY:             # codec laws live in their own section
            continue
        m = methods.get_method(name)
        out[name] = (m.aggregate, m.rank_aware, name in _DELTA_ONLY)
    return out


def _make_fleet(seed, *, rank_sufficient=False):
    """One synthetic mixed-rank client fleet of raw-LoRA pairs.

    rank_sufficient=True caps Σ ranksᵢ ≤ r_max so rank-r_max
    re-factorization (lora_exact) is exact, making delta-space
    convexity/fixed-point assertions valid for every aggregator."""
    rng = np.random.default_rng(seed)
    d_in = int(rng.integers(4, 10))
    d_out = int(rng.integers(4, 10))
    if rank_sufficient:
        r_max = int(rng.integers(C, C + 3))       # Σ ranks ≤ C ≤ r_max
        ranks = np.asarray([1] * C)
    else:
        r_max = int(rng.integers(2, 6))
        ranks = rng.integers(1, r_max + 1, size=(C,))
        ranks[rng.integers(0, C)] = r_max         # someone is at r_max
    A = np.zeros((C, d_in, r_max), np.float32)
    B = np.zeros((C, r_max, d_out), np.float32)
    for c in range(C):
        r = int(ranks[c])
        A[c, :, :r] = rng.uniform(-2, 2, size=(d_in, r))
        B[c, :r] = rng.uniform(-2, 2, size=(r, d_out))
    w = rng.uniform(0.1, 1.0, size=(C,)).astype(np.float32)
    tree = {"proj": {"lora_A": jnp.asarray(A), "lora_B": jnp.asarray(B)}}
    return tree, jnp.asarray(ranks, jnp.int32), jnp.asarray(w / w.sum())


def _call(fn, rank_aware, tree, ranks, weights=None):
    kwargs = {"ranks": ranks} if rank_aware else {}
    return fn(tree, weights, **kwargs) if weights is not None else \
        fn(tree, **kwargs)


def _delta(tree):
    return np.asarray(tree["proj"]["lora_A"] @ tree["proj"]["lora_B"])


def _assert_same(name, out_a, out_b, delta_only, atol=1e-5):
    if delta_only:
        np.testing.assert_allclose(_delta(out_a), _delta(out_b),
                                   rtol=1e-4, atol=atol, err_msg=name)
    else:
        for pa, la, lb in zip(("lora_A", "lora_B"),
                              jax.tree.leaves(out_a), jax.tree.leaves(out_b)):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       rtol=1e-5, atol=atol,
                                       err_msg=f"{name}/{pa}")


@pytest.mark.slow
@given_seeds()
def test_permutation_invariance(seed):
    tree, ranks, _ = _make_fleet(seed)
    perm = np.random.default_rng(seed + 1).permutation(C)
    tree_p = jax.tree.map(lambda x: x[perm], tree)
    ranks_p = ranks[perm]
    for name, (fn, rank_aware, delta_only) in _registry_aggregators().items():
        a = _call(fn, rank_aware, tree, ranks)
        b = _call(fn, rank_aware, tree_p, ranks_p)
        _assert_same(name, a, b, delta_only)


@pytest.mark.slow
@given_seeds()
def test_fixed_point_on_identical_clients(seed):
    tree, _, _ = _make_fleet(seed, rank_sufficient=True)
    one = jax.tree.map(lambda x: x[0], tree)
    same = agg.broadcast_to_clients(one, C)
    full = jnp.full((C,), one["proj"]["lora_A"].shape[-1], jnp.int32)
    for name, (fn, rank_aware, delta_only) in _registry_aggregators().items():
        out = _call(fn, rank_aware, same, full)
        _assert_same(name, out, one, delta_only)


@pytest.mark.slow
@given_seeds()
def test_weight_convexity(seed):
    """The weighted aggregate lies inside the per-coordinate client
    envelope — leaf-wise for mean-family aggregators, in delta space for
    re-factorizing ones (rank-sufficient fleets, so lora_exact is exact
    and Σw·AᵢBᵢ convexity applies coordinate-wise to the products)."""
    tree, ranks, w = _make_fleet(seed, rank_sufficient=True)
    for name, (fn, rank_aware, delta_only) in _registry_aggregators().items():
        out = _call(fn, rank_aware, tree, ranks, weights=w)
        if delta_only:
            deltas = np.stack(
                [np.asarray(tree["proj"]["lora_A"][c]
                            @ tree["proj"]["lora_B"][c])
                 for c in range(C)])
            checks = [(deltas, _delta(out))]
        else:
            checks = [(np.asarray(clients), np.asarray(got))
                      for clients, got in zip(jax.tree.leaves(tree),
                                              jax.tree.leaves(out))]
        for clients, got in checks:
            lo, hi = clients.min(0), clients.max(0)
            assert (got >= lo - 1e-5).all() and (got <= hi + 1e-5).all(), name


@pytest.mark.slow
@given_seeds()
def test_cohort_of_one_is_identity(seed):
    """A cohort of a single client aggregates to that client's adapters
    for every registry aggregator (trimmed-mean included: trimming 25%
    of a 1-client fleet trims nobody) — the degenerate sampled-cohort
    case the cross-device driver can legitimately produce."""
    tree, _, _ = _make_fleet(seed, rank_sufficient=True)
    one = jax.tree.map(lambda x: x[:1], tree)
    full = jnp.full((1,), one["proj"]["lora_A"].shape[-1], jnp.int32)
    want = jax.tree.map(lambda x: x[0], one)
    for name, (fn, rank_aware, delta_only) in _registry_aggregators().items():
        out = _call(fn, rank_aware, one, full)
        _assert_same(name, out, want, delta_only)


@pytest.mark.slow
@given_seeds()
def test_zero_weight_client_is_excluded(seed):
    """A zero aggregation weight removes a client from the mean exactly:
    the aggregate equals the aggregate of the remaining fleet with the
    remaining weights.  Zero weights never come from ``client_weights``
    (validated > 0 at the dataclass boundary) — they arrive at call time
    through the cohort participation mask, so this is the law dropout
    correctness rests on.  Trimmed-mean ignores weights by contract and
    is exempt (a dropped client enters its order statistics through its
    reverted round-start values, identically on both engines)."""
    rng = np.random.default_rng(seed)
    tree, _, w = _make_fleet(seed, rank_sufficient=True)
    full = jnp.full((C,), tree["proj"]["lora_A"].shape[-1], jnp.int32)
    drop = int(rng.integers(0, C))
    keep = [c for c in range(C) if c != drop]
    wz = np.asarray(w).copy()
    wz[drop] = 0.0
    sub = jax.tree.map(lambda x: x[np.asarray(keep)], tree)
    for name, (fn, rank_aware, delta_only) in _registry_aggregators().items():
        if name == "lora_trimmed":
            continue
        a = _call(fn, rank_aware, tree, full, weights=jnp.asarray(wz))
        b = _call(fn, rank_aware, sub, full[np.asarray(keep)],
                  weights=jnp.asarray(np.asarray(w)[keep]))
        _assert_same(name, a, b, delta_only)


def test_staleness_discount_law():
    """FedBuff staleness weighting: τ=0 reduces to weighted FedAvg
    EXACTLY ((1+0)^(−α) == 1.0 bitwise — the synchronous-fleet identity
    the parity sweeps rely on), and a stale client's contribution is
    discounted by (1+τ)^(−α) relative to re-weighted FedAvg."""
    rng = np.random.default_rng(7)
    x = rng.uniform(-1, 1, size=(C, 6, 4)).astype(np.float32)
    tree = {"p": {"lora_A": jnp.asarray(x)}}
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=(C,)).astype(np.float32))
    fb = agg.StalenessFedAvg(alpha=0.5)
    np.testing.assert_array_equal(
        np.asarray(fb(tree, w, staleness=jnp.zeros((C,)))["p"]["lora_A"]),
        np.asarray(agg.fedavg(tree, w)["p"]["lora_A"]))
    tau = jnp.asarray([0.0, 3.0, 0.0, 8.0], jnp.float32)
    scaled = w * agg.staleness_scale(tau, 0.5)
    np.testing.assert_allclose(
        np.asarray(fb(tree, w, staleness=tau)["p"]["lora_A"]),
        np.asarray(agg.fedavg(tree, scaled)["p"]["lora_A"]),
        rtol=1e-6, atol=1e-7)
    assert float(agg.staleness_scale(0.0)) == 1.0
    np.testing.assert_allclose(float(agg.staleness_scale(3.0)), 0.5)


# ---------------------------------------------------------------------------
# compressed-uplink codec laws (COMPRESSED comm class)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@given_seeds()
def test_sr_int8_within_one_bin_and_unbiased(seed):
    """The stochastic-rounding int8 round-trip (a) never moves a value by
    more than one quantization bin, (b) reproduces exact zeros exactly
    (zero rank-mask rows survive compression bit-for-bit), and (c) is
    unbiased: the mean decode over many rounding keys converges to the
    input at the 1/√N Monte-Carlo rate."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(-2, 2, size=(6, 5)).astype(np.float32)
    x[:, -1] = 0.0                            # a masked (zero) column
    tree = {"p": {"lora_A": jnp.asarray(x)}}
    scale = np.abs(x).max() / 127.0           # quantization bin width
    N = 256
    acc = np.zeros_like(x)
    for s in range(N):
        d = np.asarray(agg.compress_update(tree, mode="q8", step=s,
                                           client_idx=0)["p"]["lora_A"])
        assert np.abs(d - x).max() <= scale + 1e-6
        np.testing.assert_array_equal(d[:, -1], 0.0)
        acc += d
    # per-coordinate SR variance ≤ scale²/4 → 6σ bound on the mean bias
    assert np.abs(acc / N - x).max() < 3.0 * scale / math.sqrt(N)


@pytest.mark.slow
@given_seeds()
def test_q8_aggregate_error_bounded(seed):
    """The q8-compressed FedAvg stays within the weighted sum of the
    per-client quantization bins of exact FedAvg — the codec's worst
    case, independent of rounding keys."""
    tree, _, w = _make_fleet(seed)
    wnp = np.asarray(w)
    exact = agg.fedavg(tree, w)
    out = methods.get_method("lora_fedavg_q8").aggregate(
        tree, w, step=seed % 97)
    for path in ("lora_A", "lora_B"):
        x = np.asarray(tree["proj"][path])
        bins = np.abs(x).reshape(C, -1).max(1) / 127.0
        err = np.abs(np.asarray(out["proj"][path])
                     - np.asarray(exact["proj"][path])).max()
        assert err <= float((wnp * bins).sum()) + 1e-6, (path, err)


@pytest.mark.slow
@given_seeds()
def test_topk_aggregate_deterministic_and_error_bounded(seed):
    """Top-k sparsification is deterministic (same input → bitwise-equal
    aggregate, no keys involved), keeps at most k coordinates per client
    leaf, and its aggregate error is bounded by the weighted sum of each
    client's kept-magnitude threshold (every dropped coordinate is ≤ the
    k-th largest |x|)."""
    ratio = 0.3
    method = agg.CompressedFedAvg(mode="topk", topk_ratio=ratio)
    tree, _, w = _make_fleet(seed)
    wnp = np.asarray(w)
    out = method(tree, w)
    out2 = method(tree, w)
    for la, lb in zip(jax.tree.leaves(out), jax.tree.leaves(out2)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    exact = agg.fedavg(tree, w)
    for path in ("lora_A", "lora_B"):
        x = np.asarray(tree["proj"][path]).reshape(C, -1)
        k = max(1, math.ceil(ratio * x.shape[1]))
        enc = np.asarray(agg.compress_update(
            {"x": tree["proj"][path][0]}, mode="topk",
            topk_ratio=ratio)["x"])
        assert np.count_nonzero(enc) <= k
        tau = np.sort(np.abs(x), axis=1)[:, -k]   # per-client kept threshold
        err = np.abs(np.asarray(out["proj"][path])
                     - np.asarray(exact["proj"][path])).max()
        assert err <= float((wnp * tau).sum()) + 1e-6, (path, err)


@pytest.mark.slow
@given_seeds()
def test_exact_fedavg_reconstructs_where_zeropad_differs(seed):
    """On a rank-sufficient mixed-rank fleet, exact_fedavg's delta matches
    the Σ wᵢ·AᵢBᵢ oracle to f32 tolerance; zero-pad averaging — the
    factor-mean — measurably does not (unless the fleet is degenerate)."""
    tree, ranks, w = _make_fleet(seed, rank_sufficient=True)
    wnp = np.asarray(w)
    oracle = sum(
        wnp[c] * np.asarray(tree["proj"]["lora_A"][c]
                            @ tree["proj"]["lora_B"][c])
        for c in range(C))
    exact = agg.exact_fedavg(tree, w, ranks=ranks)
    np.testing.assert_allclose(_delta(exact), oracle, rtol=1e-4, atol=1e-5)
    zp = agg.zeropad_fedavg(tree, w, ranks=ranks)
    # the factor mean is provably not the product mean for non-degenerate
    # fleets: distinct rank-1 clients at the same rank row collide
    assert np.abs(_delta(zp) - oracle).max() > 1e-3


def test_replication_reweights_uncovered_rows():
    """Rows owned by one client keep that client's values; zero-padding
    dilutes them by C."""
    A = np.zeros((2, 3, 2), np.float32)
    B = np.zeros((2, 2, 3), np.float32)
    A[0, :, :1] = 1.0
    B[0, :1] = 1.0
    A[1] = 2.0                                   # rank-2 client owns row 1
    B[1] = 2.0
    tree = {"p": {"lora_A": jnp.asarray(A), "lora_B": jnp.asarray(B)}}
    ranks = jnp.asarray([1, 2], jnp.int32)
    rep = agg.replication_fedavg(tree, ranks=ranks)
    zp = agg.zeropad_fedavg(tree, ranks=ranks)
    # row 0: covered by both → same as the plain mean
    np.testing.assert_allclose(np.asarray(rep["p"]["lora_A"])[:, 0],
                               np.asarray(zp["p"]["lora_A"])[:, 0])
    # row 1: only the rank-2 client owns it → its value, not value/2
    np.testing.assert_allclose(np.asarray(rep["p"]["lora_A"])[:, 1],
                               A[1, :, 1])
    np.testing.assert_allclose(np.asarray(zp["p"]["lora_A"])[:, 1],
                               A[1, :, 1] / 2)


def test_exact_fedavg_rejects_decomposed_trees():
    tree = {"p": {"A_dir": jnp.ones((2, 3, 2)), "B_dir": jnp.ones((2, 2, 3))}}
    with pytest.raises(ValueError, match="lora_A"):
        agg.exact_fedavg(tree)


def test_comm_bytes_rank_aware():
    """A rank-2 client in an r_max=8 fleet ships 1/4 the pair bytes."""
    tree = {"p": {"lora_A": jnp.zeros((16, 8)), "lora_B": jnp.zeros((8, 16))}}
    full = agg.comm_bytes_per_round(tree)
    low = agg.comm_bytes_per_round(tree, rank=2)
    assert low == full // 4
    # rank above the allocation clamps (never bills phantom rows)
    assert agg.comm_bytes_per_round(tree, rank=99) == full
