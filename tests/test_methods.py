"""Strategy-registry + scanned-round-engine tests.

Covers: scan/per-step parity, registry round-trip for every built-in,
keep-local leaves surviving aggregate AND global-stage rebroadcast, the
FedALT-style dual-adapter baseline, and trimmed-mean robustness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core import methods
from repro.core import peft
from repro.fed.simulate import FedHyper, FedSim
from repro.models.config import ArchConfig
from repro.utils import pytree as pt

CFG = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                 dtype="float32", lora_rank=4, lora_dropout=0.0)


def _batches(C, n, seed=0):
    rng = np.random.default_rng(seed)
    return [{"tokens": jnp.asarray(rng.integers(5, 256, size=(C, 4, 32)),
                                   jnp.int32),
             "loss_mask": jnp.ones((C, 4, 32), jnp.float32)}
            for _ in range(n)]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_roundtrips_every_builtin():
    names = methods.available_methods()
    assert {"fedlora_opt", "lora", "ffa_lora", "fedprox", "prompt",
            "adapter", "fedalt", "lora_trimmed"} <= set(names)
    for name in names:
        m = methods.get_method(name)
        assert m.name == name
        assert callable(m.make_adapter) and callable(m.train_mask)


def test_unknown_method_raises_with_available_list():
    with pytest.raises(ValueError, match="fedlora_opt"):
        methods.get_method("nope")
    with pytest.raises(ValueError, match="already registered"):
        methods.register(methods.get_method("lora"))


def test_duplicate_register_overwrite_roundtrip():
    m = methods.get_method("lora")
    assert methods.register(m, overwrite=True) is m


@pytest.mark.parametrize("name", ["fedalt", "lora_trimmed"])
def test_registry_only_baselines_step_and_aggregate(name):
    """New baselines ride the engine with zero engine changes."""
    hp = FedHyper(method=name, n_clients=4, local_steps=2)
    sim = FedSim(CFG, hp)
    mets = sim.local_round(_batches(4, 2), jax.random.PRNGKey(0))
    assert np.isfinite(mets["ce"]).all()
    sim.aggregate()
    assert sim.comm_bytes > 0


# ---------------------------------------------------------------------------
# scan engine vs per-step reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["fedlora_opt", "fedprox"])
def test_scanned_round_matches_per_step_reference(method):
    """The single-scan round must produce (near-)identical adapters and
    optimizer state to the seed-style per-step host-synced loop."""
    hp = FedHyper(method=method, n_clients=2, local_steps=3, lr=1e-2,
                  prox_mu=0.01)
    b = _batches(2, 3, seed=7)
    rng = jax.random.PRNGKey(3)
    sim_scan, sim_ref = FedSim(CFG, hp), FedSim(CFG, hp)
    sim_scan.local_round(b, rng)
    sim_ref.local_round_reference(b, rng)
    assert int(sim_scan._step) == int(sim_ref._step) == 3
    for path, a, r in zip(pt.tree_paths(sim_scan.client_adapters),
                          jax.tree.leaves(sim_scan.client_adapters),
                          jax.tree.leaves(sim_ref.client_adapters)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=1e-5, atol=1e-6, err_msg=path)
    # and across a second round (step counter continuity)
    b2 = _batches(2, 2, seed=9)
    sim_scan.local_round(b2, rng)
    sim_ref.local_round_reference(b2, rng)
    for a, r in zip(jax.tree.leaves(sim_scan.client_adapters),
                    jax.tree.leaves(sim_ref.client_adapters)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# keep-local rebroadcast
# ---------------------------------------------------------------------------

def _desync(sim):
    sim.client_adapters = jax.tree.map(
        lambda x: x + jnp.arange(x.shape[0], dtype=x.dtype).reshape(
            (-1,) + (1,) * (x.ndim - 1)), sim.client_adapters)


def test_keep_local_regex_survives_aggregate_and_global_stage():
    hp = FedHyper(method="fedlora_opt", n_clients=3, global_steps=2,
                  server_lr=1e-2)
    sim = FedSim(CFG, hp)
    _desync(sim)
    personal = {p: np.asarray(FedSim._leaf(sim.client_adapters, p))
                for p in pt.tree_paths(sim.client_adapters)
                if p.endswith("dB_mag")}
    aggregated = sim.aggregate()
    for p, ref in personal.items():
        np.testing.assert_allclose(
            np.asarray(FedSim._leaf(sim.client_adapters, p)), ref,
            err_msg=f"aggregate clobbered {p}")
    sb = [{k: v[0] for k, v in b.items()} for b in _batches(1, 2, seed=3)]
    sim.global_stage(aggregated, sb, jax.random.PRNGKey(0))
    for p, ref in personal.items():
        np.testing.assert_allclose(
            np.asarray(FedSim._leaf(sim.client_adapters, p)), ref,
            err_msg=f"global_stage rebroadcast clobbered {p}")


def test_fedalt_local_pair_stays_personal_shared_pair_averages():
    hp = FedHyper(method="fedalt", n_clients=3)
    sim = FedSim(CFG, hp)
    _desync(sim)
    before = sim.client_adapters
    aggregated = sim.aggregate()
    after = sim.client_adapters
    # the server-side aggregate never contains the personal pair: the
    # global/eval model is the shared rest-of-world adapter only
    for path in pt.tree_paths(aggregated):
        if path.endswith("local_A") or path.endswith("local_B"):
            assert float(jnp.abs(FedSim._leaf(aggregated, path)).max()) == 0.0
    for path, leaf in zip(pt.tree_paths(after), jax.tree.leaves(after)):
        arr = np.asarray(leaf)
        if path.endswith("local_A") or path.endswith("local_B"):
            np.testing.assert_allclose(
                arr, np.asarray(FedSim._leaf(before, path)), err_msg=path)
        else:
            for c in range(1, arr.shape[0]):
                np.testing.assert_allclose(arr[c], arr[0], rtol=1e-5,
                                           err_msg=path)


def test_fedalt_local_pair_contributes_to_forward():
    from repro.models.layers import lora_delta
    rng = np.random.default_rng(0)
    p = {"lora_A": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
         "lora_B": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
         "local_A": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
         "local_B": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    y = lora_delta(p, x, 2.0)
    y_shared = (x @ p["lora_A"]) @ p["lora_B"] * 2.0
    y_local = (x @ p["local_A"]) @ p["local_B"] * 2.0
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(y_shared + y_local),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# trimmed-mean aggregation
# ---------------------------------------------------------------------------

def test_trimmed_fedavg_drops_outlier_client():
    C = 4
    x = jnp.asarray(np.stack([np.full((3,), v, np.float32)
                              for v in (1.0, 2.0, 3.0, 1e6)]))
    out = agg.trimmed_fedavg({"w": x}, trim_ratio=0.25)["w"]
    np.testing.assert_allclose(np.asarray(out), np.full((3,), 2.5), rtol=1e-6)
    # plain fedavg is destroyed by the same outlier
    assert float(agg.fedavg({"w": x})["w"][0]) > 1e5


def test_trimmed_fedavg_degenerate_falls_back_to_mean():
    x = jnp.asarray([[1.0], [3.0]], jnp.float32)   # C=2: 2k >= C
    out = agg.trimmed_fedavg({"w": x}, trim_ratio=0.5)["w"]
    np.testing.assert_allclose(np.asarray(out), [2.0])


# ---------------------------------------------------------------------------
# dual-LoRA adapter factory
# ---------------------------------------------------------------------------

def test_add_dual_lora_leaf_layout():
    from repro.models import model as M
    base = M.init_params(jax.random.PRNGKey(0), CFG)
    ad = peft.add_dual_lora(base, CFG, jax.random.PRNGKey(1))
    paths = pt.tree_paths(ad)
    suffixes = {p.rsplit("/", 1)[-1] for p in paths}
    assert suffixes == {"lora_A", "lora_B", "local_A", "local_B"}
    for p in paths:
        if p.endswith("local_B"):
            assert float(jnp.abs(FedSim._leaf(ad, p)).max()) == 0.0
