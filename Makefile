.PHONY: test test-serve test-het test-fast perf serve-bench

# tier-1 verify (ROADMAP.md)
test:
	bash scripts/ci.sh

# multi-tenant serving subsystem only (BGMV kernel, store, engine)
test-serve:
	bash scripts/ci.sh --serve

# heterogeneous-rank subsystem (aggregation properties, mixed-rank
# round/serving parity, het checkpoints)
test-het:
	bash scripts/ci.sh --het

# tier-1 minus the slow property/parity sweeps
test-fast:
	bash scripts/ci.sh --fast

# fed-round + per-arch microbenchmarks
perf:
	PYTHONPATH=src python -m benchmarks.perf_micro

# mixed-tenant batch vs naive merge-per-tenant serving loop
serve-bench:
	PYTHONPATH=src python -m benchmarks.serve_multitenant
