"""repro.lint — repo-aware JAX static analyzer + runtime sanitizers.

Static half: five AST rules (R1 host-sync-in-jit, R2 donation-safety,
R3 PRNG hygiene, R4 recompile hazards, R5 dead-mask detection) behind a
``FedMethod``-style registry, run by ``python -m repro.lint <paths>``
with per-line suppressions and a checked-in baseline.  Runtime half:
``repro.lint.sanitize`` (``nan_guard``, key-reuse-tracking ``tracked``
PRNG shim) for use from tests.

See docs/static_analysis.md for the rule catalog and the historical
bug each rule encodes.
"""
from .rules import available_rules, get_rule, register
from .rules.base import Finding, Rule
from .runner import main

__all__ = ["available_rules", "get_rule", "register", "Finding",
           "Rule", "main"]
