"""Pytree utilities: path-aware maps, masks, norms, flattening.

The whole framework represents model/optimizer state as nested dicts of
jnp arrays.  Paths are "/"-joined key strings, e.g.
``"blocks/attn/q_proj/lora_A"`` — every selection mechanism (trainable
masks, sharding rules, aggregation filters) keys off these paths.
"""
from __future__ import annotations

import re
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)


def path_str(path) -> str:
    return "/".join(_key_str(k) for k in path)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Pytree) -> Pytree:
    """Map ``fn(path, leaf)`` over a pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: fn(path_str(p), x), tree
    )


def tree_paths(tree: Pytree) -> list[str]:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return [path_str(p) for p, _ in leaves]


def path_mask(tree: Pytree, predicate: Callable[[str], bool]) -> Pytree:
    """Boolean mask pytree: True where predicate(path)."""
    return tree_map_with_path(lambda p, x: bool(predicate(p)), tree)


def regex_mask(tree: Pytree, pattern: str) -> Pytree:
    rx = re.compile(pattern)
    return path_mask(tree, lambda p: rx.search(p) is not None)


def tree_select(tree: Pytree, mask: Pytree, other: Pytree) -> Pytree:
    """Per-leaf select: mask ? tree : other  (mask is a bool pytree)."""
    return jax.tree.map(lambda m, a, b: a if m else b, mask, tree, other)


def tree_zeros_like(tree: Pytree) -> Pytree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return jax.tree.map(lambda x: x * s, a)


def tree_dot(a: Pytree, b: Pytree):
    parts = jax.tree.leaves(jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b))
    return sum(parts)


def global_norm(tree: Pytree):
    sq = jax.tree.leaves(jax.tree.map(lambda x: jnp.sum(jnp.square(x)), tree))
    return jnp.sqrt(sum(sq))


def tree_count_params(tree: Pytree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def tree_bytes(tree: Pytree) -> int:
    return int(
        sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree))
    )


def tree_get(tree: Mapping, path: str, default=None):
    """Fetch the node at a "/"-joined path, or ``default`` on a miss."""
    node = tree
    for k in path.split("/"):
        if not isinstance(node, Mapping) or k not in node:
            return default
        node = node[k]
    return node


def set_leaf(tree: dict, path: str, leaf) -> None:
    """Set the leaf at a "/"-joined path in a nested dict, creating
    intermediate dicts as needed (the write-side dual of ``tree_get``)."""
    keys = path.split("/")
    cur = tree
    for k in keys[:-1]:
        cur = cur.setdefault(k, {})
    cur[keys[-1]] = leaf


def filter_tree(tree: Mapping, predicate: Callable[[str], bool]) -> dict:
    """Return a nested-dict subtree containing only leaves whose path
    satisfies ``predicate``; empty dicts are pruned."""
    out: dict = {}
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    for p, leaf in leaves:
        ps = path_str(p)
        if not predicate(ps):
            continue
        keys = ps.split("/")
        cur = out
        for k in keys[:-1]:
            cur = cur.setdefault(k, {})
        cur[keys[-1]] = leaf
    return out


def merge_trees(base: Mapping, overlay: Mapping) -> dict:
    """Deep merge: overlay leaves replace base leaves."""
    out = dict(base)
    for k, v in overlay.items():
        if k in out and isinstance(out[k], Mapping) and isinstance(v, Mapping):
            out[k] = merge_trees(out[k], v)
        else:
            out[k] = v
    return out


def tree_cast(tree: Pytree, dtype) -> Pytree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_all_finite(tree: Pytree):
    leaves = jax.tree.leaves(tree)
    oks = [jnp.all(jnp.isfinite(x)) for x in leaves if jnp.issubdtype(x.dtype, jnp.floating)]
    if not oks:
        return jnp.asarray(True)
    return jnp.all(jnp.stack(oks))
