#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): the full test suite with src on PYTHONPATH.
#
#   scripts/ci.sh              # full suite (includes serving + het tests)
#   scripts/ci.sh --serve      # fast path: multi-tenant serving subsystem
#                              # only (BGMV kernel, AdapterStore, engine)
#   scripts/ci.sh --het        # heterogeneous-rank subsystem: aggregation
#                              # property suite, mixed-rank round/serving
#                              # parity, het checkpoint coverage
#   scripts/ci.sh --fast       # tier-1 minus the slow property/parity
#                              # sweeps (-m 'not slow')
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
case "${1:-}" in
  --serve)
    shift
    exec python -m pytest -x -q tests/test_batched_lora.py \
      tests/test_adapter_store.py tests/test_serve_engine.py "$@"
    ;;
  --het)
    shift
    exec python -m pytest -x -q tests/test_aggregation_properties.py \
      tests/test_het_ckpt.py tests/test_methods.py \
      tests/test_batched_lora.py tests/test_serve_engine.py "$@"
    ;;
  --fast)
    shift
    exec python -m pytest -x -q -m "not slow" "$@"
    ;;
esac
exec python -m pytest -x -q "$@"
