"""Architecture configuration + superblock pattern derivation.

A *superblock* is the smallest repeating sequence of sublayers; params are
stacked ``(n_superblocks, ...)`` and iterated with ``lax.scan`` so the HLO
stays small for 88-layer models on a 512-device dry-run mesh.  Uneven layer
counts produce a scanned main body plus a shorter scanned tail.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class SubLayer:
    """One sublayer within a superblock pattern."""
    mixer: str        # "attn" | "ssm" | "cross_attn"
    ffn: str          # "dense" | "moe" | "none"
    attn_kind: str = "global"   # "global" | "local"  (local = sliding window)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|encdec|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0               # 0 → d_model // n_heads
    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1            # every k-th sublayer's ffn is MoE
    capacity_factor: float = 1.25
    ep_fsplit: int = 1            # physical expert slots per expert: slot
                                  # j holds the j-th 1/fsplit slice of d_ff
                                  # (lets E=8 mixtral expert-parallelize
                                  # over a 16-way mesh axis)
    # --- attention flavor ---
    rope_theta: float = 1e4
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    local_global: int = 0         # gemma3: N local layers per 1 global
    mrope: bool = False           # qwen2-vl 3-section rotary
    # --- ssm (mamba2 / jamba) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    attn_every: int = 0           # hybrid: 1 attn layer per this many layers
    # --- enc-dec ---
    n_enc_layers: int = 0         # >0 → encoder-decoder; n_layers = decoder
    # --- modality frontend stub ---
    frontend: Optional[str] = None  # "audio" | "vision"
    frontend_tokens: int = 256      # stub embedding positions
    # --- adapters (paper setting: LoRA r=8 α=32 on Q,V) ---
    lora_rank: int = 8
    lora_alpha: float = 32.0
    lora_dropout: float = 0.1
    lora_targets: Sequence[str] = ("q_proj", "v_proj")
    use_fused_dora: bool = False  # fuse base+adapter matmul via the Pallas
                                  # kernel (interpret off-TPU); forward-only
                                  # — the kernel has no VJP, so keep False
                                  # for training
    # --- serving-time weight-only quantization ---
    backbone_quant: Optional[str] = None  # "int8" | "int4": store frozen
                                          # attention/FFN projection kernels
                                          # quantized with per-channel f32
                                          # scales and dequant-fuse inside
                                          # the matmul tile (see
                                          # kernels/quant_matmul); adapters
                                          # and the federated deltas stay
                                          # f32.  Serving only — training
                                          # paths keep None.
    backbone_quant_group: Optional[int] = None
                                          # quantization group size along
                                          # d_in (must divide it); None →
                                          # one per-channel scale per
                                          # output column.  Smaller groups
                                          # cut int4 quantization error at
                                          # a scale-table memory cost —
                                          # threaded into quantize_backbone
                                          # by ServeEngine.
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    source: str = ""              # citation

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    # ---- superblock pattern ------------------------------------------------
    def pattern(self) -> list[SubLayer]:
        if self.family == "ssm":
            return [SubLayer("ssm", "none")]
        if self.family == "hybrid":
            # jamba: 1 attn per attn_every layers; MoE every moe_every-th
            # sublayer, dense otherwise.
            pat = []
            for i in range(self.attn_every):
                mixer = "attn" if i == 0 else "ssm"
                ffn = "moe" if (self.n_experts and (i % self.moe_every == self.moe_every - 1)) else "dense"
                pat.append(SubLayer(mixer, ffn,
                                    "local" if self.sliding_window else "global"))
            return pat
        if self.local_global:
            pat = [SubLayer("attn", "dense", "local")] * self.local_global
            pat += [SubLayer("attn", "dense", "global")]
            return pat
        ffn = "moe" if self.n_experts else "dense"
        kind = "local" if self.sliding_window else "global"
        return [SubLayer("attn", ffn, kind)]

    def dec_pattern(self) -> list[SubLayer]:
        """Decoder pattern for enc-dec: self-attn + cross-attn per layer."""
        return [SubLayer("attn", "none"), SubLayer("cross_attn", "dense")]

    def blocks_layout(self, n_layers: Optional[int] = None,
                      pattern: Optional[list[SubLayer]] = None):
        """(n_superblocks, tail_len, pattern). tail runs pattern[:tail_len]."""
        n = self.n_layers if n_layers is None else n_layers
        pat = self.pattern() if pattern is None else pattern
        per = len(pat)
        return n // per, n % per, pat


def reduced(cfg: ArchConfig, n_layers: int = 2, d_model: int = 256,
            n_experts: int = 4, vocab: int = 512, d_ff: int = 0,
            seq_window: int = 64) -> ArchConfig:
    """Smoke-test variant of the same family (≤512 d_model, ≤4 experts)."""
    heads = max(1, min(cfg.n_heads, 4))
    kv = max(1, min(cfg.n_kv_heads, heads))
    # hybrid pattern shrinks to attn_every=2 → superblock of 2 sublayers
    nl = max(n_layers, 2) if (cfg.family == "hybrid" or cfg.local_global) \
        else n_layers
    return dataclasses.replace(
        cfg,
        n_layers=nl,
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=kv,
        d_head=d_model // heads,
        d_ff=d_ff or (2 * d_model),
        vocab_size=vocab,
        n_experts=min(cfg.n_experts, n_experts) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ep_fsplit=1,
        # drop-free capacity so prefill/decode routing agrees exactly in
        # the smoke consistency tests (capacity drops are legitimate
        # prefill/decode divergence in capacity-based MoE)
        capacity_factor=8.0,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        moe_every=min(cfg.moe_every, 2) if cfg.moe_every else 1,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=32 if cfg.ssm_state else cfg.ssm_headdim,
        ssm_chunk=16,
        sliding_window=seq_window if cfg.sliding_window else None,
        local_global=min(cfg.local_global, 1) if cfg.local_global else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2) if cfg.n_enc_layers else 0,
        frontend_tokens=8 if cfg.frontend else 0,
        lora_rank=4,
        dtype="float32",
    )
