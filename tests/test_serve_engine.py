"""Mixed-tenant serving parity: one batch across many tenants through
``ServeEngine`` must reproduce per-tenant merged-backbone generation
bit-for-bit in float32 — LoRA and decomposed-DoRA adapters, prefill +
decode — plus the scanned greedy decoder vs its loop reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import peft
from repro.launch.serve import (greedy_generate, greedy_generate_reference,
                                merge_adapters)
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.serve import AdapterStore, ServeEngine
from repro.utils import pytree as pt

CFG = ArchConfig(name="serve-t", family="dense", n_layers=2, d_model=32,
                 n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                 dtype="float32", lora_rank=4, lora_dropout=0.0)
RNG = np.random.default_rng(3)


@pytest.fixture(scope="module")
def base():
    return M.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def shared(base):
    ad = peft.add_lora(base, CFG, jax.random.PRNGKey(1), decomposed=True)
    # nonzero B magnitude so the adapter path contributes
    return pt.tree_map_with_path(
        lambda p, x: x + 0.25 if p.endswith("B_mag") else x, ad)


def _mag_variant(shared, t):
    return pt.tree_map_with_path(
        lambda p, x: x + 0.15 * (t + 1) * jnp.sign(jnp.sin(
            jnp.arange(x.size, dtype=jnp.float32) + t)).reshape(x.shape)
        if p.endswith("dB_mag") else x, shared)


def _prompts(n, S):
    return np.asarray(RNG.integers(5, CFG.vocab_size, size=(n, S)), np.int32)


def test_scanned_greedy_matches_loop_reference(base, shared):
    merged = merge_adapters(base, shared)
    prompts = {"tokens": jnp.asarray(_prompts(3, 10))}
    a = greedy_generate(merged, prompts, CFG, n_new=6)
    b = greedy_generate_reference(merged, prompts, CFG, n_new=6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mixed_batch_matches_per_tenant_dora_mag(base, shared):
    """4 tenants sharing directions, personalized ΔB_M — one mixed batch
    vs four merged-backbone runs, exact in float32."""
    store = AdapterStore(base, CFG, n_slots=4, kind="dora_mag", shared=shared)
    trees = {}
    for t in range(4):
        trees[t] = _mag_variant(shared, t)
        store.register(f"tenant{t}", pt.filter_tree(
            trees[t], lambda p: p.endswith("dB_mag")))
    eng = ServeEngine(base, CFG, store, max_rows=4, max_prompt_len=12,
                      max_len=32, decode_chunk=4)
    prompts = _prompts(4, 12)
    outs = eng.generate([(f"tenant{t}", prompts[t]) for t in range(4)],
                        n_new=7)
    for t in range(4):
        merged = merge_adapters(base, trees[t])
        ref = greedy_generate(merged, {"tokens": jnp.asarray(prompts[t:t+1])},
                              CFG, n_new=7)
        np.testing.assert_array_equal(outs[t], np.asarray(ref[0]))


def test_mixed_batch_matches_per_tenant_raw_lora(base):
    """Fully heterogeneous raw-LoRA pairs (kind='pairs')."""
    store = AdapterStore(base, CFG, n_slots=4, kind="pairs")
    trees = {}
    for t in range(4):
        trees[t] = peft.add_lora(base, CFG, jax.random.PRNGKey(100 + t))
        # push B away from its near-zero init so tenants actually differ
        trees[t] = pt.tree_map_with_path(
            lambda p, x: x * 50.0 if p.endswith("lora_B") else x, trees[t])
        store.register(f"t{t}", trees[t])
    eng = ServeEngine(base, CFG, store, max_rows=4, max_prompt_len=8,
                      max_len=24, decode_chunk=8)
    prompts = _prompts(4, 8)
    outs = eng.generate([(f"t{t}", prompts[t]) for t in range(4)], n_new=5)
    for t in range(4):
        merged = merge_adapters(base, trees[t])
        ref = greedy_generate(merged, {"tokens": jnp.asarray(prompts[t:t+1])},
                              CFG, n_new=5)
        np.testing.assert_array_equal(outs[t], np.asarray(ref[0]))


def test_continuous_batching_more_requests_than_rows(base, shared):
    """6 requests through 3 rows, ragged prompt lengths and n_new — the
    batcher refills freed rows and every request still matches its
    merged-backbone reference exactly."""
    store = AdapterStore(base, CFG, n_slots=6, kind="dora_mag", shared=shared)
    trees = {}
    for t in range(6):
        trees[t] = _mag_variant(shared, t)
        store.register(f"tenant{t}", pt.filter_tree(
            trees[t], lambda p: p.endswith("dB_mag")))
    eng = ServeEngine(base, CFG, store, max_rows=3, max_prompt_len=10,
                      max_len=32, decode_chunk=3)
    lens = [10, 7, 4, 9, 5, 10]
    n_news = [6, 3, 8, 1, 5, 4]
    prompts = [_prompts(1, L)[0] for L in lens]
    rids = [eng.submit(f"tenant{t}", prompts[t], n_news[t])
            for t in range(6)]
    results = eng.run()
    assert sorted(results) == sorted(rids)
    for t in range(6):
        merged = merge_adapters(base, trees[t])
        ref = greedy_generate(
            merged, {"tokens": jnp.asarray(prompts[t][None])}, CFG,
            n_new=n_news[t])
        got = results[rids[t]]
        assert got.shape == (n_news[t],)
        np.testing.assert_array_equal(got, np.asarray(ref[0]))


def _rank_variant(base, t, rank):
    """A raw-LoRA adapter of the given rank with B pushed off its
    near-zero init so tenants actually differ."""
    tree = peft.add_lora(base, CFG, jax.random.PRNGKey(200 + t), rank=rank)
    return pt.tree_map_with_path(
        lambda p, x: x * 50.0 if p.endswith("lora_B") else x, tree)


def test_mixed_rank_batch_matches_per_tenant(base):
    """Ranks {2, 4, 8} (pool r_max=8) + the null slot in ONE batch —
    every row must exact-match its per-tenant merged-backbone run, the
    null row the bare backbone."""
    store = AdapterStore(base, CFG, n_slots=4, kind="pairs", rank=8)
    ranks = {0: 2, 1: 4, 2: 8}
    trees = {t: _rank_variant(base, t, r) for t, r in ranks.items()}
    for t in ranks:
        store.register(f"t{t}", trees[t])
        assert store.rank_of(f"t{t}") == ranks[t]
    eng = ServeEngine(base, CFG, store, max_rows=4, max_prompt_len=8,
                      max_len=24, decode_chunk=8)
    prompts = _prompts(4, 8)
    outs = eng.generate([(f"t{t}", prompts[t]) for t in ranks]
                        + [(None, prompts[3])], n_new=5)
    for t in ranks:
        merged = merge_adapters(base, trees[t])
        ref = greedy_generate(merged, {"tokens": jnp.asarray(prompts[t:t+1])},
                              CFG, n_new=5)
        np.testing.assert_array_equal(outs[t], np.asarray(ref[0]))
    ref = greedy_generate(base, {"tokens": jnp.asarray(prompts[3:4])}, CFG,
                          n_new=5)
    np.testing.assert_array_equal(outs[3], np.asarray(ref[0]))


def test_mixed_rank_continuous_batching(base):
    """6 mixed-rank requests through 2 rows with ragged prompt lengths
    and n_new — refills admit tenants of different ranks into freed rows
    mid-flight and every request still exact-matches its reference."""
    store = AdapterStore(base, CFG, n_slots=6, kind="pairs", rank=8)
    t_ranks = [2, 8, 4, 2, 8, 4]
    trees = {t: _rank_variant(base, t, r) for t, r in enumerate(t_ranks)}
    for t in trees:
        store.register(f"t{t}", trees[t])
    eng = ServeEngine(base, CFG, store, max_rows=2, max_prompt_len=10,
                      max_len=32, decode_chunk=3)
    lens = [10, 7, 4, 9, 5, 10]
    n_news = [6, 3, 8, 1, 5, 4]
    prompts = [_prompts(1, L)[0] for L in lens]
    rids = [eng.submit(f"t{t}", prompts[t], n_news[t]) for t in range(6)]
    results = eng.run()
    assert sorted(results) == sorted(rids)
    for t in range(6):
        merged = merge_adapters(base, trees[t])
        ref = greedy_generate(
            merged, {"tokens": jnp.asarray(prompts[t][None])}, CFG,
            n_new=n_news[t])
        np.testing.assert_array_equal(results[rids[t]], np.asarray(ref[0]))


def _rank_masked_decomposed(shared, r_t, delta_overlay, pool_rank):
    """A rank-r_t tenant's own federated model: the shared decomposed
    tree re-masked to the first r_t rank rows (FedSim's rebroadcast
    re-mask) plus its ΔB_M delta, padded to the pool allocation."""
    from repro.core.peft import rank_axis

    def mask_one(p, x):
        ax = rank_axis(p)
        if ax is None:
            return x
        ax_abs = x.ndim + ax
        keep = jnp.arange(x.shape[ax_abs]) < r_t
        return x * keep.reshape([-1 if a == ax_abs else 1
                                 for a in range(x.ndim)])

    tree = pt.tree_map_with_path(mask_one, shared)
    for p in pt.tree_paths(delta_overlay):
        d = pt.tree_get(delta_overlay, p)
        pad = [(0, 0)] * (d.ndim - 1) + [(0, pool_rank - d.shape[-1])]
        pt.set_leaf(tree, p, jnp.pad(d, pad))
    return tree


def test_mixed_rank_dora_mag_matches_truncated_per_tenant(base):
    """Mixed-rank ΔB_M tenants {2, 4, 8} in a server-rank-16 pool + the
    null slot in ONE batch: each row must exact-match the merged run of
    its own federated model — the shared model's first r rank rows plus
    its delta (the raw-delta pool + magnitude rank mask; a pre-merged
    magnitude pool would serve the full-rank shared rows to every
    tenant), the null row the bare backbone."""
    shared16 = peft.add_lora(base, CFG, jax.random.PRNGKey(4),
                             decomposed=True, rank=16)
    shared16 = pt.tree_map_with_path(
        lambda p, x: x + 0.25 if p.endswith("B_mag") else x, shared16)
    store = AdapterStore(base, CFG, n_slots=4, kind="dora_mag",
                         shared=shared16)
    assert store.rank == 16
    ranks = {0: 2, 1: 4, 2: 8}
    deltas = {}
    for t, r in ranks.items():
        key = jax.random.PRNGKey(40 + t)
        deltas[t] = pt.tree_map_with_path(
            lambda p, x: 0.2 * jax.random.normal(
                jax.random.fold_in(key, hash(p) % 2**30),
                x.shape[:-1] + (r,)),
            pt.filter_tree(shared16, lambda p: p.endswith("dB_mag")))
        store.register(f"m{t}", deltas[t])
        assert store.rank_of(f"m{t}") == r
    eng = ServeEngine(base, CFG, store, max_rows=4, max_prompt_len=8,
                      max_len=24, decode_chunk=8)
    prompts = _prompts(4, 8)
    outs = eng.generate([(f"m{t}", prompts[t]) for t in ranks]
                        + [(None, prompts[3])], n_new=5)
    for t, r in ranks.items():
        tree = _rank_masked_decomposed(shared16, r, deltas[t], store.rank)
        merged = merge_adapters(base, tree)
        ref = greedy_generate(merged, {"tokens": jnp.asarray(prompts[t:t+1])},
                              CFG, n_new=5)
        np.testing.assert_array_equal(outs[t], np.asarray(ref[0]))
    ref = greedy_generate(base, {"tokens": jnp.asarray(prompts[3:4])}, CFG,
                          n_new=5)
    np.testing.assert_array_equal(outs[3], np.asarray(ref[0]))


def test_slot_reuse_masks_stale_high_rank_rows(base):
    """Evicting a rank-8 tenant and re-registering a rank-2 tenant into
    the same slot must serve the rank-2 adapter exactly — the rank mask
    (not just the evict-time zeroing) guards the padded rows."""
    store = AdapterStore(base, CFG, n_slots=1, kind="pairs", rank=8)
    big = _rank_variant(base, 0, 8)
    small = _rank_variant(base, 1, 2)
    s0 = store.register("big", big)
    store.evict("big")
    assert store.register("small", small) == s0
    assert store.rank_of("small") == 2
    eng = ServeEngine(base, CFG, store, max_rows=1, max_prompt_len=8,
                      max_len=16, decode_chunk=4)
    prompts = _prompts(1, 8)
    out = eng.generate([("small", prompts[0])], n_new=4)[0]
    ref = greedy_generate(merge_adapters(base, small),
                          {"tokens": jnp.asarray(prompts)}, CFG, n_new=4)
    np.testing.assert_array_equal(out, np.asarray(ref[0]))


def test_store_rejects_rank_above_pool(base):
    store = AdapterStore(base, CFG, n_slots=2, kind="pairs", rank=4)
    with pytest.raises(ValueError, match="mismatch"):
        store.register("too-big", _rank_variant(base, 0, 8))


def test_engine_null_tenant_serves_bare_backbone(base, shared):
    store = AdapterStore(base, CFG, n_slots=2, kind="dora_mag", shared=shared)
    store.register("x", pt.filter_tree(_mag_variant(shared, 0),
                                       lambda p: p.endswith("dB_mag")))
    eng = ServeEngine(base, CFG, store, max_rows=2, max_prompt_len=8,
                      max_len=24, decode_chunk=4)
    prompts = _prompts(1, 8)
    out = eng.generate([(None, prompts[0])], n_new=4)[0]
    ref = greedy_generate(base, {"tokens": jnp.asarray(prompts)}, CFG,
                          n_new=4)
    np.testing.assert_array_equal(out, np.asarray(ref[0]))


def test_engine_rejects_sliding_window_configs(base, shared):
    """Ring-buffer (local-attention) caches assume slot == position %
    window; the engine's padded prefill doesn't, so windowed configs must
    be refused instead of silently serving wrong prefixes."""
    import dataclasses
    wcfg = dataclasses.replace(CFG, sliding_window=4)
    store = AdapterStore(base, CFG, n_slots=2, kind="dora_mag", shared=shared)
    with pytest.raises(ValueError, match="sliding-window"):
        ServeEngine(base, wcfg, store, max_rows=2, max_prompt_len=8,
                    max_len=16)


def test_pooled_routing_outranks_fused_path(base, shared):
    """use_fused_dora=True with merged shared leaves must not shadow the
    per-row pooled adapter path (every tenant would silently get the
    shared adapter)."""
    from repro.models.layers import linear
    d, r, o, L = 16, 4, 16, 2
    p = {"kernel": jnp.asarray(RNG.normal(size=(d, o)) * 0.05, jnp.float32),
         "A_dir": jnp.asarray(RNG.normal(size=(d, r)) * 0.3, jnp.float32),
         "A_mag": jnp.ones((d,), jnp.float32),
         "B_dir": jnp.asarray(RNG.normal(size=(r, o)) * 0.3, jnp.float32),
         "B_mag": jnp.ones((r,), jnp.float32),
         "bgmv_A_dir": jnp.asarray(RNG.normal(size=(d, r)) * 0.3, jnp.float32),
         "bgmv_A_mag": jnp.ones((d,), jnp.float32),
         "bgmv_B_mag": jnp.ones((r,), jnp.float32),
         "bgmv_B_dir": jnp.asarray(RNG.normal(size=(r, o)) * 0.3, jnp.float32),
         "pool_dB_mag": jnp.asarray(RNG.normal(size=(L, r)), jnp.float32)}
    x = jnp.asarray(RNG.normal(size=(2, 3, d)), jnp.float32)
    idx = jnp.asarray([0, 1], jnp.int32)
    y_fused = linear(p, x, lora_scale=2.0, fused=True, adapter_idx=idx)
    y_plain = linear(p, x, lora_scale=2.0, fused=False, adapter_idx=idx)
    np.testing.assert_array_equal(np.asarray(y_fused), np.asarray(y_plain))


def test_engine_rid_map_does_not_leak(base, shared):
    store = AdapterStore(base, CFG, n_slots=2, kind="dora_mag", shared=shared)
    store.register("x", pt.filter_tree(_mag_variant(shared, 0),
                                       lambda p: p.endswith("dB_mag")))
    eng = ServeEngine(base, CFG, store, max_rows=2, max_prompt_len=8,
                      max_len=24, decode_chunk=4)
    prompts = _prompts(3, 8)
    for i in range(3):
        eng.generate([("x", prompts[i])], n_new=3)
    assert eng._tenant_of_rid == {}


def test_engine_rejects_bad_requests(base, shared):
    store = AdapterStore(base, CFG, n_slots=2, kind="dora_mag", shared=shared)
    eng = ServeEngine(base, CFG, store, max_rows=2, max_prompt_len=8,
                      max_len=16, decode_chunk=4)
    with pytest.raises(KeyError):
        eng.submit("nobody", np.zeros((4,), np.int32), 4)
    with pytest.raises(ValueError):
        eng.batcher.submit("", np.zeros((12,), np.int32), 2)  # prompt too long
    with pytest.raises(ValueError):
        eng.batcher.submit("", np.zeros((8,), np.int32), 12)  # exceeds max_len
