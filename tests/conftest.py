import os
import sys

# Tests run single-device (the dry-run owns the 512-device env); keep any
# inherited XLA_FLAGS from leaking in.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
