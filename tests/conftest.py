import os
import sys

# Tests run single-device (the dry-run owns the 512-device env); keep any
# inherited XLA_FLAGS from leaking in.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402

# ---------------------------------------------------------------------------
# guarded hypothesis (the repo pattern: property-based when hypothesis is
# installed, a deterministic sample of the same check when it isn't — this
# container ships without hypothesis)
# ---------------------------------------------------------------------------

try:
    import hypothesis

    HAVE_HYPOTHESIS = True
    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=25,
        suppress_health_check=list(hypothesis.HealthCheck))
    hypothesis.settings.load_profile("ci")
except ImportError:
    HAVE_HYPOTHESIS = False


# markers (slow, dist) are registered in pyproject.toml
# [tool.pytest.ini_options] — the single place `-m` filters are defined


def given_seeds(n_fallback: int = 10, lo: int = 0, hi: int = 2**31 - 1):
    """Decorator for seed-driven property tests: ``check(seed)`` builds its
    case from ``np.random.default_rng(seed)``, so the generative and the
    deterministic-fallback paths share one construction.  With hypothesis
    the seed is drawn (and shrunk); without it the check runs over
    ``n_fallback`` fixed seeds."""
    if HAVE_HYPOTHESIS:
        import hypothesis.strategies as st

        def deco(check):
            return hypothesis.given(st.integers(lo, hi))(check)
        return deco

    def deco(check):
        return pytest.mark.parametrize(
            "seed", range(n_fallback),
            ids=[f"seed{i}" for i in range(n_fallback)])(check)
    return deco
