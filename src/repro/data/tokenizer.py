"""Hashed byte-pair-free tokenizer.

Offline container → no sentencepiece/HF.  For the synthetic instruction
tasks (token-id native) this is only used by the text-facing demo paths:
deterministic word-level hashing into a fixed vocab with reserved
specials.  Round-trip is not required for training; eval compares ids.
"""
from __future__ import annotations

import hashlib


class HashTokenizer:
    PAD, BOS, EOS, SEP, ANS = 0, 1, 2, 3, 4
    N_SPECIAL = 8

    def __init__(self, vocab_size: int = 32768):
        assert vocab_size > self.N_SPECIAL
        self.vocab_size = vocab_size

    def _hash(self, word: str) -> int:
        h = int.from_bytes(hashlib.blake2s(word.encode()).digest()[:4], "little")
        return self.N_SPECIAL + h % (self.vocab_size - self.N_SPECIAL)

    def encode(self, text: str, add_bos: bool = True) -> list[int]:
        ids = [self.BOS] if add_bos else []
        ids += [self._hash(w) for w in text.strip().split()]
        return ids

    def decode_ids(self, ids) -> str:
        return " ".join(f"<{int(i)}>" for i in ids)
