"""R3 — PRNG hygiene.

Two historical failure classes:

(a) **key reuse** — the same PRNG key consumed by two samplers without
    an interleaving ``split``/``fold_in`` makes the draws identical
    (correlated dropout masks, duplicated inits).  The repo's
    convention is: every consumer gets its own key derived by
    ``fold_in`` with a distinct constant or ``split``.

(b) **fold-chain drift** — sim↔production parity (PRs 6/7) depends on
    ``launch/train.py`` and ``fed/simulate.py`` deriving per-stage keys
    with the *same* literal fold offsets (stage-1 round ``fold_in(rng,
    0 + step)``, stage-3 personalization ``fold_in(rng, 31 + step)``).
    A constant edited in one file but not the other silently breaks ~1
    ulp parity.  The rule extracts the literal fold-offset sets from
    both files and compares them.

Reuse detection (per function): a name is a *key binding* when assigned
from ``PRNGKey``/``key``/``split``/``fold_in`` (including tuple
unpacking of a ``split``).  Passing a key binding to any call that is
not itself a deriver (``split``/``fold_in``/key plumbing) counts as a
consumption.  Two consumptions of the same binding without a rebind
fire at the second site.  ``if``/``else`` branches are mutually
exclusive, so the count across branches is the *max*, not the sum; a
loop body that consumes a key which was bound outside the loop fires
(every iteration reuses it).
"""
from __future__ import annotations

import ast

from .base import (Finding, FunctionNode, ModuleInfo, ProjectContext, Rule,
                   last_seg)

_KEY_MAKERS = {"PRNGKey", "key", "split", "fold_in", "wrap_key_data"}
# calls a key can flow into without being "consumed"
_DERIVERS = {"split", "fold_in", "PRNGKey", "key", "clone", "key_data",
             "wrap_key_data", "tracked", "len", "tuple", "list", "print",
             "repr", "str", "type", "isinstance", "partial"}
# parameter names that mark engine fold-offset plumbing for check (b)
_FOLD_PARAM_NAMES = {"fold_offset", "rng_fold", "fold"}
_ENGINE_FILES = ("launch/train.py", "fed/simulate.py")


def _terminates(body) -> bool:
    """True if a statement block unconditionally leaves the enclosing
    function/loop (ends in return/raise/continue/break)."""
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


def _is_key_expr(node) -> bool:
    """Expression that evaluates to a PRNG key (or tuple of keys)."""
    if isinstance(node, ast.Call):
        return last_seg(node.func) in _KEY_MAKERS
    if isinstance(node, ast.Subscript):
        return _is_key_expr(node.value)
    return False


class _FnScanner:
    """Sequential consumption scanner for one function body."""

    def __init__(self, mod: ModuleInfo, fn):
        self.mod = mod
        self.fn = fn
        self.findings: list[Finding] = []
        # name -> consumption count since last (re)bind; None = not a key
        self.counts: dict[str, int] = {}
        # seed: parameters named like keys are key bindings — unless
        # annotated as a numpy Generator (stateful; reuse is the API)
        for a in fn.args.args + fn.args.posonlyargs + fn.args.kwonlyargs:
            low = a.arg.lower()
            if low == "rng" or low.endswith("_rng") or low == "key" \
                    or low.endswith("_key") or low == "rngs":
                ann = ast.unparse(a.annotation) if a.annotation else ""
                if "Generator" in ann:
                    continue
                self.counts[a.arg] = 0

    def scan(self) -> list[Finding]:
        self.block(self.fn.body)
        # dedupe: loop bodies are scanned twice (simulated 2nd iteration)
        seen: set[tuple] = set()
        uniq: list[Finding] = []
        for f in self.findings:
            k = (f.path, f.line, f.col)
            if k not in seen:
                seen.add(k)
                uniq.append(f)
        return uniq

    # -- statement walk ---------------------------------------------------

    def block(self, body) -> None:
        for stmt in body:
            self.stmt(stmt)

    def stmt(self, stmt) -> None:
        if isinstance(stmt, FunctionNode + (ast.ClassDef,)):
            return
        if isinstance(stmt, ast.If):
            self.expr(stmt.test)
            snap = dict(self.counts)
            self.block(stmt.body)
            then_counts = self.counts
            self.counts = dict(snap)
            self.block(stmt.orelse)
            else_counts = self.counts
            # a branch that leaves the function never reaches the code
            # after the if — its consumptions must not merge
            if _terminates(stmt.body):
                self.counts = else_counts
                return
            if stmt.orelse and _terminates(stmt.orelse):
                self.counts = then_counts
                return
            # mutually exclusive: keep the max per name
            merged = dict(else_counts)
            for k, v in then_counts.items():
                if k in merged:
                    merged[k] = max(merged[k], v)
                else:
                    merged[k] = v
            self.counts = merged
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self.expr(stmt.iter)
            # run the body twice: a key bound outside the loop and
            # consumed inside without a rebind is reused across
            # iterations — the second pass fires at the consumption site
            self.block(stmt.body)
            self.block(stmt.body)
            self.block(stmt.orelse)
            return
        if isinstance(stmt, ast.While):
            self.expr(stmt.test)
            self.block(stmt.body)
            self.block(stmt.body)
            self.block(stmt.orelse)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self.expr(item.context_expr)
            self.block(stmt.body)
            return
        if isinstance(stmt, ast.Try):
            self.block(stmt.body)
            for h in stmt.handlers:
                self.block(h.body)
            self.block(stmt.orelse)
            self.block(stmt.finalbody)
            return
        if isinstance(stmt, ast.Assign):
            self.expr(stmt.value)
            for tgt in stmt.targets:
                self.bind_target(tgt, stmt.value)
            return
        if isinstance(stmt, ast.AugAssign):
            self.expr(stmt.value)
            return
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self.expr(stmt.value)
            if isinstance(stmt.target, ast.Name):
                self.bind_target(stmt.target, stmt.value)
            return
        if isinstance(stmt, (ast.Expr, ast.Return)):
            if getattr(stmt, "value", None) is not None:
                self.expr(stmt.value)
            return
        # default: evaluate all child expressions
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self.expr(child)

    def bind_target(self, tgt, value) -> None:
        if isinstance(tgt, ast.Name):
            if _is_key_expr(value):
                self.counts[tgt.id] = 0
            elif tgt.id in self.counts:
                del self.counts[tgt.id]        # shadowed by a non-key
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            fresh = _is_key_expr(value)
            for elt in tgt.elts:
                if isinstance(elt, ast.Name):
                    if fresh:
                        self.counts[elt.id] = 0
                    elif elt.id in self.counts:
                        del self.counts[elt.id]

    # -- expression walk --------------------------------------------------

    def expr(self, node) -> None:
        if node is None:
            return
        for call in [n for n in ast.walk(node) if isinstance(n, ast.Call)]:
            self.call(call)

    def call(self, call: ast.Call) -> None:
        callee = last_seg(call.func)
        if callee in _DERIVERS:
            return
        args = list(call.args) + [kw.value for kw in call.keywords]
        for arg in args:
            if isinstance(arg, ast.Name) and arg.id in self.counts:
                self.counts[arg.id] += 1
                if self.counts[arg.id] >= 2:
                    self.findings.append(self.mod.finding(
                        "R3", arg,
                        f"key `{arg.id}` consumed again by `{callee or '<call>'}` "
                        f"without an interleaving split/fold_in — draws "
                        f"will be identical across consumers"))
                    self.counts[arg.id] = 0     # one finding per reuse


class PrngHygiene(Rule):
    code = "R3"
    name = "prng-hygiene"
    description = ("PRNG key consumed twice without split/fold_in, or "
                   "sim vs. engine fold_in offset constants drifting "
                   "apart (breaks ~1 ulp parity)")

    def check_module(self, mod: ModuleInfo) -> list[Finding]:
        out: list[Finding] = []
        for fn in [n for n in ast.walk(mod.tree)
                   if isinstance(n, FunctionNode)]:
            out.extend(_FnScanner(mod, fn).scan())
        return out

    # -- (b) fold-chain contract ------------------------------------------

    def check_project(self, ctx: ProjectContext) -> list[Finding]:
        mods = {rel: ctx.module(rel) for rel in _ENGINE_FILES}
        present = {rel: m for rel, m in mods.items() if m is not None}
        if len(present) < 2:
            return []                          # partial lint run
        offsets = {rel: self._fold_offsets(m) for rel, m in present.items()}
        vals = list(offsets.values())
        if vals[0] == vals[1]:
            return []
        (rel_a, set_a), (rel_b, set_b) = offsets.items()
        m = present[rel_a]
        anchor = m.tree.body[0] if m.tree.body else m.tree
        return [m.finding(
            "R3", anchor,
            f"fold_in offset contract drift: {rel_a} uses {sorted(set_a)} "
            f"but {rel_b} uses {sorted(set_b)} — the stage key chains "
            f"must use identical literal offsets for sim↔engine parity")]

    def _fold_offsets(self, mod: ModuleInfo) -> set[int]:
        """Literal fold-offset constants in a module's key chains:
        ``fold_in(k, N)`` / ``fold_in(k, N + x)`` plus literal arguments
        and defaults flowing into parameters named like fold offsets."""
        found: set[int] = set()
        fold_params: dict[str, list[int]] = {}  # fn name -> param indices
        for node in ast.walk(mod.tree):
            if isinstance(node, FunctionNode):
                names = [a.arg for a in node.args.args]
                idxs = [i for i, nm in enumerate(names)
                        if nm in _FOLD_PARAM_NAMES]
                kwonly = [i for i, a in enumerate(node.args.kwonlyargs)
                          if a.arg in _FOLD_PARAM_NAMES]
                if idxs or kwonly:
                    fold_params[node.name] = idxs
                    # positional defaults align right
                    off = len(names) - len(node.args.defaults)
                    for i in idxs:
                        j = i - off
                        if 0 <= j < len(node.args.defaults):
                            d = node.args.defaults[j]
                            if isinstance(d, ast.Constant) and isinstance(
                                    d.value, int):
                                found.add(d.value)
                    for i in kwonly:
                        d = node.args.kw_defaults[i]
                        if isinstance(d, ast.Constant) and isinstance(
                                d.value, int):
                            found.add(d.value)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if last_seg(node.func) == "fold_in" and len(node.args) >= 2:
                found |= self._const_terms(node.args[1])
            callee = last_seg(node.func)
            if callee in fold_params:
                for i in fold_params[callee]:
                    if i < len(node.args) and isinstance(
                            node.args[i], ast.Constant) and isinstance(
                            node.args[i].value, int):
                        found.add(node.args[i].value)
                for kw in node.keywords:
                    if kw.arg in _FOLD_PARAM_NAMES and isinstance(
                            kw.value, ast.Constant) and isinstance(
                            kw.value.value, int):
                        found.add(kw.value.value)
        return found

    def _const_terms(self, node) -> set[int]:
        """Integer literals additively contributing to a fold value:
        ``31`` in ``31 + step``; plain ``step`` contributes nothing."""
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return {node.value}
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
            return self._const_terms(node.left) | \
                self._const_terms(node.right)
        return set()
