"""Continuous batcher: tenant-tagged requests → rows of a mixed batch.

Requests queue FIFO; whenever engine rows free up (retired sequences),
the batcher admits waiting requests into them.  Admission is what makes
the batch *mixed*: rows belonging to different tenants — and admitted at
different times, hence sitting at different sequence positions — decode
together in one forward pass, with per-row ``adapter_idx`` and per-row
cache positions doing the separation the naive path does with one
merge-and-generate loop per tenant.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    tenant: str
    tokens: np.ndarray            # (prompt_len,) int32
    n_new: int
    # host clock at submit (perf_counter seconds) — admission latency
    # telemetry; one clock read per request, stamped unconditionally
    submit_ts: float = 0.0


class ContinuousBatcher:
    def __init__(self, max_rows: int, max_prompt_len: int, max_len: int):
        self.max_rows = max_rows
        self.max_prompt_len = max_prompt_len
        self.max_len = max_len
        self._queue: deque[Request] = deque()
        self._next_rid = 0

    def submit(self, tenant: str, tokens, n_new: int) -> int:
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if not 0 < tokens.size <= self.max_prompt_len:
            raise ValueError(f"prompt length {tokens.size} outside "
                             f"(0, {self.max_prompt_len}]")
        if n_new < 1 or tokens.size + n_new > self.max_len:
            raise ValueError(f"prompt {tokens.size} + n_new {n_new} exceeds "
                             f"max_len {self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, tenant, tokens, n_new,
                                   submit_ts=time.perf_counter()))
        return rid

    @property
    def pending(self) -> int:
        return len(self._queue)

    def queued_tenants(self, limit: Optional[int] = None) -> list[str]:
        """Distinct tenants with queued requests, in FIFO order (the
        empty-string pseudo-tenant is excluded).  ``limit`` caps the
        number of REQUESTS scanned, not tenants — the tiered store's
        prefetch and queue-informed eviction only care about the near
        front of the queue."""
        seen: list[str] = []
        for i, req in enumerate(self._queue):
            if limit is not None and i >= limit:
                break
            if req.tenant and req.tenant not in seen:
                seen.append(req.tenant)
        return seen

    def admit(self, free_rows: list[int]) -> list[tuple[int, Request]]:
        """Pop up to len(free_rows) queued requests, FIFO, pairing each
        with a free row index."""
        admitted = []
        for row in free_rows:
            if not self._queue:
                break
            admitted.append((row, self._queue.popleft()))
        return admitted

    def pack_prompts(self, admitted: list[tuple[int, Request]],
                     slots: dict[int, int], null_slot: int,
                     active_slots: Optional[np.ndarray] = None):
        """Build the fixed-shape (max_rows, max_prompt_len) prefill inputs:
        token matrix (pads at the *end* — causality keeps them invisible
        to real tokens), per-row prompt lengths, and per-row adapter
        slots (active rows keep theirs; idle rows point at the null
        slot)."""
        R, W = self.max_rows, self.max_prompt_len
        tokens = np.zeros((R, W), np.int32)
        lens = np.ones((R,), np.int32)
        out_slots = (np.full((R,), null_slot, np.int32)
                     if active_slots is None else
                     np.asarray(active_slots, np.int32).copy())
        for row, req in admitted:
            n = req.tokens.size
            tokens[row, :n] = req.tokens
            lens[row] = n
            out_slots[row] = slots[req.rid]
        return tokens, lens, out_slots
