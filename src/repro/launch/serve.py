"""Serving steps: batched prefill + one-token decode under pjit.

Per-tenant adapters: the decomposed-LoRA overlay merges into the
(model-sharded) backbone; personalized ΔB_M vectors are a few hundred
bytes per tenant, so a pod can hold thousands of personalized variants of
one backbone — the deployment story the paper's local optimizer implies.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.utils import pytree as pt

Params = Any


def make_prefill_step(cfg: ArchConfig, mesh=None):
    def prefill_step(params, batch):
        logits, cache = M.prefill(params, batch, cfg, mesh=mesh)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh=None):
    def decode_step(params, new_token, cache, cache_index, enc_out=None):
        return M.decode_step(params, new_token, cache, cache_index, cfg,
                             mesh=mesh, enc_out=enc_out)

    return decode_step


def greedy_generate(params, prompt_batch: dict, cfg: ArchConfig,
                    n_new: int = 16, mesh=None):
    """Simple greedy loop for the examples (prefill → decode)."""
    S = prompt_batch["tokens"].shape[1]
    logits, cache = M.prefill(params, prompt_batch, cfg, mesh=mesh,
                              cache_len=S + n_new)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    step = make_decode_step(cfg, mesh)
    idx = S
    for _ in range(n_new - 1):
        logits, cache = step(params, tok, cache, jnp.asarray(idx, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
        idx += 1
    return jnp.stack(out, axis=1)


def merge_adapters(base: Params, adapters: Params) -> Params:
    return pt.merge_trees(base, adapters)
