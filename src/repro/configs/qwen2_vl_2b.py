"""Qwen2-VL 2B — VLM backbone with M-RoPE and dynamic resolution
[arXiv:2409.12191].  The ViT vision tower is a STUB per the assignment:
input_specs provides projected patch embeddings; we build the language
decoder that consumes them, with the 3-section multimodal rotary."""
from repro.models.config import ArchConfig, reduced

ARCH = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab_size=151936, d_head=128,
    mrope=True, frontend="vision", frontend_tokens=1024,
    source="arXiv:2409.12191",
)
SMOKE = reduced(ARCH)
