"""SSM mixer + checkpoint tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.models.config import ArchConfig
from repro.models import model as M
from repro.models.ssm import _causal_conv, mamba2_mixer

CFG = ArchConfig(name="s", family="ssm", n_layers=1, d_model=32, n_heads=1,
                 n_kv_heads=1, d_ff=0, vocab_size=64, dtype="float32",
                 ssm_state=8, ssm_headdim=16, ssm_chunk=8, ssm_conv=4,
                 lora_targets=("x_proj", "out_proj"))


def test_causal_conv_is_causal():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 16, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)
    y, _ = _causal_conv(x, w)
    # changing the future must not change the past
    x2 = x.at[:, 10:].set(0.0)
    y2, _ = _causal_conv(x2, w)
    np.testing.assert_allclose(np.asarray(y[:, :10]), np.asarray(y2[:, :10]),
                               rtol=1e-6)


def test_mixer_prefill_then_decode_matches_full():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    p = params["blocks"]["sub0"]["ssm"]
    p = jax.tree.map(lambda x: x[0], p)   # unstack single layer
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 12, CFG.d_model)), jnp.float32)
    y_full, _ = mamba2_mixer(p, x, CFG)
    y_pre, cache = mamba2_mixer(p, x[:, :11], CFG, return_cache=True)
    y_dec, _ = mamba2_mixer(p, x[:, 11:], CFG, cache=cache,
                            cache_index=jnp.asarray(11))
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, 11]), rtol=1e-3,
                               atol=1e-4)


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.asarray([[1.5, -2.25]], jnp.bfloat16),
            "b": {"c": jnp.arange(6, dtype=jnp.int32).reshape(2, 3)},
            "d": jnp.asarray([0.1], jnp.float32)}
    path = os.path.join(tmp_path, "ck.msgpack")
    save_checkpoint(path, tree, step=7)
    restored, step = restore_checkpoint(path, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_sensitivity_identical_adapters_zero():
    from repro.core.sensitivity import sensitivity_report
    from repro.core import peft
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    ad = peft.add_lora(params, CFG, jax.random.PRNGKey(1), decomposed=True)
    rep = sensitivity_report({"t": ad}, ad)
    assert rep["mean"]["dM_A"] < 1e-6 and rep["mean"]["dD_B"] < 1e-5
