"""jit'd public wrapper: (B,S,H,dh)-layout flash attention w/ GQA."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref  # noqa: F401  (re-exported via repro.kernels)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_block(S, pref):
    for b in (pref, 512, 256, 128, 64):
        if S % b == 0 and b <= S:
            return b
    return S


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 512, bk: int = 512,
                    interpret: bool | None = None):
    """q (B,Sq,H,dh); k/v (B,Sk,K,dh) GQA → (B,Sq,H,dh)."""
    B, Sq, H, dh = q.shape
    Sk, K = k.shape[1], k.shape[2]
    if interpret is None:
        interpret = not _on_tpu()
    if scale is None:
        scale = float(1.0 / jnp.sqrt(dh))
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, Sk, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, Sk, dh)
    out = flash_attention_bhsd(
        qf, kf, vf, scale=scale, causal=causal, window=window,
        bq=_pick_block(Sq, bq), bk=_pick_block(Sk, bk),
        q_offset=Sk - Sq, interpret=interpret)
    return out.reshape(B, H, Sq, dh).transpose(0, 2, 1, 3)


__all__ = ["flash_attention", "attention_ref"]
