"""Production mesh construction.

Defined as functions (not module constants) so importing never touches
jax device state — smoke tests must keep seeing 1 CPU device; only
dryrun.py sets XLA_FLAGS for 512 placeholder devices before any import.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}
except ImportError:      # older jax: no explicit axis types — meshes are
    _AXIS_KW = lambda n: {}          # Auto by default, importing must work
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod slice: 16×16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: 'data' carries batch + federated clients + expert parallelism;
    'model' is tensor parallel; 'pod' is the cross-silo boundary (only
    adapter aggregation crosses it).  With 512 placeholder devices the
    single-pod mesh uses the first 256.
    """
    import numpy as np
    from jax.sharding import Mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))
    assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes,
                **_AXIS_KW(len(axes)))


def make_debug_mesh(n_data: int = 4, n_model: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for CI-scale distributed tests (8 host devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data // 2, n_model),
                             ("pod", "data", "model"), **_AXIS_KW(3))
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         **_AXIS_KW(2))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out
