"""Quickstart: FedLoRA-Optimizer on synthetic heterogeneous tasks (CPU).

    PYTHONPATH=src python examples/quickstart.py

Pretrains a small backbone (cached), runs a few federated rounds of the
paper's pipeline, and prints global vs personalized accuracy against the
plain-LoRA (FedIT) baseline.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)


from benchmarks.common import BENCH_CFG, bench_base, build_setting  # noqa: E402
from repro.core.fedlora import run_federated  # noqa: E402
from repro.fed.simulate import FedHyper  # noqa: E402


def main():
    print("== FedLoRA-Optimizer quickstart ==")
    base = bench_base("dolly", steps=400, log=print)
    cds, sds, eg, el = build_setting("dolly")
    for method in ("fedlora_opt", "lora"):
        hp = FedHyper(method=method, n_clients=len(cds), rounds=5,
                      local_steps=4, batch=8, seq_len=48, lr=2e-3,
                      personal_steps=10, global_steps=3)
        res = run_federated(BENCH_CFG, hp, cds, sds, eg, el, base=base,
                            log=print)
        print(f"--> {method:12s} global_acc={res.global_acc:.3f} "
              f"local_acc={res.local_acc:.3f} "
              f"comm={res.comm_bytes/1e6:.2f} MB")


if __name__ == "__main__":
    main()
