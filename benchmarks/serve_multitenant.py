"""Multi-tenant serving: mixed-tenant batch vs naive merge-per-tenant loop.

    PYTHONPATH=src python -m benchmarks.serve_multitenant

The paper's deployment story: one frozen backbone, per-tenant ΔB_M
magnitude vectors (a few hundred bytes each).  The seed path served this
by merging each tenant's adapter and generating one tenant at a time;
the ServeEngine runs all tenants as ONE batch, with the BGMV pooled-
adapter path keeping rows separated.  Same greedy decode, same
float32 numerics — the mixed batch amortizes every backbone matmul
across tenants, so tokens/s scales with batch size instead of being
pinned at batch-1 per tenant.

Reports tokens/s for both paths on the shared demo config
(``benchmarks.common.BENCH_CFG``) at 8 tenants, perf_micro-style
(interleaved reps, min as the noise-robust estimator).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_CFG
from repro.core import peft
from repro.launch.serve import greedy_generate, merge_adapters
from repro.models import model as M
from repro.serve import AdapterStore, ServeEngine, TieredAdapterStore
from repro.utils import pytree as pt

N_TENANTS = 8
PROMPT = 16
N_NEW = 32

# churn bench (run_churn): a 10k-tenant registry over a 32-slot pool
CHURN_TENANTS = 10_000
CHURN_SLOTS = 32
CHURN_ROWS = 16
CHURN_T1 = 256
CHURN_REQS = 32
CHURN_NEW = 16
CHURN_ZIPF_S = 1.1


def _setting(n_tenants: int):
    cfg = BENCH_CFG
    base = M.init_params(jax.random.PRNGKey(0), cfg)
    shared = peft.add_lora(base, cfg, jax.random.PRNGKey(1), decomposed=True)
    shared = pt.tree_map_with_path(
        lambda p, x: x + 0.25 if p.endswith("B_mag") else x, shared)
    tenants = {}
    for t in range(n_tenants):
        tenants[f"tenant{t}"] = pt.tree_map_with_path(
            lambda p, x: x + 0.1 * (t + 1) * jnp.sign(jnp.sin(
                jnp.arange(x.size, dtype=jnp.float32) + t)).reshape(x.shape)
            if p.endswith("dB_mag") else x, shared)
    rng = np.random.default_rng(0)
    prompts = np.asarray(rng.integers(5, cfg.vocab_size,
                                      size=(n_tenants, PROMPT)), np.int32)
    return cfg, base, shared, tenants, prompts


def _naive_loop(base, cfg, tenants, prompts):
    outs = []
    for t in range(len(tenants)):
        merged = merge_adapters(base, tenants[f"tenant{t}"])
        out = greedy_generate(merged, {"tokens": jnp.asarray(prompts[t:t+1])},
                              cfg, n_new=N_NEW)
        outs.append(np.asarray(out[0]))
    return outs


def run(log=print, n_tenants: int = N_TENANTS, reps: int = 3):
    cfg, base, shared, tenants, prompts = _setting(n_tenants)

    store = AdapterStore(base, cfg, n_slots=n_tenants, kind="dora_mag",
                         shared=shared)
    for name, tree in tenants.items():
        store.register(name, pt.filter_tree(
            tree, lambda p: p.endswith("dB_mag")))
    engine = ServeEngine(base, cfg, store, max_rows=n_tenants,
                         max_prompt_len=PROMPT, max_len=PROMPT + N_NEW + 8,
                         decode_chunk=8)
    reqs = [(f"tenant{t}", prompts[t]) for t in range(n_tenants)]

    # warm/compile both paths, check they agree, then interleave reps
    mixed_outs = engine.generate(reqs, n_new=N_NEW)
    naive_outs = _naive_loop(base, cfg, tenants, prompts)
    for a, b in zip(mixed_outs, naive_outs):
        np.testing.assert_array_equal(a, b)

    ts_mixed, ts_naive = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        engine.generate(reqs, n_new=N_NEW)
        ts_mixed.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _naive_loop(base, cfg, tenants, prompts)
        ts_naive.append(time.perf_counter() - t0)

    tok = n_tenants * N_NEW
    tps_mixed = tok / min(ts_mixed)
    tps_naive = tok / min(ts_naive)
    speedup = tps_mixed / tps_naive
    log(f"[bench] serve/mixed_batch      {tps_mixed:9.1f} tok/s  "
        f"({n_tenants} tenants x {N_NEW} new, one batch)")
    log(f"[bench] serve/naive_merge_loop {tps_naive:9.1f} tok/s  "
        f"(merge+generate per tenant)")
    log(f"[bench] serve speedup {speedup:.2f}x  "
        f"(ΔB_M payload {store.bytes_per_tenant()} B/tenant)")
    return [{"arch": "serve/mixed_batch", "tokens_s": tps_mixed,
             "us": min(ts_mixed) * 1e6},
            {"arch": "serve/naive_merge_loop", "tokens_s": tps_naive,
             "us": min(ts_naive) * 1e6}], speedup


def run_quant(log=print, n_tenants: int = N_TENANTS, reps: int = 3):
    """Mixed-tenant decode on the int8 backbone (f32 ΔB_M deltas on
    top) vs the f32 backbone.  Batch-1..N decode is weight-bytes-bound,
    so the analytic speedup is the f32/int8 weight-byte ratio — reported
    alongside the honest wall-clock of this CPU container (where XLA's
    dequant-fused fallback roughly ties f32 and the bytes win needs a
    bandwidth-bound accelerator).  Output drift vs the f32 backbone is
    checked against the documented int8 band (docs/quantization.md:
    ~4e-2 observed on this config, asserted < 1e-1)."""
    import dataclasses

    from repro.kernels.quant_matmul.ops import quantize_backbone

    cfg, base, shared, tenants, prompts = _setting(n_tenants)

    def build(run_cfg):
        store = AdapterStore(base, cfg, n_slots=n_tenants, kind="dora_mag",
                             shared=shared)
        for name, tree in tenants.items():
            store.register(name, pt.filter_tree(
                tree, lambda p: p.endswith("dB_mag")))
        return ServeEngine(base, run_cfg, store, max_rows=n_tenants,
                           max_prompt_len=PROMPT,
                           max_len=PROMPT + N_NEW + 8, decode_chunk=8)

    eng_f32 = build(cfg)
    eng_q8 = build(dataclasses.replace(cfg, backbone_quant="int8"))
    reqs = [(f"tenant{t}", prompts[t]) for t in range(n_tenants)]

    # documented tolerance: int8 drift stays in the ~4e-2 band on the
    # bench config, so greedy tokens agree except at near-ties
    batch = {"tokens": jnp.asarray(prompts)}
    drift = float(jnp.abs(
        M.forward(quantize_backbone(base, "int8"), batch, cfg)[0]
        - M.forward(base, batch, cfg)[0]).max())
    assert drift < 1e-1, f"int8 backbone drift {drift} out of band"

    outs_f32 = eng_f32.generate(reqs, n_new=N_NEW)     # compile + warm
    outs_q8 = eng_q8.generate(reqs, n_new=N_NEW)
    agree = np.mean([np.mean(a == b)
                     for a, b in zip(outs_f32, outs_q8)])

    ts_f32, ts_q8 = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        eng_f32.generate(reqs, n_new=N_NEW)
        ts_f32.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        eng_q8.generate(reqs, n_new=N_NEW)
        ts_q8.append(time.perf_counter() - t0)

    tok = n_tenants * N_NEW
    tps_f32, tps_q8 = tok / min(ts_f32), tok / min(ts_q8)
    bytes_ratio = pt.tree_bytes(base) / pt.tree_bytes(
        quantize_backbone(base, "int8"))
    log(f"[bench] serve/decode_f32  {tps_f32:9.1f} tok/s")
    log(f"[bench] serve/decode_int8 {tps_q8:9.1f} tok/s  "
        f"analytic_speedup={bytes_ratio:.2f}x (weight-byte ratio; "
        f"wall={tps_q8 / tps_f32:.2f}x on CPU)")
    log(f"[bench] serve int8 drift {drift:.2e} (band 1e-1, ~4e-2 typical), "
        f"token agreement {agree:.3f}")
    return [{"arch": "serve/decode_f32", "tokens_s": tps_f32,
             "us": min(ts_f32) * 1e6},
            {"arch": "serve/decode_int8", "tokens_s": tps_q8,
             "us": min(ts_q8) * 1e6, "bytes_ratio": bytes_ratio,
             "drift": drift, "token_agreement": float(agree)}], bytes_ratio


def run_churn(log=print, n_tenants: int = CHURN_TENANTS,
              n_slots: int = CHURN_SLOTS, reps: int = 5):
    """10k-tenant Zipf churn over a 32-slot tiered pool.

    Three measured settings, all on the shared bench config:

      * ``serve/tier_flat32``  — flat 32-slot pool, 32 resident tenants
        (the all-resident reference the tiered store must not tax);
      * ``serve/tier_warm``    — TieredAdapterStore serving the same 32
        tenants once they are T0-resident: every lookup is a pure T0
        hit, so this bounds the steady-state overhead of the tier
        bookkeeping (gate: within 1.05x of flat);
      * ``serve/tier_churn``   — Zipf(s=1.1) arrivals over all 10k
        registered tenants.  Most requests promote through T1/T2
        mid-serve (batched donated scatters between decode chunks,
        async prefetch from the batcher queue), so this measures the
        hot-swap cost under realistic skewed churn (gate: at least
        0.5x of the all-resident throughput).

    Registration itself (10k ``register`` calls spilling ~10k msgpack
    shards through the capacity-bounded T1) is timed and reported but
    not gated — it is a control-plane path.
    """
    import shutil
    import tempfile

    from repro import obs

    cfg = BENCH_CFG
    base = M.init_params(jax.random.PRNGKey(0), cfg)
    shared = peft.add_lora(base, cfg, jax.random.PRNGKey(1), decomposed=True)
    shared = pt.tree_map_with_path(
        lambda p, x: x + 0.25 if p.endswith("B_mag") else x, shared)
    # per-tenant ΔB_M payloads: tiny host trees stamped from one template
    template = jax.tree.map(np.asarray, pt.filter_tree(
        shared, lambda p: p.endswith("dB_mag")))

    def overlay(t: int):
        d = np.float32(0.05 * ((t % 37) + 1))
        return jax.tree.map(lambda x: x + d, template)

    rng = np.random.default_rng(0)
    prompts = np.asarray(rng.integers(5, cfg.vocab_size,
                                      size=(CHURN_REQS, PROMPT)), np.int32)

    def make_engine(store):
        return ServeEngine(base, cfg, store, max_rows=CHURN_ROWS,
                           max_prompt_len=PROMPT,
                           max_len=PROMPT + CHURN_NEW + 8, decode_chunk=8)

    def timed(engine, reqs):
        t0 = time.perf_counter()
        engine.generate(reqs, n_new=CHURN_NEW)
        return time.perf_counter() - t0

    # -- flat all-resident reference (32 tenants == 32 slots) ----------
    flat = AdapterStore(base, cfg, n_slots=n_slots, kind="dora_mag",
                        shared=shared)
    for t in range(n_slots):
        flat.register(f"tenant{t}", overlay(t))
    eng_flat = make_engine(flat)
    reqs32 = [(f"tenant{i % n_slots}", prompts[i]) for i in range(CHURN_REQS)]
    out_flat = eng_flat.generate(reqs32, n_new=CHURN_NEW)   # compile + warm
    tok = CHURN_REQS * CHURN_NEW

    shard_dir = tempfile.mkdtemp(prefix="tier_churn_")
    tel = obs.enable()          # metrics-only sink: tier counters below
    try:
        tiered = TieredAdapterStore(base, cfg, shard_dir=shard_dir,
                                    host_capacity=CHURN_T1, n_slots=n_slots,
                                    kind="dora_mag", shared=shared)
        t0 = time.perf_counter()
        for t in range(n_tenants):
            tiered.register(f"tenant{t}", overlay(t))
        reg_s = time.perf_counter() - t0
        eng_tier = make_engine(tiered)

        # warm-T0: same 32 tenants, all resident after the first pass —
        # and bit-identical to the flat pool
        out_warm = eng_tier.generate(reqs32, n_new=CHURN_NEW)
        for a, b in zip(out_flat, out_warm):
            np.testing.assert_array_equal(a, b)

        # Zipf churn schedules over the full registry
        ranks = np.arange(1, n_tenants + 1, dtype=np.float64)
        p = 1.0 / ranks ** CHURN_ZIPF_S
        p /= p.sum()
        ids = rng.choice(n_tenants, size=((reps + 1) * CHURN_REQS), p=p)
        scheds = [
            [(f"tenant{ids[r * CHURN_REQS + i]}", prompts[i])
             for i in range(CHURN_REQS)]
            for r in range(reps + 1)]
        eng_tier.generate(scheds[0], n_new=CHURN_NEW)       # warm the path

        # interleaved reps (perf_micro idiom): this container's wall
        # clock drifts across seconds, so the gated ratios must come
        # from measurements taken side by side, min as the estimator
        t_flat = t_warm = t_churn = float("inf")
        for r in range(reps):
            t_flat = min(t_flat, timed(eng_flat, reqs32))
            eng_tier.generate(reqs32, n_new=CHURN_NEW)  # re-pin tenants
            t_warm = min(t_warm, timed(eng_tier, reqs32))
            t_churn = min(t_churn, timed(eng_tier, scheds[r + 1]))
        tps_flat = tok / t_flat
        tps_warm = tok / t_warm
        tps_churn = tok / t_churn
        warm_ratio = t_warm / t_flat
        churn_ratio = tps_churn / tps_flat
        resident = len(tiered.resident_tenants)
        m = tel.metrics
        tier_stats = {
            "t0_hits": m.counter("pool/tier_hits").value(tier="t0"),
            "t1_hits": m.counter("pool/tier_hits").value(tier="t1"),
            "t1_misses": m.counter("pool/tier_misses").value(tier="t1"),
            "t1_promotions": m.counter("pool/promotions").value(src="t1"),
            "t2_promotions": m.counter("pool/promotions").value(src="t2"),
            "prefetched": m.counter("pool/prefetched").value(),
            "t1_spills": m.counter("pool/t1_spills").value(),
        }
    finally:
        obs.disable()
        shutil.rmtree(shard_dir, ignore_errors=True)

    log(f"[bench] serve/tier_flat32 {tps_flat:9.1f} tok/s  "
        f"({n_slots} resident tenants, flat pool)")
    log(f"[bench] serve/tier_warm   {tps_warm:9.1f} tok/s  "
        f"(warm T0 hits; {warm_ratio:.3f}x flat wall, bar 1.05x)")
    log(f"[bench] serve/tier_churn  {tps_churn:9.1f} tok/s  "
        f"(Zipf s={CHURN_ZIPF_S} over {n_tenants} tenants, "
        f"{churn_ratio:.2f}x all-resident throughput, bar 0.5x)")
    log(f"[bench] tier registration {n_tenants} tenants in {reg_s:.1f}s "
        f"(T1 cap {CHURN_T1}, {tiered.bytes_per_tenant()} B/tenant, "
        f"{resident} resident at end)")
    log(f"[bench] tier telemetry "
        + " ".join(f"{k}={int(v)}" for k, v in tier_stats.items()))
    return [{"arch": "serve/tier_flat32", "tokens_s": tps_flat,
             "us": t_flat * 1e6},
            {"arch": "serve/tier_warm", "tokens_s": tps_warm,
             "us": t_warm * 1e6, "ratio": warm_ratio},
            {"arch": "serve/tier_churn", "tokens_s": tps_churn,
             "us": t_churn * 1e6, "ratio": churn_ratio,
             "n_tenants": n_tenants, "n_slots": n_slots,
             "register_s": reg_s, **tier_stats}], (warm_ratio, churn_ratio)


def main():
    rows, speedup = run()
    qrows, bytes_ratio = run_quant()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"serve/{r['arch'].split('/')[1]},{r['us']:.0f},"
              f"tokens_s={r['tokens_s']:.1f}")
    for r in qrows:
        print(f"serve/{r['arch'].split('/')[1]},{r['us']:.0f},"
              f"tokens_s={r['tokens_s']:.1f}")
    print(f"# mixed-batch speedup over merge-per-tenant: {speedup:.2f}x")
    print(f"# int8 decode analytic speedup (weight-byte ratio): "
          f"{bytes_ratio:.2f}x")
    return rows + qrows


if __name__ == "__main__":
    main()
