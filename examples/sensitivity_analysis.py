"""Reproduce the paper's Fig.-1 exploratory experiment (Eqs. 2-3).

    PYTHONPATH=src python examples/sensitivity_analysis.py

Fine-tunes decomposed-LoRA per downstream task vs the all-task mixture
and reports the direction/magnitude sensitivity of the A and B factors —
the observation motivating the whole method (A-direction ≫, B-magnitude ≫).
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks import fig1_sensitivity  # noqa: E402


def main():
    rep = fig1_sensitivity.run(steps=60, log=print)
    print("\nper-task breakdown:")
    for t, row in rep["per_task"].items():
        print(f"  {t:8s} ΔD_A={row['dD_A']:.4f} ΔD_B={row['dD_B']:.4f} "
              f"ΔM_A={row['dM_A']:.4f} ΔM_B={row['dM_B']:.4f}")
    print(f"\nObs.1 (paper 1.7×): direction ratio A/B = "
          f"{rep['obs1_dir_ratio_A_over_B']:.2f}")
    print(f"Obs.2 (paper 41×) : magnitude ratio B/A = "
          f"{rep['obs2_mag_ratio_B_over_A']:.2f}")


if __name__ == "__main__":
    main()
