"""Telemetry layer (repro.obs): registry/event-log unit behaviour, the
zero-cost-when-disabled contract — every engine's numerics are
bit-identical with the sink on and off, and the ``TrainSettings.telemetry``
flag changes only the metric leaves, never the adapters — plus the
JSONL → ``telemetry_section`` report round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import peft
from repro.fed.simulate import FedHyper, FedSim
from repro.launch.report import telemetry_section
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.obs import EventLog, MetricsRegistry, NullRegistry, read_events
from repro.serve import AdapterStore, ServeEngine
from repro.utils import pytree as pt

CFG = ArchConfig(name="obs-t", family="dense", n_layers=2, d_model=32,
                 n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                 dtype="float32", lora_rank=4, lora_dropout=0.0)


@pytest.fixture(autouse=True)
def _null_sink():
    """Every test starts and ends with the process-global null sink —
    the engines read it at call time, so leakage across tests would make
    the invariance assertions meaningless."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_labels_key_order_irrelevant():
    reg = MetricsRegistry()
    reg.counter("fed/comm_bytes").inc(100, method="lora", comm="psum")
    reg.counter("fed/comm_bytes").inc(20, comm="psum", method="lora")
    reg.counter("fed/comm_bytes").inc(7, method="lora_gather", comm="gather")
    c = reg.counter("fed/comm_bytes")
    assert c.value(method="lora", comm="psum") == 120
    assert c.value(comm="gather", method="lora_gather") == 7
    snap = c.snapshot()
    assert len(snap) == 2 and all(set(s) == {"labels", "value"} for s in snap)


def test_gauge_last_write_wins():
    reg = MetricsRegistry()
    g = reg.gauge("serve/queue_depth")
    g.set(3)
    g.set(1)
    assert g.value() == 1.0
    assert g.value(tenant="x") == 0.0     # unset series reads 0


def test_histogram_stats_and_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("span_seconds")
    for v in (0.002, 0.02, 0.02, 3.0):
        h.observe(v, span="fed/round")
    (s,) = h.snapshot()
    assert s["labels"] == {"span": "fed/round"}
    assert s["count"] == 4 and s["min"] == 0.002 and s["max"] == 3.0
    np.testing.assert_allclose(s["sum"], 3.042)
    np.testing.assert_allclose(s["mean"], 3.042 / 4)
    # log-spaced default bounds: 0.002→le_0.0025, 0.02→le_0.025 (×2), 3→le_5
    assert s["buckets"] == {"le_0.0025": 1, "le_0.025": 2, "le_5": 1}


def test_registry_snapshot_schema_and_reset():
    reg = MetricsRegistry()
    reg.counter("a").inc()
    reg.gauge("b").set(2.0)
    reg.histogram("c").observe(0.5)
    snap = reg.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert [s["value"] for s in snap["counters"]["a"]] == [1.0]
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_null_registry_absorbs_everything():
    reg = NullRegistry()
    reg.counter("x").inc(5, k="v")
    reg.gauge("x").set(1.0)
    reg.histogram("x").observe(2.0)
    assert reg.counter("x").value() == 0.0
    assert reg.histogram("x").series() is None
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_enable_disable_lifecycle(tmp_path):
    assert not obs.enabled()
    obs.inc("dropped")                    # null sink: silently absorbed
    tel = obs.enable(str(tmp_path / "t.jsonl"))
    assert obs.enabled() and obs.active() is tel
    obs.inc("kept", method="m")
    obs.event("ping", n=1)
    snap = obs.emit_snapshot()
    assert snap["counters"]["kept"][0]["value"] == 1.0
    assert "dropped" not in snap["counters"]
    obs.disable()
    assert not obs.enabled()
    kinds = [e["kind"] for e in read_events(str(tmp_path / "t.jsonl"))]
    assert kinds == ["ping", "metrics_snapshot"]


# ---------------------------------------------------------------------------
# event log
# ---------------------------------------------------------------------------

def test_event_log_roundtrip_and_kind_filter(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    log = EventLog(path)
    log.emit("fed_round", step=0, ce=[1.5, 2.0])
    log.emit("serve_run", tokens=np.int64(64))   # numpy coerced to JSON
    log.close()
    evs = read_events(path)
    assert [e["kind"] for e in evs] == ["fed_round", "serve_run"]
    assert evs[0]["ce"] == [1.5, 2.0] and "ts" in evs[0]
    assert evs[1]["tokens"] == 64
    assert [e["kind"] for e in read_events(path, kind="serve_run")] \
        == ["serve_run"]


def test_event_log_rotation_keeps_oldest_first(tmp_path):
    path = str(tmp_path / "rot.jsonl")
    log = EventLog(path, max_bytes=200, keep=2)
    for i in range(30):
        log.emit("tick", i=i)
    log.close()
    import os
    assert os.path.exists(path + ".1")           # rotation happened
    assert not os.path.exists(path + ".3")       # keep=2 bound respected
    seen = [e["i"] for e in read_events(path)]
    assert seen == sorted(seen)                  # segments rejoined in order
    assert seen[-1] == 29                        # newest survives
    assert len(seen) < 30                        # oldest aged out past keep


def test_event_log_appends_across_enables(tmp_path):
    path = str(tmp_path / "app.jsonl")
    obs.enable(path)
    obs.event("first")
    obs.disable()
    obs.enable(path)
    obs.event("second")
    obs.disable()
    assert [e["kind"] for e in read_events(path)] == ["first", "second"]


# ---------------------------------------------------------------------------
# zero-cost-when-disabled: engine numerics identical with the sink on/off
# ---------------------------------------------------------------------------

def _fed_batches(C, n, seed=0):
    rng = np.random.default_rng(seed)
    return [{"tokens": jnp.asarray(rng.integers(5, 64, size=(C, 2, 16)),
                                   jnp.int32),
             "loss_mask": jnp.ones((C, 2, 16), jnp.float32)}
            for _ in range(n)]


def _run_sim_rounds():
    hp = FedHyper(method="fedlora_opt", n_clients=2, local_steps=2, lr=1e-2)
    sim = FedSim(CFG, hp)
    for r in range(2):
        sim.run_round(_fed_batches(2, 2, seed=r), jax.random.PRNGKey(r))
    return {p: np.asarray(v) for p, v in
            zip(pt.tree_paths(sim.client_adapters),
                jax.tree.leaves(sim.client_adapters))}


def test_fed_sim_invariant_under_telemetry(tmp_path):
    ref = _run_sim_rounds()
    obs.enable(str(tmp_path / "fed.jsonl"))
    instrumented = _run_sim_rounds()
    obs.disable()
    assert set(ref) == set(instrumented)
    for p in ref:
        np.testing.assert_array_equal(ref[p], instrumented[p], err_msg=p)
    evs = read_events(str(tmp_path / "fed.jsonl"), kind="fed_round")
    assert len(evs) == 2 and evs[0]["clients"] == 2
    assert set(evs[0]["wall"]) == {"scan", "aggregate", "rebroadcast",
                                   "total"}


def _run_serve(base, shared):
    store = AdapterStore(base, CFG, n_slots=2, kind="dora_mag", shared=shared)
    for t in range(2):
        ov = pt.tree_map_with_path(
            lambda p, x: x + 0.1 * (t + 1) if p.endswith("dB_mag") else x,
            shared)
        store.register(f"t{t}", pt.filter_tree(
            ov, lambda p: p.endswith("dB_mag")))
    eng = ServeEngine(base, CFG, store, max_rows=2, max_prompt_len=8,
                      max_len=24, decode_chunk=4)
    rng = np.random.default_rng(7)
    prompts = np.asarray(rng.integers(5, 64, size=(3, 8)), np.int32)
    outs = eng.generate([("t0", prompts[0]), ("t1", prompts[1]),
                         ("t0", prompts[2])], n_new=6)
    return [np.asarray(o) for o in outs]


def test_serve_engine_invariant_under_telemetry(tmp_path):
    base = M.init_params(jax.random.PRNGKey(0), CFG)
    shared = pt.tree_map_with_path(
        lambda p, x: x + 0.25 if p.endswith("B_mag") else x,
        peft.add_lora(base, CFG, jax.random.PRNGKey(1), decomposed=True))
    ref = _run_serve(base, shared)
    obs.enable(str(tmp_path / "serve.jsonl"))
    instrumented = _run_serve(base, shared)
    snap = obs.emit_snapshot()
    obs.disable()
    for a, b in zip(ref, instrumented):
        np.testing.assert_array_equal(a, b)
    evs = read_events(str(tmp_path / "serve.jsonl"))
    kinds = {e["kind"] for e in evs}
    assert {"pool_register", "serve_admit", "compile", "serve_run"} <= kinds
    (run,) = [e for e in evs if e["kind"] == "serve_run"]
    assert run["requests"] == 3 and run["tokens"] == 3 * 6
    hist = {s["labels"]["span"]: s
            for s in snap["histograms"]["span_seconds"]}
    assert hist["serve/prefill"]["count"] >= 1
    assert hist["serve/decode_chunk"]["count"] >= 1


def test_train_step_telemetry_flag_changes_only_metrics():
    """``TrainSettings.telemetry=True`` must add the replicated
    per-client metric leaves and nothing else — same adapters, and the
    extra leaves agree with the always-on scalar metrics."""
    from repro.launch.mesh import make_client_mesh
    from repro.launch.train import TrainSettings, make_fed_train_step

    mesh = make_client_mesh(1)
    hp = FedHyper(method="fedlora_opt", n_clients=1, local_steps=2, lr=1e-2)
    sim = FedSim(CFG, hp)
    batches = _fed_batches(1, 2, seed=3)
    big = {k: jnp.concatenate([b[k] for b in batches], axis=1)
           for k in batches[0]}
    step0 = jnp.zeros((), jnp.int32)

    outs = {}
    for tele in (False, True):
        st = TrainSettings(lr=hp.lr, micro_batches=1, clip=hp.clip,
                           remat=False, method="fedlora_opt", local_steps=2,
                           telemetry=tele)
        step_fn, opt_init = make_fed_train_step(CFG, mesh, st)
        na, no, met = step_fn(sim.base, sim.client_adapters,
                              opt_init(sim.client_adapters), step0, big)
        outs[tele] = (na, met)

    (na0, met0), (na1, met1) = outs[False], outs[True]
    for p, a, b in zip(pt.tree_paths(na0), jax.tree.leaves(na0),
                       jax.tree.leaves(na1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=p)
    extra = set(met1) - set(met0)
    assert extra == {"client_ce", "client_grad_norm", "client_drift"}
    np.testing.assert_allclose(float(np.asarray(met1["client_ce"]).mean()),
                               float(met1["ce"]), rtol=1e-6)
    np.testing.assert_allclose(
        float(np.asarray(met1["client_grad_norm"]).mean()),
        float(met1["grad_norm"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# JSONL → report round-trip
# ---------------------------------------------------------------------------

def test_telemetry_section_renders_jsonl(tmp_path):
    path = str(tmp_path / "run.jsonl")
    obs.enable(path)
    obs.event("fed_round", engine="pipeline", method="fedlora_opt", step=2,
              clients=4, ce=[1.0, 2.0, 3.0, 4.0], grad_norm=[0.5] * 4,
              drift=[0.25] * 4, loss_spread=3.0, comm_bytes=4096,
              comm_class="psum",
              wall={"round": 0.5, "global": 0.25, "personal": 0.125,
                    "total": 0.875})
    obs.event("fed_stage", engine="pipeline", stage="global",
              method="fedlora_opt", ce=1.25, wall=0.25)
    obs.event("serve_admit", rid=0, tenant="t0", row=1, wait=0.004,
              queue_depth=2)
    obs.event("serve_run", requests=3, tokens=18, wall=0.2, tokens_per_s=90.0,
              chunks=2, prefills=1, rows=2, decode_chunk=4)
    obs.inc("pool/lookups", 3, kind="dora_mag")
    obs.inc("pool/registers", 1, kind="dora_mag")
    obs.emit_snapshot()
    obs.disable()

    text = telemetry_section(path)
    assert "## §Telemetry" in text
    assert "### Federated rounds" in text
    # ce mean 2.5, spread 3.0, comm bytes formatted with separators
    assert "| pipeline | fedlora_opt | 2 | 4 | 2.5000 | 3.0000 |" in text
    assert "4,096 (psum)" in text
    assert "### Pipeline stages" in text and "| global |" in text
    assert "### Serving" in text
    assert "| 3 | 18 | 0.200 | 90.0 | 2 | 1 | 2 |" in text
    assert "admission wait mean 4.00 ms" in text
    assert "pool hit-rate 75.00% (3 lookups / 1 registers)" in text
    # list-of-dicts input renders identically to the path input
    assert telemetry_section(read_events(path)) == text


def test_telemetry_section_empty():
    assert "_no telemetry events_" in telemetry_section([])


# ---------------------------------------------------------------------------
# sub-ms bucket resolution + per-histogram bounds override
# ---------------------------------------------------------------------------

def test_sub_ms_latencies_land_in_distinct_buckets():
    """Regression: an 80 µs and a 600 µs span used to collapse into one
    "< 1 ms" bucket.  Both the refined defaults and LATENCY_BOUNDS must
    keep them apart."""
    reg = MetricsRegistry()
    h = reg.histogram("span_seconds")                 # refined defaults
    h.observe(80e-6, span="serve/prefill")
    h.observe(600e-6, span="serve/prefill")
    (s,) = h.snapshot()
    assert s["buckets"] == {"le_0.0001": 1, "le_0.001": 1}

    lo = reg.histogram("serve/admission_wait_seconds", obs.LATENCY_BOUNDS)
    lo.observe(8e-6)
    lo.observe(80e-6)
    lo.observe(600e-6)
    (s,) = lo.snapshot()
    assert s["buckets"] == {"le_1e-05": 1, "le_0.0001": 1, "le_0.001": 1}
    assert obs.LATENCY_BOUNDS[0] < obs.DEFAULT_BOUNDS[0]


def test_observe_bounds_override_first_creation_wins(tmp_path):
    obs.enable(str(tmp_path / "b.jsonl"))
    obs.observe("custom/lat", 0.3, bounds=(0.25, 0.5, 1.0))
    obs.observe("custom/lat", 0.4, bounds=(9.0,))    # ignored: name exists
    obs.observe("custom/lat", 2.0)                   # default arg: same hist
    snap = obs.emit_snapshot()
    obs.disable()
    (s,) = snap["histograms"]["custom/lat"]
    assert s["count"] == 3
    assert s["buckets"] == {"le_0.5": 2, "le_inf": 1}


def test_telemetry_section_histogram_table_separates_sub_ms(tmp_path):
    path = str(tmp_path / "h.jsonl")
    obs.enable(path)
    obs.observe("serve/prefill_seconds", 80e-6, bounds=obs.LATENCY_BOUNDS)
    obs.observe("serve/prefill_seconds", 600e-6, bounds=obs.LATENCY_BOUNDS)
    obs.emit_snapshot()
    obs.disable()
    text = telemetry_section(path)
    assert "### Histograms" in text
    # two sub-ms observations render as two distinct bucket cells
    assert "0.0001:1" in text and "0.001:1" in text
    assert "| serve/prefill_seconds |" in text


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def test_to_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("fed/comm_bytes").inc(4096, method="lora")
    reg.gauge("serve/queue_depth").set(3)
    h = reg.histogram("span_seconds", (0.001, 0.01, 0.1))
    for v in (0.0005, 0.005, 0.008, 0.5):
        h.observe(v, span="fed/round")
    text = obs.to_prometheus(reg.snapshot())
    lines = text.splitlines()
    assert text.endswith("\n")
    assert "# TYPE repro_fed_comm_bytes counter" in lines
    assert 'repro_fed_comm_bytes{method="lora"} 4096' in lines
    assert "# TYPE repro_serve_queue_depth gauge" in lines
    assert "repro_serve_queue_depth 3" in lines
    assert "# TYPE repro_span_seconds histogram" in lines
    # buckets are cumulative and close with +Inf == count
    assert 'repro_span_seconds_bucket{span="fed/round",le="0.001"} 1' in lines
    assert 'repro_span_seconds_bucket{span="fed/round",le="0.01"} 3' in lines
    assert 'repro_span_seconds_bucket{span="fed/round",le="+Inf"} 4' in lines
    assert 'repro_span_seconds_count{span="fed/round"} 4' in lines
    sum_line = [ln for ln in lines
                if ln.startswith('repro_span_seconds_sum')][0]
    np.testing.assert_allclose(float(sum_line.split()[-1]), 0.5135)
    assert obs.to_prometheus(MetricsRegistry().snapshot()) == ""


def test_serve_run_writes_prom_file(tmp_path, monkeypatch):
    prom = tmp_path / "metrics.prom"
    monkeypatch.setenv("REPRO_PROM_PATH", str(prom))
    base = M.init_params(jax.random.PRNGKey(0), CFG)
    shared = pt.tree_map_with_path(
        lambda p, x: x + 0.25 if p.endswith("B_mag") else x,
        peft.add_lora(base, CFG, jax.random.PRNGKey(1), decomposed=True))
    obs.enable(str(tmp_path / "s.jsonl"))
    _run_serve(base, shared)
    obs.disable()
    assert prom.exists()
    text = prom.read_text()
    assert "# TYPE repro_serve_requests_admitted counter" in text \
        or "repro_" in text.splitlines()[0]
    # sub-ms serve spans made it into exposition with cumulative buckets
    assert "repro_serve_prefill_seconds_bucket" in text
    assert 'le="+Inf"' in text
    assert not (tmp_path / "metrics.prom.tmp").exists()  # atomic rename
