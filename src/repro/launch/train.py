"""Production federated train step.

TPU-native mapping of the paper's round (DESIGN.md §4):

  · clients ↔ slices of the ('pod','data') axes — ONE client per data
    shard; each client's decomposed-LoRA adapters live only on its shard;
  · local SGD ↔ per-shard grad/update steps inside a shard_map that is
    MANUAL over ('pod','data') and AUTO over 'model' (XLA still does
    tensor parallelism inside each client);
  · aggregation ↔ the method's *collective form* (core.aggregation
    .CollectiveAgg) issued from inside the manual region — a weighted
    psum for the mean family, a per-row coverage-weighted psum for
    replication averaging, an all_gather of the stacked factors followed
    by QR/truncated-SVD re-factorization for exact aggregation.  The only
    cross-client (and the only cross-pod) traffic, a few MB of adapter
    state;
  · per-client state (the paper's personal ΔB_M, FedALT's individual
    pair) never crosses shards: keep-local leaves are restored from the
    shard's own values after the collective;
  · heterogeneous fleets ride the same program: per-client rank masks
    (peft.client_rank_masks) zero update rows above each client's rank
    and re-mask the rebroadcast inside the manual region;
  · FedProx's proximal anchor is the shard's round-start adapters — a
    per-shard leaf captured by the local-step scan, no extra state.

One train_step call is one federated ROUND: ``settings.local_steps``
optimizer steps per client, then one aggregation.  Every method in the
core.methods registry trains with the same math here as in the
single-process simulator (fed/simulate.py) — the 8-device parity sweep
in tests/test_distributed.py pins shard_map round == FedSim round for
all of them, mixed-rank and weighted fleets included.

Gradient accumulation: each local step's batch is split into
micro-batches (a lax.scan, so HLO stays one body deep) so scan-boundary
activations of an 88-layer model fit HBM; LoRA grads are accumulated in
f32.
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as fedagg
from repro.core import peft
from repro.core.methods import get_method
from repro.launch.mesh import data_axes, dp_size, shard_map_compat
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim import adamw, masked
from repro.optim.optimizers import apply_updates, clip_by_global_norm
from repro.utils import pytree as pt
from repro.utils import sharding as shd

Params = Any


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    lr: float = 1e-4
    micro_batches: int = 1
    clip: float = 1.0
    remat: object = True          # True (full) | "dots" | False
    # stage: which components train (paper pipeline stages)
    stage: str = "local_pretrain"   # | "global" | "local"
    # federated method (core.methods registry) — drives the adapter
    # factory, the per-stage trainable mask, the keep-local leaves, and
    # the collective aggregation form
    method: str = "fedlora_opt"
    # local optimizer steps per round (per train_step call); the batch
    # carries local_steps × per-step-batch rows per client, step-major
    local_steps: int = 1
    # FedProx proximal coefficient (only consulted for prox methods)
    prox_mu: float = 0.0
    # Heterogeneous fleet: one LoRA rank per client (len == dp_size(mesh));
    # None → uniform at cfg.lora_rank.  Mirrors FedHyper.client_ranks.
    client_ranks: Optional[tuple] = None
    # server-side allocation rank for a heterogeneous fleet (0 → fleet max)
    server_rank: int = 0
    # per-client data-size aggregation weights (len == dp_size(mesh));
    # None → uniform.  Mirrors FedHyper.client_weights.
    client_weights: Optional[tuple] = None


def pick_micro_batches(cfg: ArchConfig, per_client_batch: int,
                       seq_len: int, budget_bytes: float = 1.0e9) -> int:
    """Choose grad-accumulation depth so scan-boundary activations
    (n_superblocks × mb × S × D × 2B) stay under budget."""
    n_sb, tail, pattern = cfg.blocks_layout()
    per_mb = (n_sb + 1) * seq_len * cfg.d_model * 2 * len(pattern)
    mb_max = max(1, int(budget_bytes // max(per_mb, 1)))
    micro = max(1, -(-per_client_batch // mb_max))
    while per_client_batch % micro:
        micro += 1
    return min(micro, per_client_batch)


def _stage_mask(method, adapters, stage: str):
    if stage == "global":
        return method.stage_global_mask(adapters)
    if stage == "local":
        return method.stage_local_mask(adapters)
    return method.train_mask(adapters)


def make_fed_train_step(cfg: ArchConfig, mesh, settings: TrainSettings):
    """Returns (train_step, opt_init).  train_step signature:

        train_step(base, adapters, opt_state, step, batch)
            → (adapters, opt_state, metrics)

    base: global param tree (model-sharded, replicated over data axes).
    adapters: leading client axis C = dp_size(mesh), sharded 1-per-shard
    (for a heterogeneous fleet, allocated at the server rank and already
    rank-masked, as FedSim lays them out).
    batch: {"tokens": (C, local_steps·B_c, S), ...} sharded likewise,
    step-major: local step t consumes rows [t·B_c, (t+1)·B_c).
    step: global local-step counter; one call advances it by
    ``settings.local_steps``, so the caller passes step + local_steps to
    the next call (the optimizer's bias-correction schedule matches the
    simulator's per-step counter).

    No rng is threaded into the loss, so adapter dropout is NOT applied
    here (the simulator applies it per step when cfg.lora_dropout > 0);
    the parity contract with FedSim — and the paper's fine-tuning
    setting — is lora_dropout = 0.
    """
    if cfg.use_fused_dora:
        raise ValueError(
            "use_fused_dora is forward/serving-only (the Pallas kernel "
            "defines no VJP); the train step requires the jnp adapter path")
    daxes = data_axes(mesh)
    dp = dp_size(mesh)
    micro = settings.micro_batches
    T = settings.local_steps
    is_moe = cfg.n_experts > 0
    method = get_method(settings.method)
    keep_rx = re.compile(method.keep_local) if method.keep_local else None
    # the method's cross-client collective — resolving it here (not at
    # step time) means an aggregator with no shard_map form fails fast,
    # never silently training with different math than the simulator
    collective = fedagg.collective_form(method)
    prox_mu = settings.prox_mu if method.prox else 0.0

    # ---- fleet layout: ranks, coverage masks, aggregation weights ------
    het = settings.client_ranks is not None
    if het:
        if not method.het_ranks:
            raise ValueError(
                f"method {method.name!r} has no rank dimension "
                "(het_ranks=False); client_ranks requires a LoRA-family "
                "method")
        alloc_rank = peft.fleet_alloc_rank(settings.client_ranks, dp,
                                           settings.server_rank)
        ranks = jnp.asarray(settings.client_ranks, jnp.int32)
    else:
        alloc_rank = cfg.lora_rank
        ranks = jnp.full((dp,), alloc_rank, jnp.int32)
    if settings.client_weights is not None:
        peft.validate_client_weights(settings.client_weights, dp)
        weight_c = jnp.asarray(settings.client_weights, jnp.float32)
    else:
        weight_c = jnp.ones((dp,), jnp.float32)

    def client_body(base, adapters, opt_state, step0, batch, weight, covers):
        # ---- inside the manual region: one client per shard -------------
        adapters = jax.tree.map(lambda x: x[0], adapters)   # drop C axis
        opt_state = jax.tree.map(lambda x: x[0], opt_state)
        batch = {k: v[0] for k, v in batch.items()}
        w = weight[0]
        cover = jax.tree.map(lambda x: x[0], covers)
        mesh_tag = ("manual", mesh.shape["data"]) if is_moe else None
        # FedProx anchor: this shard's round-start adapters, captured as
        # a per-shard leaf by the local-step scan below
        anchor = adapters

        def loss_fn(ad, mb):
            params = pt.merge_trees(base, ad)
            loss, met = M.loss_and_metrics(params, mb, cfg,
                                           mesh=mesh_tag,
                                           remat=settings.remat)
            if prox_mu:
                d = pt.tree_sub(ad, anchor)
                loss = loss + 0.5 * prox_mu * pt.tree_dot(d, d)
            return loss, met

        # batch rows: step-major, then micro-batched.  Gradient
        # accumulation over micro-batches via lax.scan: one HLO body
        # regardless of depth (an unrolled loop made 88-layer compiles
        # explode), forward-only carry (grads), no cross-step residuals.
        B_c = batch["tokens"].shape[0]
        if B_c % (T * micro):
            raise ValueError(
                f"per-client batch {B_c} is not divisible by local_steps "
                f"({T}) x micro_batches ({micro})")
        mb_sz = B_c // (T * micro)
        sbatch = {k: v.reshape((T, micro, mb_sz) + v.shape[1:])
                  for k, v in batch.items()}

        def local_step(carry, sb):
            ad, ost, step = carry
            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), ad)

            def acc_body(g_acc, mb):
                (_, met), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    ad, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return g_acc, met

            g_acc, mets = jax.lax.scan(acc_body, g0, sb)
            g_acc = jax.tree.map(lambda x: x / micro, g_acc)
            g_acc = clip_by_global_norm(g_acc, settings.clip)
            upd, ost = opt.update(g_acc, ost, ad, step)
            if het:
                # heterogeneous fleet: zero the update rows above this
                # client's rank (adapters are allocated at the server rank)
                upd = jax.tree.map(jnp.multiply, upd, cover)
            ad = apply_updates(ad, upd)
            met = jax.tree.map(lambda x: jnp.sum(x, axis=0) / micro, mets)
            return (ad, ost, step + 1), met

        (adapters, opt_state, _), mets = jax.lax.scan(
            local_step, (adapters, opt_state, step0), sbatch)

        # ---- the method's collective aggregation: the only cross-client
        # (and only cross-pod) traffic.  Keep-local leaves (the paper's
        # personal ΔB_M, FedALT's individual pair) are restored from this
        # shard's own post-round values — personalization never crosses
        # shards.
        agg = collective(adapters, axes=daxes, weight=w, cover=cover)
        out = (_select_personal(adapters, agg, keep_rx)
               if keep_rx is not None else agg)
        if het:
            # rebroadcast re-mask: a rank-r client receives the first r
            # rank rows of the aggregate (matches FedSim's rebroadcast)
            out = jax.tree.map(jnp.multiply, out, cover)
        met_last = jax.tree.map(lambda m: jax.lax.pmean(m[-1], daxes), mets)

        out = jax.tree.map(lambda x: x[None], out)
        opt_state = jax.tree.map(lambda x: x[None], opt_state)
        return out, opt_state, met_last

    def _select_personal(local, agg, rx):
        return pt.tree_map_with_path(
            lambda p, leaf_agg: _pick(local, p) if rx.search(p) else leaf_agg,
            agg)

    def _pick(tree, path):
        node = tree
        for k in path.split("/"):
            node = node[k]
        return node

    # abstract adapter tree (drives the trainable mask, the shard specs,
    # and the per-client coverage masks); heterogeneous fleets allocate
    # at the server rank, exactly as FedSim does
    mk = (partial(method.make_adapter, rank=alloc_rank) if het
          else method.make_adapter)
    abs_ad = jax.eval_shape(
        lambda: mk(abstract_base(cfg), cfg, jax.random.PRNGKey(0)))
    mask = _stage_mask(method, abs_ad, settings.stage)
    opt = masked(adamw(settings.lr), mask)
    # per-client coverage masks over the rank axis of every leaf; on a
    # uniform fleet these are all-ones (and unused outside the coverage
    # collective), so the uniform program pays nothing
    covers_c = peft.client_rank_masks(abs_ad, ranks)

    ad_spec = shd.client_specs(abs_ad, mesh)
    ost_abs = jax.eval_shape(opt.init, abs_ad)
    ost_spec = shd.client_specs(ost_abs, mesh)
    cov_spec = shd.client_specs(covers_c, mesh)
    w_spec = P(shd.client_axis(mesh))

    def batch_spec_of(batch):
        return {k: P(shd.client_axis(mesh)) for k in batch}

    def train_step(base, adapters, opt_state, step, batch):
        body = shard_map_compat(
            client_body,
            mesh,
            in_specs=(base_manual_specs(base, cfg), ad_spec, ost_spec, P(),
                      batch_spec_of(batch), w_spec, cov_spec),
            out_specs=(ad_spec, ost_spec, P()),
            manual_axes=daxes,
        )
        return body(base, adapters, opt_state, step, batch, weight_c,
                    covers_c)

    def opt_init(adapters_c):
        return jax.vmap(opt.init)(adapters_c)

    return train_step, opt_init


def abstract_base(cfg: ArchConfig):
    return jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def base_manual_specs(base, cfg: ArchConfig):
    """Manual specs for the base tree over the DATA axes only: MoE expert
    slots are expert-parallel (manual over 'data'); everything else is
    replicated across clients ('model'-axis sharding stays auto)."""
    def fn(path, x):
        if cfg.n_experts and re.search(r"moe/experts/", path):
            # (n_sb, E_slots, D, F) — E_slots manual over 'data'
            lead = [None] * (len(x.shape) - 3)
            return P(*lead, "data", None, None)
        return P(*([None] * len(x.shape)))

    return pt.tree_map_with_path(fn, base)
