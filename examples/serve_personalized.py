"""Multi-tenant personalized serving demo — one mixed batch.

    PYTHONPATH=src python examples/serve_personalized.py

One frozen backbone + per-tenant DoRA-decomposed adapters where only the
ΔB_M magnitude vectors differ per tenant (the paper's local-optimizer
output — a few hundred *bytes* per tenant).  The AdapterStore pools the
magnitudes behind integer slots; the ServeEngine then serves N tenants
in ONE batch, the BGMV path gathering each row's adapter per token —
the backbone is never merged with anybody's adapter.  Tenants produce
different continuations from identical prompts while sharing every
backbone byte, and the mixed batch beats the old merge-per-tenant loop
by an order of magnitude in tokens/s.
"""
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import peft  # noqa: E402
from repro.launch.serve import greedy_generate, merge_adapters  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models.config import ArchConfig  # noqa: E402
from repro.serve import AdapterStore, ServeEngine  # noqa: E402
from repro.utils.pytree import (filter_tree, tree_bytes,  # noqa: E402
                                tree_map_with_path)

CFG = ArchConfig(name="serve-demo", family="dense", n_layers=4, d_model=256,
                 n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=1024,
                 dtype="float32", lora_rank=8, lora_dropout=0.0)

N_TENANTS = 6
PROMPT = 24
N_NEW = 8


def _tenant_variant(shared, tenant: int):
    """Per-tenant personalization = only the dB_mag leaves differ."""
    return tree_map_with_path(
        lambda p, x: x + 0.3 * (tenant + 1) * jnp.sign(
            jnp.sin(jnp.arange(x.size, dtype=jnp.float32) + tenant)
        ).reshape(x.shape) if p.endswith("dB_mag") else x, shared)


def main():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    shared = peft.add_lora(params, CFG, jax.random.PRNGKey(1),
                           decomposed=True)
    shared = tree_map_with_path(
        lambda p, x: x + 0.2 if p.endswith("B_mag") else x, shared)

    rng = np.random.default_rng(0)
    prompt = np.asarray(rng.integers(5, CFG.vocab_size, size=(PROMPT,)),
                        np.int32)

    store = AdapterStore(params, CFG, n_slots=N_TENANTS, kind="dora_mag",
                         shared=shared)
    variants = {}
    for t in range(N_TENANTS):
        variants[t] = _tenant_variant(shared, t)
        store.register(f"tenant{t}", filter_tree(
            variants[t], lambda p: p.endswith("dB_mag")))

    print(f"backbone: {tree_bytes(params)/1e6:.1f} MB shared across tenants; "
          f"ΔB_M payload {store.bytes_per_tenant()} B/tenant")

    engine = ServeEngine(params, CFG, store, max_rows=N_TENANTS,
                         max_prompt_len=PROMPT,
                         max_len=PROMPT + N_NEW + 8, decode_chunk=8)
    # every tenant gets the SAME prompt — one mixed batch, N tenants
    reqs = [(f"tenant{t}", prompt) for t in range(N_TENANTS)]
    outs = engine.generate(reqs, n_new=N_NEW)           # also compiles
    for t, out in enumerate(outs):
        print(f"tenant {t}: mixed-batch continuation: {out.tolist()}")

    # naive path: merge each tenant's adapter into the backbone, generate
    # one tenant at a time (the seed deployment story)
    def naive():
        outs = []
        for t in range(N_TENANTS):
            merged = merge_adapters(params, variants[t])
            out = greedy_generate(merged, {"tokens": jnp.asarray(prompt[None])},
                                  CFG, n_new=N_NEW)
            outs.append(np.asarray(out[0]))
        return outs

    naive_outs = naive()                                # compile + check
    for t in range(N_TENANTS):
        assert np.array_equal(outs[t], naive_outs[t]), t
    t0 = time.perf_counter()
    engine.generate(reqs, n_new=N_NEW)
    t_mixed = time.perf_counter() - t0
    t0 = time.perf_counter()
    naive()
    t_naive = time.perf_counter() - t0
    tok = N_TENANTS * N_NEW
    print(f"one mixed batch : {tok/t_mixed:8.1f} tok/s")
    print(f"merge-per-tenant: {tok/t_naive:8.1f} tok/s "
          f"(same tokens, bit-identical — {t_naive/t_mixed:.1f}x slower)")


if __name__ == "__main__":
    main()
