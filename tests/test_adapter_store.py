"""AdapterStore: slot pooling, LRU register/evict, checkpoint roundtrip,
and rejection of rank/target-mismatched adapters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import peft
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.serve import AdapterStore
from repro.utils import pytree as pt

CFG = ArchConfig(name="store-t", family="dense", n_layers=2, d_model=32,
                 n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                 dtype="float32", lora_rank=4, lora_dropout=0.0)


@pytest.fixture(scope="module")
def base():
    return M.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def shared(base):
    return peft.add_lora(base, CFG, jax.random.PRNGKey(1), decomposed=True)


def _raw_adapter(base, seed, rank=0):
    return peft.add_lora(base, CFG, jax.random.PRNGKey(seed),
                         decomposed=False, rank=rank)


def _mag_overlay(shared, seed):
    key = jax.random.PRNGKey(seed)
    full = pt.tree_map_with_path(
        lambda p, x: x + 0.1 * jax.random.normal(
            jax.random.fold_in(key, hash(p) % 2**30), x.shape)
        if p.endswith("dB_mag") else x, shared)
    return pt.filter_tree(full, lambda p: p.endswith("dB_mag"))


def test_register_assigns_slots_and_pools(base):
    store = AdapterStore(base, CFG, n_slots=3, kind="pairs")
    s0 = store.register("alice", _raw_adapter(base, 2))
    s1 = store.register("bob", _raw_adapter(base, 3))
    assert s0 != s1 and "alice" in store and "bob" in store
    ov = store.overlay()
    leaves = pt.tree_paths(ov)
    assert any(p.endswith("pool_A") for p in leaves)
    # registered slots hold the adapter; the null slot stays zero
    for p, leaf in zip(pt.tree_paths(ov), jax.tree.leaves(ov)):
        if p.endswith("pool_A"):
            slot_axis = leaf.ndim - 3          # lead? + (L, d_in, r)
            null = jnp.take(leaf, store.null_slot, axis=slot_axis)
            assert float(jnp.abs(null).max()) == 0.0
            reg = jnp.take(leaf, s0, axis=slot_axis)
            assert float(jnp.abs(reg).max()) > 0.0


def test_lru_evict_and_slot_reuse(base):
    store = AdapterStore(base, CFG, n_slots=2, kind="pairs")
    store.register("a", _raw_adapter(base, 2))
    s_b = store.register("b", _raw_adapter(base, 3))
    store.slot_of("a")                          # touch a → b becomes LRU
    s_c = store.register("c", _raw_adapter(base, 4))
    assert s_c == s_b                           # b's slot reused
    assert "b" not in store and "a" in store and "c" in store
    # explicit evict zeroes the slot
    store.evict("c")
    ov = store.overlay()
    for p, leaf in zip(pt.tree_paths(ov), jax.tree.leaves(ov)):
        if p.endswith("pool_A"):
            slot_axis = leaf.ndim - 3
            assert float(jnp.abs(jnp.take(leaf, s_c, axis=slot_axis)).max()) \
                == 0.0


def test_reregister_updates_in_place(base):
    store = AdapterStore(base, CFG, n_slots=2, kind="pairs")
    s0 = store.register("a", _raw_adapter(base, 2))
    s1 = store.register("a", _raw_adapter(base, 9))
    assert s0 == s1 and len(store.tenants) == 1


def test_rejects_rank_and_target_mismatch(base, shared):
    store = AdapterStore(base, CFG, n_slots=2, kind="pairs")
    with pytest.raises(ValueError, match="mismatch"):
        store.register("bad-rank", _raw_adapter(base, 2, rank=8))
    with pytest.raises(ValueError, match="missing target"):
        store.register("empty", {})
    # leaves outside the store's targets (e.g. an o_proj adapter when the
    # config targets q/v) are rejected rather than silently dropped
    import dataclasses
    wide_cfg = dataclasses.replace(CFG, lora_targets=("q_proj", "v_proj",
                                                      "o_proj"))
    wide = peft.add_lora(M.init_params(jax.random.PRNGKey(0), wide_cfg),
                         wide_cfg, jax.random.PRNGKey(5))
    with pytest.raises(ValueError, match="outside"):
        store.register("too-wide", wide)
    mag_store = AdapterStore(base, CFG, n_slots=2, kind="dora_mag",
                             shared=shared)
    with pytest.raises(ValueError, match="dB_mag"):
        mag_store.register("no-mags", _raw_adapter(base, 2))


def test_dora_mag_kind_needs_shared(base):
    with pytest.raises(ValueError, match="shared"):
        AdapterStore(base, CFG, n_slots=2, kind="dora_mag")


def test_bytes_per_tenant_is_tiny_for_mag_kind(base, shared):
    mag_store = AdapterStore(base, CFG, n_slots=2, kind="dora_mag",
                             shared=shared)
    pair_store = AdapterStore(base, CFG, n_slots=2, kind="pairs")
    # ΔB_M payload: 4 bytes · r per target per layer — a few hundred bytes
    n_targets = sum(
        (int(np.prod(lead)) if lead else 1)
        for lead, _, _ in mag_store.targets.values())
    assert mag_store.bytes_per_tenant() == 4 * CFG.lora_rank * n_targets
    assert mag_store.bytes_per_tenant() < pair_store.bytes_per_tenant() // 8


def test_checkpoint_roundtrip(base, shared, tmp_path):
    path = str(tmp_path / "store.msgpack")
    store = AdapterStore(base, CFG, n_slots=3, kind="dora_mag", shared=shared)
    store.register("alice", _mag_overlay(shared, 1))
    store.register("bob", _mag_overlay(shared, 2))
    store.slot_of("alice")
    store.save(path, step=7)

    fresh = AdapterStore(base, CFG, n_slots=3, kind="dora_mag", shared=shared)
    assert fresh.load(path) == 7
    assert fresh.tenants == store.tenants
    assert fresh.slot_of("alice") == store._slot_of["alice"]
    for (pa, la), (pb, lb) in zip(
            zip(pt.tree_paths(store.overlay()),
                jax.tree.leaves(store.overlay())),
            zip(pt.tree_paths(fresh.overlay()),
                jax.tree.leaves(fresh.overlay()))):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # LRU state survives: bob is now least-recently-used, so a register
    # into the full... (3 slots, 2 used) — fill then add one more
    fresh.register("carol", _mag_overlay(shared, 3))
    fresh.register("dave", _mag_overlay(shared, 4))     # evicts bob (LRU)
    assert "bob" not in fresh and "alice" in fresh
