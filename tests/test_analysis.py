"""Unit tests for the roofline analysis machinery (no compilation)."""

from repro.configs import SHAPES, get_config
from repro.launch import analysis as AN

FAKE_HLO = """\
HloModule jit_step

%inner.1 (p0: f32[4,4]) -> f32[4,4] {
  %ag = f32[4,4]{1,0} all-gather(%p0), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %r = f32[4,4]{1,0} add(%ag, %ag)
}

%body.2 (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %ar = f32[4,4]{1,0} all-reduce(%x), to_apply=%add.red
  %c = f32[4,4]{1,0} call(%ar), to_apply=%inner.1
  ROOT %t = (s32[], f32[4,4]) tuple(%i, %c)
}

%cond.3 (p: (s32[], f32[4,4])) -> pred[] {
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main.9 (a: f32[8,4]) -> f32[4,4] {
  %top = f32[8,4]{1,0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[4,4]) while(%init), condition=%cond.3, body=%body.2, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %o = f32[4,4]{1,0} get-tuple-element(%w), index=1
}
"""


def test_parse_collectives_loop_multipliers():
    out = AN.parse_collectives(FAKE_HLO)
    # top-level all-gather: 8*4*4 = 128 B; inner (in while via call): 4*4*4
    # = 64 B × trip 5; all-reduce in body: 64 B × 2 (AR factor) × 5
    assert out["all-gather"] == 128 + 64 * 5
    assert out["all-reduce"] == 64 * 2 * 5
    assert out["total"] == out["all-gather"] + out["all-reduce"]


def test_parse_handles_tuple_typed_params():
    # the while-body computation header contains a nested tuple type —
    # regression test for the header regex (missed → multiplier 0)
    comps = AN._split_computations(FAKE_HLO)
    assert "body.2" in comps
    assert "main.9" in comps


def test_analytic_flops_sane_for_dense():
    cfg = get_config("deepseek-7b")
    shape = SHAPES["train_4k"]
    fl = AN.analytic_step_flops(cfg, shape)
    # 6·N·D ballpark: 7B × 1M tokens × 6 ≈ 4.1e19; analytic adds attention
    n = 6.9e9
    tokens = shape.global_batch * shape.seq_len
    lo, hi = 0.9 * 6 * n * tokens, 2.0 * 6 * n * tokens
    assert lo < fl["flops_global"] < hi


def test_analytic_decode_much_smaller_than_prefill():
    cfg = get_config("gemma3-1b")
    f_pre = AN.analytic_step_flops(cfg, SHAPES["prefill_32k"])
    f_dec = AN.analytic_step_flops(cfg, SHAPES["decode_32k"])
    assert f_dec["flops_global"] < f_pre["flops_global"] / 100


def test_roofline_dominant():
    r = AN.roofline_terms(1e15, 1e9, 1e6, 256)
    assert r.dominant == "compute"
    r = AN.roofline_terms(1e10, 1e10, 1e6, 256)
    assert r.dominant == "memory"


def test_sliding_window_caps_decode_flops():
    import dataclasses
    cfg = get_config("mixtral-8x22b")
    nosw = dataclasses.replace(cfg, sliding_window=None)
    f_sw = AN.analytic_step_flops(cfg, SHAPES["long_500k"])
    f_no = AN.analytic_step_flops(nosw, SHAPES["long_500k"])
    assert f_sw["flops_global"] < f_no["flops_global"]
