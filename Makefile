.PHONY: test test-serve perf serve-bench

# tier-1 verify (ROADMAP.md)
test:
	bash scripts/ci.sh

# multi-tenant serving subsystem only (BGMV kernel, store, engine)
test-serve:
	bash scripts/ci.sh --serve

# fed-round + per-arch microbenchmarks
perf:
	PYTHONPATH=src python -m benchmarks.perf_micro

# mixed-tenant batch vs naive merge-per-tenant serving loop
serve-bench:
	PYTHONPATH=src python -m benchmarks.serve_multitenant
