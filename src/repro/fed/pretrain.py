"""Backbone pretraining for CPU-scale experiments.

The paper fine-tunes *pretrained* 7B checkpoints; offline we must make our
own backbone competence.  ``get_pretrained_base`` full-param-trains the
reduced model on the task-family mixture, then freezes it — the federated
PEFT experiments adapt on top, exactly mirroring the paper's setting.
Checkpoints are cached on disk keyed by (config, steps, seed).
"""
from __future__ import annotations

import hashlib
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.data.synthetic import SyntheticInstructionDataset
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim import adamw, chain_clip
from repro.optim.optimizers import apply_updates

CACHE_DIR = os.environ.get("REPRO_CACHE", "/root/repo/.cache")


def _key(cfg: ArchConfig, steps: int, seed: int, family: str) -> str:
    blob = f"{cfg}|{steps}|{seed}|{family}".encode()
    return hashlib.blake2s(blob).hexdigest()[:16]


def pretrain_base(cfg: ArchConfig, dataset: SyntheticInstructionDataset,
                  steps: int = 600, batch: int = 32, seq_len: int = 48,
                  lr: float = 3e-3, seed: int = 0, log=lambda s: None):
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    opt = chain_clip(adamw(lr), 1.0)
    ost = opt.init(params)

    @jax.jit
    def step(params, ost, b, i):
        (_, met), g = jax.value_and_grad(
            lambda p: M.loss_and_metrics(p, b, cfg), has_aux=True)(params)
        upd, ost = opt.update(g, ost, params, i)
        return apply_updates(params, upd), ost, met

    rng = np.random.default_rng(seed)
    met = {}
    for i in range(steps):
        b = {k: jnp.asarray(v)
             for k, v in dataset.sample_batch(rng, batch, seq_len).items()}
        params, ost, met = step(params, ost, b, jnp.asarray(i))
        if i % 100 == 0:
            log(f"pretrain step {i}: ce={float(met['ce']):.3f} "
                f"acc={float(met['acc']):.3f}")
    log(f"pretrain done: acc={float(met['acc']):.3f}")
    return params


def get_pretrained_base(cfg: ArchConfig,
                        dataset: SyntheticInstructionDataset,
                        steps: int = 600, seed: int = 0,
                        log=lambda s: None):
    """Disk-cached pretrained backbone."""
    key = _key(cfg, steps, seed, dataset.family.name)
    path = os.path.join(CACHE_DIR, f"base_{cfg.name}_{key}.msgpack")
    template = M.init_params(jax.random.PRNGKey(seed), cfg)
    if os.path.exists(path):
        params, _ = restore_checkpoint(path, template)
        log(f"restored pretrained base from {path}")
        return params
    params = pretrain_base(cfg, dataset, steps=steps, seed=seed, log=log)
    save_checkpoint(path, params, step=steps)
    return params
