"""TieredAdapterStore: T2→T1→T0 promotion parity (bit-identical in f32
to the all-resident flat pool), queue-informed eviction, T1 spill/reload,
deterministic prefetch/decode interleaving under a seeded churn schedule,
legacy (single-tier) checkpoint compatibility, and tier telemetry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.checkpoint import list_shards
from repro.core import peft
from repro.launch.serve import greedy_generate, merge_adapters
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.serve import AdapterStore, ServeEngine, TieredAdapterStore
from repro.utils import pytree as pt

CFG = ArchConfig(name="tier-t", family="dense", n_layers=2, d_model=32,
                 n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                 dtype="float32", lora_rank=4, lora_dropout=0.0)
RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def base():
    return M.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def shared(base):
    ad = peft.add_lora(base, CFG, jax.random.PRNGKey(1), decomposed=True)
    return pt.tree_map_with_path(
        lambda p, x: x + 0.25 if p.endswith("B_mag") else x, ad)


def _pair_adapter(base, t):
    tree = peft.add_lora(base, CFG, jax.random.PRNGKey(300 + t))
    return pt.tree_map_with_path(
        lambda p, x: x * 50.0 if p.endswith("lora_B") else x, tree)


def _mag_overlay(shared, t):
    full = pt.tree_map_with_path(
        lambda p, x: x + 0.15 * (t + 1) * jnp.sign(jnp.sin(
            jnp.arange(x.size, dtype=jnp.float32) + t)).reshape(x.shape)
        if p.endswith("dB_mag") else x, shared)
    return pt.filter_tree(full, lambda p: p.endswith("dB_mag"))


def _prompts(n, S):
    return np.asarray(RNG.integers(5, CFG.vocab_size, size=(n, S)), np.int32)


def _pool_row(store, prefix, key, slot):
    lead, _, _ = store.targets[prefix]
    arr = np.asarray(store._pools[prefix][key])
    return arr[:, slot] if lead else arr[slot]


# ---------------------------------------------------------------------------
# tier mechanics
# ---------------------------------------------------------------------------

def test_register_goes_to_t1_install_promotes(base, tmp_path):
    ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                            host_capacity=8, n_slots=2)
    for t in range(4):
        assert ts.register(f"t{t}", _pair_adapter(base, t)) == -1
    assert ts.tenants == ["t0", "t1", "t2", "t3"]
    assert ts.resident_tenants == []          # nothing on device yet
    slots = ts.install_batch(["t0", "t1"])
    assert sorted(slots.values()) == [0, 1]
    assert ts.resident_tenants == ["t0", "t1"]
    # promoted rows carry the packed bytes exactly
    packed, _ = ts._pack_adapter("t0", _pair_adapter(base, 0))
    for prefix in ts.targets:
        for key in ("pool_A", "pool_B"):
            np.testing.assert_array_equal(
                _pool_row(ts, prefix, key, slots["t0"]), packed[prefix][key])


def test_t1_capacity_spills_dirty_entries_to_shards(base, tmp_path):
    ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                            host_capacity=2, n_slots=2)
    for t in range(5):
        ts.register(f"t{t}", _pair_adapter(base, t))
    assert len(ts._t1) == 2                   # capacity-bounded
    # the three evicted entries were dirty → spilled to T2
    assert sorted(list_shards(ts.shard_dir)) == ["t0", "t1", "t2"]
    # a spilled tenant still promotes — via a shard read — bit-exactly
    slot = ts.slot_of("t0")
    packed, _ = ts._pack_adapter("t0", _pair_adapter(base, 0))
    for prefix in ts.targets:
        np.testing.assert_array_equal(
            _pool_row(ts, prefix, "pool_A", slot), packed[prefix]["pool_A"])


def test_queued_tenants_evicted_only_as_last_resort(base, tmp_path):
    ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                            host_capacity=8, n_slots=3)
    for t in range(5):
        ts.register(f"t{t}", _pair_adapter(base, t))
    ts.install_batch(["t0", "t1", "t2"])
    # t0 is LRU, but it sits in the batcher queue — the unqueued t1
    # must be the victim instead
    ts.install_batch(["t3"], queued={"t0", "t2"})
    assert "t0" in ts.resident_tenants and "t2" in ts.resident_tenants
    assert "t1" not in ts.resident_tenants
    # only queued victims remain → eviction falls back to queued LRU
    ts.install_batch(["t4"], pinned={"t3"}, queued={"t0", "t2"})
    assert "t4" in ts.resident_tenants and "t3" in ts.resident_tenants


def test_pinned_slots_are_never_evicted(base, tmp_path):
    ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                            host_capacity=8, n_slots=2)
    for t in range(3):
        ts.register(f"t{t}", _pair_adapter(base, t))
    ts.install_batch(["t0", "t1"])
    with pytest.raises(RuntimeError, match="pinned"):
        ts.install_batch(["t2"], pinned={"t0", "t1"})
    assert ts.resident_tenants == ["t0", "t1"]   # nothing corrupted


def test_reregister_refreshes_resident_row(base, tmp_path):
    ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                            host_capacity=4, n_slots=2)
    ts.register("t0", _pair_adapter(base, 0))
    slot = ts.slot_of("t0")
    assert ts.register("t0", _pair_adapter(base, 99)) == slot
    packed, _ = ts._pack_adapter("t0", _pair_adapter(base, 99))
    for prefix in ts.targets:
        np.testing.assert_array_equal(
            _pool_row(ts, prefix, "pool_B", slot), packed[prefix]["pool_B"])


def test_unknown_tenant_raises(base, tmp_path):
    ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                            host_capacity=4, n_slots=2)
    with pytest.raises(KeyError, match="register"):
        ts.install_batch(["ghost"])


# ---------------------------------------------------------------------------
# prefetch
# ---------------------------------------------------------------------------

def test_prefetch_folds_into_t1_with_identical_bytes(base, tmp_path):
    ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                            host_capacity=4, n_slots=2)
    for t in range(3):
        ts.register(f"t{t}", _pair_adapter(base, t))
    ts.flush()
    ts._t1.clear()                            # force everything to T2
    ts.prefetch(["t1"])
    assert ts.wait_prefetch(timeout=10.0)
    ts.drain_prefetch()
    assert "t1" in ts._t1
    packed_pf = ts._t1["t1"][0]
    packed_sync, _ = ts._read_shard("t1")     # the synchronous-path bytes
    for prefix in ts.targets:
        for key in packed_sync[prefix]:
            np.testing.assert_array_equal(packed_pf[prefix][key],
                                          packed_sync[prefix][key])
    assert ts._t1["t1"][2] is False           # prefetched entries are clean


def test_stale_prefetch_is_discarded_after_reregister(base, tmp_path):
    ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                            host_capacity=4, n_slots=2)
    ts.register("t0", _pair_adapter(base, 0))
    ts.flush()
    ts._t1.clear()
    ts.prefetch(["t0"])
    assert ts.wait_prefetch(timeout=10.0)
    ts.register("t0", _pair_adapter(base, 1))  # supersedes the in-flight load
    ts._t1.clear()                             # drop even the fresh T1 copy
    ts.drain_prefetch()
    # the stale load must NOT resurrect the old adapter
    assert "t0" not in ts._t1


# ---------------------------------------------------------------------------
# promotion parity — the acceptance-criteria test
# ---------------------------------------------------------------------------

def test_promoted_mixed_batch_bit_identical_to_flat_pool(base, tmp_path):
    """Mixed batch served through T1- and T2-promoted adapters must be
    bit-identical in f32 to the all-resident flat pool AND to each
    tenant's merged-backbone reference."""
    trees = {t: _pair_adapter(base, t) for t in range(6)}
    reqs = [(f"t{i % 6}", p) for i, p in enumerate(_prompts(12, 8))]

    flat = AdapterStore(base, CFG, n_slots=8)
    for t, tree in trees.items():
        flat.register(f"t{t}", tree)
    eng_flat = ServeEngine(base, CFG, flat, max_rows=4, max_prompt_len=8,
                           max_len=24, decode_chunk=4)
    out_flat = eng_flat.generate(reqs, n_new=8)

    ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                            host_capacity=3, n_slots=4)
    for t, tree in trees.items():
        ts.register(f"t{t}", tree)
    ts.flush()
    # leave a mixed residency: some T1, some T2-only
    while len(ts._t1) > 2:
        ts._t1.popitem(last=False)
    eng = ServeEngine(base, CFG, ts, max_rows=4, max_prompt_len=8,
                      max_len=24, decode_chunk=4)
    out_tier = eng.generate(reqs, n_new=8)
    for (tenant, prompt), a, b in zip(reqs, out_flat, out_tier):
        np.testing.assert_array_equal(a, b)
    for t in range(6):
        merged = merge_adapters(base, trees[t])
        ref = greedy_generate(merged, {"tokens": jnp.asarray(
            reqs[t][1][None])}, CFG, n_new=8)
        np.testing.assert_array_equal(out_tier[t], np.asarray(ref[0]))


def test_dora_mag_promotion_parity(base, shared, tmp_path):
    """The paper's deployment layout (shared directions + per-tenant raw
    ΔB_M, 4·r bytes each) through T2 promotion."""
    overlays = {t: _mag_overlay(shared, t) for t in range(4)}
    ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                            host_capacity=2, n_slots=4, kind="dora_mag",
                            shared=shared)
    for t, ov in overlays.items():
        ts.register(f"m{t}", ov)
    ts.flush()
    ts._t1.clear()                            # all promotions come from T2
    eng = ServeEngine(base, CFG, ts, max_rows=4, max_prompt_len=8,
                      max_len=24, decode_chunk=4)
    prompts = _prompts(4, 8)
    outs = eng.generate([(f"m{t}", prompts[t]) for t in range(4)], n_new=6)
    for t in range(4):
        full = pt.tree_map_with_path(
            lambda p, x: pt.tree_get(overlays[t], p, x), shared)
        ref = greedy_generate(merge_adapters(base, full),
                              {"tokens": jnp.asarray(prompts[t:t + 1])},
                              CFG, n_new=6)
        np.testing.assert_array_equal(outs[t], np.asarray(ref[0]))


def test_seeded_churn_is_deterministic_with_and_without_prefetch(base,
                                                                 tmp_path):
    """A seeded churn schedule (more tenants than slots, repeats, T1
    thrash) must produce identical tokens run-to-run — and identically
    whether the async prefetcher participates or not (the interleaving-
    independence contract)."""
    trees = {t: _pair_adapter(base, t) for t in range(8)}
    sched_rng = np.random.default_rng(7)
    order = sched_rng.integers(0, 8, size=16)
    prompts = _prompts(16, 8)
    reqs = [(f"t{order[i]}", prompts[i]) for i in range(16)]

    def serve(tag, use_prefetch):
        ts = TieredAdapterStore(base, CFG,
                                shard_dir=str(tmp_path / f"s{tag}"),
                                host_capacity=3, n_slots=4)
        for t, tree in trees.items():
            ts.register(f"t{t}", tree)
        ts.flush()
        ts._t1.clear()
        if not use_prefetch:
            ts.prefetch = lambda tenants: None           # disable async path
        eng = ServeEngine(base, CFG, ts, max_rows=4, max_prompt_len=8,
                          max_len=24, decode_chunk=4)
        return eng.generate(reqs, n_new=6)

    a = serve(0, use_prefetch=True)
    b = serve(1, use_prefetch=True)
    c = serve(2, use_prefetch=False)

    for x, y, z in zip(a, b, c):
        np.testing.assert_array_equal(x, y)
        np.testing.assert_array_equal(x, z)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_tiered_checkpoint_roundtrip(base, tmp_path):
    ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                            host_capacity=4, n_slots=2)
    for t in range(5):
        ts.register(f"t{t}", _pair_adapter(base, t))
    ts.install_batch(["t0", "t1"])
    path = str(tmp_path / "tier.ckpt")
    ts.save(path)
    assert sorted(list_shards(ts.shard_dir)) == [f"t{t}" for t in range(5)]

    ts2 = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                             host_capacity=4, n_slots=2)
    ts2.load(path)
    assert ts2.tenants == ts.tenants
    assert ts2.resident_tenants == ["t0", "t1"]
    assert ts2.rank_of("t3") == CFG.lora_rank
    # a demote/re-promote cycle after restore serves the exact bytes
    ts2.install_batch(["t3", "t4"])
    slot = ts2.slot_of("t0")
    packed, _ = ts._pack_adapter("t0", _pair_adapter(base, 0))
    for prefix in ts2.targets:
        np.testing.assert_array_equal(
            _pool_row(ts2, prefix, "pool_A", slot), packed[prefix]["pool_A"])


def test_legacy_flat_checkpoint_loads_unchanged(base, tmp_path):
    """A single-tier AdapterStore checkpoint restores into the tiered
    store: same residents, same pool bytes, and the residents survive a
    demote/re-promote cycle (T1 adoption keeps demotion lossless)."""
    flat = AdapterStore(base, CFG, n_slots=2)
    flat.register("a", _pair_adapter(base, 0))
    flat.register("b", _pair_adapter(base, 1))
    path = str(tmp_path / "flat.ckpt")
    flat.save(path)

    ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                            host_capacity=4, n_slots=2)
    ts.load(path)
    assert ts.tenants == ["a", "b"] and ts.resident_tenants == ["a", "b"]
    for prefix in ts.targets:
        for key in ("pool_A", "pool_B"):
            np.testing.assert_array_equal(
                np.asarray(ts._pools[prefix][key]),
                np.asarray(flat._pools[prefix][key]))
    # legacy residents were adopted into T1 → demotion cannot lose them
    ts.register("c", _pair_adapter(base, 2))
    ts.install_batch(["c"])                   # evicts one legacy resident
    demoted = [t for t in ("a", "b") if t not in ts.resident_tenants]
    assert demoted
    back = ts.slot_of(demoted[0])             # …and it comes back intact
    packed, _ = ts._pack_adapter(
        demoted[0], _pair_adapter(base, 0 if demoted[0] == "a" else 1))
    for prefix in ts.targets:
        np.testing.assert_array_equal(
            _pool_row(ts, prefix, "pool_A", back), packed[prefix]["pool_A"])


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_tier_metrics_and_events(base, tmp_path):
    tel = obs.enable(str(tmp_path / "tier.jsonl"))
    try:
        ts = TieredAdapterStore(base, CFG, shard_dir=str(tmp_path / "s"),
                                host_capacity=2, n_slots=2)
        for t in range(4):
            ts.register(f"t{t}", _pair_adapter(base, t))
        ts.install_batch(["t0", "t1"])        # t0/t1 spilled → T2 promotions
        ts.install_batch(["t0"])              # T0 hit
        ts.prefetch(["t2"])
        assert ts.wait_prefetch(timeout=10.0)
        ts.drain_prefetch()
        ts.install_batch(["t2"])              # T1 hit from prefetch
        m = tel.metrics
        assert m.counter("pool/tier_hits").value(tier="t0") >= 1
        assert m.counter("pool/tier_hits").value(tier="t1") >= 1
        assert m.counter("pool/tier_misses").value(tier="t1") >= 1
        assert m.counter("pool/promotions").value(src="t2") >= 1
        assert m.counter("pool/promotions").value(src="t1") >= 1
        assert m.counter("pool/prefetched").value() >= 1
        assert m.counter("pool/t1_spills").value() >= 1
        assert m.gauge("pool/t1_occupancy").value() > 0
        obs.disable()
        kinds = {e["kind"] for e in obs.read_events(str(tmp_path
                                                        / "tier.jsonl"))}
        assert {"pool_promote", "pool_prefetch",
                "pool_register"} <= kinds
    finally:
        obs.disable()
