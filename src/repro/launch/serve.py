"""Serving steps: batched prefill + scanned greedy decode under jit.

Per-tenant adapters, two deployment modes:

  * merge-per-tenant (this module's ``merge_adapters`` + a generate call
    per tenant) — the naive reference path;
  * mixed-batch multi-tenant via ``repro.serve`` — one batch spanning
    many tenants, adapters gathered per row from pooled storage by the
    BGMV kernel (never merged into the backbone).

``greedy_generate`` runs the decode loop as ONE jitted ``lax.scan`` with
the KV cache donated — no per-token Python dispatch or host sync (same
pattern as the scanned federated round engine).
``greedy_generate_reference`` keeps the per-step Python loop as the
parity oracle.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.utils import pytree as pt

Params = Any


def make_prefill_step(cfg: ArchConfig, mesh=None):
    def prefill_step(params, batch):
        logits, cache = M.prefill(params, batch, cfg, mesh=mesh)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh=None):
    def decode_step(params, new_token, cache, cache_index, enc_out=None):
        return M.decode_step(params, new_token, cache, cache_index, cfg,
                             mesh=mesh, enc_out=enc_out)

    return decode_step


@functools.partial(jax.jit, static_argnames=("cfg", "n_new"))
def _scan_decode(params, tok0, cache, start, cfg: ArchConfig, n_new: int,
                 adapter_idx=None):
    # (no cache donation: the final cache is not an output here, so the
    # donated buffer would have nothing to alias — XLA already reuses it
    # freely inside the scan)
    """(n_new - 1) greedy decode steps as one scan.  tok0 (B,) is the
    first generated token (from prefill logits); returns (B, n_new)."""
    def body(carry, _):
        tok, cache, idx = carry
        logits, cache = M.decode_step(params, tok, cache, idx, cfg,
                                      adapter_idx=adapter_idx)
        ntok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (ntok, cache, idx + 1), ntok

    (_, _, _), toks = jax.lax.scan(
        body, (tok0, cache, jnp.asarray(start, jnp.int32)),
        length=n_new - 1)
    return jnp.concatenate([tok0[:, None], toks.T], axis=1)


def greedy_generate(params, prompt_batch: dict, cfg: ArchConfig,
                    n_new: int = 16, mesh=None, adapter_idx=None):
    """Greedy prefill → scanned decode.  adapter_idx (B,) routes rows to
    pooled-adapter slots (mixed-tenant batches; see repro.serve)."""
    if mesh is not None:
        # multi-device meshes keep the explicit per-step loop (the scan
        # would jit under whatever sharding context the caller set up)
        if adapter_idx is not None:
            raise NotImplementedError(
                "pooled-adapter routing (adapter_idx) is single-mesh only; "
                "the mesh fallback would silently serve the bare backbone")
        return greedy_generate_reference(params, prompt_batch, cfg,
                                         n_new=n_new, mesh=mesh)
    S = prompt_batch["tokens"].shape[1]
    if adapter_idx is not None:
        prompt_batch = dict(prompt_batch, adapter_idx=adapter_idx)
    logits, cache = M.prefill(params, prompt_batch, cfg, cache_len=S + n_new)
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return _scan_decode(params, tok0, cache, S, cfg, n_new,
                        adapter_idx=adapter_idx)


def greedy_generate_reference(params, prompt_batch: dict, cfg: ArchConfig,
                              n_new: int = 16, mesh=None):
    """Per-step Python loop (the seed implementation) — parity oracle for
    the scanned path and the multi-device fallback."""
    S = prompt_batch["tokens"].shape[1]
    logits, cache = M.prefill(params, prompt_batch, cfg, mesh=mesh,
                              cache_len=S + n_new)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    step = make_decode_step(cfg, mesh)
    idx = S
    for _ in range(n_new - 1):
        logits, cache = step(params, tok, cache, jnp.asarray(idx, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
        idx += 1
    return jnp.stack(out, axis=1)


def merge_adapters(base: Params, adapters: Params) -> Params:
    return pt.merge_trees(base, adapters)
