"""Lane-drift guard: the CI matrix (.github/workflows/ci.yml), the
ci.sh case dispatch, and the Makefile test-* targets must all name the
same lane set — a lane added to one surface but not the others runs
locally yet silently never runs in CI (or vice versa).  Also pins the
``timeout-minutes`` bound on both CI jobs so a hung lane cannot eat the
runner's 6-hour default."""
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _read(*parts):
    with open(os.path.join(ROOT, *parts)) as f:
        return f.read()


def ci_yml_lanes() -> set[str]:
    text = _read(".github", "workflows", "ci.yml")
    m = re.search(r"^\s*lane:\s*\[([^\]]+)\]", text, re.M)
    assert m, "no `lane: [...]` matrix line in ci.yml"
    return {x.strip() for x in m.group(1).split(",")}


def ci_sh_lanes() -> set[str]:
    text = _read("scripts", "ci.sh")
    return set(re.findall(r"^\s*--([a-z]+)\)", text, re.M))


def makefile_lanes() -> set[str]:
    text = _read("Makefile")
    return set(re.findall(r"^test-([a-z]+):", text, re.M))


def test_matrix_matches_ci_sh_flags():
    # ruff is a lint gate with no ci.sh/Makefile counterpart by design
    assert ci_yml_lanes() - {"ruff"} == ci_sh_lanes()


def test_matrix_matches_makefile_targets():
    assert ci_yml_lanes() - {"ruff"} == makefile_lanes()


def test_every_lane_documented_in_ci_yml_header():
    text = _read(".github", "workflows", "ci.yml")
    header = text.split("name: ci")[0]
    for lane in sorted(ci_yml_lanes()):
        assert re.search(rf"^#\s+{lane}\s", header, re.M), (
            f"lane {lane!r} is in the matrix but not described in the "
            f"ci.yml header comment")


def test_every_lane_documented_in_ci_sh_header():
    text = _read("scripts", "ci.sh")
    for lane in sorted(ci_sh_lanes()):
        assert f"ci.sh --{lane}" in text.split("set -euo")[0], (
            f"lane {lane!r} dispatches in ci.sh but its header comment "
            f"does not document it")


def test_ci_jobs_have_timeouts():
    text = _read(".github", "workflows", "ci.yml")
    jobs = dict(re.findall(
        r"^  (\w[\w-]*):\n((?:    .*\n|\n)*)", text, re.M))
    for job in ("lane", "bench-smoke"):
        assert job in jobs, f"job {job!r} missing from ci.yml"
        assert "timeout-minutes:" in jobs[job], (
            f"job {job!r} has no timeout-minutes — a hung lane would "
            f"hold the runner for the 6-hour GitHub default")


def test_lint_lane_on_every_surface():
    """The static-analysis lane must exist end to end: ci.yml matrix →
    ci.sh dispatch → Makefile target, and the analyzer invocation itself
    must appear in both the lane and the quick `lint-fed` target (the
    drift the equality tests can't see: a lane that runs the tests but
    forgot the analyzer)."""
    assert "lint" in ci_yml_lanes()
    assert "lint" in ci_sh_lanes()
    assert "lint" in makefile_lanes()
    assert "python -m repro.lint src/repro" in _read("scripts", "ci.sh")
    mk = _read("Makefile")
    assert re.search(r"^lint-fed:", mk, re.M), "make lint-fed missing"
    assert "python -m repro.lint src/repro" in mk


def test_bench_smoke_only_lists_cover_gated_benches():
    """Every bench the regression checker gates must be produced by the
    bench-smoke run (main --only list) — and the retry loop must re-run
    at least the timing-sensitive gated subset."""
    yml = _read(".github", "workflows", "ci.yml")
    mk = _read("Makefile")
    onlys = re.findall(r"--only\s+([a-z,0-9]+)", yml + mk)
    assert onlys, "no --only lists found in ci.yml/Makefile"
    # gate source of truth: the baseline files consumed by check_bench
    gated = {"het_round.json": "het", "quant_decode.json": "quant",
             "obs_overhead.json": "obs", "cohort_round.json": "cohort",
             "tier_churn.json": "tier"}
    baselines = set(os.listdir(os.path.join(ROOT, "benchmarks",
                                            "baselines")))
    assert set(gated) <= baselines
    for only in onlys:
        missing = set(gated.values()) - set(only.split(","))
        assert not missing, (
            f"--only list {only!r} drops gated benches {sorted(missing)}: "
            f"check_bench would fail on missing results")
