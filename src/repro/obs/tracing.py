"""Span timers and jax-profiler naming wrappers.

Three layers, all safe to leave in production call sites:

* ``span(name, **labels)`` — host wall-clock context manager.  When
  telemetry is enabled it records the elapsed seconds into the
  ``span_seconds`` histogram (label ``span=<name>`` plus any extras)
  and opens a ``jax.profiler.TraceAnnotation`` so the region shows up
  named in a captured trace.  When disabled it degrades to a bare
  ``yield`` — no clock reads, no annotation, no allocation beyond the
  generator frame.

  ``span`` does NOT block on device work: callers that want the span to
  cover device execution must ``block_until_ready`` inside the span
  (the instrumented engines only do so when telemetry is enabled, so
  the disabled path keeps its async dispatch).

* ``annotate(name)`` — decorator naming a traced/jitted function in
  profiler output via ``jax.profiler.annotate_function``; identity
  when the profiler API is unavailable.

* ``named_scope(name)`` — re-export of ``jax.named_scope`` for naming
  *operations inside* a jitted program (BGMV, quant matmul); metadata
  only, never changes the compiled computation.
"""
from __future__ import annotations

import contextlib
import time

try:  # pure-host fallback when no profiler is built in (CPU-only jax
    # still has these, but keep the subsystem importable without jax)
    from jax.profiler import TraceAnnotation as _TraceAnnotation
    from jax.profiler import annotate_function as _annotate_function
except Exception:  # pragma: no cover - exercised only on stripped jax
    _TraceAnnotation = None
    _annotate_function = None

try:
    from jax import named_scope
except Exception:  # pragma: no cover
    @contextlib.contextmanager
    def named_scope(name: str):
        yield


def annotate(name: str):
    """Decorator: name ``fn`` in profiler traces (identity w/o profiler)."""
    def deco(fn):
        if _annotate_function is None:
            return fn
        return _annotate_function(fn, name=name)
    return deco


@contextlib.contextmanager
def span(name: str, **labels):
    """Time a host-side region into the ``span_seconds`` histogram."""
    import repro.obs as _obs  # late: repro.obs imports this module
    if not _obs.enabled():
        yield
        return
    tel = _obs.active()
    ann = _TraceAnnotation(name) if _TraceAnnotation is not None else None
    if ann is not None:
        ann.__enter__()
    t0 = time.perf_counter()
    try:
        yield
    finally:
        dt = time.perf_counter() - t0
        if ann is not None:
            ann.__exit__(None, None, None)
        tel.metrics.histogram("span_seconds").observe(dt, span=name, **labels)
