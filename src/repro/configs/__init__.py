"""Assigned-architecture registry + input shapes.

Every architecture from the assignment pool is one module exposing ARCH
(exact assigned hyperparameters, source cited) and SMOKE (the reduced
same-family variant used by CPU smoke tests).  ``get_config("<id>")``
resolves either spelling (hyphens or underscores).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ArchConfig

ARCH_IDS = [
    "jamba-v0.1-52b",
    "seamless-m4t-large-v2",
    "granite-34b",
    "qwen3-moe-30b-a3b",
    "gemma3-1b",
    "deepseek-7b",
    "mixtral-8x22b",
    "mamba2-2.7b",
    "qwen2-vl-2b",
    "qwen3-32b",
    # the paper's own fine-tuning targets
    "llama2-7b",
]


def _modname(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_modname(arch_id)}")
    return mod.ARCH


def get_smoke_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_modname(arch_id)}")
    return mod.SMOKE


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# long_500k needs bounded attention state (see DESIGN.md §6): SSM/hybrid
# always; dense only with a sliding-window/local-global variant.
LONG_CONTEXT_ARCHS = {"jamba-v0.1-52b", "mamba2-2.7b", "gemma3-1b",
                      "mixtral-8x22b"}


def shape_supported(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_CONTEXT_ARCHS
    return True
