"""Host-side metrics registry: counters / gauges / histograms.

Every instrument carries *labeled series*: a single ``Counter`` named
``fed/comm_bytes`` holds one monotonically-increasing value per label
set (``method=lora, comm=psum`` vs ``method=lora_gather, comm=gather``),
so engines never pre-bake label combinations.  Labels are plain
``str -> str|int`` kwargs; a series key is the sorted tuple of items,
making label order irrelevant.

The registry is **pure host state** — no jax arrays, no device
transfers.  Engines that need device-side statistics compute them as
extra jitted outputs (replicated leaves on the shard_map path) and feed
the host values here.  ``snapshot()`` returns a plain-dict schema that
``launch/report.telemetry_section`` and ``benchmarks/run.py`` share:

    {"counters":   {name: [{"labels": {...}, "value": float}, ...]},
     "gauges":     {name: [{"labels": {...}, "value": float}, ...]},
     "histograms": {name: [{"labels": {...}, "count": int, "sum": ...,
                            "min": ..., "max": ..., "buckets": {...}},
                           ...]}}

``NullRegistry`` implements the same surface as cheap no-ops; it is the
globally-installed sink when telemetry is disabled (see ``repro.obs``),
so instrumented call sites never branch beyond one attribute lookup.
"""
from __future__ import annotations

import bisect
import threading

# Default histogram bucket upper bounds (inclusive), log-spaced so one
# set covers microsecond spans and multi-second rounds alike.  Values
# above the last bound land in the +Inf bucket.  The sub-ms decades
# matter: decode chunks and admission waits on a warm serve engine sit
# well under 1 ms, and a histogram whose first bound is 1 ms collapses
# them all into one bucket (p50 == p99 == "under a millisecond").
DEFAULT_BOUNDS = (
    0.00005, 0.0001, 0.00025, 0.0005,
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 1000.0,
)

# Serve-path latency bounds: denser sub-ms resolution, capped at 10 s —
# the ServeEngine/batcher hot spans (admission wait, prefill, decode
# chunk) thread these through ``obs.observe(..., bounds=...)`` so a 80 µs
# and a 600 µs chunk land in distinct buckets.
LATENCY_BOUNDS = (
    0.00001, 0.000025, 0.00005, 0.0001, 0.00025, 0.0005,
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0,
)


def _key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _labels(key: tuple) -> dict:
    return dict(key)


class Counter:
    """Monotonic per-series accumulator (``inc`` only)."""

    def __init__(self, name: str):
        self.name = name
        self._series: dict[tuple, float] = {}

    def inc(self, value: float = 1.0, **labels) -> None:
        k = _key(labels)
        self._series[k] = self._series.get(k, 0.0) + float(value)

    def value(self, **labels) -> float:
        return self._series.get(_key(labels), 0.0)

    def snapshot(self) -> list[dict]:
        return [{"labels": _labels(k), "value": v}
                for k, v in sorted(self._series.items())]


class Gauge:
    """Last-write-wins per-series value (``set``)."""

    def __init__(self, name: str):
        self.name = name
        self._series: dict[tuple, float] = {}

    def set(self, value: float, **labels) -> None:
        self._series[_key(labels)] = float(value)

    def value(self, **labels) -> float:
        return self._series.get(_key(labels), 0.0)

    def snapshot(self) -> list[dict]:
        return [{"labels": _labels(k), "value": v}
                for k, v in sorted(self._series.items())]


class _HistSeries:
    __slots__ = ("count", "sum", "min", "max", "bucket_counts")

    def __init__(self, n_buckets: int):
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.bucket_counts = [0] * (n_buckets + 1)  # +1 for +Inf


class Histogram:
    """Per-series distribution: count/sum/min/max + bucket counts."""

    def __init__(self, name: str, bounds: tuple[float, ...] = DEFAULT_BOUNDS):
        self.name = name
        self.bounds = tuple(bounds)
        self._series: dict[tuple, _HistSeries] = {}

    def observe(self, value: float, **labels) -> None:
        k = _key(labels)
        s = self._series.get(k)
        if s is None:
            s = self._series[k] = _HistSeries(len(self.bounds))
        value = float(value)
        s.count += 1
        s.sum += value
        if value < s.min:
            s.min = value
        if value > s.max:
            s.max = value
        s.bucket_counts[bisect.bisect_left(self.bounds, value)] += 1

    def series(self, **labels) -> _HistSeries | None:
        return self._series.get(_key(labels))

    def snapshot(self) -> list[dict]:
        out = []
        for k, s in sorted(self._series.items()):
            buckets = {}
            for bound, c in zip(self.bounds, s.bucket_counts):
                if c:
                    buckets[f"le_{bound:g}"] = c
            if s.bucket_counts[-1]:
                buckets["le_inf"] = s.bucket_counts[-1]
            out.append({"labels": _labels(k), "count": s.count,
                        "sum": s.sum, "min": s.min, "max": s.max,
                        "mean": s.sum / max(s.count, 1),
                        "buckets": buckets})
        return out


class MetricsRegistry:
    """Get-or-create registry of named instruments.

    Thread-safe at the instrument-creation level (the serve engine and a
    background personalization loop may both first-touch a metric); the
    per-observation path is a plain dict update, which is atomic enough
    under the GIL for the host-side counters this registry holds.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str,
                  bounds: tuple[float, ...] = DEFAULT_BOUNDS) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(name, Histogram(name, bounds))
        return h

    def snapshot(self) -> dict:
        return {
            "counters": {n: c.snapshot()
                         for n, c in sorted(self._counters.items())},
            "gauges": {n: g.snapshot()
                       for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.snapshot()
                           for n, h in sorted(self._histograms.items())},
        }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


class _NullInstrument:
    """Absorbs any instrument method call at one attribute lookup."""

    __slots__ = ()

    def inc(self, value=1.0, **labels):
        pass

    def set(self, value, **labels):
        pass

    def observe(self, value, **labels):
        pass

    def value(self, **labels):
        return 0.0

    def series(self, **labels):
        return None

    def snapshot(self):
        return []


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry:
    """Disabled-telemetry sink: every instrument is the shared no-op."""

    def counter(self, name: str) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def gauge(self, name: str) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name: str, bounds=DEFAULT_BOUNDS) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def snapshot(self) -> dict:
        return {"counters": {}, "gauges": {}, "histograms": {}}

    def reset(self) -> None:
        pass


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _prom_name(name: str) -> str:
    """Metric names here are slash-namespaced (``fed/comm_bytes``);
    Prometheus names admit only ``[a-zA-Z_:][a-zA-Z0-9_:]*``."""
    out = "".join(c if (c.isalnum() or c in "_:") else "_" for c in name)
    if out and out[0].isdigit():
        out = "_" + out
    return "repro_" + out


def _prom_labels(labels: dict, extra: tuple = ()) -> str:
    items = sorted(labels.items()) + list(extra)
    if not items:
        return ""
    body = ",".join(
        '{}="{}"'.format(k, str(v).replace("\\", r"\\").replace('"', r'\"'))
        for k, v in items)
    return "{" + body + "}"


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def to_prometheus(snapshot: dict) -> str:
    """Render a ``MetricsRegistry.snapshot()`` as Prometheus text
    exposition format (version 0.0.4) — counters/gauges verbatim,
    histograms as cumulative ``_bucket{le=...}`` series plus ``_sum`` /
    ``_count``.  Pure function of the snapshot dict, so the serve loop's
    ``REPRO_PROM_PATH`` hook and offline converters share one encoder."""
    lines: list[str] = []
    for name, series in snapshot.get("counters", {}).items():
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} counter")
        for s in series:
            lines.append(f"{pn}{_prom_labels(s['labels'])} "
                         f"{_fmt(s['value'])}")
    for name, series in snapshot.get("gauges", {}).items():
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} gauge")
        for s in series:
            lines.append(f"{pn}{_prom_labels(s['labels'])} "
                         f"{_fmt(s['value'])}")
    for name, series in snapshot.get("histograms", {}).items():
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} histogram")
        for s in series:
            # snapshot buckets are sparse per-bucket counts keyed
            # "le_{bound:g}" / "le_inf"; prometheus wants cumulative
            finite = sorted(
                (float(k[3:]), c) for k, c in s["buckets"].items()
                if k != "le_inf")
            cum = 0
            for bound, c in finite:
                cum += c
                lines.append(
                    f"{pn}_bucket{_prom_labels(s['labels'], (('le', f'{bound:g}'),))} "
                    f"{cum}")
            lines.append(
                f"{pn}_bucket{_prom_labels(s['labels'], (('le', '+Inf'),))} "
                f"{s['count']}")
            lines.append(f"{pn}_sum{_prom_labels(s['labels'])} "
                         f"{_fmt(s['sum'])}")
            lines.append(f"{pn}_count{_prom_labels(s['labels'])} "
                         f"{s['count']}")
    return "\n".join(lines) + ("\n" if lines else "")
