"""Mamba-2 2.7B — attention-free SSD (state-space duality)
[arXiv:2405.21060].  d_inner = 2*2560 = 5120, 80 heads of 64, state 128.
Assigned vocab 50280 padded to 50288 (16-way model axis) — DESIGN.md §10.
The paper\'s LoRA targets (attention Q/V) do not exist; adapters attach to
the mixer in/out projections instead (DESIGN.md §8)."""
from repro.models.config import ArchConfig, reduced

ARCH = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=50288,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_headdim=64, ssm_groups=1,
    ssm_chunk=128,
    lora_targets=("x_proj", "out_proj"),
    source="arXiv:2405.21060",
)
SMOKE = reduced(ARCH, d_ff=1)
