"""Multi-tenant adapter serving engine (S-LoRA / Punica style).

One frozen backbone, many tiny per-tenant adapters, one mixed batch:

  adapter_store  — packs per-tenant LoRA / decomposed-DoRA adapters into
                   stacked pools [n_slots, ...] with LRU register/evict;
                   TieredAdapterStore pages 10k+ tenants through a
                   host-RAM cache (T1) and per-tenant disk shards (T2)
                   with batched hot-swap and async prefetch
  batcher        — continuous batcher: admits tenant-tagged requests
                   into free rows of a persistent batch
  engine         — prefill/decode loop threading per-row adapter_idx
                   through the model (BGMV kernel or einsum fallback)
"""
from repro.serve.adapter_store import (AdapterStore,  # noqa: F401
                                       TieredAdapterStore)
from repro.serve.batcher import ContinuousBatcher, Request  # noqa: F401
from repro.serve.engine import ServeEngine  # noqa: F401
