"""msgpack pytree checkpointing.

Arrays are gathered to host, serialized with shape/dtype headers, and
restored with optional resharding (``shardings`` pytree of NamedSharding).
bfloat16 is round-tripped via uint16 views (msgpack/numpy have no bf16).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro import obs
from repro.utils.pytree import tree_map_with_path, path_str

_BF16 = "__bf16__"


def _pack_leaf(x) -> dict:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype == jnp.bfloat16:
        return {"d": _BF16, "s": list(arr.shape),
                "b": arr.view(np.uint16).tobytes()}
    return {"d": arr.dtype.str, "s": list(arr.shape), "b": arr.tobytes()}


def _unpack_leaf(rec: dict) -> np.ndarray:
    shape = tuple(rec["s"])
    if rec["d"] == _BF16:
        return np.frombuffer(rec["b"], np.uint16).reshape(shape).view(jnp.bfloat16)
    return np.frombuffer(rec["b"], np.dtype(rec["d"])).reshape(shape)


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    payload = {
        "step": step,
        "leaves": {path_str(p): _pack_leaf(x) for p, x in leaves},
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)
    if obs.enabled():
        obs.event("ckpt_save", path=str(path), step=int(step),
                  leaves=len(payload["leaves"]),
                  bytes=sum(len(r["b"]) for r in payload["leaves"].values()))
        obs.inc("ckpt/saves")


def checkpoint_leaf_paths(path: str) -> list[str]:
    """Leaf paths stored in a checkpoint, without unpacking any arrays —
    the cheap schema probe migration shims use to recognize old layouts
    (e.g. AdapterStore's pre-raw-delta ``pool_B_mag`` pools)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    return sorted(payload["leaves"])


def load_checkpoint_flat(path: str) -> tuple[dict, int]:
    """Load a checkpoint as a flat ``{leaf_path: np.ndarray}`` dict plus
    its step, with no ``like`` template.  The shape-flexible read path:
    callers whose state has a variable-length axis between save and load
    (e.g. CohortSim's in-flight straggler buffers, AdapterStore tier-2
    shards) reconstruct their structure from the paths instead of
    asserting shapes against a template."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat = {p: _unpack_leaf(rec) for p, rec in payload["leaves"].items()}
    if obs.enabled():
        obs.event("ckpt_restore", path=str(path),
                  step=int(payload["step"]), leaves=len(flat))
        obs.inc("ckpt/restores")
    return flat, payload["step"]


# ---------------------------------------------------------------------------
# per-key shards — the AdapterStore's tier-2 layout
# ---------------------------------------------------------------------------
#
# One tiny msgpack checkpoint per key (tenant id), written through the
# same codec as full checkpoints.  Keys are arbitrary 1..64-byte utf-8
# strings (the AdapterStore tenant-id contract), so filenames are the
# hex encoding of the utf-8 bytes — reversible, case-safe, and free of
# path separators.

_SHARD_EXT = ".msgpack"


def shard_path(shard_dir: str, key: str) -> str:
    """Filesystem path of ``key``'s shard under ``shard_dir``."""
    return os.path.join(shard_dir, key.encode("utf-8").hex() + _SHARD_EXT)


def save_shard(shard_dir: str, key: str, tree: Any, step: int = 0) -> None:
    """Write one key's pytree as a per-key shard (atomic, same codec as
    ``save_checkpoint``)."""
    save_checkpoint(shard_path(shard_dir, key), tree, step=step)


def load_shard_flat(shard_dir: str, key: str) -> tuple[dict, int]:
    """Lazy per-key load: one shard as a flat ``{path: array}`` dict."""
    return load_checkpoint_flat(shard_path(shard_dir, key))


def has_shard(shard_dir: str, key: str) -> bool:
    return os.path.exists(shard_path(shard_dir, key))


def list_shards(shard_dir: str) -> list[str]:
    """Decode every shard filename under ``shard_dir`` back to its key
    (sorted).  Non-shard files are ignored."""
    if not os.path.isdir(shard_dir):
        return []
    keys = []
    for name in os.listdir(shard_dir):
        if not name.endswith(_SHARD_EXT):
            continue
        try:
            keys.append(bytes.fromhex(name[:-len(_SHARD_EXT)]).decode("utf-8"))
        except ValueError:
            continue
    return sorted(keys)


def restore_checkpoint(path: str, like: Any, shardings: Any = None,
                       strict: bool = True, allow_missing: str | None = None,
                       to_host: bool = False):
    """Restore into the structure of ``like``; device_put with shardings if
    given (sharding-aware restore for multi-host meshes).

    Missing-leaf policy: a leaf of ``like`` absent from the checkpoint
    raises, unless its path matches the ``allow_missing`` regex (the
    schema-evolution escape hatch — e.g. adapter-pool checkpoints written
    before the slot-rank table existed restore with the caller's default
    ranks) or ``strict=False`` waives the check for every leaf.

    Integer leaves whose dtype jnp would silently narrow (int64 under the
    default x64-disabled config) are returned as host numpy arrays so
    counters never wrap through a save/load cycle.

    ``to_host=True`` skips device placement entirely and returns plain
    numpy arrays — host-resident state (e.g. fed/cohort.ClientBank, whose
    N ≫ C client bank never lives on device) restores without ever
    materializing N× adapter bytes in HBM."""
    import re
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    recs = payload["leaves"]
    miss_rx = re.compile(allow_missing) if allow_missing else None

    def fn(p, x):
        if p not in recs:
            if strict and not (miss_rx and miss_rx.search(p)):
                raise KeyError(
                    f"checkpoint {path} has no leaf {p!r} (present: "
                    f"{len(recs)} leaves); pass strict=False or a matching "
                    f"allow_missing regex to keep the caller's default")
            return np.asarray(x)
        arr = _unpack_leaf(recs[p])
        assert tuple(arr.shape) == tuple(x.shape), (p, arr.shape, x.shape)
        return arr

    def to_device(x):
        arr = jnp.asarray(x)
        if arr.dtype != x.dtype and np.issubdtype(x.dtype, np.integer):
            return np.asarray(x)          # keep host precision (no x64)
        return arr

    host_tree = tree_map_with_path(fn, like)
    if to_host:
        # np.array, not np.asarray: unpacked leaves are read-only views
        # over the msgpack payload, and host-resident state (ClientBank)
        # is mutated in place after restore — a view would make the
        # first post-restore scatter raise "assignment destination is
        # read-only" (and would pin the whole payload buffer alive)
        host_tree = jax.tree.map(np.array, host_tree)
        if obs.enabled():
            obs.event("ckpt_restore", path=str(path),
                      step=int(payload["step"]), leaves=len(recs))
            obs.inc("ckpt/restores")
        return host_tree, payload["step"]
    if shardings is not None:
        host_tree = jax.tree.map(jax.device_put, host_tree, shardings)
    else:
        host_tree = jax.tree.map(to_device, host_tree)
    if obs.enabled():
        obs.event("ckpt_restore", path=str(path),
                  step=int(payload["step"]), leaves=len(recs))
        obs.inc("ckpt/restores")
    return host_tree, payload["step"]
