"""Cross-device-scale federation (repro.fed.cohort): ClientBank
gather/scatter semantics, deterministic cohort sampling, fault-plan
draws, straggler buffering with delivery-time comm billing, checkpoint
round-trips, and the participation/staleness telemetry surface.

The *numerics* of faulted rounds (production shard_map engine vs the
FedSim oracle, ~1 ulp) live in tests/test_distributed.py — this file
covers the host-side orchestration layer around that engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.fed import ClientBank, CohortSampler, CohortSim, FaultPlan
from repro.fed.cohort import STALENESS_BOUNDS
from repro.fed.simulate import FedHyper, FedSim
from repro.models.config import ArchConfig

CFG = ArchConfig(name="cohort-t", family="dense", n_layers=2, d_model=32,
                 n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                 dtype="float32", lora_rank=4, lora_dropout=0.0)


@pytest.fixture(autouse=True)
def _null_sink():
    obs.disable()
    yield
    obs.disable()


def _sim(method="lora", C=3, local_steps=2, lr=1e-2, **kw):
    hp = FedHyper(method=method, n_clients=C, local_steps=local_steps,
                  lr=lr, **kw)
    return FedSim(CFG, hp)


def _batches(C, n, seed=0):
    rng = np.random.default_rng(seed)
    return [{"tokens": jnp.asarray(rng.integers(5, 64, size=(C, 2, 16)),
                                   jnp.int32),
             "loss_mask": jnp.ones((C, 2, 16), jnp.float32)}
            for _ in range(n)]


# ---------------------------------------------------------------------------
# sampler + fault plan
# ---------------------------------------------------------------------------

def test_sampler_deterministic_distinct_and_bounded():
    s = CohortSampler(n_total=50, cohort=5, seed=11)
    a, b = s.sample(3), s.sample(3)
    np.testing.assert_array_equal(a, b)          # (seed, round) keyed
    assert len(set(a.tolist())) == 5             # without replacement
    assert a.min() >= 0 and a.max() < 50
    assert not np.array_equal(s.sample(3), s.sample(4))
    # a different seed reshuffles the same round
    assert not np.array_equal(a, CohortSampler(50, 5, seed=12).sample(3))
    with pytest.raises(ValueError, match="cohort size"):
        CohortSampler(n_total=4, cohort=5)
    with pytest.raises(ValueError, match="cohort size"):
        CohortSampler(n_total=4, cohort=0)


def test_fault_plan_validation_and_partition():
    with pytest.raises(ValueError, match="dropout_rate"):
        FaultPlan(dropout_rate=0.7, straggler_rate=0.5)
    with pytest.raises(ValueError, match="straggler_delay"):
        FaultPlan(straggler_delay=(0, 2))
    with pytest.raises(ValueError, match="straggler_delay"):
        FaultPlan(straggler_delay=(3, 1))
    assert not FaultPlan().any
    plan = FaultPlan(dropout_rate=0.3, straggler_rate=0.3, corrupt_rate=0.5,
                     corrupt_scale=7.0, seed=5)
    assert plan.any
    d1, d2 = plan.draw(2, 64), plan.draw(2, 64)
    for k in d1:
        np.testing.assert_array_equal(d1[k], d2[k])   # replayable
    f = plan.draw(0, 256)
    # fates partition: dropout/straggler disjoint, participation is the rest
    assert not np.any(f["dropout"] & f["straggler"])
    np.testing.assert_array_equal(
        f["participation"], (~(f["dropout"] | f["straggler"])).astype(
            np.float32))
    # corruption only hits participants, and scales exactly corrupt_scale
    assert not np.any(f["corrupt"] & (f["participation"] == 0))
    np.testing.assert_array_equal(
        f["update_scale"], np.where(f["corrupt"], 7.0, 1.0))
    assert np.all((f["delays"] >= 1) & (f["delays"] <= 3))
    # all fault classes actually occur at these rates over 256 slots
    assert f["dropout"].sum() and f["straggler"].sum() and f["corrupt"].sum()


def test_fault_plan_heavy_tailed_straggler_delays():
    """arXiv 2410.22815-style straggler models: lognormal/pareto delay
    draws alongside uniform, clipped into [lo, hi] so in-flight buffers
    stay bounded — the tail mass piles up at the hi cap instead of the
    uniform's flat spread."""
    with pytest.raises(ValueError, match="straggler_dist"):
        FaultPlan(straggler_dist="cauchy")
    with pytest.raises(ValueError, match="straggler_tail"):
        FaultPlan(straggler_dist="pareto", straggler_tail=0.0)
    lo, hi, n = 1, 12, 4096
    draws = {}
    for dist in ("uniform", "lognormal", "pareto"):
        plan = FaultPlan(straggler_rate=0.5, straggler_delay=(lo, hi),
                         straggler_dist=dist, straggler_tail=1.0, seed=9)
        d1, d2 = plan.draw(0, n), plan.draw(0, n)
        np.testing.assert_array_equal(d1["delays"], d2["delays"])
        assert d1["delays"].min() >= lo and d1["delays"].max() <= hi
        draws[dist] = d1["delays"]
    # heavy tails: most clients are fast (median below uniform's), yet
    # the extreme quantile still reaches the cap — p95/median dispersion
    # far exceeds uniform's
    unif_disp = (np.percentile(draws["uniform"], 95)
                 / np.median(draws["uniform"]))
    for dist in ("lognormal", "pareto"):
        assert np.median(draws[dist]) < np.median(draws["uniform"])
        assert draws[dist].max() == hi
        disp = np.percentile(draws[dist], 95) / np.median(draws[dist])
        assert disp > unif_disp
    # a sharper pareto tail (bigger α) means fewer slow clients
    sharp = FaultPlan(straggler_rate=0.5, straggler_delay=(lo, hi),
                      straggler_dist="pareto", straggler_tail=3.0,
                      seed=9).draw(0, n)["delays"]
    assert sharp.mean() < draws["pareto"].mean()


def test_cohort_rounds_run_with_heavy_tailed_stragglers():
    """End-to-end: a lognormal-delay plan drives CohortSim rounds with
    buffered deliveries and exact billing, same as uniform."""
    sim = _sim(C=3, local_steps=1, lr=2e-2)
    cs = CohortSim(sim, n_total=5,
                   faults=FaultPlan(straggler_rate=0.4,
                                    straggler_delay=(1, 3),
                                    straggler_dist="lognormal",
                                    straggler_tail=1.5, seed=2),
                   seed=1)
    unit = sim.client_comm_bytes()
    batches = _batches(3, 1, seed=4)
    expected, delivered = 0, 0
    for r in range(8):
        out = cs.run_round(batches, jax.random.PRNGKey(r))
        live = int(out["participation"].sum())
        expected += unit * (live + out["delivered_billed"])
        delivered += out["delivered"]
        assert np.all(np.isfinite(out["metrics"]["ce"]))
    assert sim.comm_bytes == expected
    assert delivered > 0                      # stragglers actually matured


# ---------------------------------------------------------------------------
# bank semantics
# ---------------------------------------------------------------------------

def test_bank_gather_scatter_mask_semantics():
    sim = _sim(C=3)
    bank = ClientBank.from_sim(sim, n_total=8)
    leaf0 = jax.tree.leaves(bank.adapters)[0]
    assert leaf0.shape[0] == 8 and isinstance(leaf0, np.ndarray)

    idx = np.asarray([1, 4, 6])
    ad, ost = bank.gather(idx)
    assert jax.tree.leaves(ad)[0].shape[0] == 3
    before = jax.tree.map(np.copy, bank.adapters)

    # perturb all three cohort slots, scatter back only slots 0 and 2
    ad = jax.tree.map(lambda x: x + 1.0, ad)
    bank.scatter(idx, ad, ost, round_idx=5,
                 mask=np.asarray([True, False, True]))
    for old, new in zip(jax.tree.leaves(before),
                        jax.tree.leaves(bank.adapters)):
        np.testing.assert_array_equal(new[[1, 6]], old[[1, 6]] + 1.0)
        np.testing.assert_array_equal(new[4], old[4])     # masked-out
        np.testing.assert_array_equal(new[[0, 2, 3, 5, 7]],
                                      old[[0, 2, 3, 5, 7]])
    np.testing.assert_array_equal(bank.last_sync,
                                  [0, 5, 0, 0, 0, 0, 5, 0])
    np.testing.assert_array_equal(bank.staleness([1, 4, 6], 7),
                                  np.asarray([2.0, 7.0, 2.0], np.float32))


def test_bank_rejects_mixed_rank_fleet():
    sim = _sim(C=2, client_ranks=(2, 4))
    with pytest.raises(ValueError, match="uniform-rank fleet"):
        ClientBank.from_sim(sim, n_total=8)
    with pytest.raises(ValueError, match="n_total"):
        ClientBank.from_sim(_sim(C=2), n_total=0)


# ---------------------------------------------------------------------------
# the acceptance round: straggler-dropout rounds converge, billing exact
# ---------------------------------------------------------------------------

def test_faulted_cohort_rounds_converge_with_exact_billing():
    """ISSUE acceptance: a straggler/dropout/corruption round schedule
    still drives the fleet's loss down, and every wire byte is accounted
    for — live participants bill in-round, stragglers bill when their
    buffered update *arrives*, dropped clients bill nothing."""
    sim = _sim(method="lora_trimmed", C=4, local_steps=3, lr=5e-2)
    cs = CohortSim(sim, n_total=6,
                   faults=FaultPlan(dropout_rate=0.25, straggler_rate=0.25,
                                    corrupt_rate=0.2, corrupt_scale=10.0,
                                    straggler_delay=(1, 2), seed=3),
                   seed=0)
    unit = sim.client_comm_bytes()
    batches = _batches(4, 3, seed=0)     # fixed batch → memorizable
    ces, expected = [], 0
    fates = set()
    for r in range(12):
        out = cs.run_round(batches, jax.random.PRNGKey(r))
        live = int(out["participation"].sum())
        expected += unit * (live + out["delivered_billed"])
        ces.append(float(np.mean(out["metrics"]["ce"])))
        assert np.all(np.isfinite(out["metrics"]["ce"]))
        assert len(out["cohort"]) == 4
        assert np.all(out["staleness"] >= 0)
        fates |= {("drop", 4 - live - 0 >= 0)}
        fates |= {("strag", out["pending"] > 0 or out["delivered"] > 0)}
    assert sim.comm_bytes == expected
    assert any(f == ("strag", True) for f in fates)   # plan actually fired
    assert cs.round == 12
    # convergence under faults: the tail of the run beats its start
    assert np.mean(ces[-3:]) < ces[0] - 0.03
    # stragglers really did resync late: some last_sync values lag round-1
    synced = cs.bank.last_sync[cs.bank.last_sync > 0]
    assert synced.size > 0


def test_stale_delivery_is_billed_but_discarded():
    """A straggler whose client re-participated (fresher sync) before the
    buffered update matured: the upload is billed, the state discarded."""
    sim = _sim(C=2, local_steps=1)
    cs = CohortSim(sim, n_total=2, faults=FaultPlan(seed=0), seed=0)
    batches = _batches(2, 1, seed=1)
    cs.run_round(batches, jax.random.PRNGKey(0))     # honest round 0
    # forge an in-flight delivery trained *before* round 0's sync
    stale_ad = jax.tree.map(lambda x: np.asarray(x[0]) + 99.0,
                            jax.device_get(sim.client_adapters))
    stale_ost = jax.tree.map(lambda x: np.asarray(x[0]),
                             jax.device_get(sim.opt_state))
    cs._pending.append({"client": 0, "deliver_at": 1, "trained_round": -1,
                        "adapters": stale_ad, "opt_state": stale_ost})
    before_bytes = sim.comm_bytes
    before_bank = jax.tree.map(np.copy, cs.bank.adapters)
    out = cs.run_round(batches, jax.random.PRNGKey(1))
    assert out["delivered_billed"] == 1 and out["delivered"] == 0
    assert sim.comm_bytes > before_bytes           # wire billed anyway
    # the forged +99 state never landed in the bank
    for old, new in zip(jax.tree.leaves(before_bank),
                        jax.tree.leaves(cs.bank.adapters)):
        assert not np.any(np.abs(new) > np.abs(old).max() + 50.0)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_cohort_checkpoint_roundtrip(tmp_path):
    sim = _sim(method="lora_fedbuff", C=3)
    cs = CohortSim(sim, n_total=9,
                   faults=FaultPlan(dropout_rate=0.3, straggler_rate=0.2,
                                    seed=2), seed=4)
    batches = _batches(3, 2, seed=2)
    for r in range(3):
        cs.run_round(batches, jax.random.PRNGKey(r))
    path = str(tmp_path / "cohort.ckpt")
    cs.save(path)

    cs2 = CohortSim(_sim(method="lora_fedbuff", C=3), n_total=9,
                    faults=cs.faults, seed=4)
    assert cs2.load(path) == 3
    assert cs2.round == 3 and cs2.sim.comm_bytes == sim.comm_bytes
    np.testing.assert_array_equal(cs2.bank.last_sync, cs.bank.last_sync)
    for a, b in zip(jax.tree.leaves(cs.bank.adapters),
                    jax.tree.leaves(cs2.bank.adapters)):
        np.testing.assert_array_equal(a, b)        # bitwise bank restore
    for a, b in zip(jax.tree.leaves(cs.bank.opt_state),
                    jax.tree.leaves(cs2.bank.opt_state)):
        np.testing.assert_array_equal(a, b)
    # in-flight straggler buffers persist: same count, clients, delivery
    # rounds, and bitwise-identical buffered trees
    assert len(cs2._pending) == len(cs._pending)
    for d, d2 in zip(cs._pending, cs2._pending):
        assert (d2["client"], d2["deliver_at"], d2["trained_round"]) == \
            (d["client"], d["deliver_at"], d["trained_round"])
        for a, b in zip(jax.tree.leaves(d["adapters"]),
                        jax.tree.leaves(d2["adapters"])):
            np.testing.assert_array_equal(a, b)
        for a, b in zip(jax.tree.leaves(d["opt_state"]),
                        jax.tree.leaves(d2["opt_state"])):
            np.testing.assert_array_equal(a, b)
    assert all(isinstance(x, np.ndarray)
               for x in jax.tree.leaves(cs2.bank.adapters))  # host-resident
    out = cs2.run_round(batches, jax.random.PRNGKey(3))      # resumable
    assert np.all(np.isfinite(out["metrics"]["ce"]))


def test_cohort_restart_mid_delay_delivers_at_original_round(tmp_path):
    """A straggler buffered before a checkpoint must still deliver —
    and bill — at its original delivery round after a restart, instead
    of degrading into a silent dropout."""
    sim = _sim(method="lora_fedbuff", C=3)
    cs = CohortSim(sim, n_total=9,
                   faults=FaultPlan(straggler_rate=1.0,
                                    straggler_delay=(2, 2), seed=7), seed=5)
    batches = _batches(3, 2, seed=3)
    cs.run_round(batches, jax.random.PRNGKey(0))   # round 0: all straggle
    assert cs._pending, "fault plan should have buffered stragglers"
    pend = [dict(d) for d in cs._pending]
    path = str(tmp_path / "mid_delay.ckpt")
    cs.save(path)

    cs2 = CohortSim(_sim(method="lora_fedbuff", C=3), n_total=9,
                    faults=FaultPlan(seed=7), seed=5)    # no new faults
    assert cs2.load(path) == 1
    assert [d["deliver_at"] for d in cs2._pending] == \
        [d["deliver_at"] for d in pend]
    bytes_before = cs2.sim.comm_bytes
    out1 = cs2.run_round(batches, jax.random.PRNGKey(1))  # round 1: too early
    assert out1["delivered"] == 0 and out1["delivered_billed"] == 0
    out2 = cs2.run_round(batches, jax.random.PRNGKey(2))  # round 2: matures
    assert out2["delivered_billed"] == len(pend)
    assert cs2.sim.comm_bytes > bytes_before       # billed on arrival
    assert cs2._pending == []
    # delivered buffers deposited at their original trained_round
    for d in pend:
        if out2["delivered"]:
            assert cs2.bank.last_sync[d["client"]] >= d["trained_round"]


# ---------------------------------------------------------------------------
# telemetry surface
# ---------------------------------------------------------------------------

def test_cohort_telemetry_metrics_and_events(tmp_path):
    from repro.launch.report import telemetry_section
    from repro.obs import read_events

    path = str(tmp_path / "cohort.jsonl")
    sim = _sim(C=3)
    cs = CohortSim(sim, n_total=8,
                   faults=FaultPlan(dropout_rate=0.3, straggler_rate=0.3,
                                    seed=1), seed=0)
    batches = _batches(3, 1, seed=5)
    obs.enable(path)
    for r in range(4):
        cs.run_round(batches, jax.random.PRNGKey(r))
    snap = obs.emit_snapshot()
    obs.disable()

    g = snap["gauges"]["fed/participation_rate"]
    assert g and 0.0 <= g[0]["value"] <= 1.0
    (h,) = snap["histograms"]["fed/staleness_rounds"]
    assert h["count"] >= 1
    # staleness-shaped bounds, not the latency defaults: integer-round
    # buckets like le_1 / le_2 exist, sub-ms buckets don't
    assert set(h["buckets"]) <= {f"le_{b:g}" for b in STALENESS_BOUNDS} \
        | {"le_inf"}
    for name in ("fed/dropouts", "fed/stragglers"):
        assert name in snap["counters"], name

    evs = read_events(path, kind="fed_cohort")
    assert len(evs) == 4
    assert evs[0]["round"] == 0 and len(evs[0]["cohort"]) == 3
    assert evs[-1]["comm_bytes"] == sim.comm_bytes
    text = telemetry_section(path)
    assert "### Cohort rounds (partial participation)" in text
    assert "| lora | 0 | 3 |" in text


def test_honest_cohort_emits_full_participation(tmp_path):
    path = str(tmp_path / "honest.jsonl")
    sim = _sim(C=2)
    cs = CohortSim(sim, n_total=5, seed=0)        # no FaultPlan
    obs.enable(path)
    out = cs.run_round(_batches(2, 1), jax.random.PRNGKey(0))
    snap = obs.emit_snapshot()
    obs.disable()
    assert out["participation"].all() and out["pending"] == 0
    assert snap["gauges"]["fed/participation_rate"][0]["value"] == 1.0
    assert "fed/dropouts" in snap["counters"]     # present, value 0
    assert snap["counters"]["fed/dropouts"][0]["value"] == 0.0
