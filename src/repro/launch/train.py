"""Production federated train step + the paper's three-stage pipeline.

TPU-native mapping of the paper's round (DESIGN.md §4):

  · clients ↔ slices of the ('pod','data') axes — ONE client per data
    shard; each client's decomposed-LoRA adapters live only on its shard;
  · local SGD ↔ per-shard grad/update steps inside a shard_map that is
    MANUAL over ('pod','data') and AUTO over 'model' (XLA still does
    tensor parallelism inside each client);
  · aggregation ↔ the method's *collective form* (core.aggregation
    .CollectiveAgg) issued from inside the manual region — a weighted
    psum for the mean family, a per-row coverage-weighted psum for
    replication averaging, an all_gather of the stacked factors followed
    by QR/truncated-SVD re-factorization for exact aggregation.  The only
    cross-client (and the only cross-pod) traffic, a few MB of adapter
    state;
  · per-client state (the paper's personal ΔB_M, FedALT's individual
    pair) never crosses shards: keep-local leaves are restored from the
    shard's own values after the collective;
  · heterogeneous fleets ride the same program: per-client rank masks
    (peft.client_rank_masks) zero update rows above each client's rank
    and re-mask the rebroadcast inside the manual region;
  · FedProx's proximal anchor is the shard's round-start adapters — a
    per-shard leaf captured by the local-step scan, no extra state.

``make_fed_train_step`` returns ONE federated round (stage 1 + the
collective).  ``make_fed_pipeline_step`` extends that into the paper's
full three-stage pipeline (Eqs. 9–11) as three jitted shard_map
programs sharing one layout:

  stage 1  the round above — per-client local steps, then the method's
           collective; also emits the aggregate as a replicated leaf;
  stage 2  the global optimizer: only ``method.stage_global_mask``
           leaves (ΔA_D for the paper, Eq. 9) train on the server batch
           mixture — the aggregate carries no client axis and its
           optimizer state lives outside the client axis.  When the
           server batch divides evenly over the client axis, each shard
           computes gradients on its own slice of every micro-batch and
           a token-weighted psum recovers the full-batch gradient (dp×
           fewer backbone FLOPs per shard); otherwise every shard runs
           the identical replicated math.  The result is rebroadcast
           with the same keep-local/het-re-mask semantics as stage 1;
  stage 3  per-client personalization: only ``method.stage_local_mask``
           leaves (ΔB_M, Eq. 10) train per shard with the Eq. 11
           ½λ‖·‖²_F regularizer and NO collective — personalization
           never crosses shards.

``FedPipeline.run_pipeline`` sequences the three stages exactly like
the single-process oracle (``FedSim.run_round`` → ``global_stage`` →
``personalize``); the rebroadcast/keep-local/het-re-mask logic is the
shared ``core.aggregation.client_rebroadcast`` so the two paths cannot
diverge.  The parity sweep in tests/test_distributed.py pins the full
pipeline to the simulator for every registry method.

Gradient accumulation: each local step's batch is split into
micro-batches (a lax.scan, so HLO stays one body deep) so scan-boundary
activations of an 88-layer model fit HBM; LoRA grads are accumulated in
f32.
"""
from __future__ import annotations

import dataclasses
import re
import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.core import aggregation as fedagg
from repro.core import peft
from repro.core.methods import get_method
from repro.launch.mesh import data_axes, dp_size, shard_map_compat
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim import adamw, masked
from repro.optim.optimizers import apply_updates, clip_by_global_norm
from repro.utils import pytree as pt
from repro.utils import sharding as shd

Params = Any


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    lr: float = 1e-4
    micro_batches: int = 1
    clip: float = 1.0
    remat: object = True          # True (full) | "dots" | False
    # stage: which components train (paper pipeline stages)
    stage: str = "local_pretrain"   # | "global" | "local"
    # federated method (core.methods registry) — drives the adapter
    # factory, the per-stage trainable mask, the keep-local leaves, and
    # the collective aggregation form
    method: str = "fedlora_opt"
    # local optimizer steps per round (per train_step call); the batch
    # carries local_steps × per-step-batch rows per client, step-major
    local_steps: int = 1
    # FedProx proximal coefficient (only consulted for prox methods)
    prox_mu: float = 0.0
    # Heterogeneous fleet: one LoRA rank per client (len == dp_size(mesh));
    # None → uniform at cfg.lora_rank.  Mirrors FedHyper.client_ranks.
    client_ranks: Optional[tuple] = None
    # server-side allocation rank for a heterogeneous fleet (0 → fleet max)
    server_rank: int = 0
    # per-client data-size aggregation weights (len == dp_size(mesh));
    # None → uniform.  Mirrors FedHyper.client_weights.
    client_weights: Optional[tuple] = None
    # ---- pipeline stages 2/3 (mirror FedHyper) -----------------------
    server_lr: float = 5e-4       # stage-2 global-optimizer lr
    global_steps: int = 5         # stage-2 steps per global_step call
    personal_steps: int = 20      # stage-3 steps per personal_step call
    lam: float = 1e-3             # Eq. 11 Frobenius regularizer (stage 3)
    # Telemetry: when True the round program additionally all_gathers
    # per-client {ce, grad_norm, drift} as replicated metric leaves
    # (repro.obs consumes them host-side — no callbacks enter the jit)
    # and ``FedPipeline.run_pipeline`` emits fed_round/fed_stage events.
    # False (the default) leaves the compiled programs byte-identical to
    # the pre-telemetry ones.
    telemetry: bool = False

    def __post_init__(self):
        """Normalize the fleet vectors at the dataclass boundary
        (lists/ndarrays → plain tuples; mirrors FedHyper).  Length checks
        need the mesh and stay in ``make_fed_pipeline_step``."""
        if self.client_ranks is not None:
            object.__setattr__(self, "client_ranks",
                               tuple(int(r) for r in self.client_ranks))
        if self.client_weights is not None:
            object.__setattr__(self, "client_weights",
                               tuple(float(w) for w in self.client_weights))


def pick_micro_batches(cfg: ArchConfig, per_client_batch: int,
                       seq_len: int, budget_bytes: float = 1.0e9) -> int:
    """Choose grad-accumulation depth so scan-boundary activations
    (n_superblocks × mb × S × D × 2B) stay under budget."""
    n_sb, tail, pattern = cfg.blocks_layout()
    per_mb = (n_sb + 1) * seq_len * cfg.d_model * 2 * len(pattern)
    mb_max = max(1, int(budget_bytes // max(per_mb, 1)))
    micro = max(1, -(-per_client_batch // mb_max))
    while per_client_batch % micro:
        micro += 1
    return min(micro, per_client_batch)


@dataclasses.dataclass(frozen=True)
class FedPipeline:
    """The three jitted shard_map stage programs plus the sequencing
    driver.  Signatures (C = dp_size(mesh); trees as in
    ``make_fed_train_step``):

      round_step(base, adapters, opt_state, step, batch, anchor=None,
                 rng=None)
          → (adapters, opt_state, aggregated, metrics)
      global_step(base, aggregated, adapters, server_batch)
          → (aggregated, adapters, metrics)
      personal_step(base, adapters, batch) → (adapters, metrics)

    ``aggregated`` is the replicated server model (no client axis) — the
    same tree ``FedSim.aggregate`` returns.  ``server_batch`` is a
    replicated {tokens, loss_mask} dict of ``global_steps · B`` rows,
    step-major; ``batch`` trees carry the leading client axis.
    ``anchor`` is the FedProx proximal reference (defaults to the call's
    input adapters — correct for round-only training; the pipeline
    driver threads the post-round rebroadcast through subsequent rounds
    exactly like ``FedSim._round_ref``).  ``rng`` trees thread the
    adapter dropout keys: stage 1 takes ``rng`` in ``round_step``,
    stages 2/3 take ``rng`` as their last argument, with the simulator's
    exact key chains (see make_fed_pipeline_step)."""
    round_step: Callable
    global_step: Callable
    personal_step: Callable
    opt_init: Callable
    method: Any
    # unjitted stage-1 body — make_fed_train_step wraps it so the
    # round-only engine can drop the aggregate output INSIDE its own jit
    # (XLA then DCEs the replicated materialization the pipeline needs)
    round_step_raw: Callable = None
    # telemetry (set from TrainSettings.telemetry): run_pipeline emits
    # fed_round / fed_stage events using the per-client metric leaves the
    # round program all_gathers; comm_bytes_round is the analytic wire
    # cost of one round's collective (same accounting as FedSim)
    telemetry: bool = False
    comm_bytes_round: int = 0
    comm_class: str = "psum"

    def run_pipeline(self, base, adapters, opt_state, step, batch,
                     server_batch, personal_batch, prox_anchor=None,
                     rng=None, global_rng=None, personal_rng=None):
        """One full paper-pipeline iteration: stage-1 round → stage-2
        global optimizer → stage-3 personalization, with the simulator's
        sequencing (``FedSim.run_round`` → ``global_stage`` →
        ``personalize``).  Returns (adapters, opt_state, aggregated,
        prox_anchor, metrics); pass the returned ``prox_anchor`` (and
        ``step + local_steps``) into the next iteration — for prox
        methods the anchor is the post-round rebroadcast, which stages
        2/3 must not disturb (mirrors ``FedSim._round_ref``)."""
        enabled = self.telemetry and obs.enabled()
        t0 = time.perf_counter() if enabled else 0.0
        adapters, opt_state, agg, met1 = self.round_step(
            base, adapters, opt_state, step, batch, prox_anchor, rng)
        if enabled:
            jax.block_until_ready(adapters)
            t1 = time.perf_counter()
        anchor = adapters if self.method.prox else None
        agg, adapters, met2 = self.global_step(base, agg, adapters,
                                               server_batch, global_rng)
        if enabled:
            jax.block_until_ready(adapters)
            t2 = time.perf_counter()
        adapters, met3 = self.personal_step(base, adapters, personal_batch,
                                            personal_rng)
        if enabled:
            jax.block_until_ready(adapters)
            t3 = time.perf_counter()
            self._emit_round_event(step, met1, met2, met3,
                                   (t1 - t0, t2 - t1, t3 - t2, t3 - t0))
        return adapters, opt_state, agg, anchor, {
            "round": met1, "global": met2, "personal": met3}

    def _emit_round_event(self, step, met1, met2, met3, wall):
        """Host epilogue: feed the round program's replicated per-client
        metric leaves into the global telemetry sink."""
        name = self.method.name
        dt_round, dt_global, dt_personal, total = wall
        ce = np.asarray(met1.get("client_ce", []), np.float64).reshape(-1)
        gn = np.asarray(met1.get("client_grad_norm", []),
                        np.float64).reshape(-1)
        drift = np.asarray(met1.get("client_drift", []),
                           np.float64).reshape(-1)
        spread = float(ce.max() - ce.min()) if ce.size else 0.0
        obs.inc("fed/rounds", method=name, engine="pipeline")
        obs.inc("fed/comm_bytes", self.comm_bytes_round, method=name,
                comm=self.comm_class)
        obs.set_gauge("fed/loss_spread", spread, method=name)
        for span, dt in (("fed/round", dt_round),
                         ("fed/stage2_global", dt_global),
                         ("fed/stage3_personalize", dt_personal)):
            obs.observe("span_seconds", dt, span=span, method=name)
        for c in range(ce.size):
            obs.observe("fed/client_ce", float(ce[c]), method=name, client=c)
        obs.event(
            "fed_round", engine="pipeline", method=name, step=int(step),
            clients=int(ce.size),
            ce=[round(float(v), 6) for v in ce],
            grad_norm=[round(float(v), 6) for v in gn],
            drift=[round(float(v), 6) for v in drift],
            loss_spread=round(spread, 6),
            comm_bytes=int(self.comm_bytes_round),
            comm_class=self.comm_class,
            wall={"round": round(dt_round, 6),
                  "global": round(dt_global, 6),
                  "personal": round(dt_personal, 6),
                  "total": round(total, 6)})
        for stage, met, dt in (("global", met2, dt_global),
                               ("personal", met3, dt_personal)):
            obs.event("fed_stage", engine="pipeline", stage=stage,
                      method=name, ce=round(float(np.asarray(met["ce"])), 6),
                      wall=round(dt, 6))


def make_fed_pipeline_step(cfg: ArchConfig, mesh,
                           settings: TrainSettings) -> FedPipeline:
    """Build the three-stage pipeline engine (see FedPipeline).

    base: global param tree (model-sharded, replicated over data axes).
    adapters: leading client axis C = dp_size(mesh), sharded 1-per-shard
    (for a heterogeneous fleet, allocated at the server rank and already
    rank-masked, as FedSim lays them out).
    batch: {"tokens": (C, local_steps·B_c, S), ...} sharded likewise,
    step-major: local step t consumes rows [t·B_c, (t+1)·B_c).
    step: global local-step counter; one round advances it by
    ``settings.local_steps``, so the caller passes step + local_steps to
    the next round (the optimizer's bias-correction schedule matches the
    simulator's per-step counter; stages 2/3 restart their counters at 0
    each call with freshly initialized optimizer state, exactly like
    ``FedSim.global_stage``/``personalize``).

    Adapter dropout: pass ``rng`` into ``round_step`` and each local
    step derives this client's dropout key as
    ``jax.random.split(fold_in(rng, step), C)[client]`` — the exact key
    chain ``FedSim.local_round`` uses, so ``cfg.lora_dropout > 0``
    trains with the same masks in both engines (bit-exact at
    micro_batches=1; micro-batching reshapes the activations, which
    redraws the Bernoulli masks).  With ``rng=None`` the loss sees no
    key and dropout is off regardless of cfg, the previous contract.

    Stages 2/3 take their own ``rng`` (last argument of ``global_step``
    / ``personal_step``) with the simulator's key chains: stage 2 draws
    ``fold_in(rng, step)`` per server step (no client split —
    ``FedSim.global_stage``); stage 3 draws
    ``split(fold_in(rng, 31 + step), C)[client]`` (``FedSim.personalize``
    — the 31 offset decorrelates stage-3 masks from a stage-1 round fed
    the same key).  A stage-2 rng forces the replicated stage-2 path
    (each shard of the sharded path grads a different row slice, which
    would redraw different Bernoulli masks than the full-batch oracle).
    """
    if cfg.use_fused_dora:
        raise ValueError(
            "use_fused_dora is forward/serving-only (the Pallas kernel "
            "defines no VJP); the train step requires the jnp adapter path")
    daxes = data_axes(mesh)
    dp = dp_size(mesh)
    micro = settings.micro_batches
    is_moe = cfg.n_experts > 0
    method = get_method(settings.method)
    keep_rx = re.compile(method.keep_local) if method.keep_local else None
    # the method's cross-client collective — resolving it here (not at
    # step time) means an aggregator with no shard_map form fails fast,
    # never silently training with different math than the simulator
    collective = fedagg.collective_form(method)
    # leaves the host aggregate zeroes in the server model (fedalt's
    # individual pair): the collective meaned them, the stage-2 server
    # model must not see that mean
    zrx = fedagg.aggregate_zero_rx(method)
    zero_rx = re.compile(zrx) if zrx else None
    prox_mu = settings.prox_mu if method.prox else 0.0
    lam = settings.lam if method.personal_reg is not None else 0.0

    # ---- fleet layout: ranks, coverage masks, aggregation weights ------
    het = settings.client_ranks is not None
    if het:
        if not method.het_ranks:
            raise ValueError(
                f"method {method.name!r} has no rank dimension "
                "(het_ranks=False); client_ranks requires a LoRA-family "
                "method")
        alloc_rank = peft.fleet_alloc_rank(settings.client_ranks, dp,
                                           settings.server_rank)
        ranks = jnp.asarray(settings.client_ranks, jnp.int32)
    else:
        alloc_rank = cfg.lora_rank
        ranks = jnp.full((dp,), alloc_rank, jnp.int32)
    if settings.client_weights is not None:
        peft.validate_client_weights(settings.client_weights, dp)
        weight_c = jnp.asarray(settings.client_weights, jnp.float32)
    else:
        weight_c = jnp.ones((dp,), jnp.float32)

    # abstract adapter tree (drives the per-stage trainable masks, the
    # shard specs, and the per-client coverage masks); heterogeneous
    # fleets allocate at the server rank, exactly as FedSim does
    mk = (partial(method.make_adapter, rank=alloc_rank) if het
          else method.make_adapter)
    abs_ad = jax.eval_shape(
        lambda: mk(abstract_base(cfg), cfg, jax.random.PRNGKey(0)))
    # per-stage optimizers over the per-stage masks — one adamw per
    # stage, exactly the simulator's opt / opt_global / opt_local
    opt = masked(adamw(settings.lr),
                 method.stage_mask(abs_ad, settings.stage))
    opt_g = masked(adamw(settings.server_lr), method.stage_global_mask(abs_ad))
    opt_l = masked(adamw(settings.lr), method.stage_local_mask(abs_ad))
    reg_mask = method.personal_reg(abs_ad) if method.personal_reg else None
    # per-client coverage masks over the rank axis of every leaf; on a
    # uniform fleet these are all-ones (and unused outside the coverage
    # collective), so the uniform program pays nothing
    covers_c = peft.client_rank_masks(abs_ad, ranks)

    ad_spec = shd.client_specs(abs_ad, mesh)
    ost_abs = jax.eval_shape(opt.init, abs_ad)
    ost_spec = shd.client_specs(ost_abs, mesh)
    cov_spec = shd.client_specs(covers_c, mesh)
    w_spec = shd.client_vector_spec(mesh)   # weights / participation /
                                            # staleness / update scales
    # the aggregated server model carries no client axis: replicated in,
    # replicated out (stages 1 → 2 hand it off in this layout)
    agg_spec = shd.replicated_specs(abs_ad)
    mesh_tag = ("manual", mesh.shape["data"]) if is_moe else None

    def batch_spec_of(batch):
        return {k: P(shd.client_axis(mesh)) for k in batch}

    # ---- shared per-shard training scan --------------------------------
    # One loop body for all three stages: T optimizer steps, each
    # micro-batched via lax.scan (one HLO body regardless of depth — an
    # unrolled loop made 88-layer compiles explode), forward-only carry
    # (grads), LoRA grads accumulated in f32.
    def train_scan(base, ad, ost, step0, batch, *, T, stage_opt, cover,
                   stage_lam, stage_prox, anchor, stage, rng=None,
                   rng_fold=0, rng_split=True, grad_axes=None):
        def loss_fn(ad_, mb, rng_):
            params = pt.merge_trees(base, ad_)
            loss, met = M.loss_and_metrics(params, mb, cfg, rng=rng_,
                                           mesh=mesh_tag,
                                           remat=settings.remat)
            if stage_lam:
                # Eq. 11 ½λ‖·‖²_F over the method's personal_reg leaves
                reg = sum(jnp.sum(jnp.square(x)) for m, x in zip(
                    jax.tree.leaves(reg_mask), jax.tree.leaves(ad_)) if m)
                loss = loss + 0.5 * stage_lam * reg
            if stage_prox:
                d = pt.tree_sub(ad_, anchor)
                loss = loss + 0.5 * stage_prox * pt.tree_dot(d, d)
            return loss, met

        B_c = batch["tokens"].shape[0]
        shards = dp if grad_axes is not None else 1
        if B_c % (T * micro * shards):
            raise ValueError(
                f"{stage} batch of {B_c} rows is not divisible by steps "
                f"({T}) x micro_batches ({micro})"
                + (f" x shards ({shards})" if shards > 1 else ""))
        mb_sz = B_c // (T * micro * shards)
        if grad_axes is not None:
            # data-parallel stage: each shard takes its slice of every
            # micro-batch; the token-weighted psum below recovers the
            # full-batch gradient
            cidx = fedagg.client_index(grad_axes)
            sbatch = {k: v.reshape((T, micro, shards, mb_sz)
                                   + v.shape[1:])[:, :, cidx]
                      for k, v in batch.items()}
        else:
            sbatch = {k: v.reshape((T, micro, mb_sz) + v.shape[1:])
                      for k, v in batch.items()}

        def local_step(carry, sb):
            ad_, ost_, step = carry
            # per-step dropout key: the simulator's chains —
            # split(fold_in(rng, fold + step), C)[client] on per-client
            # stages (fold 0 for the round, 31 for personalization), and
            # the unsplit fold_in(rng, step) on the replicated stage-2
            # server model — so both engines draw the same masks for the
            # same step/client
            if rng is None:
                step_rng = None
            else:
                k = jax.random.fold_in(rng, rng_fold + step)
                step_rng = (jax.random.split(k, dp)
                            [fedagg.client_index(daxes)]
                            if rng_split else k)
            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), ad_)

            def acc_body(carry_g, mb):
                g_acc, n_acc = carry_g
                (_, met), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    ad_, mb, step_rng)
                # grad weight: the CE denominator (n_tok) when sharded,
                # so uneven loss masks still reduce to the full-batch
                # gradient; 1 on the replicated/per-client path
                n = (met["n_tok"] if grad_axes is not None
                     else jnp.ones((), jnp.float32))
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) * n, g_acc, g)
                return (g_acc, n_acc + n), met

            (g_acc, n_tot), mets = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), sb)
            if grad_axes is not None:
                n_tot = jax.lax.psum(n_tot, grad_axes)
                g_acc = jax.tree.map(
                    lambda x: jax.lax.psum(x, grad_axes) / n_tot, g_acc)
            else:
                g_acc = jax.tree.map(lambda x: x / micro, g_acc)
            # pre-clip gradient norm rides the metrics unconditionally
            # (not telemetry-gated) so the compiled program is identical
            # with obs on and off; equals the simulator's per-client
            # grad_norm at micro_batches=1
            gnorm = pt.global_norm(g_acc)
            g_acc = clip_by_global_norm(g_acc, settings.clip)
            upd, ost_ = stage_opt.update(g_acc, ost_, ad_, step)
            if cover is not None:
                # heterogeneous fleet: zero the update rows above this
                # client's rank (adapters are allocated at the server rank)
                upd = jax.tree.map(jnp.multiply, upd, cover)
            ad_ = apply_updates(ad_, upd)
            met = jax.tree.map(lambda x: jnp.sum(x, axis=0) / micro, mets)
            met = dict(met, grad_norm=gnorm)
            return (ad_, ost_, step + 1), met

        (ad, ost, _), mets = jax.lax.scan(local_step, (ad, ost, step0),
                                          sbatch)
        return ad, ost, jax.tree.map(lambda m: m[-1], mets)

    # ---- stage 1: the federated round ----------------------------------
    def round_body(base, adapters, opt_state, step0, batch, anchor, weight,
                   part, stale, scale, covers, rng, *, use_rng, use_faults):
        # inside the manual region: one client per shard
        adapters = jax.tree.map(lambda x: x[0], adapters)   # drop C axis
        opt_state = jax.tree.map(lambda x: x[0], opt_state)
        batch = {k: v[0] for k, v in batch.items()}
        anchor = jax.tree.map(lambda x: x[0], anchor)
        w = weight[0]
        cover = jax.tree.map(lambda x: x[0], covers)
        if use_faults:
            ad0, ost0 = adapters, opt_state     # round-start snapshot
        adapters, opt_state, mets = train_scan(
            base, adapters, opt_state, step0, batch,
            T=settings.local_steps, stage_opt=opt,
            cover=cover if het else None, stage_lam=0.0,
            stage_prox=prox_mu, anchor=anchor, stage="round",
            rng=rng if use_rng else None)
        if use_faults:
            # fault layer — statically gated (``old + 1·(new−old) ≠ new``
            # in f32, so the honest path must never run these), and when
            # active BOTH engines apply the identical expressions to ALL
            # shards (identity values for honest clients) so parity with
            # FedSim.run_cohort_round holds bit for bit:
            #   scale  corrupted-update adversaries inflate this shard's
            #          round update;
            #   part   a 0-participation shard reverts adapters AND
            #          optimizer state to round start (its mid-round work
            #          is lost) and contributes weight 0 below.
            p, s = part[0], scale[0]
            adapters = jax.tree.map(
                lambda new, old: old + s * (new - old), adapters, ad0)
            adapters = jax.tree.map(
                lambda new, old: jnp.where(p > 0, new, old), adapters, ad0)
            opt_state = jax.tree.map(
                lambda new, old: jnp.where(p > 0, new, old), opt_state, ost0)
            w = w * p

        # the method's collective aggregation: the only cross-client (and
        # only cross-pod) traffic.  Keep-local leaves (the paper's
        # personal ΔB_M, FedALT's individual pair) are restored from this
        # shard's own post-round values — personalization never crosses
        # shards.  ``step`` feeds the COMPRESSED codecs' rounding keys:
        # the post-round counter, = FedSim._step at FedSim.aggregate time.
        # ``staleness`` feeds the STALENESS (FedBuff) discount; other
        # kinds ignore it.
        agg = collective(adapters, axes=daxes, weight=w, cover=cover,
                         step=step0 + settings.local_steps,
                         staleness=stale[0])
        if settings.telemetry:
            # per-client aggregate drift ‖client − aggregate‖ over the
            # shared leaves, pre-rebroadcast (the simulator's
            # _client_drift) — a per-shard scalar, all_gathered below
            sq = jnp.zeros((), jnp.float32)
            for (p, x), y, m in zip(
                    jax.tree_util.tree_leaves_with_path(adapters),
                    jax.tree.leaves(agg), jax.tree.leaves(cover)):
                if keep_rx is not None and keep_rx.search(pt.path_str(p)):
                    continue
                d = x - y
                if het:
                    d = d * m
                sq = sq + jnp.sum(jnp.square(d))
            drift = jnp.sqrt(sq)
        if zero_rx is not None:
            agg = pt.tree_map_with_path(
                lambda p, x: jnp.zeros_like(x) if zero_rx.search(p) else x,
                agg)
        out = fedagg.client_rebroadcast(agg, adapters, keep_rx,
                                        cover if het else None)
        met_last = jax.tree.map(lambda m: jax.lax.pmean(m, daxes), mets)
        if settings.telemetry:
            # per-client metric leaves, replicated by the all_gather so
            # they satisfy the replicated out_spec — the host pulls them
            # after the jit returns (no callbacks inside the program)
            met_last = dict(
                met_last,
                client_ce=jax.lax.all_gather(mets["ce"], daxes),
                client_grad_norm=jax.lax.all_gather(mets["grad_norm"],
                                                    daxes),
                client_drift=jax.lax.all_gather(drift, daxes))
        return (jax.tree.map(lambda x: x[None], out),
                jax.tree.map(lambda x: x[None], opt_state), agg, met_last)

    def round_step(base, adapters, opt_state, step, batch, anchor=None,
                   rng=None, weights=None, participation=None,
                   staleness=None, update_scale=None):
        if anchor is None:
            # round-only training: the proximal reference is the call's
            # input adapters (a round ends in rebroadcast, so the next
            # round's input IS the last rebroadcast)
            anchor = adapters
        use_rng = rng is not None
        if not use_rng:
            rng = jnp.zeros((2,), jnp.uint32)   # placeholder, never consumed
        # cohort/fault inputs (mirror FedSim.run_cohort_round): all (C,)
        # vectors riding w_spec.  ``use_faults`` is a static gate — with
        # every argument None the fault transforms never enter the
        # program and the placeholder vectors are dead inputs, so the
        # honest round compiles to the identical math as before.
        use_faults = participation is not None or update_scale is not None
        w_c = weight_c if weights is None else jnp.asarray(
            weights, jnp.float32)
        part_c = (jnp.ones((dp,), jnp.float32) if participation is None
                  else jnp.asarray(participation, jnp.float32))
        stale_c = (jnp.zeros((dp,), jnp.float32) if staleness is None
                   else jnp.asarray(staleness, jnp.float32))
        scale_c = (jnp.ones((dp,), jnp.float32) if update_scale is None
                   else jnp.asarray(update_scale, jnp.float32))
        body = shard_map_compat(
            partial(round_body, use_rng=use_rng, use_faults=use_faults),
            mesh,
            in_specs=(base_manual_specs(base, cfg), ad_spec, ost_spec, P(),
                      batch_spec_of(batch), ad_spec, w_spec, w_spec,
                      w_spec, w_spec, cov_spec, P()),
            out_specs=(ad_spec, ost_spec, agg_spec, P()),
            manual_axes=daxes,
        )
        return body(base, adapters, opt_state, step, batch, anchor,
                    w_c, part_c, stale_c, scale_c, covers_c, rng)

    # ---- stage 2: the global optimizer (replicated server model) -------
    def global_body(base, agg, adapters, sbatch, covers, rng, *, use_rng):
        own = jax.tree.map(lambda x: x[0], adapters)
        cover = jax.tree.map(lambda x: x[0], covers)
        # the server model trains at the full allocated rank with no rank
        # mask and a fresh zero-state optimizer (FedSim.global_stage).
        # agg/sbatch come in replicated; when the server batch divides
        # evenly over the client axis each shard grads its own slice of
        # every micro-batch and the token-weighted psum inside train_scan
        # recovers the full-batch gradient (dp× fewer backbone FLOPs per
        # shard, updates stay replicated); otherwise every shard runs the
        # identical replicated math.  Dropout rng forces the replicated
        # path: sharded rows would redraw different Bernoulli masks than
        # the full-batch oracle (mask shape follows the activations).
        B_s = sbatch["tokens"].shape[0]
        shard2 = (dp > 1 and not use_rng
                  and B_s % (settings.global_steps * micro * dp) == 0)
        ost = opt_g.init(agg)
        agg, _, mets = train_scan(
            base, agg, ost, jnp.zeros((), jnp.int32), sbatch,
            T=settings.global_steps, stage_opt=opt_g, cover=None,
            stage_lam=0.0, stage_prox=0.0, anchor=None, stage="global",
            rng=rng if use_rng else None, rng_split=False,
            grad_axes=daxes if shard2 else None)
        if shard2:
            # per-shard metrics differ (different rows) — mean them so
            # the replicated out_spec holds
            mets = jax.tree.map(lambda m: jax.lax.pmean(m, daxes), mets)
        out = fedagg.client_rebroadcast(agg, own, keep_rx,
                                        cover if het else None)
        return agg, jax.tree.map(lambda x: x[None], out), mets

    def global_step(base, aggregated, adapters, server_batch, rng=None):
        use_rng = rng is not None
        if not use_rng:
            rng = jnp.zeros((2,), jnp.uint32)   # placeholder, never consumed
        body = shard_map_compat(
            partial(global_body, use_rng=use_rng),
            mesh,
            in_specs=(base_manual_specs(base, cfg), agg_spec, ad_spec, P(),
                      cov_spec, P()),
            out_specs=(agg_spec, ad_spec, P()),
            manual_axes=daxes,
        )
        return body(base, aggregated, adapters, server_batch, covers_c, rng)

    # ---- stage 3: per-client personalization (no collective) -----------
    def personal_body(base, adapters, batch, covers, rng, *, use_rng):
        ad = jax.tree.map(lambda x: x[0], adapters)
        batch = {k: v[0] for k, v in batch.items()}
        cover = jax.tree.map(lambda x: x[0], covers)
        ost = opt_l.init(ad)
        ad, _, mets = train_scan(
            base, ad, ost, jnp.zeros((), jnp.int32), batch,
            T=settings.personal_steps, stage_opt=opt_l,
            cover=cover if het else None, stage_lam=lam, stage_prox=0.0,
            anchor=None, stage="personal",
            rng=rng if use_rng else None, rng_fold=31)
        met_last = jax.tree.map(lambda m: jax.lax.pmean(m, daxes), mets)
        return jax.tree.map(lambda x: x[None], ad), met_last

    def personal_step(base, adapters, batch, rng=None):
        use_rng = rng is not None
        if not use_rng:
            rng = jnp.zeros((2,), jnp.uint32)   # placeholder, never consumed
        body = shard_map_compat(
            partial(personal_body, use_rng=use_rng),
            mesh,
            in_specs=(base_manual_specs(base, cfg), ad_spec,
                      batch_spec_of(batch), cov_spec, P()),
            out_specs=(ad_spec, P()),
            manual_axes=daxes,
        )
        return body(base, adapters, batch, covers_c, rng)

    def opt_init(adapters_c):
        return jax.vmap(opt.init)(adapters_c)

    # analytic wire cost of one round's collective — FedSim.aggregate's
    # exact billing, evaluated once at build time on the abstract adapter
    # template (heterogeneous fleets bill each client at its own rank)
    comm_cls = fedagg.comm_class(method)
    topk_ratio = getattr(collective, "topk_ratio", 0.01)
    if het:
        comm_bytes = sum(
            fedagg.comm_bytes_per_round(
                abs_ad, exclude_rx=method.keep_local, rank=int(r),
                comm=comm_cls, n_clients=dp, topk_ratio=topk_ratio)
            for r in settings.client_ranks)
    else:
        comm_bytes = dp * fedagg.comm_bytes_per_round(
            abs_ad, exclude_rx=method.keep_local, comm=comm_cls,
            n_clients=dp, topk_ratio=topk_ratio)

    return FedPipeline(round_step=jax.jit(round_step),
                       global_step=jax.jit(global_step),
                       personal_step=jax.jit(personal_step),
                       opt_init=opt_init, method=method,
                       round_step_raw=round_step,
                       telemetry=settings.telemetry,
                       comm_bytes_round=int(comm_bytes),
                       comm_class=comm_cls)


def make_fed_train_step(cfg: ArchConfig, mesh, settings: TrainSettings):
    """Returns (train_step, opt_init).  train_step signature:

        train_step(base, adapters, opt_state, step, batch, rng=None)
            → (adapters, opt_state, metrics)

    One train_step call is one federated ROUND: ``settings.local_steps``
    optimizer steps per client, then one aggregation — the stage-1
    program of ``make_fed_pipeline_step`` with the aggregate output
    dropped.  Every method in the core.methods registry trains with the
    same math here as in the single-process simulator (fed/simulate.py).
    """
    pipe = make_fed_pipeline_step(cfg, mesh, settings)

    def train_step(base, adapters, opt_state, step, batch, rng=None,
                   weights=None, participation=None, staleness=None,
                   update_scale=None):
        # the aggregate is dropped inside this jit so round-only training
        # never pays for materializing the pipeline's replicated output;
        # the cohort/fault vectors pass straight through to the stage-1
        # body (see round_step)
        adapters, opt_state, _, met = pipe.round_step_raw(
            base, adapters, opt_state, step, batch, rng=rng,
            weights=weights, participation=participation,
            staleness=staleness, update_scale=update_scale)
        return adapters, opt_state, met

    return jax.jit(train_step), pipe.opt_init


def abstract_base(cfg: ArchConfig):
    return jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def base_manual_specs(base, cfg: ArchConfig):
    """Manual specs for the base tree over the DATA axes only: MoE expert
    slots are expert-parallel (manual over 'data'); everything else is
    replicated across clients ('model'-axis sharding stays auto)."""
    def fn(path, x):
        if cfg.n_experts and re.search(r"moe/experts/", path):
            # (n_sb, E_slots, D, F) — E_slots manual over 'data'
            lead = [None] * (len(x.shape) - 3)
            return P(*lead, "data", None, None)
        return P(*([None] * len(x.shape)))

    return pt.tree_map_with_path(fn, base)
