"""Generic federated PEFT engine.

Clients are a leading vmapped axis on the adapter overlay; the frozen
backbone is shared.  On a multi-device mesh the client axis is sharded
over ('pod','data') so aggregation lowers to an all-reduce carrying only
adapter bytes (see launch/train.py for the pjit'd variant); on CPU this
same code runs on one device for the paper-scale benchmarks.

The engine is method-agnostic: the paper's FedLoRA-Optimizer and every
baseline (LoRA/FedIT, FFA-LoRA, FedProx, prompt-, adapter-tuning) are
(adapter-type, trainable-mask, loss-extras) triples on top of it.
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core import peft
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim import adamw, masked, chain_clip
from repro.optim.optimizers import apply_updates
from repro.utils import pytree as pt

Params = Any


@dataclasses.dataclass(frozen=True)
class FedHyper:
    method: str = "fedlora_opt"   # lora | ffa_lora | fedprox | prompt | adapter
    n_clients: int = 4
    rounds: int = 10
    local_steps: int = 5
    batch: int = 8
    seq_len: int = 64
    lr: float = 1e-3
    server_lr: float = 5e-4
    global_steps: int = 5          # stage-2 ΔA_D steps per round (pipeline)
    personal_steps: int = 20       # stage-3 ΔB_M steps
    lam: float = 1e-3              # Eq. 11 Frobenius regularizer
    prox_mu: float = 0.0           # FedProx proximal coefficient
    pipeline: bool = True          # global→local staging (Fig. 3 ablation)
    clip: float = 1.0
    seed: int = 0


class FedSim:
    """Federated simulation over one ArchConfig + per-client datasets."""

    def __init__(self, cfg: ArchConfig, hp: FedHyper, base=None):
        self.cfg, self.hp = cfg, hp
        rng = jax.random.PRNGKey(hp.seed)
        r_base, r_ad = jax.random.split(rng)
        self.base = M.init_params(r_base, cfg) if base is None else base

        m = hp.method
        if m in ("fedlora_opt",):
            ad = peft.add_lora(self.base, cfg, r_ad, decomposed=True)
            self.train_mask = peft.mask_stage_local_pretrain(ad)
        elif m in ("lora", "fedprox"):
            ad = peft.add_lora(self.base, cfg, r_ad, decomposed=False)
            self.train_mask = peft.mask_all(ad)
        elif m == "ffa_lora":
            ad = peft.add_lora(self.base, cfg, r_ad, decomposed=False)
            self.train_mask = peft.mask_ffa(ad)
        elif m == "prompt":
            ad = peft.add_prompt_tuning(self.base, cfg, r_ad)
            self.train_mask = peft.mask_all(ad)
        elif m == "adapter":
            ad = peft.add_adapter_tuning(self.base, cfg, r_ad)
            self.train_mask = peft.mask_all(ad)
        else:
            raise ValueError(m)
        self.adapter_template = ad
        self.reg_mask = peft.reg_mask_dB(ad)
        self.global_mask = (peft.mask_stage_global(ad)
                            if m == "fedlora_opt" else self.train_mask)
        self.local_mask = (peft.mask_stage_local(ad)
                           if m == "fedlora_opt" else self.train_mask)

        C = hp.n_clients
        self.client_adapters = agg.broadcast_to_clients(ad, C)
        self._build_steps()
        self.opt_state = jax.vmap(self.opt.init)(self.client_adapters)
        self.step_count = jnp.zeros((C,), jnp.int32)
        self.comm_bytes = 0
        self._round_ref = self.client_adapters

    # ------------------------------------------------------------------
    def _loss(self, base, adapters, batch, rng, lam, prox_ref, prox_mu):
        mask_reg = self.reg_mask
        params = pt.merge_trees(base, adapters)
        loss, met = M.loss_and_metrics(params, batch, self.cfg, rng=rng)
        if lam:
            reg = sum(jnp.sum(jnp.square(x)) for m, x in zip(
                jax.tree.leaves(mask_reg), jax.tree.leaves(adapters)) if m)
            loss = loss + 0.5 * lam * reg
        if prox_mu and prox_ref is not None:
            prox = pt.tree_dot(pt.tree_sub(adapters, prox_ref),
                               pt.tree_sub(adapters, prox_ref))
            loss = loss + 0.5 * prox_mu * prox
        return loss, met

    def _build_steps(self):
        hp, cfg = self.hp, self.cfg
        self.opt = chain_clip(masked(adamw(hp.lr), self.train_mask), hp.clip)
        self.opt_global = chain_clip(masked(adamw(hp.server_lr),
                                            self.global_mask), hp.clip)
        self.opt_local = chain_clip(masked(adamw(hp.lr), self.local_mask),
                                    hp.clip)

        def one_client_step(base, adapters, opt_state, batch, rng, step,
                            prox_ref, *, opt, lam, prox_mu):
            (loss, met), g = jax.value_and_grad(
                self._loss, argnums=1, has_aux=True)(
                base, adapters, batch, rng, lam, prox_ref, prox_mu)
            upd, opt_state = opt.update(g, opt_state, adapters, step)
            return apply_updates(adapters, upd), opt_state, met

        prox_mu = hp.prox_mu if hp.method == "fedprox" else 0.0
        step_train = partial(one_client_step, opt=self.opt, lam=0.0,
                             prox_mu=prox_mu)
        self._vstep = jax.jit(jax.vmap(
            step_train, in_axes=(None, 0, 0, 0, 0, 0, 0)))
        step_pers = partial(one_client_step, opt=self.opt_local,
                            lam=hp.lam if hp.method == "fedlora_opt" else 0.0,
                            prox_mu=0.0)
        self._vstep_pers = jax.jit(jax.vmap(
            step_pers, in_axes=(None, 0, 0, 0, 0, 0, 0)))
        step_glob = partial(one_client_step, opt=self.opt_global, lam=0.0,
                            prox_mu=0.0)
        self._gstep = jax.jit(step_glob)

        def eval_fn(base, adapters, batch):
            params = pt.merge_trees(base, adapters)
            _, met = M.loss_and_metrics(params, batch, cfg)
            return met
        self._eval = jax.jit(eval_fn)
        self._veval = jax.jit(jax.vmap(eval_fn, in_axes=(None, 0, 0)))
        self._agg = jax.jit(
            lambda ca: agg.decomposed_fedavg(ca)
            if hp.method == "fedlora_opt" else agg.fedavg(ca))

    # ------------------------------------------------------------------
    def local_round(self, batches: list[dict], rng) -> dict:
        """One round of stage-1 local training.  batches: list (per local
        step) of stacked (C, B, S) dicts."""
        C = self.hp.n_clients
        mets = None
        for b in batches:
            rngs = jax.random.split(jax.random.fold_in(rng, int(self.step_count[0])), C)
            self.client_adapters, self.opt_state, mets = self._vstep(
                self.base, self.client_adapters, self.opt_state, b, rngs,
                self.step_count, self._round_ref)
            self.step_count = self.step_count + 1
        return {k: np.asarray(v) for k, v in (mets or {}).items()}

    def aggregate(self) -> Params:
        """Eqs. 5–8 (or plain FedAvg) + comm accounting; broadcasts the
        aggregate back (dB_mag stays local for the paper method)."""
        aggregated = self._agg(self.client_adapters)
        self.comm_bytes += self.hp.n_clients * agg.comm_bytes_per_round(
            self.adapter_template)
        bcast = agg.broadcast_to_clients(aggregated, self.hp.n_clients)
        if self.hp.method == "fedlora_opt":
            rx = re.compile(r"dB_mag$")
            bcast = pt.tree_map_with_path(
                lambda p, leaf: self._leaf(self.client_adapters, p)
                if rx.search(p) else leaf, bcast)
        self.client_adapters = bcast
        self._round_ref = bcast
        return aggregated

    @staticmethod
    def _leaf(tree, path):
        node = tree
        for k in path.split("/"):
            node = node[k]
        return node

    def global_stage(self, aggregated: Params, server_batches: list[dict],
                     rng) -> Params:
        """Stage 2 — train ΔA_D on the global task mixture (Eq. 9)."""
        opt_state = self.opt_global.init(aggregated)
        step = jnp.zeros((), jnp.int32)
        for i, b in enumerate(server_batches):
            aggregated, opt_state, _ = self._gstep(
                self.base, aggregated, opt_state, b,
                jax.random.fold_in(rng, i), step, aggregated)
            step = step + 1
        self.client_adapters = agg.broadcast_to_clients(
            aggregated, self.hp.n_clients) if self.hp.method != "fedlora_opt" \
            else self._rebroadcast_keep_personal(aggregated)
        return aggregated

    def _rebroadcast_keep_personal(self, aggregated):
        bcast = agg.broadcast_to_clients(aggregated, self.hp.n_clients)
        rx = re.compile(r"dB_mag$")
        return pt.tree_map_with_path(
            lambda p, leaf: self._leaf(self.client_adapters, p)
            if rx.search(p) else leaf, bcast)

    def personalize(self, batches: list[dict], rng) -> None:
        """Stage 3 — per-client ΔB_M fine-tune with Eq. 11 regularizer."""
        C = self.hp.n_clients
        opt_state = jax.vmap(self.opt_local.init)(self.client_adapters)
        steps = jnp.zeros((C,), jnp.int32)
        for b in batches:
            rngs = jax.random.split(jax.random.fold_in(rng, 31 + int(steps[0])), C)
            self.client_adapters, opt_state, _ = self._vstep_pers(
                self.base, self.client_adapters, opt_state, b, rngs, steps,
                self.client_adapters)
            steps = steps + 1

    # ------------------------------------------------------------------
    def eval_global(self, aggregated: Params, batches: list[dict]) -> dict:
        accs, ces = [], []
        for b in batches:
            met = self._eval(self.base, aggregated, b)
            accs.append(float(met["acc"]))
            ces.append(float(met["ce"]))
        return {"acc": float(np.mean(accs)), "ce": float(np.mean(ces))}

    def eval_personalized(self, batches_stacked: list[dict]) -> dict:
        """batches_stacked: list of (C,B,S) dicts, each client evaluated on
        its own task distribution."""
        accs = []
        for b in batches_stacked:
            met = self._veval(self.base, self.client_adapters, b)
            accs.append(np.asarray(met["acc"]))
        per_client = np.mean(np.stack(accs), axis=0)
        return {"acc": float(np.mean(per_client)),
                "per_client": per_client.tolist()}
