"""Lint-rule registry — same shape as the ``FedMethod`` registry in
``core.methods``: rules register by code, consumers ask for them by
code, and adding a rule is one ``register(...)`` call.
"""
from __future__ import annotations

from .base import Finding, ModuleInfo, ProjectContext, Rule
from .dead_mask import DeadMask
from .donation import DonationSafety
from .host_sync import HostSyncInJit
from .prng import PrngHygiene
from .recompile import RecompileHazards

_REGISTRY: dict[str, Rule] = {}


def register(rule: Rule, *, overwrite: bool = False) -> Rule:
    if rule.code in _REGISTRY and not overwrite:
        raise ValueError(f"lint rule {rule.code!r} already registered")
    _REGISTRY[rule.code] = rule
    return rule


def get_rule(code: str) -> Rule:
    try:
        return _REGISTRY[code]
    except KeyError:
        raise ValueError(
            f"unknown lint rule {code!r}; available: "
            f"{', '.join(available_rules())}") from None


def available_rules() -> list[str]:
    return sorted(_REGISTRY)


register(HostSyncInJit())
register(DonationSafety())
register(PrngHygiene())
register(RecompileHazards())
register(DeadMask())

__all__ = [
    "Finding", "ModuleInfo", "ProjectContext", "Rule",
    "register", "get_rule", "available_rules",
    "HostSyncInJit", "DonationSafety", "PrngHygiene",
    "RecompileHazards", "DeadMask",
]
