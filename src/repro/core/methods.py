"""Federated method strategy registry.

A federated PEFT method is fully described by a ``FedMethod``: how to
build its adapter overlay, which leaves train in each pipeline stage,
how client adapters aggregate, which loss extras apply (FedProx prox
term, the paper's Eq. 11 Frobenius regularizer), and which leaves stay
client-local when the aggregate is rebroadcast.  The round engine
(``fed/simulate.py``), the production train step (``launch/train.py``)
and the benchmark driver (``core/fedlora.py``) consume only this
interface — adding a baseline is one ``register(...)`` call, never an
``if hp.method == ...`` branch.

Built-ins:

  fedlora_opt   the paper's pipeline: decomposed adapters, Eqs. 5–8
                aggregation, stage masks, dB_mag kept client-local
  lora          raw LoRA + FedAvg (FedIT-style)
  ffa_lora      raw LoRA with A frozen (Sun et al.)
  fedprox       raw LoRA + proximal term (Li et al.)
  prompt        prompt-tuning (Lester et al.)
  adapter       Houlsby bottleneck adapters
  fedalt        dual local+global LoRA pairs; the individual pair is
                never aggregated (FedALT-style)
  lora_trimmed  raw LoRA + coordinate-wise trimmed-mean aggregation
                (robust to client outliers, cf. Koo et al.)
  lora_fedbuff  raw LoRA + FedBuff-style staleness-weighted aggregation
                (async/buffered rounds; synchronous fleets reduce to
                weighted FedAvg exactly)

Compressed-uplink family (COMPRESSED comm class — the client update is
encoded before the collective, see docs/quantization.md):

  lora_fedavg_q8    stochastic-rounded int8 uplink (unbiased codec)
  lora_fedavg_topk  magnitude top-k sparsified uplink (5% density)

Heterogeneous-rank family (mixed-rank fleets; adapters allocated at
r_max with per-client rank masks — see docs/heterogeneous_ranks.md):

  lora_zeropad      naive zero-pad averaging (degradation baseline)
  lora_replication  coverage-weighted averaging (replication-style)
  lora_exact        exact Σw·AB via stacked factors + truncated SVD
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

from repro.core import aggregation as agg
from repro.core import peft

Params = Any
MaskFn = Callable[[Params], Params]


@dataclasses.dataclass(frozen=True)
class FedMethod:
    """Everything the engine needs to know about one federated method."""
    name: str
    # adapter factory: (base_params, ArchConfig, rng) -> adapter overlay
    make_adapter: Callable[[Params, Any, Any], Params]
    # stage-1 trainable mask (client local training)
    train_mask: MaskFn
    # stage-2 / stage-3 masks; None → same leaves as stage 1
    global_mask: Optional[MaskFn] = None
    local_mask: Optional[MaskFn] = None
    # aggregation over the leading client axis: (client_adapters) -> tree
    aggregate: Callable[[Params], Params] = agg.fedavg
    # regex over leaf paths; matching leaves are NEVER overwritten when the
    # aggregate is rebroadcast (personalized state stays client-local)
    keep_local: Optional[str] = None
    # loss extras
    prox: bool = False                       # FedProx ½µ‖θ−θ_ref‖² term
    personal_reg: Optional[MaskFn] = None    # Eq. 11 ½λ‖·‖²_F mask (stage 3)
    # True → the method runs the paper's staged pipeline (aggregate →
    # global stage on the server mixture → final per-client stage)
    pipeline: bool = False
    # True → the adapter factory accepts rank= and its leaves follow
    # peft.rank_axis, so the engine can run a mixed-rank fleet (adapters
    # allocated at r_max, per-client rank masks on every update)
    het_ranks: bool = False
    # True → ``aggregate`` accepts a ranks=(C,) kwarg (the rank-aware
    # family); the engine partials in the fleet's ranks
    rank_aware: bool = False
    # shard_map-expressible form of ``aggregate`` for the production
    # train step (core.aggregation.CollectiveAgg).  None → inferred from
    # ``aggregate`` by ``aggregation.collective_form`` (covers the whole
    # built-in family); set explicitly when registering a method with a
    # custom aggregator so it can run on launch/train.py.
    collective: Optional[agg.CollectiveAgg] = None
    # regex over leaf paths the *aggregated/server* model zeroes (leaves
    # the host aggregate excludes from the global model, e.g. FedALT's
    # individual pair).  None → inferred from ``aggregate`` by
    # ``aggregation.aggregate_zero_rx`` (covers the built-in
    # fedavg_excluding partial); set explicitly when a custom aggregate
    # zeroes leaves, or the production pipeline's stage-2 server model
    # would silently train on their mean.
    server_zero_rx: Optional[str] = None
    description: str = ""

    def stage_global_mask(self, adapters: Params) -> Params:
        return (self.global_mask or self.train_mask)(adapters)

    def stage_local_mask(self, adapters: Params) -> Params:
        return (self.local_mask or self.train_mask)(adapters)

    def stage_mask(self, adapters: Params, stage: str) -> Params:
        """Trainable mask for one pipeline stage — the single dispatch
        both engines (fed/simulate.py, launch/train.py) use, so the
        stage → leaves mapping can never diverge between them.  Stages:
        'local_pretrain' (stage 1, client rounds), 'global' (stage 2,
        server optimizer), 'local' (stage 3, personalization)."""
        if stage == "global":
            return self.stage_global_mask(adapters)
        if stage == "local":
            return self.stage_local_mask(adapters)
        if stage == "local_pretrain":
            return self.train_mask(adapters)
        raise ValueError(f"unknown pipeline stage {stage!r} "
                         "(local_pretrain | global | local)")


_REGISTRY: dict[str, FedMethod] = {}


def register(method: FedMethod, *, overwrite: bool = False) -> FedMethod:
    """Add a method to the registry (returns it, so usable inline)."""
    if method.name in _REGISTRY and not overwrite:
        raise ValueError(f"method {method.name!r} already registered")
    _REGISTRY[method.name] = method
    return method


def get_method(name: str) -> FedMethod:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown federated method {name!r}; available: "
            f"{', '.join(available_methods())}") from None


def available_methods() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# built-ins
# ---------------------------------------------------------------------------

register(FedMethod(
    name="fedlora_opt",
    het_ranks=True,
    make_adapter=partial(peft.add_lora, decomposed=True),
    train_mask=peft.mask_stage_local_pretrain,
    global_mask=peft.mask_stage_global,
    local_mask=peft.mask_stage_local,
    aggregate=agg.decomposed_fedavg,
    keep_local=r"dB_mag$",
    personal_reg=peft.reg_mask_dB,
    pipeline=True,
    description="the paper's global+local optimizer pipeline (Fig. 2)",
))

register(FedMethod(
    name="lora",
    het_ranks=True,
    make_adapter=partial(peft.add_lora, decomposed=False),
    train_mask=peft.mask_all,
    description="raw LoRA + FedAvg (FedIT-style baseline)",
))

register(FedMethod(
    name="ffa_lora",
    het_ranks=True,
    make_adapter=partial(peft.add_lora, decomposed=False),
    train_mask=peft.mask_ffa,
    description="LoRA with A frozen (FFA-LoRA, Sun et al.)",
))

register(FedMethod(
    name="fedprox",
    het_ranks=True,
    make_adapter=partial(peft.add_lora, decomposed=False),
    train_mask=peft.mask_all,
    prox=True,
    description="LoRA + proximal term to the round reference (FedProx)",
))

register(FedMethod(
    name="prompt",
    make_adapter=peft.add_prompt_tuning,
    train_mask=peft.mask_all,
    description="prompt-tuning (Lester et al.)",
))

register(FedMethod(
    name="adapter",
    make_adapter=peft.add_adapter_tuning,
    train_mask=peft.mask_all,
    description="Houlsby bottleneck adapters",
))

register(FedMethod(
    name="fedalt",
    het_ranks=True,
    make_adapter=peft.add_dual_lora,
    train_mask=peft.mask_all,
    # the individual pair never reaches the server: zeroed in the
    # aggregate (global/eval model = shared pair only) and restored
    # per client by the keep-local rebroadcast
    aggregate=partial(agg.fedavg_excluding, exclude_rx=r"local_[AB]$"),
    keep_local=r"local_[AB]$",
    server_zero_rx=r"local_[AB]$",
    description=("dual adapters: shared rest-of-world LoRA pair is "
                 "aggregated, the individual local_A/local_B pair never "
                 "leaves the client (FedALT-style)"),
))

register(FedMethod(
    name="lora_trimmed",
    het_ranks=True,
    make_adapter=partial(peft.add_lora, decomposed=False),
    train_mask=peft.mask_all,
    aggregate=partial(agg.trimmed_fedavg, trim_ratio=0.25),
    collective=agg.gather_trimmed(0.25),
    description=("LoRA + coordinate-wise trimmed-mean aggregation — "
                 "robust to adversarial/outlier clients (cf. Koo et al.)"),
))

register(FedMethod(
    name="lora_fedbuff",
    het_ranks=True,
    make_adapter=partial(peft.add_lora, decomposed=False),
    train_mask=peft.mask_all,
    aggregate=agg.StalenessFedAvg(alpha=0.5),
    description=("raw LoRA + FedBuff-style staleness-weighted buffered "
                 "aggregation — each client's update is discounted by "
                 "(1+τ)^(−α) for τ rounds of staleness before the "
                 "weighted mean (async/buffered rounds; Nguyen et al.)"),
))

register(FedMethod(
    name="lora_fedavg_q8",
    het_ranks=True,
    make_adapter=partial(peft.add_lora, decomposed=False),
    train_mask=peft.mask_all,
    aggregate=agg.CompressedFedAvg(mode="q8"),
    collective=agg.COMPRESSED_Q8,
    description=("raw LoRA + FedAvg over a stochastic-rounded int8 "
                 "uplink — ~4× less uplink traffic, unbiased rounding "
                 "(COMPRESSED comm class)"),
))

register(FedMethod(
    name="lora_fedavg_topk",
    het_ranks=True,
    make_adapter=partial(peft.add_lora, decomposed=False),
    train_mask=peft.mask_all,
    aggregate=agg.CompressedFedAvg(mode="topk", topk_ratio=0.05),
    collective=agg.compressed_topk(0.05),
    description=("raw LoRA + FedAvg over a magnitude top-k sparsified "
                 "uplink (5% density, deterministic; COMPRESSED comm "
                 "class)"),
))

register(FedMethod(
    name="lora_zeropad",
    het_ranks=True,
    rank_aware=True,
    make_adapter=partial(peft.add_lora, decomposed=False),
    train_mask=peft.mask_all,
    aggregate=agg.zeropad_fedavg,
    description=("raw LoRA, mixed-rank fleet, naive zero-pad averaging "
                 "(the degradation baseline of Koo et al.)"),
))

register(FedMethod(
    name="lora_replication",
    het_ranks=True,
    rank_aware=True,
    make_adapter=partial(peft.add_lora, decomposed=False),
    train_mask=peft.mask_all,
    aggregate=agg.replication_fedavg,
    collective=agg.COVERAGE,
    description=("raw LoRA, mixed-rank fleet, coverage-weighted "
                 "(replication-style) averaging — rank row j averages "
                 "only the clients that own it (cf. Koo et al.)"),
))

register(FedMethod(
    name="lora_exact",
    het_ranks=True,
    rank_aware=True,
    make_adapter=partial(peft.add_lora, decomposed=False),
    train_mask=peft.mask_all,
    aggregate=agg.exact_fedavg,
    collective=agg.GATHER_EXACT,
    description=("raw LoRA, mixed-rank fleet, exact Σw·AB aggregation "
                 "via stacked factors + truncated-SVD re-factorization "
                 "(cf. Nguyen et al.)"),
))
