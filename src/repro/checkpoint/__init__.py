from repro.checkpoint.ckpt import (  # noqa: F401
    has_shard, list_shards, load_checkpoint_flat, load_shard_flat,
    restore_checkpoint, save_checkpoint, save_shard, shard_path)
