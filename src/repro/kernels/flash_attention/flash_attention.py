"""Pallas TPU kernel: causal / sliding-window flash attention with GQA.

Online-softmax tiling adapted for the TPU memory hierarchy: one (bq × dh)
query tile stays VMEM-resident while (bk × dh) key/value tiles stream
HBM→VMEM; the running max/denominator live in VMEM scratch across the
key loop (grid dim 2 innermost).  GQA is handled in the BlockSpec index
maps — query head h reads kv head h // (H/K), so kv tiles are fetched
once per group, not repeated in HBM like the naive jnp.repeat path.

Grid: (B·H, Sq/bq, Sk/bk).

VMEM working set (bq=bk=512, dh=128, bf16):
  q + k + v tiles ≈ 0.4 MB, scratch (acc 512·128·4 + m/l) ≈ 0.27 MB — MXU
  dims (bq, dh, bk) all multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window, n_k: int, bq: int, bk: int,
            sk_valid: int, q_offset: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    q = q_ref[0]                                   # (bq, dh)
    k = k_ref[0]                                   # (bk, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kj < sk_valid
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk", "interpret",
                                             "sk_valid", "q_offset"))
def flash_attention_bhsd(q, k, v, *, scale: float, causal: bool = True,
                         window=None, bq: int = 512, bk: int = 512,
                         sk_valid: int = 0, q_offset: int = 0,
                         interpret: bool = False):
    """q (BH, Sq, dh); k/v (BK, Sk, dh); BH = B·H, BK = B·K (kv heads)."""
    BH, Sq, dh = q.shape
    BK, Sk, _ = k.shape
    assert BH % BK == 0
    rep = BH // BK         # == H // K per batch iff layout is (b, h) fused
    bq, bk = min(bq, Sq), min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    n_k = Sk // bk
    sk_valid = sk_valid or Sk

    grid = (BH, Sq // bq, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          n_k=n_k, bq=bq, bk=bk, sk_valid=sk_valid,
                          q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j: (h // rep, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j: (h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # running max
            pltpu.VMEM((bq,), jnp.float32),        # running denom
            pltpu.VMEM((bq, dh), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
