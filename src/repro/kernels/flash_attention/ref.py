"""Pure-jnp oracle for (sliding-window) causal flash attention, GQA."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None):
    """q (B,Sq,H,dh), k/v (B,Sk,K,dh) → (B,Sq,H,dh)."""
    B, Sq, H, dh = q.shape
    Sk, K = k.shape[1], k.shape[2]
    rep = H // K
    kf = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = scale if scale is not None else 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf) * s
    qi = jnp.arange(Sq)[:, None] + (Sk - Sq)   # align ends (decode-friendly)
    kj = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vf)
    return out.astype(q.dtype)
