"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds:

  compute    = FLOPs_per_device / 197e12        (v5e bf16 peak)
  memory     = HBM_bytes_per_device / 819e9
  collective = collective_bytes_per_device / 50e9  (per-link ICI)

Sources & honesty notes (EXPERIMENTS.md §Roofline):
  · collective bytes are parsed from compiled HLO text; ops inside scan
    bodies (metadata op_name containing "/while/") are multiplied by the
    loop trip count (measured: XLA's static text lists a while body once).
  · FLOPs/HBM bytes use exact analytic formulas from the config (below),
    because cost_analysis() counts every while body once (measured 0.1×
    for a 10-iteration scan) and several model loops nest; the raw
    cost_analysis numbers are reported alongside as a diagnostic.
  · memory fit is taken from compiled.memory_analysis() (per-device).
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

from repro.configs import InputShape
from repro.models.config import ArchConfig

PEAK_FLOPS = 197e12          # v5e bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLL_RX = re.compile(
    r"(?P<typ>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RX = re.compile(r"=\s*(?:\()?\s*(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")


def _result_bytes(line: str) -> int:
    """Sum result-tuple element bytes on an HLO op line."""
    total = 0
    for m in _SHAPE_RX.finditer(line.split("metadata=")[0]):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# computation headers: "%name (params...) -> type {" — params may contain
# nested tuple types, so only anchor on the leading name
_COMP_HDR_RX = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RX = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RX = re.compile(r"body=%?([\w.\-]+)")
_CALL_RX = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = _COMP_HDR_RX.match(line) if (line and not line[0].isspace()) else None
        if m and ls.endswith("{") and "->" in line:
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
        elif ls == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(ls)
    comps["__entry__"] = [entry or ""]
    return comps


def parse_collectives(hlo_text: str,
                      loop_trips: tuple[int, ...] = ()) -> dict:
    """Collective bytes per device by op type, loop-aware.

    XLA's static text lists a while body once (measured); we rebuild the
    call graph, read each while's backend_config known_trip_count, and
    multiply collective bytes by the product of enclosing trip counts.
    loop_trips[0] is the fallback trip for loops without the annotation.
    """
    comps = _split_computations(hlo_text)
    entry = comps.pop("__entry__")[0]
    default_trip = loop_trips[0] if loop_trips else 1

    # per-computation: collective bytes + outgoing edges (child, trip)
    coll_b: dict[str, dict[str, float]] = {}
    counts: dict[str, int] = {}
    edges: dict[str, list[tuple[str, int]]] = {c: [] for c in comps}
    for cname, lines in comps.items():
        cb: dict[str, float] = {}
        for line in lines:
            mb = _BODY_RX.search(line)
            if mb and "while(" in line:
                mt = _TRIP_RX.search(line)
                trip = int(mt.group(1)) if mt else default_trip
                edges[cname].append((mb.group(1), trip))
            for mc in _CALL_RX.finditer(line):
                if "while(" not in line:
                    edges[cname].append((mc.group(1), 1))
            m = _COLL_RX.search(line)
            if not m or "-done" in line.split("=")[0]:
                continue
            typ = m.group("typ")
            b = _result_bytes(line)
            if typ == "all-reduce":
                b *= 2                       # ring AR moves ≈2× payload
            cb[typ] = cb.get(typ, 0.0) + b
            counts[typ] = counts.get(typ, 0) + 1
        coll_b[cname] = cb

    # propagate multipliers from entry
    mult: dict[str, float] = {c: 0.0 for c in comps}
    if entry in mult:
        mult[entry] = 1.0
    changed = True
    it = 0
    while changed and it < 10_000:
        changed = False
        it += 1
        for cname, outs in edges.items():
            if mult.get(cname, 0.0) <= 0:
                continue
            for child, trip in outs:
                want = mult[cname] * trip
                if child in mult and want > mult[child]:
                    mult[child] = want
                    changed = True

    out: dict[str, float] = {}
    for cname, cb in coll_b.items():
        f = mult.get(cname, 0.0) or (1.0 if cname == entry else 0.0)
        for typ, b in cb.items():
            out[typ] = out.get(typ, 0.0) + b * f
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["op_counts"] = counts
    return out


# ---------------------------------------------------------------------------
# analytic FLOPs / bytes (documented formulas)
# ---------------------------------------------------------------------------

def _sublayer_flops_per_token(cfg: ArchConfig, sub, kind: str,
                              seq_len: int) -> float:
    D = cfg.d_model
    fl = 0.0
    if sub.mixer in ("attn", "cross_attn"):
        Hdh = cfg.n_heads * cfg.head_dim
        Kdh = cfg.n_kv_heads * cfg.head_dim
        fl += 2 * D * Hdh + 2 * 2 * D * Kdh + 2 * Hdh * D
        if kind == "decode":
            eff = seq_len if sub.attn_kind != "local" or not cfg.sliding_window \
                else min(cfg.sliding_window, seq_len)
        else:
            full = seq_len / 2                       # causal average
            eff = full if sub.attn_kind != "local" or not cfg.sliding_window \
                else min(cfg.sliding_window, full)
        fl += 4 * cfg.n_heads * cfg.head_dim * eff   # qk^T + pv
    elif sub.mixer == "ssm":
        H = D * cfg.ssm_expand // cfg.ssm_headdim
        P = cfg.ssm_headdim
        N = cfg.ssm_state
        GN = cfg.ssm_groups * N
        d_inner = H * P
        fl += 2 * D * (2 * d_inner) + 2 * D * 2 * GN + 2 * D * H
        fl += 2 * cfg.ssm_conv * (d_inner + 2 * GN)
        if kind == "decode":
            fl += 6 * H * N * P                      # state update + read
        else:
            Q = min(cfg.ssm_chunk, seq_len)
            fl += H * (2 * Q * (N + P) + 4 * N * P)  # SSD chunked
        fl += 2 * d_inner * D
    if sub.ffn == "dense":
        fl += 3 * 2 * D * cfg.d_ff
    elif sub.ffn == "moe":
        fl += 2 * D * cfg.n_experts
        fl += 3 * 2 * D * cfg.d_ff * cfg.top_k * cfg.capacity_factor
    return fl


def _layer_list(cfg: ArchConfig):
    n_sb, tail, pattern = cfg.blocks_layout()
    if cfg.n_enc_layers:
        pattern = cfg.dec_pattern()
        n_sb, tail = cfg.n_layers, 0
    return n_sb, tail, pattern


def analytic_step_flops(cfg: ArchConfig, shape: InputShape) -> dict:
    """Global (all-device) FLOPs for one step of the shape's kind."""
    kind = shape.kind
    S, B = shape.seq_len, shape.global_batch
    n_sb, tail, pattern = _layer_list(cfg)
    per_tok = sum(_sublayer_flops_per_token(cfg, s, kind, S) for s in pattern)
    per_tok_tail = sum(_sublayer_flops_per_token(cfg, pattern[i], kind, S)
                       for i in range(tail))
    layers_per_tok = per_tok * n_sb + per_tok_tail
    if cfg.n_enc_layers:
        enc_sub = type(pattern[0])("attn", "dense", "global")
        layers_per_tok += _sublayer_flops_per_token(
            cfg, enc_sub, "prefill", S // 2) * cfg.n_enc_layers

    head = 2 * cfg.d_model * cfg.vocab_size
    if kind == "train":
        tokens = B * S
        fwd = layers_per_tok * tokens + head * tokens
        total = 3.0 * fwd                 # fwd + remat-fwd + dL/dx bwd
    elif kind == "prefill":
        tokens = B * S
        total = layers_per_tok * tokens + head * B
    else:                                 # decode: one token per sequence
        tokens = B
        total = layers_per_tok * tokens + head * B
    return {"flops_global": float(total), "tokens": float(tokens)}


def param_counts(cfg: ArchConfig, abstract_params) -> dict:
    import jax
    total = 0
    expert = 0
    embed_head = 0
    for p, x in jax.tree_util.tree_leaves_with_path(abstract_params):
        n = int(np.prod(x.shape))
        total += n
        path = "/".join(str(getattr(k, "key", k)) for k in p)
        if "experts" in path:
            expert += n
        if path.startswith(("embed/", "lm_head/")):
            embed_head += n
    active = total - expert
    if cfg.n_experts:
        active += expert * cfg.top_k / cfg.n_experts
    return {"n_params": total, "n_active": int(active),
            "n_active_body": int(active - embed_head),
            "embed_head_params": embed_head,
            "expert_params": expert}


def analytic_step_bytes(cfg: ArchConfig, shape: InputShape, n_params: int,
                        n_devices: int, cache_bytes_global: int = 0) -> dict:
    """Per-device HBM traffic model (documented, coarse but stated):

      train:   3 passes over resident params (fwd, remat, bwd)
               + activation traffic ≈ L · T_dev · D · 2B · 12
      prefill: 1 pass over params + activations + cache write
      decode:  1 pass over params + cache read   (weights+cache bound)
    """
    pbytes_dev = n_params * 2 / n_devices * _param_replication(cfg)
    S, B = shape.seq_len, shape.global_batch
    L = cfg.n_layers + cfg.n_enc_layers
    D = cfg.d_model
    if shape.kind == "train":
        t_dev = B * S / n_devices
        act = L * t_dev * D * 2 * 12
        total = 3 * pbytes_dev + act
    elif shape.kind == "prefill":
        t_dev = B * S / n_devices
        act = L * t_dev * D * 2 * 8
        total = pbytes_dev + act + cache_bytes_global / n_devices
    else:
        total = pbytes_dev + cache_bytes_global / n_devices
    return {"hbm_bytes_dev": float(total),
            "param_bytes_dev": float(pbytes_dev)}


def _param_replication(cfg: ArchConfig) -> float:
    """Non-expert params are replicated across the data axes (16×) but the
    per-device RESIDENT bytes are what one step reads — replication factor
    1 for traffic purposes."""
    return 1.0


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)


def roofline_terms(flops_global: float, hbm_bytes_dev: float,
                   coll_bytes_dev: float, n_devices: int) -> Roofline:
    return Roofline(
        compute_s=flops_global / n_devices / PEAK_FLOPS,
        memory_s=hbm_bytes_dev / HBM_BW,
        collective_s=coll_bytes_dev / ICI_BW,
    )
