"""R1 — host-sync-in-jit.

Historical bug: PR 7's zero-cost-telemetry contract.  An ``obs.inc``
call (or any host materialization — ``np.asarray``, ``.item()``,
``jax.device_get``, ``time.perf_counter``) inside a function traced by
``jax.jit`` / ``shard_map`` / ``lax.scan`` either breaks tracing
outright or, worse, silently freezes a trace-time value into the
compiled program and the telemetry counter never moves again.  The
contract is: telemetry rides *replicated metric leaves* through the
carry; host emission happens outside jit.

What gets flagged inside a jit-reachable function body:

* ``np.asarray`` / ``np.array`` / ``np.copy`` (numpy materializes the
  tracer — concretization error or silent constant-folding)
* ``jax.device_get(...)`` and ``.item()`` / ``.tolist()`` /
  ``.block_until_ready()`` method calls
* ``float(x)`` / ``bool(x)`` where ``x`` is a *parameter* of the traced
  function (a tracer for sure; ``float`` of locals is often static
  trace-time math and stays allowed)
* ``obs.<emit>`` calls: inc / set_gauge / observe / event /
  emit_snapshot (``obs.annotate`` is a host-side wrapper and is fine)
* ``time.time`` / ``time.perf_counter`` / ``print``

Suppress with ``# lint: ok[R1] <reason>`` when the call provably runs
at trace time only (e.g. shaping static python config).
"""
from __future__ import annotations

import ast

from .base import Finding, ModuleInfo, Rule, dotted_name, walk_skip_nested

_OBS_EMITS = {"inc", "set_gauge", "observe", "event", "emit_snapshot",
              "to_prometheus"}
_NP_MATERIALIZE = {"asarray", "array", "copy"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}


class HostSyncInJit(Rule):
    code = "R1"
    name = "host-sync-in-jit"
    description = ("host callback / device sync inside a jitted or "
                   "scanned body (breaks the zero-cost telemetry "
                   "contract; freezes trace-time values)")

    def check_module(self, mod: ModuleInfo) -> list[Finding]:
        np_aliases = mod.numpy_aliases()
        obs_aliases = {alias for alias, full in mod.imports.items()
                       if full.endswith(".obs") or full == "repro.obs"
                       or full.endswith("import obs")}
        obs_aliases.add("obs")
        out: list[Finding] = []
        for fn in mod.jit_reachable():
            params = {a.arg for a in fn.args.args + fn.args.posonlyargs
                      + fn.args.kwonlyargs}
            for node in walk_skip_nested(fn):
                if not isinstance(node, ast.Call):
                    continue
                f = self._classify(node, np_aliases, obs_aliases, params)
                if f:
                    out.append(mod.finding(
                        "R1", node,
                        f"{f} inside jit-reachable `{fn.name}` — host "
                        f"sync/callback is forbidden in traced bodies "
                        f"(carry metrics as replicated leaves instead)"))
        return out

    def _classify(self, call: ast.Call, np_aliases, obs_aliases,
                  params) -> str:
        func = call.func
        dotted = dotted_name(func)
        if isinstance(func, ast.Attribute):
            head = dotted.split(".")[0] if dotted else ""
            if head in np_aliases and func.attr in _NP_MATERIALIZE:
                return f"`{dotted}` (numpy materialization)"
            if func.attr in _SYNC_METHODS:
                return f"`.{func.attr}()` (device sync)"
            if dotted in ("jax.device_get",):
                return "`jax.device_get` (device sync)"
            if head in obs_aliases and func.attr in _OBS_EMITS:
                return f"`{dotted}` (telemetry emit)"
            if dotted in ("time.time", "time.perf_counter",
                          "time.monotonic"):
                return f"`{dotted}` (wall clock)"
        elif isinstance(func, ast.Name):
            if func.id == "print":
                return "`print` (host IO)"
            if func.id in ("float", "bool") and call.args and isinstance(
                    call.args[0], ast.Name) and call.args[0].id in params:
                return (f"`{func.id}()` of traced parameter "
                        f"`{call.args[0].id}` (concretization)")
        return ""
