"""Strategy-registry + scanned-round-engine tests.

Covers: scan/per-step parity, registry round-trip for every built-in,
keep-local leaves surviving aggregate AND global-stage rebroadcast, the
FedALT-style dual-adapter baseline, and trimmed-mean robustness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core import methods
from repro.core import peft
from repro.fed.simulate import FedHyper, FedSim
from repro.models.config import ArchConfig
from repro.utils import pytree as pt

CFG = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                 dtype="float32", lora_rank=4, lora_dropout=0.0)


def _batches(C, n, seed=0):
    rng = np.random.default_rng(seed)
    return [{"tokens": jnp.asarray(rng.integers(5, 256, size=(C, 4, 32)),
                                   jnp.int32),
             "loss_mask": jnp.ones((C, 4, 32), jnp.float32)}
            for _ in range(n)]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_roundtrips_every_builtin():
    names = methods.available_methods()
    assert {"fedlora_opt", "lora", "ffa_lora", "fedprox", "prompt",
            "adapter", "fedalt", "lora_trimmed"} <= set(names)
    for name in names:
        m = methods.get_method(name)
        assert m.name == name
        assert callable(m.make_adapter) and callable(m.train_mask)


def test_unknown_method_raises_with_available_list():
    with pytest.raises(ValueError, match="fedlora_opt"):
        methods.get_method("nope")
    with pytest.raises(ValueError, match="already registered"):
        methods.register(methods.get_method("lora"))


def test_duplicate_register_overwrite_roundtrip():
    m = methods.get_method("lora")
    assert methods.register(m, overwrite=True) is m


@pytest.mark.parametrize("name", ["fedalt", "lora_trimmed"])
def test_registry_only_baselines_step_and_aggregate(name):
    """New baselines ride the engine with zero engine changes."""
    hp = FedHyper(method=name, n_clients=4, local_steps=2)
    sim = FedSim(CFG, hp)
    mets = sim.local_round(_batches(4, 2), jax.random.PRNGKey(0))
    assert np.isfinite(mets["ce"]).all()
    sim.aggregate()
    assert sim.comm_bytes > 0


# ---------------------------------------------------------------------------
# scan engine vs per-step reference
# ---------------------------------------------------------------------------

def _assert_adapters_match(sim_a, sim_b, rtol=1e-5, atol=1e-6):
    for path, a, r in zip(pt.tree_paths(sim_a.client_adapters),
                          jax.tree.leaves(sim_a.client_adapters),
                          jax.tree.leaves(sim_b.client_adapters)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=rtol, atol=atol, err_msg=path)


@pytest.mark.slow
@pytest.mark.parametrize("method", methods.available_methods())
def test_scanned_round_matches_reference_every_method(method):
    """Cross-method parity sweep: for EVERY registry entry the single-scan
    round must reproduce the seed-style per-step loop.  (Tolerances are
    the repo's f32 parity bars, not bit-equality: XLA fuses the unrolled
    scan body differently from the standalone jitted step, which moves
    individual f32 values by ~1 ulp on this backend.)"""
    hp = FedHyper(method=method, n_clients=2, local_steps=3, lr=1e-2,
                  prox_mu=0.01)
    b = _batches(2, 3, seed=11)
    rng = jax.random.PRNGKey(5)
    sim_scan, sim_ref = FedSim(CFG, hp), FedSim(CFG, hp)
    sim_scan.local_round(b, rng)
    sim_ref.local_round_reference(b, rng)
    assert int(sim_scan._step) == int(sim_ref._step) == 3
    _assert_adapters_match(sim_scan, sim_ref)


@pytest.mark.slow
@pytest.mark.parametrize("method", ["lora_zeropad", "lora_exact",
                                    "fedlora_opt"])
def test_scanned_round_matches_reference_mixed_rank(method):
    """Parity must also hold for a mixed-rank fleet riding the same
    masked scan (ranks {2, 3, 4} across 3 clients)."""
    hp = FedHyper(method=method, n_clients=3, local_steps=2, lr=1e-2,
                  client_ranks=(2, 3, 4))
    b = _batches(3, 2, seed=13)
    rng = jax.random.PRNGKey(6)
    sim_scan, sim_ref = FedSim(CFG, hp), FedSim(CFG, hp)
    sim_scan.local_round(b, rng)
    sim_ref.local_round_reference(b, rng)
    _assert_adapters_match(sim_scan, sim_ref)


@pytest.mark.parametrize("method", ["fedlora_opt", "fedprox"])
def test_scanned_round_matches_per_step_reference(method):
    """The single-scan round must produce (near-)identical adapters and
    optimizer state to the seed-style per-step host-synced loop."""
    hp = FedHyper(method=method, n_clients=2, local_steps=3, lr=1e-2,
                  prox_mu=0.01)
    b = _batches(2, 3, seed=7)
    rng = jax.random.PRNGKey(3)
    sim_scan, sim_ref = FedSim(CFG, hp), FedSim(CFG, hp)
    sim_scan.local_round(b, rng)
    sim_ref.local_round_reference(b, rng)
    assert int(sim_scan._step) == int(sim_ref._step) == 3
    for path, a, r in zip(pt.tree_paths(sim_scan.client_adapters),
                          jax.tree.leaves(sim_scan.client_adapters),
                          jax.tree.leaves(sim_ref.client_adapters)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=1e-5, atol=1e-6, err_msg=path)
    # and across a second round (step counter continuity)
    b2 = _batches(2, 2, seed=9)
    sim_scan.local_round(b2, rng)
    sim_ref.local_round_reference(b2, rng)
    for a, r in zip(jax.tree.leaves(sim_scan.client_adapters),
                    jax.tree.leaves(sim_ref.client_adapters)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# keep-local rebroadcast
# ---------------------------------------------------------------------------

def _desync(sim):
    sim.client_adapters = jax.tree.map(
        lambda x: x + jnp.arange(x.shape[0], dtype=x.dtype).reshape(
            (-1,) + (1,) * (x.ndim - 1)), sim.client_adapters)


def test_keep_local_regex_survives_aggregate_and_global_stage():
    hp = FedHyper(method="fedlora_opt", n_clients=3, global_steps=2,
                  server_lr=1e-2)
    sim = FedSim(CFG, hp)
    _desync(sim)
    personal = {p: np.asarray(FedSim._leaf(sim.client_adapters, p))
                for p in pt.tree_paths(sim.client_adapters)
                if p.endswith("dB_mag")}
    aggregated = sim.aggregate()
    for p, ref in personal.items():
        np.testing.assert_allclose(
            np.asarray(FedSim._leaf(sim.client_adapters, p)), ref,
            err_msg=f"aggregate clobbered {p}")
    sb = [{k: v[0] for k, v in b.items()} for b in _batches(1, 2, seed=3)]
    sim.global_stage(aggregated, sb, jax.random.PRNGKey(0))
    for p, ref in personal.items():
        np.testing.assert_allclose(
            np.asarray(FedSim._leaf(sim.client_adapters, p)), ref,
            err_msg=f"global_stage rebroadcast clobbered {p}")


def test_fedalt_local_pair_stays_personal_shared_pair_averages():
    hp = FedHyper(method="fedalt", n_clients=3)
    sim = FedSim(CFG, hp)
    _desync(sim)
    before = sim.client_adapters
    aggregated = sim.aggregate()
    after = sim.client_adapters
    # the server-side aggregate never contains the personal pair: the
    # global/eval model is the shared rest-of-world adapter only
    for path in pt.tree_paths(aggregated):
        if path.endswith("local_A") or path.endswith("local_B"):
            assert float(jnp.abs(FedSim._leaf(aggregated, path)).max()) == 0.0
    for path, leaf in zip(pt.tree_paths(after), jax.tree.leaves(after)):
        arr = np.asarray(leaf)
        if path.endswith("local_A") or path.endswith("local_B"):
            np.testing.assert_allclose(
                arr, np.asarray(FedSim._leaf(before, path)), err_msg=path)
        else:
            for c in range(1, arr.shape[0]):
                np.testing.assert_allclose(arr[c], arr[0], rtol=1e-5,
                                           err_msg=path)


def test_fedalt_local_pair_contributes_to_forward():
    from repro.models.layers import lora_delta
    rng = np.random.default_rng(0)
    p = {"lora_A": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
         "lora_B": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
         "local_A": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
         "local_B": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    y = lora_delta(p, x, 2.0)
    y_shared = (x @ p["lora_A"]) @ p["lora_B"] * 2.0
    y_local = (x @ p["local_A"]) @ p["local_B"] * 2.0
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(y_shared + y_local),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# trimmed-mean aggregation
# ---------------------------------------------------------------------------

def test_trimmed_fedavg_drops_outlier_client():
    x = jnp.asarray(np.stack([np.full((3,), v, np.float32)
                              for v in (1.0, 2.0, 3.0, 1e6)]))
    out = agg.trimmed_fedavg({"w": x}, trim_ratio=0.25)["w"]
    np.testing.assert_allclose(np.asarray(out), np.full((3,), 2.5), rtol=1e-6)
    # plain fedavg is destroyed by the same outlier
    assert float(agg.fedavg({"w": x})["w"][0]) > 1e5


def test_trimmed_fedavg_degenerate_falls_back_to_mean():
    x = jnp.asarray([[1.0], [3.0]], jnp.float32)   # C=2: 2k >= C
    out = agg.trimmed_fedavg({"w": x}, trim_ratio=0.5)["w"]
    np.testing.assert_allclose(np.asarray(out), [2.0])


# ---------------------------------------------------------------------------
# heterogeneous-rank fleets (mixed ranks through the one scanned engine)
# ---------------------------------------------------------------------------

HET_RANKS = (2, 4, 8, 2, 4, 8)


def _assert_rank_masked(sim, ranks, tag):
    """Every adapter leaf must be exactly zero above each client's rank."""
    from repro.core import peft as _peft
    for p, leaf in zip(pt.tree_paths(sim.client_adapters),
                       jax.tree.leaves(sim.client_adapters)):
        ax = _peft.rank_axis(p)
        if ax is None:
            continue
        x = np.asarray(leaf)
        axis = x.ndim + ax
        for c, r in enumerate(ranks):
            idx = [slice(None)] * x.ndim
            idx[0], idx[axis] = c, slice(r, None)
            sl = x[tuple(idx)]
            assert sl.size == 0 or np.abs(sl).max() == 0.0, (tag, p, c)


@pytest.mark.slow
@pytest.mark.parametrize("method", ["lora_zeropad", "lora_replication",
                                    "lora_exact", "fedlora_opt"])
def test_mixed_rank_fleet_full_pipeline(method):
    """Ranks {2,4,8} across 6 clients through local_round → aggregate →
    (global_stage) → personalize on the single jitted scan path; rows
    above each client's rank stay exactly zero at every stage."""
    hp = FedHyper(method=method, n_clients=6, local_steps=2,
                  client_ranks=HET_RANKS, global_steps=2, server_lr=1e-2)
    sim = FedSim(CFG, hp)
    assert sim.alloc_rank == max(HET_RANKS)
    mets = sim.local_round(_batches(6, 2, seed=1), jax.random.PRNGKey(1))
    assert np.isfinite(mets["ce"]).all()
    _assert_rank_masked(sim, HET_RANKS, "round")
    aggregated = sim.aggregate()
    _assert_rank_masked(sim, HET_RANKS, "aggregate")
    if methods.get_method(method).pipeline:
        sb = [{k: v[0] for k, v in b.items()} for b in _batches(1, 2, seed=3)]
        aggregated = sim.global_stage(aggregated, sb, jax.random.PRNGKey(0))
        _assert_rank_masked(sim, HET_RANKS, "global_stage")
    sim.personalize(_batches(6, 2, seed=5), jax.random.PRNGKey(2))
    _assert_rank_masked(sim, HET_RANKS, "personalize")


def test_exact_fedavg_engine_delta_matches_oracle():
    """Engine-level acceptance: after a mixed-rank round, lora_exact's
    aggregated delta equals Σ wᵢ·AᵢBᵢ of the client adapters (uniform
    weights) to f32 tolerance, while lora_zeropad's does not — on the
    very same trained fleet.  server_rank=4 ≥ Σ rᵢ makes the truncated
    re-factorization exact."""
    ranks = (1, 1, 2)
    hp = FedHyper(method="lora_exact", n_clients=3, local_steps=3, lr=5e-2,
                  client_ranks=ranks, server_rank=4)
    sim = FedSim(CFG, hp)
    sim.local_round(_batches(3, 3, seed=2), jax.random.PRNGKey(7))
    clients = sim.client_adapters
    aggregated = sim._agg(clients)
    zp = agg.zeropad_fedavg(clients)
    worst_gap = 0.0
    for prefix in {p.rsplit("/", 1)[0]
                   for p in pt.tree_paths(clients) if p.endswith("lora_A")}:
        A = np.asarray(FedSim._leaf(clients, f"{prefix}/lora_A"))
        B = np.asarray(FedSim._leaf(clients, f"{prefix}/lora_B"))
        oracle = np.mean(np.einsum("c...ir,c...ro->c...io", A, B), axis=0)
        A_x = np.asarray(FedSim._leaf(aggregated, f"{prefix}/lora_A"))
        B_x = np.asarray(FedSim._leaf(aggregated, f"{prefix}/lora_B"))
        np.testing.assert_allclose(
            np.einsum("...ir,...ro->...io", A_x, B_x), oracle,
            rtol=1e-4, atol=1e-6, err_msg=prefix)
        A_z = np.asarray(FedSim._leaf(zp, f"{prefix}/lora_A"))
        B_z = np.asarray(FedSim._leaf(zp, f"{prefix}/lora_B"))
        worst_gap = max(worst_gap, float(np.abs(
            np.einsum("...ir,...ro->...io", A_z, B_z) - oracle).max()))
    assert worst_gap > 1e-6, "zeropad accidentally exact — fleet degenerate"


def test_het_comm_accounting_bills_true_ranks():
    """A (2,4,8) fleet must move fewer bytes than three r=8 clients."""
    hp_het = FedHyper(method="lora", n_clients=3, client_ranks=(2, 4, 8))
    hp_uni = FedHyper(method="lora", n_clients=3, client_ranks=(8, 8, 8))
    sim_het, sim_uni = FedSim(CFG, hp_het), FedSim(CFG, hp_uni)
    sim_het.aggregate()
    sim_uni.aggregate()
    assert 0 < sim_het.comm_bytes < sim_uni.comm_bytes
    # (2+4+8)/(8·3) of the uniform bytes — rank-axis leaves are the whole
    # raw-LoRA payload
    assert sim_het.comm_bytes * 24 == sim_uni.comm_bytes * 14


def test_client_ranks_validation():
    with pytest.raises(ValueError, match="het_ranks"):
        FedSim(CFG, FedHyper(method="prompt", n_clients=2,
                             client_ranks=(2, 4)))
    with pytest.raises(ValueError, match="entries"):
        FedSim(CFG, FedHyper(method="lora", n_clients=3, client_ranks=(2, 4)))
    with pytest.raises(ValueError, match=">= 1"):
        FedSim(CFG, FedHyper(method="lora", n_clients=2, client_ranks=(0, 4)))


# ---------------------------------------------------------------------------
# dual-LoRA adapter factory
# ---------------------------------------------------------------------------

def test_add_dual_lora_leaf_layout():
    from repro.models import model as M
    base = M.init_params(jax.random.PRNGKey(0), CFG)
    ad = peft.add_dual_lora(base, CFG, jax.random.PRNGKey(1))
    paths = pt.tree_paths(ad)
    suffixes = {p.rsplit("/", 1)[-1] for p in paths}
    assert suffixes == {"lora_A", "lora_B", "local_A", "local_B"}
    for p in paths:
        if p.endswith("local_B"):
            assert float(jnp.abs(FedSim._leaf(ad, p)).max()) == 0.0
