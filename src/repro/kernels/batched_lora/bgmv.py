"""Pallas TPU kernel: batched-gather LoRA (BGMV, Punica / S-LoRA style).

One mixed batch crosses many tenants: row i of ``x`` carries the tokens
of the tenant whose adapter occupies pool slot ``idx[i]``.  The kernel
computes, per row,

    y[i] = scale · (x[i] @ A[idx[i]]) @ B[idx[i]]

without ever merging an adapter into the backbone and without
materializing gathered per-row adapter copies: the index vector rides in
scalar-prefetch memory, so each grid step's BlockSpec index map selects
the right pool slot and the DMA engine streams exactly one
(d_in, r) + (r, d_out) adapter pair per row into VMEM.

Grid: (B, S/bs) — token blocks innermost, so a row's adapter pair keeps
the same block index across its token blocks and Pallas skips the
re-fetch (revisiting an unchanged block index is a no-op DMA).

A second entry point covers the paper's decomposed-DoRA deployment
shape, where tenants share every *direction* factor and differ only in
the per-rank magnitude vector (ΔB_M — a few hundred bytes per tenant):

    y[i] = scale · (((x[i] ⊙ A_mag) @ A_dir) ⊙ mag[idx[i]]) @ B_dir

Here only the tiny (1, r) magnitude block is gathered per row; the
shared factors load once and stay VMEM-resident across the whole grid.

VMEM working set (bs=256, d=1024, r=16, f32): x(256·1024) + a(1024·16)
+ b(16·1024) + out(256·1024) ≈ 2.2 MB « 16 MB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bgmv_kernel(idx_ref, x_ref, a_ref, b_ref, o_ref, *, scale: float):
    del idx_ref  # consumed by the BlockSpec index maps
    x = x_ref[0]                                          # (bs, d_in)
    h = jax.lax.dot_general(
        x, a_ref[0].astype(x.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bs, r)
    y = jax.lax.dot_general(
        h.astype(x.dtype), b_ref[0].astype(x.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bs, d_out)
    o_ref[0] = (y * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bs", "interpret"))
def bgmv_matmul(x, a_pool, b_pool, idx, *, scale: float = 1.0,
                bs: int = 256, interpret: bool = False):
    """x (B, S, d_in), pools (n_slots, d_in, r) / (n_slots, r, d_out),
    idx (B,) int32 → (B, S, d_out) per-row adapter deltas."""
    B, S, d_in = x.shape
    r = a_pool.shape[-1]
    d_out = b_pool.shape[-1]
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    grid = (B, S // bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, d_in), lambda i, s, idx_ref: (i, s, 0)),
            pl.BlockSpec((1, d_in, r),
                         lambda i, s, idx_ref: (idx_ref[i], 0, 0)),
            pl.BlockSpec((1, r, d_out),
                         lambda i, s, idx_ref: (idx_ref[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, d_out), lambda i, s, idx_ref: (i, s, 0)),
    )
    return pl.pallas_call(
        functools.partial(_bgmv_kernel, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, d_out), x.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), x, a_pool, b_pool)


def _bgmv_mag_kernel(idx_ref, x_ref, adir_ref, amag_ref, mag_ref, bdir_ref,
                     o_ref, *, scale: float):
    del idx_ref
    x = x_ref[0]                                          # (bs, d_in)
    xs = x * amag_ref[...][None, :].astype(x.dtype)
    h = jax.lax.dot_general(
        xs, adir_ref[...].astype(x.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bs, r)
    h = h * mag_ref[0][None, :]
    y = jax.lax.dot_general(
        h.astype(x.dtype), bdir_ref[...].astype(x.dtype),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bs, d_out)
    o_ref[0] = (y * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bs", "interpret"))
def bgmv_mag_matmul(x, a_dir, a_mag, mag_pool, b_dir, idx, *,
                    scale: float = 1.0, bs: int = 256,
                    interpret: bool = False):
    """Decomposed-DoRA magnitude path: shared a_dir (d_in, r) /
    a_mag (d_in,) / b_dir (r, d_out); mag_pool (n_slots, r) gathered
    per row via idx (B,).  x (B, S, d_in) → (B, S, d_out)."""
    B, S, d_in = x.shape
    r = a_dir.shape[-1]
    d_out = b_dir.shape[-1]
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    grid = (B, S // bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, d_in), lambda i, s, idx_ref: (i, s, 0)),
            pl.BlockSpec((d_in, r), lambda i, s, idx_ref: (0, 0)),
            pl.BlockSpec((d_in,), lambda i, s, idx_ref: (0,)),
            pl.BlockSpec((1, r), lambda i, s, idx_ref: (idx_ref[i], 0)),
            pl.BlockSpec((r, d_out), lambda i, s, idx_ref: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, d_out), lambda i, s, idx_ref: (i, s, 0)),
    )
    return pl.pallas_call(
        functools.partial(_bgmv_mag_kernel, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, d_out), x.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), x, a_dir, a_mag.astype(jnp.float32),
      mag_pool.astype(jnp.float32), b_dir)
