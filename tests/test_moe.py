"""MoE routing/dispatch correctness (single device)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import moe_ffn_dense_ref, moe_ffn_local

CFG = ArchConfig(name="m", family="moe", n_layers=2, d_model=32, n_heads=2,
                 n_kv_heads=1, d_ff=64, vocab_size=64, dtype="float32",
                 n_experts=4, top_k=2, capacity_factor=8.0)  # no drops


def _params(rng, cfg, fsplit=1):
    E = cfg.n_experts * fsplit
    F = cfg.d_ff // fsplit
    k = jax.random.split(rng, 4)
    return {
        "router": {"kernel": jax.random.normal(k[0], (cfg.d_model, cfg.n_experts)) * 0.2},
        "experts": {
            "gate": jax.random.normal(k[1], (E, cfg.d_model, F)) * 0.2,
            "up": jax.random.normal(k[2], (E, cfg.d_model, F)) * 0.2,
            "down": jax.random.normal(k[3], (E, F, cfg.d_model)) * 0.2,
        },
    }


def test_grouped_matches_dense_ref_when_no_drops():
    p = _params(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, CFG.d_model))
    y_g, aux_g = moe_ffn_local(p, x, CFG)
    y_d, aux_d = moe_ffn_dense_ref(p, x, CFG)
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_d),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_g), float(aux_d), rtol=1e-5)


def test_capacity_drops_only_reduce_output():
    import dataclasses
    tight = dataclasses.replace(CFG, capacity_factor=0.25)
    p = _params(jax.random.PRNGKey(0), tight)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, CFG.d_model))
    y, _ = moe_ffn_local(p, x, tight)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_fsplit_slot_layout_matches_logical_experts():
    """ep_fsplit=2: two half-d_ff slots per expert must reproduce the
    fsplit=1 output exactly (same logical weights, re-laid-out)."""
    import dataclasses
    cfg1 = CFG
    cfg2 = dataclasses.replace(CFG, ep_fsplit=2)
    p1 = _params(jax.random.PRNGKey(0), cfg1)
    E, D, F = cfg1.n_experts, cfg1.d_model, cfg1.d_ff
    # re-lay gate/up: (E,D,F) → (E,fs,D,F/2) → (2E, D, F/2)
    def relay_up(w):
        return w.reshape(E, D, 2, F // 2).transpose(0, 2, 1, 3).reshape(2 * E, D, F // 2)
    def relay_down(w):
        return w.reshape(E, 2, F // 2, D).reshape(2 * E, F // 2, D)
    p2 = {"router": p1["router"],
          "experts": {"gate": relay_up(p1["experts"]["gate"]),
                      "up": relay_up(p1["experts"]["up"]),
                      "down": relay_down(p1["experts"]["down"])}}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, D))
    y1, _ = moe_ffn_local(p1, x, cfg1)
    y2, _ = moe_ffn_local(p2, x, cfg2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_router_aux_penalizes_imbalance():
    from repro.models.layers import moe_router
    # positive inputs so the +5 column is the max logit for EVERY token
    xt = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (64, CFG.d_model)))
    # balanced-ish random router vs collapsed router
    p_rand = {"router": {"kernel": jax.random.normal(jax.random.PRNGKey(3), (CFG.d_model, 4)) * 0.01}}
    collapse = jnp.zeros((CFG.d_model, 4)).at[:, 0].set(5.0)
    p_coll = {"router": {"kernel": collapse}}
    _, _, aux_r = moe_router(p_rand, xt, CFG, 1)
    _, _, aux_c = moe_router(p_coll, xt, CFG, 1)
    assert float(aux_c) > float(aux_r)
