"""Roofline table from dry-run artifacts (experiments/dryrun/*.json).

One row per (arch × shape × mesh): the three terms, dominant bottleneck,
MODEL_FLOPS/HLO ratio, memory fit.  This is the §Roofline source of truth
— also exported into EXPERIMENTS.md by scripts in launch/report.py.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(path: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs: list[dict]) -> list[str]:
    hdr = (f"{'arch':24s} {'shape':11s} {'mesh':8s} {'ok':3s} "
           f"{'mem GB':>7s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dom':10s} {'useful%':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:24s} {r['shape']:11s} {r['mesh']:8s} "
                         f"ERR {str(r.get('error'))[:60]}")
            continue
        ro = r["roofline"]
        name = r['arch']
        if r.get('variant', 'baseline') != 'baseline':
            name += f"+{r['variant']}"
        lines.append(
            f"{name:24s} {r['shape']:11s} {r['mesh']:8s} "
            f"{'y' if r['fits_16g'] else 'N':3s} "
            f"{r['memory']['peak_estimate_bytes']/1e9:7.2f} "
            f"{ro['compute_s']:10.3e} {ro['memory_s']:10.3e} "
            f"{ro['collective_s']:10.3e} {ro['dominant']:10s} "
            f"{100*ro['useful_flops_ratio']:8.1f}")
    return lines


def main():
    recs = load_records()
    if not recs:
        print("no dry-run records found — run "
              "`python -m repro.launch.dryrun --all` first")
        print("name,us_per_call,derived")
        return []
    for line in table(recs):
        print(line)
    print()
    print("name,us_per_call,derived")
    for r in recs:
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        step_s = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        vtag = "" if r.get("variant", "baseline") == "baseline" \
            else f"+{r['variant']}"
        print(f"roofline/{r['arch']}{vtag}/{r['shape']}/{r['mesh']},"
              f"{step_s*1e6:.1f},"
              f"dom={ro['dominant']};fits={r['fits_16g']};"
              f"useful={ro['useful_flops_ratio']:.3f}")
    return recs


if __name__ == "__main__":
    main()
