"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (attention_ref, flash_attention, fused_dora,
                           fused_dora_ref, ssd_naive, ssd_ref, ssd_scan)

RNG = np.random.default_rng(7)


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("M,K,N,r", [(128, 256, 128, 8), (256, 512, 256, 16),
                                     (64, 128, 384, 4), (128, 128, 128, 32)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_fused_dora_sweep(M, K, N, r, dt):
    x = jnp.asarray(RNG.normal(size=(M, K)), dt)
    w0 = jnp.asarray(RNG.normal(size=(K, N)) * 0.05, dt)
    ad = jnp.asarray(RNG.normal(size=(K, r)) * 0.3, jnp.float32)
    am = jnp.asarray(RNG.uniform(0.5, 1.5, size=(K,)), jnp.float32)
    bd = jnp.asarray(RNG.normal(size=(r, N)) * 0.3, jnp.float32)
    bm = jnp.asarray(RNG.uniform(0.1, 0.5, size=(r,)), jnp.float32)
    dad = jnp.asarray(RNG.normal(size=(K, r)) * 0.05, jnp.float32)
    dbm = jnp.asarray(RNG.normal(size=(r,)) * 0.05, jnp.float32)
    y = fused_dora(x, w0, ad, am, bd, bm, dad, dbm, scale=2.0)
    yr = fused_dora_ref(x, w0, ad, am, bd, bm, dad, dbm, 2.0)
    scale = float(jnp.max(jnp.abs(yr))) + 1e-6
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32) - yr.astype(jnp.float32))))
    tol = 2e-2 if dt == jnp.bfloat16 else 1e-4
    assert err / scale < tol, (err, scale)


def test_linear_fused_flag_matches_jnp_path():
    """layers.linear(fused=True) (ArchConfig.use_fused_dora) must agree
    with the plain jnp base+lora_delta path on decomposed adapters."""
    from repro.models.layers import linear
    p = {"kernel": jnp.asarray(RNG.normal(size=(64, 128)) * 0.05, jnp.float32),
         "A_dir": jnp.asarray(RNG.normal(size=(64, 8)) * 0.3, jnp.float32),
         "A_mag": jnp.asarray(RNG.uniform(0.5, 1.5, size=(64,)), jnp.float32),
         "B_dir": jnp.asarray(RNG.normal(size=(8, 128)) * 0.3, jnp.float32),
         "B_mag": jnp.asarray(RNG.uniform(0.1, 0.5, size=(8,)), jnp.float32),
         "dA_dir": jnp.asarray(RNG.normal(size=(64, 8)) * 0.05, jnp.float32),
         "dB_mag": jnp.asarray(RNG.normal(size=(8,)) * 0.05, jnp.float32)}
    x = jnp.asarray(RNG.normal(size=(2, 16, 64)), jnp.float32)
    y_fused = linear(p, x, lora_scale=2.0, fused=True)
    y_ref = linear(p, x, lora_scale=2.0, fused=False)
    assert y_fused.shape == y_ref.shape == (2, 16, 128)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    # flag is inert for raw-LoRA / plain params
    p_raw = {"kernel": p["kernel"],
             "lora_A": jnp.asarray(RNG.normal(size=(64, 4)), jnp.float32),
             "lora_B": jnp.asarray(RNG.normal(size=(4, 128)), jnp.float32)}
    np.testing.assert_allclose(
        np.asarray(linear(p_raw, x, lora_scale=2.0, fused=True)),
        np.asarray(linear(p_raw, x, lora_scale=2.0, fused=False)))


def test_model_forward_with_use_fused_dora_flag():
    """End-to-end: ArchConfig.use_fused_dora routes the decomposed-LoRA
    projections through the fused kernel with matching loss."""
    import dataclasses
    import jax
    from repro.core import peft
    from repro.models import model as M
    from repro.models.config import ArchConfig
    from repro.utils import pytree as pt
    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=32,
                     n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                     dtype="float32", lora_rank=4, lora_dropout=0.0)
    base = M.init_params(jax.random.PRNGKey(0), cfg)
    ad = peft.add_lora(base, cfg, jax.random.PRNGKey(1), decomposed=True)
    # give B nonzero magnitude so the adapter path actually contributes
    ad = pt.tree_map_with_path(
        lambda p, x: x + 0.3 if p.endswith("B_mag") else x, ad)
    params = pt.merge_trees(base, ad)
    batch = {"tokens": jnp.asarray(RNG.integers(5, 64, size=(2, 16)),
                                   jnp.int32),
             "loss_mask": jnp.ones((2, 16), jnp.float32)}
    loss_ref, _ = M.loss_and_metrics(params, batch, cfg)
    cfg_fused = dataclasses.replace(cfg, use_fused_dora=True)
    loss_fused, _ = M.loss_and_metrics(params, batch, cfg_fused)
    np.testing.assert_allclose(float(loss_fused), float(loss_ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_dora_batched_input():
    x = jnp.asarray(RNG.normal(size=(2, 64, 128)), jnp.float32)
    w0 = jnp.asarray(RNG.normal(size=(128, 128)) * 0.05, jnp.float32)
    ad = jnp.asarray(RNG.normal(size=(128, 8)), jnp.float32)
    am = jnp.ones((128,), jnp.float32)
    bd = jnp.asarray(RNG.normal(size=(8, 128)), jnp.float32)
    bm = jnp.ones((8,), jnp.float32)
    y = fused_dora(x, w0, ad, am, bd, bm)
    assert y.shape == (2, 64, 128)


@pytest.mark.parametrize("case", [
    dict(B=2, Sq=256, Sk=256, H=4, K=2, dh=64, causal=True, window=None),
    dict(B=1, Sq=128, Sk=128, H=4, K=4, dh=32, causal=True, window=48),
    dict(B=2, Sq=256, Sk=256, H=8, K=1, dh=64, causal=False, window=None),
    dict(B=1, Sq=512, Sk=512, H=2, K=2, dh=128, causal=True, window=128),
    dict(B=1, Sq=128, Sk=256, H=2, K=2, dh=64, causal=True, window=None),
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dt):
    c = case
    q = jnp.asarray(RNG.normal(size=(c["B"], c["Sq"], c["H"], c["dh"])), dt)
    k = jnp.asarray(RNG.normal(size=(c["B"], c["Sk"], c["K"], c["dh"])), dt)
    v = jnp.asarray(RNG.normal(size=(c["B"], c["Sk"], c["K"], c["dh"])), dt)
    y = flash_attention(q, k, v, causal=c["causal"], window=c["window"])
    yr = attention_ref(q, k, v, causal=c["causal"], window=c["window"])
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32) - yr.astype(jnp.float32))))
    assert err < (2e-2 if dt == jnp.bfloat16 else 2e-5)


@pytest.mark.parametrize("b,S,H,G,P,N,Q", [
    (2, 64, 4, 2, 16, 8, 16),
    (1, 128, 2, 1, 32, 16, 32),
    (2, 32, 4, 4, 8, 8, 8),
    (1, 64, 2, 2, 16, 16, 64),   # single chunk
])
def test_ssd_scan_sweep(b, S, H, G, P, N, Q):
    x = jnp.asarray(RNG.normal(size=(b, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, S, H)), jnp.float32)
    A_log = jnp.asarray(np.log(RNG.uniform(0.5, 4.0, size=(H,))), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, S, G, N)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, S, G, N)), jnp.float32)
    y_k, st_k = ssd_scan(x, dt, A_log, B, C, chunk=Q)
    y_n, st_n = ssd_naive(x, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_n),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_n),
                               rtol=1e-3, atol=1e-4)


def test_ssd_model_ref_matches_naive():
    b, S, H, G, P, N = 1, 48, 2, 1, 8, 4
    x = jnp.asarray(RNG.normal(size=(b, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.05, 0.3, size=(b, S, H)), jnp.float32)
    A_log = jnp.zeros((H,), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, S, G, N)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, S, G, N)), jnp.float32)
    y_r, st_r = ssd_ref(x, dt, A_log, B, C, 16)
    y_n, st_n = ssd_naive(x, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_n),
                               rtol=1e-3, atol=1e-4)
