from repro.fed.simulate import FedSim, FedHyper  # noqa: F401
from repro.fed.cohort import (ClientBank, CohortSampler,  # noqa: F401
                              CohortSim, FaultPlan)
