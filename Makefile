.PHONY: test perf

# tier-1 verify (ROADMAP.md)
test:
	bash scripts/ci.sh

# fed-round + per-arch microbenchmarks
perf:
	PYTHONPATH=src python -m benchmarks.perf_micro
