"""Federated aggregation strategies (paper Eqs. 5–8 + baselines).

Client adapter trees carry a leading client axis C on every leaf.  Because
the paper's representation *stores* the four D-M components as separate
leaves, the decomposed aggregation of Eqs. 5–8 is exactly "mean every leaf
over the client axis" on that representation — while the raw-LoRA baseline
is the same mean on {lora_A, lora_B}.  The semantic difference the paper
exploits is therefore carried by the *parameterization*, and both
aggregators share one collective (an all-reduce over the client/data axis
on TPU).

Every aggregator takes the client-stacked tree (plus optional weights,
plus ``ranks`` for the rank-aware family) and returns the aggregated
tree without the client axis; communication accounting lives separately
in ``comm_bytes_per_round``.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import pytree as pt

Params = Any


def _mean_over_clients(tree: Params) -> Params:
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


def fedavg(client_adapters: Params, weights=None) -> Params:
    """FedAvg (McMahan et al.): weighted mean over the client axis."""
    if weights is None:
        return _mean_over_clients(client_adapters)
    w = weights / jnp.sum(weights)

    def wmean(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(x * wb, axis=0)

    return jax.tree.map(wmean, client_adapters)


def decomposed_fedavg(client_adapters: Params, weights=None) -> Params:
    """Paper Eqs. 5–8: Ā_D, Ā_M, B̄_M, B̄_D averaged separately.

    On the decomposed representation this is leaf-wise FedAvg; kept as its
    own entry point (a) for intent at call sites, (b) to renormalize
    nothing — the paper averages directions *without* re-normalizing, and
    tests pin that behaviour.
    """
    return fedavg(client_adapters, weights)


def trimmed_fedavg(client_adapters: Params, weights=None, *,
                   trim_ratio: float = 0.25) -> Params:
    """Coordinate-wise trimmed mean over the client axis.

    Robust aggregation (cf. Koo et al., "Towards Robust and Efficient
    Federated Low-Rank Adaptation with Heterogeneous Clients"): per
    coordinate, drop the k lowest and k highest client values with
    k = ⌊trim_ratio · C⌋ and average the rest.  Falls back to the plain
    mean when trimming would leave nothing (2k ≥ C).  ``weights`` are
    ignored — order statistics do not compose with client weighting.
    """
    def tmean(x):
        C = x.shape[0]
        k = int(trim_ratio * C)
        if k == 0 or 2 * k >= C:
            return jnp.mean(x, axis=0)
        xs = jnp.sort(x, axis=0)
        return jnp.mean(xs[k:C - k], axis=0)

    return jax.tree.map(tmean, client_adapters)


# ---------------------------------------------------------------------------
# rank-aware aggregation family (heterogeneous-rank fleets)
# ---------------------------------------------------------------------------
#
# Mixed-rank client adapters live zero-padded at r_max (see
# peft.client_rank_masks).  Three aggregation policies over that layout:
#
#   zeropad_fedavg      the naive baseline: a plain weighted mean IS
#                       zero-pad averaging on padded trees (Koo et al.
#                       show it dilutes high-rank rows);
#   replication_fedavg  rows above a client's rank are treated as absent
#                       rather than zero — each rank row averages only
#                       over the clients that actually own it (the
#                       replication-style re-weighting of Koo et al.);
#   exact_fedavg        reconstructs Σ wᵢ·AᵢBᵢ exactly by stacking the
#                       weighted pairs along the rank axis, then
#                       re-factors to the server rank via truncated SVD
#                       (Nguyen et al.: averaging A and B separately is
#                       NOT the mean of the products).


def zeropad_fedavg(client_adapters: Params, weights=None, *,
                   ranks=None) -> Params:
    """Naive mixed-rank baseline.  ``ranks`` is accepted for the family
    signature but unused — the zero padding above each client's rank does
    the zero-pad averaging by construction."""
    del ranks
    return fedavg(client_adapters, weights)


def _client_weights(x0, weights):
    C = x0.shape[0]
    if weights is None:
        return jnp.full((C,), 1.0 / C, jnp.float32)
    w = jnp.asarray(weights, jnp.float32)
    return w / jnp.sum(w)


def replication_fedavg(client_adapters: Params, weights=None, *,
                       ranks) -> Params:
    """Coverage-weighted mean over the client axis: rank row j of a
    rank-axis leaf averages only the clients with rank > j, so low-rank
    clients never dilute the rows they don't own.  On a uniform-rank
    fleet this reduces exactly to ``fedavg``.  Coverage masks come from
    ``peft.client_rank_masks`` — the one source of truth for which axis
    of each leaf indexes rank (non-rank leaves get all-ones covers, i.e.
    the plain weighted mean)."""
    from repro.core import peft
    leaves = jax.tree.leaves(client_adapters)
    w = _client_weights(leaves[0], weights)
    template = jax.tree.map(lambda x: x[0], client_adapters)
    covers = peft.client_rank_masks(template, ranks)   # (C, 1.., r, ..1)

    def one(x, cover):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        num = jnp.sum(x * cover * wb, axis=0)
        den = jnp.sum(cover * wb, axis=0)
        return jnp.where(den > 0, num / jnp.maximum(den, 1e-12), 0.0)

    return jax.tree.map(one, client_adapters, covers)


def _refactor_pair(a_cat, b_cat, r_out: int):
    """Best rank-``r_out`` factorization of ``a_cat @ b_cat`` via QR-reduced
    SVD.  a_cat (..., d_in, K), b_cat (..., K, d_out) with K = Σ rᵢ; exact
    whenever rank(a_cat @ b_cat) ≤ r_out."""
    qa, ra = jnp.linalg.qr(a_cat)                          # (.., d_in, k)(k, K)
    qb, rb = jnp.linalg.qr(jnp.swapaxes(b_cat, -1, -2))    # (.., d_out, k)
    m = ra @ jnp.swapaxes(rb, -1, -2)                      # (.., k, k)
    u, s, vt = jnp.linalg.svd(m, full_matrices=False)
    k = s.shape[-1]
    take = min(r_out, k)
    root = jnp.sqrt(s[..., :take])
    a_new = (qa @ u[..., :, :take]) * root[..., None, :]
    b_new = root[..., :, None] * (vt[..., :take, :] @ jnp.swapaxes(qb, -1, -2))
    if take < r_out:                                       # pad back to r_out
        pad_a = [(0, 0)] * (a_new.ndim - 1) + [(0, r_out - take)]
        pad_b = ([(0, 0)] * (b_new.ndim - 2)
                 + [(0, r_out - take), (0, 0)])
        a_new, b_new = jnp.pad(a_new, pad_a), jnp.pad(b_new, pad_b)
    return a_new, b_new


def exact_fedavg(client_adapters: Params, weights=None, *, ranks=None,
                 r_out: int | None = None) -> Params:
    """Exact product aggregation for raw-LoRA pairs.

    The weighted sum of client deltas Σ wᵢ·AᵢBᵢ equals the product of the
    client-concatenated factors [w₁A₁ | w₂A₂ | ...] @ [B₁; B₂; ...] — no
    approximation.  That stacked pair (rank Σ rᵢ) is then re-factored to
    ``r_out`` (default: the allocated rank, r_max) by truncated SVD, so
    the aggregated tree keeps the fleet's leaf shapes.  The result is the
    best rank-``r_out`` approximation of the exact mean — and IS the
    exact mean whenever rank(Σ wᵢ·AᵢBᵢ) ≤ r_out.  ``ranks`` is accepted
    for the family signature; padded columns above a client's rank are
    zero and only add zero singular values."""
    del ranks
    leaves = jax.tree.leaves(client_adapters)
    w = _client_weights(leaves[0], weights)
    paths = set(pt.tree_paths(client_adapters))
    a_paths = sorted(p for p in paths if p.endswith("lora_A"))
    if not a_paths or any(p.rsplit("/", 1)[0] + "/lora_B" not in paths
                          for p in a_paths):
        raise ValueError("exact_fedavg needs raw-LoRA {lora_A, lora_B} "
                         "pairs (decomposed/dual trees have no exact "
                         "product aggregation)")

    out = fedavg(client_adapters, w)              # non-pair leaves: mean
    for pa in a_paths:
        prefix = pa.rsplit("/", 1)[0]
        A = pt.tree_get(client_adapters, pa)             # (C, *lead, d_in, r)
        B = pt.tree_get(client_adapters, f"{prefix}/lora_B")
        C = A.shape[0]
        r = r_out or A.shape[-1]
        wa = w.reshape((C,) + (1,) * (A.ndim - 1))
        Aw = A * wa
        # client-major concat along the rank axis via one reshape
        a_cat = jnp.moveaxis(Aw, 0, -2).reshape(
            *A.shape[1:-1], C * A.shape[-1])             # (*lead, d_in, C·r)
        b_cat = jnp.moveaxis(B, 0, -3).reshape(
            *B.shape[1:-2], C * B.shape[-2], B.shape[-1])  # (*lead, C·r, d_out)
        a_new, b_new = _refactor_pair(a_cat, b_cat, r)
        pt.set_leaf(out, pa, a_new.astype(A.dtype))
        pt.set_leaf(out, f"{prefix}/lora_B", b_new.astype(B.dtype))
    return out


# ---------------------------------------------------------------------------
# compressed client→server uplink (COMPRESSED comm class)
# ---------------------------------------------------------------------------
#
# The psum/all_gather classes move full-precision adapters.  The COMPRESSED
# class encodes each client's update *before* the collective and decodes
# server-side, so the uplink bills int8 codes (or a sparse top-k set)
# instead of f32 — the downlink aggregate stays dense f32.  Two codecs:
#
#   q8    stochastic-rounded symmetric int8 with one f32 scale per leaf.
#         Stochastic rounding makes the codec *unbiased* (E[decode] = x
#         per coordinate), so the aggregate error is pure zero-mean
#         rounding noise — the property suite pins both laws.
#   topk  magnitude top-k sparsification (deterministic, biased); k =
#         ⌈topk_ratio·n⌉ per leaf.
#
# Parity contract: the simulator's host aggregate (CompressedFedAvg) and
# the shard_map collective derive the q8 rounding key from the same
# (seed, round step, client index, leaf index) chain, so both engines
# draw bit-identical rounding masks — the dist parity sweep covers the
# compressed methods like every other.


def client_index(axes) -> jnp.ndarray:
    """Linear index of this shard along the stacked client axis inside a
    shard_map manual region — row-major over ``axes``, matching the order
    ``jax.lax.all_gather`` (and the simulator's client stacking) uses."""
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    idx = jnp.zeros((), jnp.int32)
    for ax in axes:
        idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return idx


def _sr_int8_roundtrip(x, key):
    """Stochastically-rounded symmetric int8 encode→decode of one leaf
    (one f32 scale per leaf).  q = ⌊y⌋ + Bernoulli(y − ⌊y⌋) is unbiased
    per coordinate, and an all-zero leaf round-trips to exact zeros (the
    heterogeneous-rank padding rows never pick up noise)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    y = jnp.clip(x.astype(jnp.float32) / scale, -127.0, 127.0)
    lo = jnp.floor(y)
    q = lo + (jax.random.uniform(key, x.shape) < (y - lo))
    return (q * scale).astype(x.dtype)


def _topk_roundtrip(x, ratio: float):
    """Keep the ⌈ratio·n⌉ largest-magnitude coordinates of the leaf, zero
    the rest.  Deterministic (no rng) and biased — the property suite
    bounds its aggregate error instead of an unbiasedness law."""
    k = max(1, int(math.ceil(ratio * x.size)))
    if k >= x.size:
        return x
    flat = x.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat).astype(jnp.float32), k)
    return jnp.zeros_like(flat).at[idx].set(flat[idx]).reshape(x.shape)


def compress_update(adapters: Params, *, mode: str, step=0, client_idx=0,
                    topk_ratio: float = 0.01, seed: int = 0) -> Params:
    """Encode→decode one client's adapter update through the compressed
    uplink.  ``mode`` "q8" draws its stochastic-rounding mask from a key
    chained over (seed, step, client_idx, leaf index) — both engines pass
    the same chain, so their draws match bit-for-bit; "topk" is
    deterministic and ignores the rng inputs."""
    if mode == "topk":
        return jax.tree.map(lambda x: _topk_roundtrip(x, topk_ratio),
                            adapters)
    if mode != "q8":
        raise ValueError(f"unknown compression mode {mode!r} (q8 | topk)")
    base = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), step), client_idx)
    leaves, treedef = jax.tree.flatten(adapters)
    enc = [_sr_int8_roundtrip(x, jax.random.fold_in(base, i))
           for i, x in enumerate(leaves)]
    return jax.tree.unflatten(treedef, enc)


@dataclasses.dataclass(frozen=True)
class CompressedFedAvg:
    """Host aggregate: every client's update rides the compressed uplink
    (``compress_update``) before the weighted mean — the client-stacked
    twin of the COMPRESSED collective.  ``needs_step`` (class attribute)
    tells ``FedSim.aggregate`` to pass its round counter so the q8
    rounding keys match the production engine's."""
    mode: str                     # "q8" | "topk"
    topk_ratio: float = 0.01
    seed: int = 0

    needs_step = True             # no annotation → class attr, not a field

    def __call__(self, client_adapters: Params, weights=None, *,
                 step=0) -> Params:
        C = jax.tree.leaves(client_adapters)[0].shape[0]
        enc = jax.vmap(
            lambda ad, c: compress_update(
                ad, mode=self.mode, step=step, client_idx=c,
                topk_ratio=self.topk_ratio, seed=self.seed)
        )(client_adapters, jnp.arange(C))
        return fedavg(enc, weights)


# ---------------------------------------------------------------------------
# staleness-weighted (FedBuff-style) buffered aggregation
# ---------------------------------------------------------------------------
#
# Async/buffered rounds incorporate updates computed against server models
# that are τ rounds old.  FedBuff (Nguyen et al.) discounts each buffered
# update by a staleness function s(τ); we use the polynomial discount
# s(τ) = (1 + τ)^(−α), which is 1 at τ=0 (a fresh update is a plain
# FedAvg contribution) and decays smoothly — so a synchronous fleet
# (all-zero staleness) reproduces weighted FedAvg *exactly*, which is
# what the parity sweeps exploit.


def staleness_scale(staleness, alpha: float = 0.5):
    """FedBuff polynomial staleness discount s(τ) = (1 + τ)^(−α).
    ``staleness`` is a per-client round count (scalar inside the shard_map
    manual region, a (C,) array on the client-stacked host path)."""
    return jnp.power(1.0 + jnp.asarray(staleness, jnp.float32), -alpha)


@dataclasses.dataclass(frozen=True)
class StalenessFedAvg:
    """Host aggregate: weighted mean with each client's weight discounted
    by its staleness, wᵢ·(1+τᵢ)^(−α) — the client-stacked twin of the
    STALENESS collective.  ``needs_staleness`` (class attribute) tells
    ``FedSim.aggregate`` to thread the per-client staleness vector; with
    no staleness (or all zeros) this IS weighted FedAvg."""
    alpha: float = 0.5

    needs_staleness = True        # no annotation → class attr, not a field

    def __call__(self, client_adapters: Params, weights=None, *,
                 staleness=None) -> Params:
        C = jax.tree.leaves(client_adapters)[0].shape[0]
        w = (jnp.ones((C,), jnp.float32) if weights is None
             else jnp.asarray(weights, jnp.float32))
        if staleness is not None:
            w = w * staleness_scale(staleness, self.alpha)
        return fedavg(client_adapters, w)


def broadcast_to_clients(agg: Params, n_clients: int) -> Params:
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape), agg)


def client_rebroadcast(aggregated: Params, own_adapters: Params,
                       keep_rx=None, cover: Params | None = None) -> Params:
    """One client's view of the rebroadcast aggregate: leaves matching
    the method's keep-local regex retain this client's ``own_adapters``
    values (personalized state never leaves the client), and on a
    heterogeneous fleet the result is re-masked by the client's rank
    ``cover`` — a rank-r client receives the first r rank rows of the
    server model.  This is the per-shard form the production shard_map
    round/pipeline (launch/train.py) applies inside the manual region;
    ``rebroadcast_keep_personal`` is the same logic over a client-stacked
    tree.  ``keep_rx``: compiled pattern or regex string (or None)."""
    out = aggregated
    if keep_rx is not None:
        import re
        rx = re.compile(keep_rx) if isinstance(keep_rx, str) else keep_rx
        out = pt.tree_map_with_path(
            lambda p, leaf: pt.tree_get(own_adapters, p)
            if rx.search(p) else leaf, out)
    if cover is not None:
        out = jax.tree.map(jnp.multiply, out, cover)
    return out


def rebroadcast_keep_personal(aggregated: Params, client_adapters: Params,
                              keep_rx=None,
                              rank_masks: Params | None = None) -> Params:
    """Broadcast the aggregate to every client of a client-stacked tree
    with the engine's keep-local / heterogeneous-re-mask semantics (the
    one place this logic lives — ``FedSim.aggregate``/``global_stage``
    and any host pipeline driver share it; the production shard_map path
    applies the identical per-shard form, ``client_rebroadcast``).
    Leaves matching ``keep_rx`` retain each client's own value; with
    ``rank_masks`` (peft.client_rank_masks) each client is re-masked to
    its own rank."""
    C = jax.tree.leaves(client_adapters)[0].shape[0]
    bcast = broadcast_to_clients(aggregated, C)
    # the stacked broadcast and the client tree line up leaf-for-leaf,
    # so the per-shard restore applies verbatim (keep-local logic lives
    # only in client_rebroadcast)
    bcast = client_rebroadcast(bcast, client_adapters, keep_rx)
    if rank_masks is not None:
        from repro.core import peft
        bcast = peft.apply_rank_masks(bcast, rank_masks)
    return bcast


def comm_bytes_per_round(adapters_one_client: Params,
                         exclude_rx: str | None = None,
                         rank: int | None = None,
                         comm: str = "psum",
                         n_clients: int | None = None,
                         topk_ratio: float = 0.01) -> int:
    """Per-client bytes for one round's aggregation (adapter leaves only
    — the frozen backbone never moves; the PEFT communication story).
    Leaves matching ``exclude_rx`` stay client-local (a method's
    keep-local set, e.g. dB_mag or FedALT's individual pair) and are
    never transmitted, so they don't count.  ``rank``: the client's own
    rank in a heterogeneous fleet — rank-axis leaves are billed at the
    client's rank, not the allocated r_max (padding rows are zero and
    never leave the device).

    ``comm`` is the collective's comm class (``CollectiveAgg.comm``,
    resolved via ``comm_class``), billed per transmitted leaf of n
    elements × ``itemsize`` bytes:

      psum        2·n·itemsize — updates up, aggregate down.
      all_gather  (C+1)·n·itemsize — each client uplinks its adapters
                  once and downlinks all C clients' stacks (the gather
                  methods re-run the host aggregator per client), so
                  ``n_clients`` is required.
      q8          n·1 + 4 up (int8 codes + one f32 scale per leaf),
                  n·itemsize down (the dense f32 aggregate).
      topk        k·(itemsize + 4) up (k = max(1, ⌈topk_ratio·n⌉)
                  value/int32-index pairs), n·itemsize down.
    """
    import re
    from repro.core.peft import rank_axis
    tree = adapters_one_client
    if exclude_rx is not None:
        rx = re.compile(exclude_rx)
        tree = pt.filter_tree(tree, lambda p: not rx.search(p))
    if comm == "all_gather" and n_clients is None:
        raise ValueError("all_gather comm accounting needs n_clients "
                         "(each client downlinks every client's stack)")
    if comm not in ("psum", "all_gather", "q8", "topk"):
        raise ValueError(f"unknown comm class {comm!r} "
                         "(psum | all_gather | q8 | topk)")
    total = 0
    for path, leaf in zip(pt.tree_paths(tree), jax.tree.leaves(tree)):
        shape = list(leaf.shape)
        if rank is not None:
            ax = rank_axis(path)
            if ax is not None:
                shape[leaf.ndim + ax] = min(rank, shape[leaf.ndim + ax])
        n = int(np.prod(shape))
        sz = leaf.dtype.itemsize
        if comm == "psum":
            total += 2 * n * sz
        elif comm == "all_gather":
            total += (n_clients + 1) * n * sz
        elif comm == "q8":
            total += n + 4 + n * sz
        else:                               # topk
            k = max(1, int(math.ceil(topk_ratio * n)))
            total += k * (sz + 4) + n * sz
    return total


def fedavg_excluding(client_adapters: Params, weights=None, *,
                     exclude_rx: str) -> Params:
    """FedAvg that zeroes the mean for leaves matching ``exclude_rx`` —
    those leaves are client-personal and must not appear in the server's
    aggregated/global model (the engine's rebroadcast restores each
    client's own values, so the zeros never reach a client)."""
    import re
    rx = re.compile(exclude_rx)
    out = fedavg(client_adapters, weights)
    return pt.tree_map_with_path(
        lambda p, x: jnp.zeros_like(x) if rx.search(p) else x, out)


def keep_components(tree: Params, component_rx: str) -> Params:
    """Zero out the mean for components that should NOT be aggregated (e.g.
    personalization keeps dB_mag local — it is excluded from averaging)."""
    import re
    rx = re.compile(component_rx)
    return pt.tree_map_with_path(
        lambda p, x: x if rx.search(p) else jnp.zeros_like(x), tree)


def aggregate_with_personal_exclusion(client_adapters: Params,
                                      exclude_rx: str = r"dB_mag$"):
    """Paper pipeline: aggregate everything except the personalized
    magnitude deltas, which stay client-local."""
    import re
    rx = re.compile(exclude_rx)
    agg = _mean_over_clients(client_adapters)
    n = jax.tree.leaves(client_adapters)[0].shape[0]
    bcast = broadcast_to_clients(agg, n)
    return pt.tree_map_with_path(
        lambda p, new_leaf: client_adapters_leaf(p, new_leaf, client_adapters, rx),
        bcast)


def client_adapters_leaf(path, new_leaf, client_adapters, rx):
    if rx.search(path):
        node = client_adapters
        for k in path.split("/"):
            node = node[k]
        return node
    return new_leaf


# ---------------------------------------------------------------------------
# collective forms (the distributed aggregation engine)
# ---------------------------------------------------------------------------
#
# Every aggregator above consumes a *client-stacked* tree — the layout the
# single-process engine (fed/simulate.py) materializes.  The production
# shard_map train step (launch/train.py) never holds that stack: each
# client's adapters live on its own shard, and aggregation must be a
# cross-shard collective issued from inside the manual region.  A
# ``CollectiveAgg`` is that shard_map-expressible form.  Comm classes:
#
#   psum        weighted psum of updates over psum of weights — one
#               all-reduce of adapter bytes.  Covers the whole mean
#               family (fedavg / decomposed / zeropad / excluding) and,
#               with per-row coverage masks, replication_fedavg.
#   all_gather  stack the factors back on every shard, then run the SAME
#               host aggregator the simulator jits (exact_fedavg's
#               QR+truncated-SVD re-factorization, trimmed_fedavg's order
#               statistics — neither is expressible as an all-reduce).
#               C× the comm of psum, compute replicated per shard; the
#               payload is adapter-sized, so both stay trivially small
#               next to one microbatch of activations.
#   q8 / topk   COMPRESSED: encode the update on-shard (compress_update)
#               before a weighted psum of the *decoded* values — the
#               uplink bills int8 codes / a sparse top-k set, the
#               downlink the dense f32 aggregate.
#
# Parity with the host aggregators is by construction for the gather
# class (same function, same bits in) and by algebra for the psum class
# (Σ wᵢxᵢ / Σ wᵢ with w normalized on one side and not the other — equal
# up to f32 rounding, which the 8-device parity sweep pins).


@dataclasses.dataclass(frozen=True)
class CollectiveAgg:
    """A shard_map-expressible collective form of a client aggregator.

    Called inside the manual region with this shard's adapter tree (no
    client axis), the mesh axis names that enumerate clients, this
    client's scalar data weight, and this client's per-leaf rank-coverage
    masks (1.0 everywhere on uniform fleets).  Returns the aggregated
    tree, replicated across shards.
    """
    kind: str            # "wmean" | "coverage" | "staleness" |
                         # "gather_exact" | "gather_trimmed" | "q8" | "topk"
    comm: str            # "psum" | "all_gather" | "q8" | "topk" — comm
                         # class (docs/accounting)
    trim_ratio: float = 0.0
    topk_ratio: float = 0.01
    seed: int = 0
    alpha: float = 0.5   # staleness discount exponent ("staleness" kind)

    def __call__(self, adapters: Params, *, axes, weight, cover=None,
                 step=0, staleness=0.0):
        if self.kind in ("q8", "topk"):
            # encode this client's update before it hits the wire; the
            # weighted psum of decoded updates is then the same algebra
            # as WMEAN over the compressed tree
            enc = compress_update(
                adapters, mode=self.kind, step=step,
                client_idx=client_index(axes),
                topk_ratio=self.topk_ratio, seed=self.seed)
            den = jax.lax.psum(weight, axes)
            return jax.tree.map(
                lambda x: jax.lax.psum(x * weight, axes) / den, enc)
        if self.kind == "wmean":
            den = jax.lax.psum(weight, axes)
            return jax.tree.map(
                lambda x: jax.lax.psum(x * weight, axes) / den, adapters)
        if self.kind == "staleness":
            # FedBuff-style buffered aggregation: this shard's update is
            # discounted by its staleness before the weighted psum — the
            # algebra of WMEAN over the discounted weights
            sw = weight * staleness_scale(staleness, self.alpha)
            den = jax.lax.psum(sw, axes)
            return jax.tree.map(
                lambda x: jax.lax.psum(x * sw, axes) / den, adapters)
        if self.kind == "coverage":
            def one(x, c):
                num = jax.lax.psum(x * c * weight, axes)
                den = jax.lax.psum(c * weight, axes)
                return jnp.where(den > 0, num / jnp.maximum(den, 1e-12), 0.0)
            return jax.tree.map(one, adapters, cover)
        gathered = jax.tree.map(
            lambda x: jax.lax.all_gather(x, axes, axis=0, tiled=False),
            adapters)
        if self.kind == "gather_trimmed":
            return trimmed_fedavg(gathered, trim_ratio=self.trim_ratio)
        if self.kind == "gather_exact":
            w_all = jax.lax.all_gather(weight, axes, axis=0, tiled=False)
            return exact_fedavg(gathered, w_all)
        raise ValueError(f"unknown collective kind {self.kind!r}")


WMEAN = CollectiveAgg(kind="wmean", comm="psum")
COVERAGE = CollectiveAgg(kind="coverage", comm="psum")
GATHER_EXACT = CollectiveAgg(kind="gather_exact", comm="all_gather")
COMPRESSED_Q8 = CollectiveAgg(kind="q8", comm="q8")
STALENESS = CollectiveAgg(kind="staleness", comm="psum")


def gather_trimmed(trim_ratio: float) -> CollectiveAgg:
    return CollectiveAgg(kind="gather_trimmed", comm="all_gather",
                         trim_ratio=trim_ratio)


def compressed_topk(topk_ratio: float) -> CollectiveAgg:
    return CollectiveAgg(kind="topk", comm="topk", topk_ratio=topk_ratio)


def collective_form(method) -> CollectiveAgg:
    """Resolve a FedMethod's collective form.

    An explicit ``method.collective`` wins; otherwise the host aggregate
    fn maps to its known collective.  Raises for aggregators with no
    registered collective form — a method must never silently train with
    different math than the simulator (register a ``CollectiveAgg`` on
    the method to extend the production path).
    """
    if getattr(method, "collective", None) is not None:
        return method.collective
    a = method.aggregate
    if isinstance(a, CompressedFedAvg):
        # the collective inherits the host codec's parameters, so the
        # two engines can never disagree on mode/ratio/seed
        return CollectiveAgg(kind=a.mode, comm=a.mode,
                             topk_ratio=a.topk_ratio, seed=a.seed)
    if isinstance(a, StalenessFedAvg):
        # same inheritance for the staleness discount exponent
        return CollectiveAgg(kind="staleness", comm="psum", alpha=a.alpha)
    if a in (fedavg, decomposed_fedavg, zeropad_fedavg):
        return WMEAN
    if a is replication_fedavg:
        return COVERAGE
    if a is exact_fedavg:
        return GATHER_EXACT
    if isinstance(a, functools.partial) and not a.args:
        # a partial only maps to a collective when every baked-in keyword
        # is one the collective honors — anything else (baked weights, a
        # custom r_out, pre-bound ranks) would make the production path
        # silently train with different math than the simulator
        kw = set(a.keywords)
        if a.func is fedavg_excluding and kw == {"exclude_rx"}:
            # sound only when the excluded leaves are exactly the
            # method's keep-local set: the production step's keep-local
            # restore then overwrites them with each client's own values,
            # so the (never-used) WMEAN of the excluded leaves is
            # harmless.  Any other exclude_rx would silently average
            # leaves the simulator zeroes — refuse those.
            if a.keywords["exclude_rx"] == method.keep_local:
                return WMEAN
        if a.func is trimmed_fedavg and kw <= {"trim_ratio"}:
            return gather_trimmed(a.keywords.get("trim_ratio", 0.25))
        if not kw:
            if a.func in (fedavg, decomposed_fedavg, zeropad_fedavg):
                return WMEAN
            if a.func is replication_fedavg:
                return COVERAGE
            if a.func is exact_fedavg:
                return GATHER_EXACT
    raise ValueError(
        f"method {method.name!r} has no shard_map collective form; set "
        "FedMethod.collective (a core.aggregation.CollectiveAgg) to run "
        "it on the production train step")


def comm_class(method) -> str:
    """The comm class ('psum' | 'all_gather') a method's aggregation
    moves on the wire, for ``comm_bytes_per_round`` accounting.  Resolved
    from the method's collective form; a method with no registered
    collective (simulator-only custom aggregate) bills at the psum rate —
    register a ``FedMethod.collective`` for true gather-class billing."""
    try:
        return collective_form(method).comm
    except ValueError:
        return "psum"


def aggregate_zero_rx(method) -> str | None:
    """Regex of leaves the method's *host* aggregate zeroes in the
    aggregated/global model (``fedavg_excluding``'s client-personal
    leaves), or None.  The production pipeline applies this to its
    collective output so the stage-2 server model matches the
    simulator's aggregate bit-for-bit — the WMEAN collective meaned
    those leaves, and while the keep-local restore hides that from every
    client, the *server* model must not train on it.  An explicit
    ``FedMethod.server_zero_rx`` wins; the built-in fedavg_excluding
    partial is recognized as a fallback (a custom aggregate that zeroes
    leaves any other way must set the field)."""
    explicit = getattr(method, "server_zero_rx", None)
    if explicit is not None:
        return explicit
    a = method.aggregate
    if isinstance(a, functools.partial) and a.func is fedavg_excluding:
        return a.keywords.get("exclude_rx")
    return None
