"""Pure-jnp oracle for the fused DoRA-LoRA linear.

    y = x @ W0  +  scale · ((x ⊙ A_mag) @ (A_dir + dA_dir)) ⊙ (B_mag + dB_mag) @ B_dir

Shapes: x (M, K), W0 (K, N), A_dir (K, r), A_mag (K,), B_dir (r, N),
B_mag (r,).  This is the per-token compute of the paper's Eq. 9/10 weight
composition, applied factor-wise (ΔW is never materialized).
"""
from __future__ import annotations

import jax.numpy as jnp


def fused_dora_ref(x, w0, a_dir, a_mag, b_dir, b_mag, da_dir, db_mag,
                   scale: float):
    f32 = jnp.float32
    y = x.astype(f32) @ w0.astype(f32)
    h = (x.astype(f32) * a_mag.astype(f32)[None, :]) @ (
        a_dir.astype(f32) + da_dir.astype(f32))
    h = h * (b_mag.astype(f32) + db_mag.astype(f32))[None, :]
    y = y + scale * (h @ b_dir.astype(f32))
    return y.astype(x.dtype)
