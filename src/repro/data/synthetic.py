"""Synthetic heterogeneous instruction tasks.

The paper fine-tunes on Databricks-Dolly-15k / Natural-Instructions task
mixtures (causal reasoning, QA, information extraction, ...).  Those
datasets are not available offline, so we build *structured* synthetic
instruction tasks whose answers are computable functions of the context —
a model must actually learn the task to score, and task types differ
enough that client mixtures create genuine statistical heterogeneity
(the paper's "heterogeneous data scenario").

Task types (token-id native; sequences end with  SEP <query> ANS <answer> EOS):

  causal : next-token dynamics from a client-specific permutation table;
           the query is a token, the answer is its successor π(q).
           (stands in for "causal reasoning" — learn the world's rule)
  qa     : context is key/value pairs  k1 v1 k2 v2 ...; query is some ki,
           answer is vi.  (retrieval QA)
  ie     : context is noise with one MARK token followed by an entity;
           answer = the entity.  (information extraction / copying)
  sum    : context tokens are drawn around a theme token that appears most
           often; answer = the theme.  (summarize the gist)

Heterogeneity knobs:
  * per-client task mixture (Dirichlet over the 4 tasks),
  * per-client vocabulary sub-range (domain shift),
  * per-client causal permutation tables (concept shift).

A "dataset family" (dolly-like vs ni-like) fixes the vocab regions and
noise levels so benchmarks can report two dataset columns like Table I.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

PAD, BOS, EOS, SEP, ANS, MARK = 0, 1, 2, 3, 4, 5
N_SPECIAL = 8

TASK_TYPES = ("causal", "qa", "ie", "sum")


@dataclasses.dataclass(frozen=True)
class FamilyConfig:
    name: str
    vocab_size: int = 512
    key_lo: int = N_SPECIAL          # key/entity token range
    key_hi: int = 200
    val_lo: int = 200                # value/answer token range
    val_hi: int = 400
    noise_lo: int = 400              # filler range
    noise_hi: int = 512
    noise_level: float = 0.0         # prob of corrupting a context token
    n_pairs: int = 4                 # qa pairs per example


def make_dataset_family(name: str, vocab_size: int = 512) -> FamilyConfig:
    """Two families mimic the paper's two datasets: 'dolly' (clean, short)
    and 'ni' (noisier, more pairs) — different difficulty profiles."""
    third = (vocab_size - N_SPECIAL) // 3
    if name == "dolly":
        return FamilyConfig(
            name=name, vocab_size=vocab_size,
            key_lo=N_SPECIAL, key_hi=N_SPECIAL + third,
            val_lo=N_SPECIAL + third, val_hi=N_SPECIAL + 2 * third,
            noise_lo=N_SPECIAL + 2 * third, noise_hi=vocab_size,
            noise_level=0.0, n_pairs=4)
    if name == "ni":
        return FamilyConfig(
            name=name, vocab_size=vocab_size,
            key_lo=N_SPECIAL, key_hi=N_SPECIAL + third,
            val_lo=N_SPECIAL + third, val_hi=N_SPECIAL + 2 * third,
            noise_lo=N_SPECIAL + 2 * third, noise_hi=vocab_size,
            noise_level=0.05, n_pairs=6)
    raise ValueError(f"unknown family {name}")


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    name: str
    generate: Callable  # (rng, fam, client_state, seq_len) -> (tokens, loss_mask)


class SyntheticInstructionDataset:
    """Per-client sampler over a task mixture."""

    AUX_LM_WEIGHT = 0.1

    def __init__(self, family: FamilyConfig, task_probs, client_seed: int = 0,
                 pool_size: int = 0, pool_seq_len: int = 48):
        """pool_size > 0 makes the client's TRAINING data finite (the
        paper's setting: a 15k-sample dataset split across clients gives
        each client a small fixed shard) — full-capacity personalization
        can then overfit, which is exactly the failure mode the paper's
        magnitude-only local optimizer avoids.  Eval paths
        (sample_task_batch) always generate fresh held-out samples."""
        self.family = family
        self.task_probs = np.asarray(task_probs, np.float64)
        self.task_probs = self.task_probs / self.task_probs.sum()
        self.client_seed = client_seed
        rng = np.random.default_rng(10_000 + client_seed)
        # client-specific causal permutation over the key range
        n_keys = family.key_hi - family.key_lo
        self.perm = family.val_lo + rng.permutation(
            family.val_hi - family.val_lo)[:n_keys] if n_keys <= (
            family.val_hi - family.val_lo) else family.val_lo + rng.integers(
            0, family.val_hi - family.val_lo, size=n_keys)
        self._pool = None
        if pool_size:
            prng = np.random.default_rng(77_000 + client_seed)
            toks = np.zeros((pool_size, pool_seq_len), np.int32)
            msk = np.zeros((pool_size, pool_seq_len), np.float32)
            tid = np.zeros((pool_size,), np.int32)
            for i in range(pool_size):
                toks[i], msk[i], tid[i] = self._fresh_sample(prng,
                                                             pool_seq_len)
            self._pool = (toks, msk, tid)

    # ---- task generators ------------------------------------------------
    def _gen_causal(self, rng, S):
        f = self.family
        q = rng.integers(f.key_lo, f.key_hi)
        a = self.perm[q - f.key_lo]
        # context: demonstration transitions k -> π(k); the query's own
        # pair is guaranteed present (solvable by induction OR memory)
        ctx = []
        for _ in range((S - 6) // 2 - 1):
            k = rng.integers(f.key_lo, f.key_hi)
            ctx += [k, self.perm[k - f.key_lo]]
        ins = rng.integers(0, max(len(ctx) // 2, 1)) * 2
        ctx = ctx[:ins] + [q, a] + ctx[ins:]
        return self._assemble(rng, ctx, q, a, S)

    def _gen_qa(self, rng, S):
        f = self.family
        ks = rng.choice(np.arange(f.key_lo, f.key_hi), size=f.n_pairs,
                        replace=False)
        vs = rng.integers(f.val_lo, f.val_hi, size=f.n_pairs)
        i = rng.integers(0, f.n_pairs)
        ctx = [t for kv in zip(ks, vs) for t in kv]
        return self._assemble(rng, ctx, int(ks[i]), int(vs[i]), S)

    def _gen_ie(self, rng, S):
        f = self.family
        n_ctx = max(4, S - 6)
        ctx = list(rng.integers(f.noise_lo, f.noise_hi, size=n_ctx))
        ent = int(rng.integers(f.val_lo, f.val_hi))
        pos = rng.integers(0, n_ctx - 1)
        ctx[pos] = MARK
        ctx[pos + 1] = ent
        return self._assemble(rng, ctx, MARK, ent, S)

    def _gen_sum(self, rng, S):
        f = self.family
        theme = int(rng.integers(f.val_lo, f.val_hi))
        n_ctx = max(4, S - 6)
        ctx = list(rng.integers(f.noise_lo, f.noise_hi, size=n_ctx))
        idx = rng.choice(n_ctx, size=max(2, n_ctx // 2), replace=False)
        for j in idx:
            ctx[j] = theme
        return self._assemble(rng, ctx, SEP, theme, S)

    def _assemble(self, rng, ctx, query, answer, S):
        f = self.family
        toks = [BOS] + list(ctx)
        toks = toks[: S - 4]
        if f.noise_level > 0:
            toks = [
                int(rng.integers(f.noise_lo, f.noise_hi))
                if (t > N_SPECIAL and rng.random() < f.noise_level) else t
                for t in toks
            ]
        toks += [SEP, int(query), ANS, int(answer)]
        pad = S - len(toks)
        toks += [EOS] * min(pad, 1) + [PAD] * max(pad - 1, 0)
        toks = np.asarray(toks[:S], np.int32)
        # next-token targets: model predicts toks[1:].  The answer position
        # carries weight 1.0; in-context positions carry a small auxiliary
        # LM weight (dense signal — with only 1/48 supervised tokens the
        # tasks are unlearnable at bench scale).  Accuracy is measured only
        # where mask == 1.0 (see models.loss_and_metrics).
        ans_pos = S - max(pad, 0) - 1
        mask = np.zeros(S, np.float32)
        mask[: ans_pos - 1] = self.AUX_LM_WEIGHT
        mask[ans_pos - 1] = 1.0  # predicting toks[ans_pos]
        return toks, mask

    _GEN = {"causal": _gen_causal, "qa": _gen_qa, "ie": _gen_ie,
            "sum": _gen_sum}

    # ---- public API -------------------------------------------------------
    def _fresh_sample(self, rng: np.random.Generator, seq_len: int):
        t = rng.choice(len(TASK_TYPES), p=self.task_probs)
        name = TASK_TYPES[t]
        toks, mask = self._GEN[name](self, rng, seq_len)
        return toks, mask, t

    def sample(self, rng: np.random.Generator, seq_len: int):
        if self._pool is not None:
            toks, msk, tid = self._pool
            assert seq_len == toks.shape[1], "pool_seq_len mismatch"
            i = rng.integers(0, toks.shape[0])
            return toks[i], msk[i], tid[i]
        return self._fresh_sample(rng, seq_len)

    def sample_batch(self, rng: np.random.Generator, batch: int, seq_len: int):
        toks = np.zeros((batch, seq_len), np.int32)
        mask = np.zeros((batch, seq_len), np.float32)
        tid = np.zeros((batch,), np.int32)
        for b in range(batch):
            toks[b], mask[b], tid[b] = self.sample(rng, seq_len)
        return {"tokens": toks, "loss_mask": mask, "task_id": tid}

    def sample_task_batch(self, rng, batch: int, seq_len: int, task: str):
        toks = np.zeros((batch, seq_len), np.int32)
        mask = np.zeros((batch, seq_len), np.float32)
        for b in range(batch):
            # lint: ok[R3] numpy Generator — stateful, sequential reuse is the API
            toks[b], mask[b] = self._GEN[task](self, rng, seq_len)
        tid = np.full((batch,), TASK_TYPES.index(task), np.int32)
        return {"tokens": toks, "loss_mask": mask, "task_id": tid}
