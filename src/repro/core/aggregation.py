"""Federated aggregation strategies (paper Eqs. 5–8 + baselines).

Client adapter trees carry a leading client axis C on every leaf.  Because
the paper's representation *stores* the four D-M components as separate
leaves, the decomposed aggregation of Eqs. 5–8 is exactly "mean every leaf
over the client axis" on that representation — while the raw-LoRA baseline
is the same mean on {lora_A, lora_B}.  The semantic difference the paper
exploits is therefore carried by the *parameterization*, and both
aggregators share one collective (an all-reduce over the client/data axis
on TPU).

``aggregate`` returns (aggregated_tree_without_client_axis, comm_bytes).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import pytree as pt

Params = Any


def _mean_over_clients(tree: Params) -> Params:
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


def fedavg(client_adapters: Params, weights=None) -> Params:
    """FedAvg (McMahan et al.): weighted mean over the client axis."""
    if weights is None:
        return _mean_over_clients(client_adapters)
    w = weights / jnp.sum(weights)

    def wmean(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(x * wb, axis=0)

    return jax.tree.map(wmean, client_adapters)


def decomposed_fedavg(client_adapters: Params, weights=None) -> Params:
    """Paper Eqs. 5–8: Ā_D, Ā_M, B̄_M, B̄_D averaged separately.

    On the decomposed representation this is leaf-wise FedAvg; kept as its
    own entry point (a) for intent at call sites, (b) to renormalize
    nothing — the paper averages directions *without* re-normalizing, and
    tests pin that behaviour.
    """
    return fedavg(client_adapters, weights)


def trimmed_fedavg(client_adapters: Params, weights=None, *,
                   trim_ratio: float = 0.25) -> Params:
    """Coordinate-wise trimmed mean over the client axis.

    Robust aggregation (cf. Koo et al., "Towards Robust and Efficient
    Federated Low-Rank Adaptation with Heterogeneous Clients"): per
    coordinate, drop the k lowest and k highest client values with
    k = ⌊trim_ratio · C⌋ and average the rest.  Falls back to the plain
    mean when trimming would leave nothing (2k ≥ C).  ``weights`` are
    ignored — order statistics do not compose with client weighting.
    """
    def tmean(x):
        C = x.shape[0]
        k = int(trim_ratio * C)
        if k == 0 or 2 * k >= C:
            return jnp.mean(x, axis=0)
        xs = jnp.sort(x, axis=0)
        return jnp.mean(xs[k:C - k], axis=0)

    return jax.tree.map(tmean, client_adapters)


def broadcast_to_clients(agg: Params, n_clients: int) -> Params:
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape), agg)


def comm_bytes_per_round(adapters_one_client: Params,
                         exclude_rx: str | None = None) -> int:
    """Uplink+downlink bytes for one client-round (adapter leaves only —
    the frozen backbone never moves; the PEFT communication story).
    Leaves matching ``exclude_rx`` stay client-local (a method's
    keep-local set, e.g. dB_mag or FedALT's individual pair) and are
    never transmitted, so they don't count."""
    import re
    tree = adapters_one_client
    if exclude_rx is not None:
        rx = re.compile(exclude_rx)
        tree = pt.filter_tree(tree, lambda p: not rx.search(p))
    return 2 * pt.tree_bytes(tree)


def fedavg_excluding(client_adapters: Params, weights=None, *,
                     exclude_rx: str) -> Params:
    """FedAvg that zeroes the mean for leaves matching ``exclude_rx`` —
    those leaves are client-personal and must not appear in the server's
    aggregated/global model (the engine's rebroadcast restores each
    client's own values, so the zeros never reach a client)."""
    import re
    rx = re.compile(exclude_rx)
    out = fedavg(client_adapters, weights)
    return pt.tree_map_with_path(
        lambda p, x: jnp.zeros_like(x) if rx.search(p) else x, out)


def keep_components(tree: Params, component_rx: str) -> Params:
    """Zero out the mean for components that should NOT be aggregated (e.g.
    personalization keeps dB_mag local — it is excluded from averaging)."""
    import re
    rx = re.compile(component_rx)
    return pt.tree_map_with_path(
        lambda p, x: x if rx.search(p) else jnp.zeros_like(x), tree)


def aggregate_with_personal_exclusion(client_adapters: Params,
                                      exclude_rx: str = r"dB_mag$"):
    """Paper pipeline: aggregate everything except the personalized
    magnitude deltas, which stay client-local."""
    import re
    rx = re.compile(exclude_rx)
    agg = _mean_over_clients(client_adapters)
    n = jax.tree.leaves(client_adapters)[0].shape[0]
    bcast = broadcast_to_clients(agg, n)
    return pt.tree_map_with_path(
        lambda p, new_leaf: client_adapters_leaf(p, new_leaf, client_adapters, rx),
        bcast)


def client_adapters_leaf(path, new_leaf, client_adapters, rx):
    if rx.search(path):
        node = client_adapters
        for k in path.split("/"):
            node = node[k]
        return node
    return new_leaf
