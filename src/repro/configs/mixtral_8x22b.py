"""Mixtral 8x22B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088].  ep_fsplit=2: the 8 experts are stored as 16 physical
half-d_ff slots so expert-parallelism matches the 16-wide data axis
(DESIGN.md §7)."""
from repro.models.config import ArchConfig, reduced

ARCH = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32768,
    n_experts=8, top_k=2, ep_fsplit=2,
    sliding_window=4096,
    source="arXiv:2401.04088",
)
SMOKE = reduced(ARCH)
