"""Roofline table from dry-run artifacts (experiments/dryrun/*.json).

One row per (arch × shape × mesh): the three terms, dominant bottleneck,
MODEL_FLOPS/HLO ratio, memory fit.  This is the §Roofline source of truth
— also exported into EXPERIMENTS.md by scripts in launch/report.py.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(path: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs: list[dict]) -> list[str]:
    hdr = (f"{'arch':24s} {'shape':11s} {'mesh':8s} {'ok':3s} "
           f"{'mem GB':>7s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dom':10s} {'useful%':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:24s} {r['shape']:11s} {r['mesh']:8s} "
                         f"ERR {str(r.get('error'))[:60]}")
            continue
        ro = r["roofline"]
        name = r['arch']
        if r.get('variant', 'baseline') != 'baseline':
            name += f"+{r['variant']}"
        lines.append(
            f"{name:24s} {r['shape']:11s} {r['mesh']:8s} "
            f"{'y' if r['fits_16g'] else 'N':3s} "
            f"{r['memory']['peak_estimate_bytes']/1e9:7.2f} "
            f"{ro['compute_s']:10.3e} {ro['memory_s']:10.3e} "
            f"{ro['collective_s']:10.3e} {ro['dominant']:10s} "
            f"{100*ro['useful_flops_ratio']:8.1f}")
    return lines


def quant_decode_table() -> list[str]:
    """Analytic batch-1 decode roofline for the quantized backbone on
    the serve bench config: decode reads every live weight byte once per
    token, so step time is tree_bytes/HBM_BW and the f32/int8 byte ratio
    IS the bandwidth-bound decode speedup (adapters + logit-critical
    leaves stay f32; see docs/quantization.md)."""
    import jax

    from benchmarks.common import BENCH_CFG
    from repro.kernels.quant_matmul.ops import quantize_backbone
    from repro.launch.analysis import HBM_BW
    from repro.models import model as M
    from repro.utils import pytree as pt

    base = M.init_params(jax.random.PRNGKey(0), BENCH_CFG)
    f32 = pt.tree_bytes(base)
    lines = [f"{'decode backbone':24s} {'bytes':>10s} {'step_s':>10s} "
             f"{'speedup':>8s}   (batch-1, weight-bytes-bound)"]
    for mode, tree in [("f32", base),
                       ("int8", quantize_backbone(base, "int8")),
                       ("int4", quantize_backbone(base, "int4"))]:
        b = pt.tree_bytes(tree)
        lines.append(f"{BENCH_CFG.name + '/' + mode:24s} {b:10d} "
                     f"{b / HBM_BW:10.3e} {f32 / b:7.2f}x")
    return lines


def main():
    recs = load_records()
    print()
    for line in quant_decode_table():
        print(line)
    print()
    if not recs:
        print("no dry-run records found — run "
              "`python -m repro.launch.dryrun --all` first")
        print("name,us_per_call,derived")
        return []
    for line in table(recs):
        print(line)
    print()
    print("name,us_per_call,derived")
    for r in recs:
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        step_s = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        vtag = "" if r.get("variant", "baseline") == "baseline" \
            else f"+{r['variant']}"
        print(f"roofline/{r['arch']}{vtag}/{r['shape']}/{r['mesh']},"
              f"{step_s*1e6:.1f},"
              f"dom={ro['dominant']};fits={r['fits_16g']};"
              f"useful={ro['useful_flops_ratio']:.3f}")
    return recs


if __name__ == "__main__":
    main()
