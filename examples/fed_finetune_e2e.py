"""End-to-end federated fine-tuning driver.

    PYTHONPATH=src python examples/fed_finetune_e2e.py [--profile 25m|100m]
        [--rounds 8] [--pretrain-steps 300]

Full path: backbone pretraining → heterogeneous client split (one task
per client, like the paper) → FedLoRA-Optimizer rounds (stage-1 local,
Eqs. 5-8 aggregation, stage-2 global ΔA_D) → stage-3 ΔB_M
personalization → eval table + checkpoint.

The 100m profile is the deliverable-scale run (~95 M params — budget a
few hours on this 1-core container); 25m is the default demonstrator.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint import save_checkpoint  # noqa: E402
from repro.core.fedlora import run_federated  # noqa: E402
from repro.data.loader import eval_batches  # noqa: E402
from repro.data.synthetic import (SyntheticInstructionDataset,  # noqa: E402
                                  make_dataset_family)
from repro.fed.pretrain import get_pretrained_base  # noqa: E402
from repro.fed.simulate import FedHyper  # noqa: E402
from repro.models.config import ArchConfig  # noqa: E402

PROFILES = {
    "25m": ArchConfig(name="e2e-25m", family="dense", n_layers=6,
                      d_model=384, n_heads=6, n_kv_heads=2, d_ff=1536,
                      vocab_size=2048, dtype="float32", lora_rank=8,
                      lora_dropout=0.0),
    "100m": ArchConfig(name="e2e-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                       vocab_size=8192, dtype="float32", lora_rank=8,
                       lora_dropout=0.0),
}
TASKS = ("causal", "qa", "ie")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="25m", choices=PROFILES)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--pretrain-steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = PROFILES[args.profile]
    from repro.utils.pytree import tree_count_params
    fam = make_dataset_family("dolly", vocab_size=cfg.vocab_size)
    mix = SyntheticInstructionDataset(fam, [1 / 3, 1 / 3, 1 / 3, 0],
                                      client_seed=0)
    t0 = time.time()
    base = get_pretrained_base(cfg, mix, steps=args.pretrain_steps, log=print)
    print(f"backbone: {tree_count_params(base)/1e6:.1f} M params "
          f"(pretrain {time.time()-t0:.0f}s)")

    from repro.data.synthetic import TASK_TYPES
    cds = [SyntheticInstructionDataset(
        fam, [1.0 if t == TASKS[c] else 0.0 for t in TASK_TYPES],
        client_seed=0) for c in range(3)]
    eg = eval_batches(mix, 32, args.seq, 4)
    rng = np.random.default_rng(1)
    el = []
    for _ in range(3):
        outs = [d.sample_batch(rng, 32, args.seq) for d in cds]
        el.append({k: jnp.asarray(np.stack([o[k] for o in outs]))
                   for k in outs[0]})

    hp = FedHyper(method="fedlora_opt", n_clients=3, rounds=args.rounds,
                  local_steps=5, batch=8, seq_len=args.seq, lr=2e-3,
                  server_lr=5e-4, global_steps=3, personal_steps=20,
                  lam=1e-3)
    res = run_federated(cfg, hp, cds, mix, eg, el, base=base, log=print)
    print("\n=== results ===")
    print(f"global model acc : {res.global_acc:.3f}")
    print(f"personalized acc : {res.local_acc:.3f}")
    for c, a in enumerate(res.per_client):
        print(f"  client {c} ({TASKS[c]}): {a:.3f}")
    print(f"adapter comm     : {res.comm_bytes/1e6:.2f} MB "
          f"over {args.rounds} rounds")
    save_checkpoint(f"experiments/e2e_{args.profile}.msgpack",
                    {"history": jnp.asarray([h['acc'] for h in res.history])})
    print("history checkpoint → experiments/")


if __name__ == "__main__":
    main()
