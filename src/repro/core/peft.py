"""PEFT adapter zoo: param-tree transformations over the shared backbone.

Each ``add_*`` function returns an *adapter tree*: a sparse overlay pytree
whose leaves sit at the same paths the model's ``linear`` consults
(``.../q_proj/lora_A`` etc.).  ``merge_trees(base, adapters)`` produces the
full forward params.  Keeping adapters separate is what makes the
federated runtime cheap: only the overlay is vmapped per client,
aggregated, and communicated.

Methods:
  lora            raw LoRA (baseline; FedIT-style federated averaging)
  dora_lora       DoRA-decomposed LoRA — the paper's representation:
                  {A_dir, A_mag, B_dir, B_mag, dA_dir, dB_mag}
  prompt          prompt-tuning (Lester et al.)
  adapter         Houlsby bottleneck adapters after each dense FFN
  ffa_lora        raw LoRA with A frozen (Sun et al.) — via trainable mask
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import dora
from repro.models.config import ArchConfig
from repro.utils import pytree as pt

Params = Any

_KERNEL_RX = re.compile(r"(?P<proj>[a-zA-Z0-9_]+)/kernel$")


def _target_kernels(base: Params, targets) -> list[tuple[str, Any]]:
    out = []
    for path in pt.tree_paths(base):
        m = _KERNEL_RX.search(path)
        if m and m.group("proj") in targets:
            # fetch leaf
            node = base
            for k in path.split("/"):
                node = node[k]
            out.append((path, node))
    return out


_set_path = pt.set_leaf


def add_lora(base: Params, cfg: ArchConfig, rng, *, decomposed: bool = False,
             rank: int = 0) -> Params:
    """Build the adapter overlay for every target projection.

    Raw LoRA init: A ~ N(0, 1/r), B ~ N(0, 1e-3) — B near-zero so the
    initial ΔW ≈ 0 (can't be exactly 0 or its D-M decomposition is
    undefined).

    Decomposed (DoRA-faithful) init: B_dir is a random *unit-norm*
    direction and B_mag = 0, so ΔW = 0 exactly at init.  This matters for
    the paper's training dynamics: the gradient w.r.t. B_dir scales with
    B_mag, so early training pours task energy into the *magnitude* of B
    while its direction stays near init — the asymmetry behind the paper's
    Obs. 1/2 (a near-zero random B instead makes its direction maximally
    plastic and inverts the measurement; see DESIGN.md §10).
    """
    r = rank or cfg.lora_rank
    overlay: dict = {}
    for i, (path, kern) in enumerate(_target_kernels(base, cfg.lora_targets)):
        *lead, d_in, d_out = kern.shape
        k1, k2 = jax.random.split(jax.random.fold_in(rng, i))
        A = jax.random.normal(k1, (*lead, d_in, r), jnp.float32) / jnp.sqrt(r)
        rawB = jax.random.normal(k2, (*lead, r, d_out), jnp.float32)
        B = rawB * 1e-3
        prefix = path.rsplit("/", 1)[0]
        if decomposed:
            A_mag, A_dir = dora.decompose(A)
            _, B_dir = dora.decompose(rawB)
            B_mag = jnp.zeros((*lead, r), jnp.float32)
            _set_path(overlay, f"{prefix}/A_dir", A_dir)
            _set_path(overlay, f"{prefix}/A_mag", A_mag)
            _set_path(overlay, f"{prefix}/B_dir", B_dir)
            _set_path(overlay, f"{prefix}/B_mag", B_mag)
            _set_path(overlay, f"{prefix}/dA_dir", jnp.zeros_like(A_dir))
            _set_path(overlay, f"{prefix}/dB_mag", jnp.zeros_like(B_mag))
        else:
            _set_path(overlay, f"{prefix}/lora_A", A)
            _set_path(overlay, f"{prefix}/lora_B", B)
    return overlay


def add_dual_lora(base: Params, cfg: ArchConfig, rng, *,
                  rank: int = 0) -> Params:
    """FedALT-style dual adapters on every target projection.

    The shared "rest-of-world" pair {lora_A, lora_B} is aggregated like
    raw LoRA; the individual pair {local_A, local_B} carries the client's
    personal delta and never leaves the client (the method's keep-local
    regex excludes it from rebroadcast).  local_B starts at exact zero so
    the personal delta is 0 at init — these leaves are never D-M
    decomposed, so the raw-LoRA near-zero trick is unnecessary.
    """
    r = rank or cfg.lora_rank
    r_shared, r_local = jax.random.split(rng)
    overlay = add_lora(base, cfg, r_shared, decomposed=False, rank=r)
    for i, (path, kern) in enumerate(_target_kernels(base, cfg.lora_targets)):
        *lead, d_in, d_out = kern.shape
        k1, _ = jax.random.split(jax.random.fold_in(r_local, i))
        A = jax.random.normal(k1, (*lead, d_in, r), jnp.float32) / jnp.sqrt(r)
        prefix = path.rsplit("/", 1)[0]
        _set_path(overlay, f"{prefix}/local_A", A)
        _set_path(overlay, f"{prefix}/local_B",
                  jnp.zeros((*lead, r, d_out), jnp.float32))
    return overlay


def add_prompt_tuning(base: Params, cfg: ArchConfig, rng,
                      n_prompt: int = 16) -> Params:
    return {"prompt_embed": jax.random.normal(
        rng, (n_prompt, cfg.d_model), jnp.float32) * 0.02}


def add_adapter_tuning(base: Params, cfg: ArchConfig, rng,
                       bottleneck: int = 16) -> Params:
    """Houlsby bottleneck after each dense FFN (``mlp`` dicts)."""
    overlay: dict = {}
    i = 0
    for path in pt.tree_paths(base):
        m = re.search(r"(.*mlp)/down_proj/kernel$", path)
        if not m:
            continue
        node = base
        for k in path.split("/"):
            node = node[k]
        *lead, _, d_out = node.shape
        k1, k2 = jax.random.split(jax.random.fold_in(rng, 7000 + i))
        i += 1
        down = jax.random.normal(k1, (*lead, d_out, bottleneck), jnp.float32) * 0.02
        up = jnp.zeros((*lead, bottleneck, d_out), jnp.float32)
        _set_path(overlay, f"{m.group(1)}/adapter_down", down)
        _set_path(overlay, f"{m.group(1)}/adapter_up", up)
    return overlay


# ---------------------------------------------------------------------------
# heterogeneous ranks (per-client / per-tenant adapter capacity)
# ---------------------------------------------------------------------------
#
# Mixed-rank fleets keep every adapter tree allocated at r_max so the
# client axis stays stackable (one vmapped/scanned program for the whole
# fleet); a per-leaf *rank mask* zeroes the rows/columns above each
# client's own rank.  The table below is the single source of truth for
# which axis of each adapter leaf is the rank axis.

_RANK_AXIS = {
    "lora_A": -1, "local_A": -1, "A_dir": -1, "dA_dir": -1,
    "lora_B": -2, "local_B": -2, "B_dir": -2,
    "B_mag": -1, "dB_mag": -1,
}


def rank_axis(path: str) -> int | None:
    """Which axis of the adapter leaf at ``path`` indexes LoRA rank
    (negative, relative to the per-client leaf), or None for leaves with
    no rank dimension (A_mag, prompt embeddings, bottleneck adapters)."""
    return _RANK_AXIS.get(path.rsplit("/", 1)[-1])


def fleet_alloc_rank(client_ranks, n_clients: int,
                     server_rank: int = 0) -> int:
    """Validate a heterogeneous fleet's per-client ranks and return the
    allocation rank (server_rank, or the fleet max when 0).  The one
    source of truth for fleet-shape errors — shared by the simulator
    (fed/simulate.py) and the production train step (launch/train.py) so
    both paths reject the same bad fleets with the same message."""
    client_ranks = tuple(int(r) for r in client_ranks)
    if len(client_ranks) != n_clients:
        raise ValueError(
            f"client_ranks has {len(client_ranks)} entries for "
            f"{n_clients} clients")
    if min(client_ranks) < 1:
        raise ValueError(f"client ranks must be >= 1, got {client_ranks}")
    alloc = int(server_rank or max(client_ranks))
    if alloc < max(client_ranks):
        raise ValueError(
            f"server_rank {server_rank} is below the fleet max "
            f"{max(client_ranks)}")
    return alloc


def validate_client_weights(client_weights, n_clients: int) -> None:
    """Validate per-client data-size aggregation weights — shared by the
    simulator (FedHyper.client_weights) and the production train step
    (TrainSettings.client_weights) so both reject the same bad fleets."""
    if len(client_weights) != n_clients:
        raise ValueError(
            f"client_weights has {len(client_weights)} entries for "
            f"{n_clients} clients")
    if min(client_weights) <= 0:
        raise ValueError(
            f"client weights must be > 0, got {tuple(client_weights)}")


def client_rank_masks(adapters: Params, ranks) -> Params:
    """Per-client 0/1 masks over the rank axis of every adapter leaf.

    ``ranks`` is a (C,) int array of per-client ranks; the returned pytree
    matches ``broadcast_to_clients(adapters, C)`` under broadcasting: each
    leaf has shape (C, 1, ..., r, ..., 1) with 1.0 where the rank index is
    below the client's rank and 0.0 above.  Multiplying client-stacked
    adapters (or their updates) by these masks is what lets a mixed-rank
    fleet ride one jitted ``lax.scan``."""
    ranks = jnp.asarray(ranks, jnp.int32)
    C = ranks.shape[0]

    def one(path, x):
        ax = rank_axis(path)
        if ax is None:
            return jnp.ones((C,) + (1,) * x.ndim, jnp.float32)
        ax_abs = x.ndim + ax                       # absolute, per-client leaf
        r = x.shape[ax_abs]
        shape = [1] * (x.ndim + 1)
        shape[ax_abs + 1] = r
        keep = (jnp.arange(r).reshape(shape)
                < ranks.reshape((C,) + (1,) * x.ndim))
        return keep.astype(jnp.float32)

    return pt.tree_map_with_path(one, adapters)


def apply_rank_masks(client_adapters: Params, masks: Params) -> Params:
    """Zero the rows above each client's rank (masks broadcast per leaf)."""
    return jax.tree.map(jnp.multiply, client_adapters, masks)


# ---------------------------------------------------------------------------
# trainable masks (drive optim.masked + the paper's stage pipeline)
# ---------------------------------------------------------------------------

def mask_all(adapters: Params) -> Params:
    return pt.path_mask(adapters, lambda p: True)


def mask_stage_local_pretrain(adapters: Params) -> Params:
    """Stage 1 — client LoRA fine-tune: train the base components, not the
    pipeline deltas (dA_dir / dB_mag stay zero until their stages)."""
    return pt.path_mask(adapters, lambda p: not re.search(r"d[AB]_(dir|mag)", p))


def mask_stage_global(adapters: Params) -> Params:
    """Stage 2 — global optimizer: ΔA_D only (paper Eq. 9)."""
    return pt.path_mask(adapters, lambda p: p.endswith("dA_dir"))


def mask_stage_local(adapters: Params) -> Params:
    """Stage 3 — local optimizer: ΔB_M only (paper Eq. 10/11)."""
    return pt.path_mask(adapters, lambda p: p.endswith("dB_mag"))


def mask_ffa(adapters: Params) -> Params:
    """FFA-LoRA: freeze A, train B only."""
    return pt.path_mask(adapters, lambda p: p.endswith("lora_B"))


def reg_mask_dB(adapters: Params) -> Params:
    return pt.path_mask(adapters, lambda p: p.endswith("dB_mag"))
