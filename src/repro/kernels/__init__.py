"""Pallas TPU kernels (validated in interpret mode on CPU).

  fused_dora       — base matmul + DoRA-decomposed LoRA adapter, one pass
  flash_attention  — causal/sliding-window online-softmax attention, GQA
  ssd_scan         — Mamba-2 SSD chunked scan with VMEM-resident state
  batched_lora     — BGMV: per-row adapter gather for mixed-tenant serving
  quant_matmul     — dequant-fused int8/int4 backbone matmul for serving
"""
from repro.kernels.fused_dora.ops import fused_dora, fused_dora_ref  # noqa: F401
from repro.kernels.flash_attention.ops import flash_attention, attention_ref  # noqa: F401
from repro.kernels.ssd_scan.ops import ssd_scan, ssd_ref, ssd_naive  # noqa: F401
from repro.kernels.batched_lora.ops import (bgmv, bgmv_mag,  # noqa: F401
                                            bgmv_mag_ref, bgmv_ref)
from repro.kernels.quant_matmul.ops import (dequantize,  # noqa: F401
                                            quant_matmul, quant_matmul_ref,
                                            quantize_backbone, quantize_int4,
                                            quantize_int8, unpack_int4)
