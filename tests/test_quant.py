"""Quantized-backbone numerics (tentpole a of the quantized hot paths).

Covers the int8/int4 weight codecs (round-trip error within half a
quantization bin, exact zeros, pack/unpack inverses), the dequant-fused
Pallas matmul against its XLA oracle — forced through the kernel body
with ``impl="interpret"`` on CPU — including shapes that exercise the
pad-and-slice grid path, the ``quantize_backbone`` leaf-coverage
contract (projection kernels quantize, logit-critical leaves stay f32),
and end-to-end quantized serving: bit-exact engine↔reference parity on
the same quantized tree plus bounded logit drift vs the f32 backbone.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given_seeds

from repro.kernels.quant_matmul.ops import quant_matmul, quantize_backbone
from repro.kernels.quant_matmul.ref import (dequantize, quant_matmul_ref,
                                            quantize_int4, quantize_int8,
                                            unpack_int4)
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.utils import pytree as pt

CFG = ArchConfig(name="quant-t", family="dense", n_layers=2, d_model=32,
                 n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                 dtype="float32", lora_rank=4, lora_dropout=0.0)
RNG = np.random.default_rng(7)


def _w(d_in, d_out, seed=0):
    return np.random.default_rng(seed).normal(
        size=(d_in, d_out)).astype(np.float32) * 0.1


# ---------------------------------------------------------------------------
# codec round-trips
# ---------------------------------------------------------------------------

@given_seeds()
def test_int8_roundtrip_within_half_bin(seed):
    """Round-to-nearest: |dequant(quantize(w)) − w| ≤ scale/2 everywhere,
    per-channel and per-group."""
    w = _w(32, 24, seed)
    for gs in (None, 8):
        q, s = quantize_int8(w, group_size=gs)
        assert q.dtype == jnp.int8 and s.shape == ((1, 24) if gs is None
                                                  else (4, 24))
        err = np.abs(np.asarray(dequantize(q, s)) - w)
        bound = np.repeat(np.asarray(s), 32 // s.shape[0], axis=0) / 2
        assert (err <= bound + 1e-7).all()


@given_seeds()
def test_int4_roundtrip_within_half_bin(seed):
    w = _w(32, 24, seed)
    for gs in (None, 16):
        q, s = quantize_int4(w, group_size=gs)
        assert q.dtype == jnp.uint8 and q.shape == (16, 24)
        codes = np.asarray(unpack_int4(q))
        assert codes.shape == (32, 24)
        assert codes.min() >= -7 and codes.max() <= 7
        err = np.abs(np.asarray(dequantize(q, s)) - w)
        bound = np.repeat(np.asarray(s), 32 // s.shape[0], axis=0) / 2
        assert (err <= bound + 1e-7).all()


def test_zero_channels_dequantize_to_exact_zero():
    """The scale floor keeps all-zero channels exactly zero through the
    round-trip — rank-masked rows must survive quantization bit-for-bit."""
    w = _w(16, 8, 3)
    w[:, -2:] = 0.0
    for quant in (quantize_int8, quantize_int4):
        out = np.asarray(dequantize(*quant(w)))
        np.testing.assert_array_equal(out[:, -2:], 0.0)


def test_stacked_superblock_leaves_quantize_per_slice():
    """A scanned (n_sb, d_in, d_out) kernel stack quantizes each slice
    with its own scales — identical to quantizing the slices alone."""
    w = np.stack([_w(16, 12, s) for s in range(3)])
    q, s = quantize_int8(w)
    assert q.shape == (3, 16, 12) and s.shape == (3, 1, 12)
    for i in range(3):
        qi, si = quantize_int8(w[i])
        np.testing.assert_array_equal(np.asarray(q[i]), np.asarray(qi))
        np.testing.assert_array_equal(np.asarray(s[i]), np.asarray(si))


def test_codec_error_cases():
    with pytest.raises(ValueError, match="even d_in"):
        quantize_int4(_w(15, 8))
    with pytest.raises(ValueError, match="does not divide"):
        quantize_int8(_w(16, 8), group_size=5)
    with pytest.raises(ValueError, match="unknown quant_matmul impl"):
        quant_matmul(jnp.ones((2, 16)), *quantize_int8(_w(16, 8)),
                     impl="cuda")
    with pytest.raises(ValueError, match="backbone_quant"):
        quantize_backbone({}, "fp8")


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 64, 48),      # single tile
                                   (300, 96, 80),    # pad M and N
                                   (2, 3, 32, 24)])  # leading batch dims
@pytest.mark.parametrize("mode", ["int8", "int4"])
@pytest.mark.parametrize("gs", [None, 16])
def test_kernel_matches_oracle(shape, mode, gs):
    """The Pallas kernel body (interpret mode on CPU) must match the XLA
    dequant-matmul oracle on every layout: int8/int4, per-channel and
    grouped scales, and grids that need the pad-and-slice path."""
    *lead, d_in, d_out = (1,) * (3 - len(shape)) + shape \
        if len(shape) < 3 else shape
    x = jnp.asarray(RNG.normal(size=(*lead, d_in)), jnp.float32)
    quant = quantize_int8 if mode == "int8" else quantize_int4
    q, s = quant(jnp.asarray(_w(d_in, d_out, 5)), group_size=gs)
    got = quant_matmul(x, q, s, impl="interpret")
    want = quant_matmul_ref(x, q, s)
    assert got.shape == want.shape == (*lead, d_out)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_einsum_impl_is_the_oracle():
    x = jnp.asarray(RNG.normal(size=(4, 32)), jnp.float32)
    q, s = quantize_int8(jnp.asarray(_w(32, 16, 9)))
    np.testing.assert_array_equal(
        np.asarray(quant_matmul(x, q, s, impl="einsum")),
        np.asarray(quant_matmul_ref(x, q, s)))


# ---------------------------------------------------------------------------
# quantize_backbone coverage
# ---------------------------------------------------------------------------

def test_quantize_backbone_leaf_coverage():
    """Projection kernels become {kernel_q, kernel_scale}; embeddings,
    norms, and the LM head stay f32 — and the quantized tree is
    materially smaller than the f32 one."""
    base = M.init_params(jax.random.PRNGKey(0), CFG)
    qt = quantize_backbone(base, "int8")
    paths = pt.tree_paths(qt)
    assert not any(p.endswith("_proj/kernel") for p in paths)
    n_q = sum(p.endswith("kernel_q") for p in paths)
    n_s = sum(p.endswith("kernel_scale") for p in paths)
    assert n_q == n_s and n_q > 0
    for p, leaf in zip(paths, jax.tree.leaves(qt)):
        if p.endswith("kernel_q"):
            assert leaf.dtype == jnp.int8
        elif "embed" in p or "norm" in p or p.endswith("head/kernel"):
            assert leaf.dtype == jnp.float32, p
    assert pt.tree_bytes(qt) < 0.55 * pt.tree_bytes(base)
    # int4 packs two codes per byte along d_in
    q4 = quantize_backbone(base, "int4")
    for p, leaf in zip(pt.tree_paths(q4), jax.tree.leaves(q4)):
        if p.endswith("kernel_q"):
            assert leaf.dtype == jnp.uint8
            assert leaf.shape[-2] == pt.tree_get(
                qt, p).shape[-2] // 2, p


def test_quantized_forward_drift_bounded():
    """End-to-end forward through the quantized backbone stays within
    the codec's noise band of the f32 model (int8 ≪ int4)."""
    base = M.init_params(jax.random.PRNGKey(0), CFG)
    batch = {"tokens": jnp.asarray(RNG.integers(5, 64, size=(2, 16)),
                                   jnp.int32)}
    ref = np.asarray(M.forward(base, batch, CFG)[0])
    drift = {}
    for mode, tol in [("int8", 2e-2), ("int4", 2e-1)]:
        got = np.asarray(
            M.forward(quantize_backbone(base, mode), batch, CFG)[0])
        drift[mode] = np.abs(got - ref).max()
        assert drift[mode] < tol, (mode, drift[mode])
    assert drift["int8"] < drift["int4"]


def test_quantized_engine_matches_quantized_reference():
    """ServeEngine with cfg.backbone_quant set serves the *same* tokens
    as greedy decoding over the quantized tree directly — quantization
    happens once at engine build, not per path."""
    from repro.launch.serve import greedy_generate
    from repro.serve import AdapterStore, ServeEngine

    base = M.init_params(jax.random.PRNGKey(0), CFG)
    qcfg = dataclasses.replace(CFG, backbone_quant="int8")
    store = AdapterStore(base, CFG, n_slots=2, kind="pairs")
    eng = ServeEngine(base, qcfg, store, max_rows=2, max_prompt_len=8,
                      max_len=24, decode_chunk=4)
    prompts = np.asarray(RNG.integers(5, 64, size=(1, 8)), np.int32)
    out = eng.generate([(None, prompts[0])], n_new=5)[0]
    ref = greedy_generate(quantize_backbone(base, "int8"),
                          {"tokens": jnp.asarray(prompts)}, CFG, n_new=5)
    np.testing.assert_array_equal(out, np.asarray(ref[0]))


def test_backbone_quant_group_threads_to_engine():
    """``ArchConfig.backbone_quant_group`` must reach the engine-build
    ``quantize_backbone`` call: a grouped engine serves exactly what
    greedy decoding over the *grouped* quantized tree serves, and the
    grouped codec is a genuinely different program (finer scale grid,
    different codes) than the per-channel default."""
    from repro.launch.serve import greedy_generate
    from repro.serve import AdapterStore, ServeEngine

    assert CFG.backbone_quant_group is None          # default: per-channel
    base = M.init_params(jax.random.PRNGKey(0), CFG)

    perchan = quantize_backbone(base, "int8")
    grouped = quantize_backbone(base, "int8", group_size=16)
    for p in pt.tree_paths(grouped):
        if p.endswith("kernel_scale"):
            gs, ps = pt.tree_get(grouped, p), pt.tree_get(perchan, p)
            assert gs.shape[-2] == ps.shape[-2] * (gs.size // ps.size), p
            assert gs.size > ps.size                 # finer grid
    diff = any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(grouped),
                               jax.tree.leaves(perchan)))
    assert diff

    qcfg = dataclasses.replace(CFG, backbone_quant="int8",
                               backbone_quant_group=16)
    store = AdapterStore(base, CFG, n_slots=2, kind="pairs")
    eng = ServeEngine(base, qcfg, store, max_rows=2, max_prompt_len=8,
                      max_len=24, decode_chunk=4)
    prompts = np.asarray(RNG.integers(5, 64, size=(1, 8)), np.int32)
    out = eng.generate([(None, prompts[0])], n_new=5)[0]
    ref = greedy_generate(grouped, {"tokens": jnp.asarray(prompts)},
                          CFG, n_new=5)
    np.testing.assert_array_equal(out, np.asarray(ref[0]))
