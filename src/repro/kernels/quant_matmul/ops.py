"""Public dispatcher for the dequant-fused quantized matmul, plus the
``quantize_backbone`` pass that produces the quantized param tree.

``quant_matmul`` routes to the Pallas TPU kernel on TPU backends and to
the jnp oracle elsewhere.  Like ``batched_lora``, the CPU default is
the *oracle*, not interpret mode: this op sits on the serving hot path
and the Pallas interpreter is orders of magnitude slower than XLA.
Tests force the kernel body with ``impl="interpret"``.

A quantized leaf is a dict ``{"kernel_q", "kernel_scale"}`` replacing
the f32 ``{"kernel"}`` — ``models/layers.linear`` detects the shape and
dispatches here; the LoRA/BGMV overlay leaves ride alongside untouched,
so adapters stay full precision on top of the quantized backbone.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul.quant_matmul import quant_matmul_kernel
from repro.kernels.quant_matmul.ref import (dequantize, quant_matmul_ref,
                                            quantize_int4, quantize_int8,
                                            unpack_int4)
from repro.obs.tracing import named_scope
from repro.utils import pytree as pt

_BM = 256                       # token-block size for the Pallas grid
_BN = 256                       # output-channel block size

# the backbone leaves that quantize: attention + FFN projection kernels.
# Embeddings, norms, biases, the LM head, and MoE router/expert tables
# stay f32 (see docs/quantization.md) — they either carry logit-critical
# precision or bypass layers.linear entirely.
_PROJ_RX = re.compile(r"(?:^|/)(?:q|k|v|o|gate|up|down)_proj/kernel$")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl):
    if impl is None:
        return "pallas" if _on_tpu() else "einsum"
    if impl not in ("pallas", "interpret", "einsum"):
        raise ValueError(f"unknown quant_matmul impl {impl!r}")
    return impl


def quant_matmul(x, q, scale, *, impl=None):
    """x (..., d_in) @ dequant(q, scale) → (..., d_out).

    ``q`` int8 (d_in, d_out) or packed-int4 uint8 (d_in/2, d_out);
    ``scale`` (G, d_out) f32 per-channel (G=1) or per-group scales."""
    impl = _resolve(impl)
    with named_scope("kernels/quant_matmul"):
        if impl == "einsum":
            return quant_matmul_ref(x, q, scale)
        lead, d_in = x.shape[:-1], x.shape[-1]
        xm = x.reshape(-1, d_in)
        M, N = xm.shape[0], q.shape[-1]
        bm, bn = min(_BM, M), min(_BN, N)
        pm, pn = -M % bm, -N % bn
        if pm:
            xm = jnp.pad(xm, ((0, pm), (0, 0)))
        if pn:                   # zero scales → padded columns dequant to 0
            q = jnp.pad(q, ((0, 0), (0, pn)))
            scale = jnp.pad(scale, ((0, 0), (0, pn)))
        y = quant_matmul_kernel(
            xm, q, scale, bm=bm, bn=bn,
            interpret=(impl == "interpret") or not _on_tpu())
        return y[:M, :N].reshape(*lead, N)


def quantize_backbone(base, mode: str, *, group_size=None):
    """Return a copy of the base param tree with every attention/FFN
    projection kernel replaced by ``{kernel_q, kernel_scale}`` in
    ``mode`` ("int8" | "int4").

    Stacked block kernels (n_sb, d_in, d_out) quantize per superblock
    slice (the leading axis broadcasts through the per-channel max), so
    ``lax.scan`` over the blocks hands each layer a clean 2-D quantized
    leaf.  Everything else — embeddings, norms, biases, the LM head,
    MoE router/experts — is carried through untouched, as is any LoRA
    overlay already merged into the tree."""
    if mode not in ("int8", "int4"):
        raise ValueError(
            f"backbone_quant must be 'int8' or 'int4', got {mode!r}")
    quant = quantize_int8 if mode == "int8" else quantize_int4
    out: dict = {}
    for p, leaf in jax.tree_util.tree_leaves_with_path(base):
        path = pt.path_str(p)
        if _PROJ_RX.search(path) and leaf.ndim in (2, 3):
            qv, s = quant(leaf, group_size=group_size)
            stem = path[: -len("kernel")]
            pt.set_leaf(out, stem + "kernel_q", qv)
            pt.set_leaf(out, stem + "kernel_scale", s)
        else:
            pt.set_leaf(out, path, leaf)
    return out


__all__ = ["quant_matmul", "quant_matmul_ref", "quant_matmul_kernel",
           "quantize_backbone", "quantize_int8", "quantize_int4",
           "dequantize", "unpack_int4"]
