"""AdapterStore: slot-pooled per-tenant adapters for mixed-batch serving.

The store owns, per target projection, stacked pools with an ``L =
n_slots + 1`` slot axis the BGMV kernel gathers over (slot ``n_slots``
is the permanent all-zero null adapter — rows without a tenant adapter
point there).  Targets under the model's scanned ``blocks`` keep their
leading superblock axis *ahead of* the slot axis — ``(n_sb, L, ...)`` —
so ``lax.scan`` slices off ``n_sb`` and every layer sees a clean
``(L, ...)`` pool.  Two pool layouts:

  kind="pairs"     pool_A (L, d_in, r) + pool_B (L, r, d_out): one
                   effective LoRA pair per tenant.  Raw-LoRA adapters
                   pack as-is; decomposed-DoRA adapters collapse to
                   their effective pair (A_mag·(A_dir+dA_dir),
                   (B_mag+dB_mag)·B_dir).

  kind="dora_mag"  the paper's deployment shape: every tenant shares the
                   direction/magnitude factors (A_dir+dA_dir, A_mag,
                   B_dir, B_mag) and differs only in its RAW per-rank
                   magnitude delta ΔB_M — pool_dB_mag (L, r); the
                   effective magnitude B_mag+ΔB_M is formed inside the
                   BGMV kernel.  Bytes per tenant = 4·r per target (a
                   few hundred bytes total), so one host holds millions
                   of personalized variants.

Heterogeneous tenants: one pool serves adapters of mixed ranks.  The
store's ``rank`` is the pool allocation — pass the fleet's server rank
to serve a server-rank fleet (it may exceed cfg.lora_rank; for
kind='dora_mag' it defaults to the shared tree's own rank).  A tenant
may register any rank ≤ the pool rank — its leaves are zero-padded into
the slot and its true rank is recorded in the slot-rank table (saved
with the tenant table, exposed as a ``pool_ranks`` leaf for BOTH kinds
so the BGMV kernel masks each row at its slot's own rank).  Storing the
dora_mag delta RAW is what makes that mask correct for magnitudes too:
a rank-r tenant's federated model is the first r rank rows of the
server model plus its ΔB_M (FedSim's rebroadcast re-mask), so serving
must mask the shared rows above r as well — and the null/evicted slot
(rank 0) masks everything, serving the bare backbone.

Register/evict is LRU over slots; ``save``/``load`` round-trip the pools
plus the tenant table through ``checkpoint/ckpt.py`` (tenant ids are
encoded as fixed-width uint8 rows so every checkpoint leaf stays a plain
numeric array).
"""
from __future__ import annotations

import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.ckpt import (checkpoint_leaf_paths, restore_checkpoint,
                                   save_checkpoint)
from repro.core.peft import _target_kernels
from repro.models.config import ArchConfig
from repro.utils import pytree as pt

Params = Any

_ID_BYTES = 64

_DECOMPOSED = ("A_dir", "A_mag", "B_dir", "B_mag")

# pool leaves carrying a slot axis (cleared on evict); the bgmv_* leaves
# are shared across tenants and never change per slot
_SLOT_KEYS = ("pool_A", "pool_B", "pool_dB_mag")


def _encode_id(tenant: str) -> np.ndarray:
    raw = tenant.encode("utf-8")
    if not raw or len(raw) > _ID_BYTES:
        raise ValueError(f"tenant id must be 1..{_ID_BYTES} utf-8 bytes, "
                         f"got {tenant!r}")
    return np.frombuffer(raw.ljust(_ID_BYTES, b"\0"), np.uint8).copy()


def _decode_id(row: np.ndarray) -> str:
    return bytes(np.asarray(row, np.uint8)).rstrip(b"\0").decode("utf-8")


_get = pt.tree_get


class AdapterStore:
    """Pools per-tenant adapters behind integer slots for BGMV serving."""

    def __init__(self, base: Params, cfg: ArchConfig, *, n_slots: int = 8,
                 kind: str = "pairs", rank: int = 0,
                 shared: Optional[Params] = None):
        if kind not in ("pairs", "dora_mag"):
            raise ValueError(f"unknown AdapterStore kind {kind!r}")
        if kind == "dora_mag" and shared is None:
            raise ValueError("kind='dora_mag' needs the shared decomposed "
                             "adapter tree (direction factors)")
        self.cfg = cfg
        self.kind = kind
        if not rank and kind == "dora_mag":
            # the pool allocation follows the shared model's own rank —
            # a fleet trained at server_rank > cfg.lora_rank serves
            # without truncation
            rank = int(jax.tree.leaves(pt.filter_tree(
                shared, lambda p: p.endswith("A_dir")))[0].shape[-1])
        self.rank = rank or cfg.lora_rank
        self.n_slots = n_slots
        self.null_slot = n_slots                      # all-zero identity slot
        # target prefix (".../q_proj") → (lead_dims, d_in, d_out); lead is
        # () for tail/unstacked params, (n_sb,) under the scanned blocks
        self.targets: dict[str, tuple[tuple, int, int]] = {}
        for path, kern in _target_kernels(base, cfg.lora_targets):
            *lead, d_in, d_out = kern.shape
            if len(lead) > 1:
                raise ValueError(f"unsupported kernel layout at {path}: "
                                 f"{kern.shape}")
            self.targets[path.rsplit("/", 1)[0]] = (tuple(lead), d_in, d_out)
        if not self.targets:
            raise ValueError(f"no lora_targets {cfg.lora_targets} in base")

        L, r = n_slots + 1, self.rank
        self._pools: dict[str, dict[str, jnp.ndarray]] = {}
        for prefix, (lead, d_in, d_out) in self.targets.items():
            if kind == "pairs":
                self._pools[prefix] = {
                    "pool_A": jnp.zeros((*lead, L, d_in, r), jnp.float32),
                    "pool_B": jnp.zeros((*lead, L, r, d_out), jnp.float32),
                }
            else:
                sh = {k: _get(shared, f"{prefix}/{k}") for k in _DECOMPOSED}
                if any(v is None for v in sh.values()):
                    raise ValueError(f"shared tree missing decomposed leaves "
                                     f"under {prefix}")
                if sh["A_dir"].shape != (*lead, d_in, r):
                    raise ValueError(
                        f"shared rank mismatch at {prefix}: "
                        f"{sh['A_dir'].shape} vs {(*lead, d_in, r)}")
                da = _get(shared, f"{prefix}/dA_dir")
                a_dir = sh["A_dir"] + (da if da is not None else 0.0)
                self._pools[prefix] = {
                    "bgmv_A_dir": jnp.asarray(a_dir, jnp.float32),
                    "bgmv_A_mag": jnp.asarray(sh["A_mag"], jnp.float32),
                    "bgmv_B_dir": jnp.asarray(sh["B_dir"], jnp.float32),
                    "bgmv_B_mag": jnp.asarray(sh["B_mag"], jnp.float32),
                    # RAW ΔB_M per slot — the kernel adds the shared
                    # B_mag and rank-masks the product, so slots above a
                    # tenant's rank (and the null slot) contribute zero
                    "pool_dB_mag": jnp.zeros((*lead, L, r), jnp.float32),
                }

        self._slot_of: dict[str, int] = {}            # tenant → slot
        self._tenant_of: dict[int, str] = {}          # slot → tenant
        self._last_used = np.zeros((n_slots,), np.int64)
        self._counter = 0
        # per-slot adapter ranks (null slot stays 0: an all-zero rank-0
        # identity); tenants below r_max are zero-padded into their slot
        self._slot_ranks = np.zeros((n_slots + 1,), np.int32)

    # ------------------------------------------------------------------
    # slot management
    # ------------------------------------------------------------------

    def __contains__(self, tenant: str) -> bool:
        return tenant in self._slot_of

    @property
    def tenants(self) -> list[str]:
        return sorted(self._slot_of)

    def slot_of(self, tenant: str) -> int:
        """Slot for a registered tenant; bumps LRU recency."""
        slot = self._slot_of[tenant]
        self._touch(slot)
        obs.inc("pool/lookups", kind=self.kind)
        return slot

    def rank_of(self, tenant: str) -> int:
        """The tenant's own adapter rank (≤ the pool's r_max)."""
        return int(self._slot_ranks[self._slot_of[tenant]])

    def _touch(self, slot: int) -> None:
        self._counter += 1
        self._last_used[slot] = self._counter

    def _alloc(self, tenant: str) -> int:
        if tenant in self._slot_of:
            return self._slot_of[tenant]
        for slot in range(self.n_slots):
            if slot not in self._tenant_of:
                return slot
        lru = min(self._tenant_of, key=lambda s: self._last_used[s])
        self.evict(self._tenant_of[lru])
        return lru

    def _set_slot(self, prefix: str, key: str, slot: int, val):
        pool = self._pools[prefix]
        lead, _, _ = self.targets[prefix]
        idx = (slice(None), slot) if lead else (slot,)
        pool[key] = pool[key].at[idx].set(val)

    def evict(self, tenant: str) -> None:
        slot = self._slot_of.pop(tenant)
        del self._tenant_of[slot]
        self._last_used[slot] = 0
        self._slot_ranks[slot] = 0
        for prefix, pool in self._pools.items():
            for key in _SLOT_KEYS:
                if key in pool:
                    self._set_slot(prefix, key, slot, 0.0)
        if obs.enabled():
            obs.inc("pool/evictions", kind=self.kind)
            obs.set_gauge("pool/occupancy",
                          len(self._tenant_of) / self.n_slots, kind=self.kind)
            obs.event("pool_evict", tenant=tenant, slot=slot, pool=self.kind)

    # ------------------------------------------------------------------
    # register
    # ------------------------------------------------------------------

    def register(self, tenant: str, adapter: Params, rank: int = 0) -> int:
        """Pack one tenant's adapter tree into a pool slot (LRU evict when
        full).  Accepts raw-LoRA {lora_A, lora_B} or decomposed-DoRA
        leaves for kind='pairs'; a dB_mag overlay (or full decomposed
        tree) for kind='dora_mag'.  The tenant's rank may be anything
        ≤ the pool's r_max — lower ranks are zero-padded into the slot
        and recorded in the slot-rank table.  ``rank``: the tenant's TRUE
        rank when it differs from the leaves' allocation — a server-rank
        fleet pads every client's adapters to the server rank (rows above
        the client's own rank are zero), so the shape alone over-states
        the rank and the BGMV mask would not truncate.  Raises ValueError
        on rank/target mismatch."""
        _encode_id(tenant)                            # validate early
        packed, t_ranks = {}, set()
        for p in self.targets:
            packed[p], r_t = self._pack_one(p, adapter)
            t_ranks.add(r_t)
        if len(t_ranks) != 1:
            raise ValueError(f"adapter rank mismatch across targets: "
                             f"{sorted(t_ranks)}")
        if rank:
            if not 1 <= rank <= min(t_ranks):
                raise ValueError(
                    f"explicit rank {rank} mismatch: outside [1, "
                    f"{min(t_ranks)}] (the adapter leaves' own rank)")
            t_ranks = {rank}
        extra = [p for p in pt.tree_paths(adapter)
                 if not any(p.startswith(t + "/") for t in self.targets)]
        if extra:
            raise ValueError(f"adapter has leaves outside the store's "
                             f"targets: {extra[:3]}")
        slot = self._alloc(tenant)
        for prefix, leaves in packed.items():
            for key, val in leaves.items():
                self._set_slot(prefix, key, slot, val)
        self._slot_of[tenant] = slot
        self._tenant_of[slot] = tenant
        self._slot_ranks[slot] = t_ranks.pop()
        self._touch(slot)
        if obs.enabled():
            obs.inc("pool/registers", kind=self.kind)
            obs.set_gauge("pool/occupancy",
                          len(self._tenant_of) / self.n_slots, kind=self.kind)
            obs.event("pool_register", tenant=tenant, slot=slot,
                      rank=int(self._slot_ranks[slot]), pool=self.kind)
        return slot

    def _pad_rank(self, x, axis: int):
        """Zero-pad a rank-``r_t`` leaf up to the pool's r_max along
        ``axis`` (negative).  Raises (with 'mismatch' in the message) when
        the leaf exceeds the pool allocation."""
        r_t = x.shape[axis]
        if not 1 <= r_t <= self.rank:
            raise ValueError(f"rank mismatch: adapter rank {r_t} outside "
                             f"[1, r_max={self.rank}]")
        if r_t == self.rank:
            return x
        pad = [(0, 0)] * x.ndim
        pad[x.ndim + axis] = (0, self.rank - r_t)
        return jnp.pad(x, pad)

    def _pack_one(self, prefix: str, adapter: Params) -> tuple[dict, int]:
        """Pack one target's leaves for a slot; returns (leaves, rank)."""
        lead, d_in, d_out = self.targets[prefix]
        r = self.rank
        sub = _get(adapter, prefix)
        if sub is None:
            raise ValueError(f"adapter missing target {prefix} "
                             f"(store targets: {list(self.targets)})")
        if self.kind == "dora_mag":
            db = sub.get("dB_mag")
            if db is None:
                raise ValueError(f"{prefix}: kind='dora_mag' needs a dB_mag "
                                 f"leaf per target")
            r_t = db.shape[-1]
            if db.shape != (*lead, r_t) or r_t > r:
                raise ValueError(f"{prefix}: dB_mag rank mismatch "
                                 f"{db.shape} vs {(*lead, f'<={r}')}")
            # stored RAW: the kernel forms B_mag + ΔB_M itself and its
            # rank mask covers the magnitude rows too — padded rows,
            # stale rows, and the null slot all contribute exactly zero
            return {"pool_dB_mag": self._pad_rank(
                jnp.asarray(db, jnp.float32), -1)}, r_t
        if "lora_A" in sub:
            A, B = sub["lora_A"], sub["lora_B"]
        elif "A_dir" in sub:
            da = sub.get("dA_dir")
            db = sub.get("dB_mag")
            A = sub["A_mag"][..., None] * (
                sub["A_dir"] + (da if da is not None else 0.0))
            B = (sub["B_mag"] + (db if db is not None else 0.0)
                 )[..., None] * sub["B_dir"]
        else:
            raise ValueError(f"{prefix}: no lora_A/A_dir leaves in adapter")
        r_t = A.shape[-1]
        if (r_t > r or A.shape != (*lead, d_in, r_t)
                or B.shape != (*lead, r_t, d_out)):
            raise ValueError(f"{prefix}: shape mismatch A{A.shape} B{B.shape} "
                             f"vs {(*lead, d_in, f'<={r}')} / "
                             f"{(*lead, f'<={r}', d_out)}")
        A = self._pad_rank(jnp.asarray(A, jnp.float32), -1)
        B = self._pad_rank(jnp.asarray(B, jnp.float32), -2)
        return {"pool_A": A, "pool_B": B}, r_t

    # ------------------------------------------------------------------
    # serving views
    # ------------------------------------------------------------------

    def overlay(self) -> Params:
        """Pooled overlay pytree to merge into the backbone params —
        ``layers.linear`` consults these leaves when adapter_idx is set.
        Both kinds carry the per-slot rank table as a ``pool_ranks`` leaf
        (broadcast over any scanned-block lead axis) so the BGMV kernel
        masks each row at its slot's own rank — for kind='dora_mag' the
        mask covers the magnitude rows (shared B_mag + raw ΔB_M), which
        is what serves a rank-r tenant its own rank-r slice of the shared
        model and the null slot (rank 0) the bare backbone."""
        slot_ranks = jnp.asarray(self._slot_ranks)
        out: dict = {}
        for prefix, pool in self._pools.items():
            keys = prefix.split("/")
            cur = out
            for k in keys:
                cur = cur.setdefault(k, {})
            cur.update(pool)
            lead, _, _ = self.targets[prefix]
            cur["pool_ranks"] = jnp.broadcast_to(
                slot_ranks, (*lead, self.n_slots + 1))
        return out

    def bytes_per_tenant(self, tenant: str | None = None) -> int:
        """Marginal pool bytes one registered tenant occupies (at the
        tenant's own rank when given; at the pool's r_max otherwise —
        padding rows are zero and compress away at rest, but they do
        occupy pool memory)."""
        r = self.rank if tenant is None else self.rank_of(tenant)
        total = 0
        for prefix, (lead, d_in, d_out) in self.targets.items():
            n = int(np.prod(lead)) if lead else 1
            if self.kind == "dora_mag":
                total += 4 * r * n
            else:
                total += 4 * r * (d_in + d_out) * n
        return total

    # ------------------------------------------------------------------
    # checkpointing
    # ------------------------------------------------------------------

    def _meta_arrays(self) -> dict:
        ids = np.zeros((self.n_slots, _ID_BYTES), np.uint8)
        for slot, tenant in self._tenant_of.items():
            ids[slot] = _encode_id(tenant)
        return {"tenant_ids": ids,
                "last_used": self._last_used.copy(),
                "counter": np.asarray(self._counter, np.int64),
                "slot_ranks": self._slot_ranks.copy()}

    def state_tree(self) -> dict:
        return {"pools": {p.replace("/", "."): dict(v)
                          for p, v in self._pools.items()},
                "meta": self._meta_arrays()}

    def save(self, path: str, step: int = 0) -> None:
        save_checkpoint(path, self.state_tree(), step=step)

    def load(self, path: str) -> int:
        """Restore pools + tenant table saved by ``save`` into this store
        (must be constructed with the same base/cfg/n_slots/kind and the
        same pool rank).  Checkpoints written before the slot-rank table
        existed restore every occupied slot at the pool's full rank
        (their pools were never padded).  kind='dora_mag' checkpoints
        from the pre-raw-delta layout (a ``pool_B_mag`` pool of MERGED
        magnitudes ``B_mag + ΔB_M`` per slot) are migrated best-effort:
        the shared magnitude is subtracted back out per occupied slot
        (see ``_load_legacy_b_mag``); the conversion is rejected with a
        ValueError when it is genuinely non-invertible — the checkpoint's
        shared ``B_mag`` differs from this store's, or the pool shapes
        don't match this allocation."""
        if self.kind == "dora_mag":
            try:
                old_paths = checkpoint_leaf_paths(path)
            except Exception:
                old_paths = []
            if any(p.endswith("/pool_B_mag") for p in old_paths):
                return self._load_legacy_b_mag(path)
        like = self.state_tree()
        like["meta"]["slot_ranks"] = np.full((self.n_slots + 1,), self.rank,
                                             np.int32)
        tree, step = restore_checkpoint(path, like,
                                        allow_missing=r"^meta/slot_ranks$")
        for p in self._pools:
            self._pools[p] = {k: jnp.asarray(v) for k, v in
                              tree["pools"][p.replace("/", ".")].items()}
        self._restore_meta(tree["meta"])
        return step

    def _restore_meta(self, meta: dict) -> None:
        ids = np.asarray(meta["tenant_ids"], np.uint8)
        self._last_used = np.asarray(meta["last_used"], np.int64).copy()
        self._counter = int(meta["counter"])
        self._slot_ranks = np.asarray(meta["slot_ranks"], np.int32).copy()
        self._slot_of, self._tenant_of = {}, {}
        for slot in range(self.n_slots):
            tenant = _decode_id(ids[slot])
            if tenant:
                self._slot_of[tenant] = slot
                self._tenant_of[slot] = tenant
        for slot in range(self.n_slots + 1):          # empty/null slots: rank 0
            if slot not in self._tenant_of:
                self._slot_ranks[slot] = 0

    def _load_legacy_b_mag(self, path: str) -> int:
        """Migration shim: restore a pre-raw-delta kind='dora_mag'
        checkpoint whose per-slot pool held MERGED magnitudes
        (``pool_B_mag[slot] = B_mag + ΔB_M``, zero-padded above the
        tenant's rank) instead of today's raw ``pool_dB_mag``.

        Best-effort inversion: ``ΔB_M = pool_B_mag[slot] − B_mag`` for
        every occupied slot (empty and null slots reset to zero).  That
        subtraction is only valid against the shared magnitude the
        checkpoint was WRITTEN with — when the checkpoint carries its
        ``bgmv_B_mag`` leaf and it disagrees with this store's shared
        tree, or the pool shapes don't match this allocation, the merge
        is genuinely non-invertible here and a ValueError is raised
        (re-register the tenants instead)."""
        warnings.warn(
            f"{path}: legacy pre-raw-delta AdapterStore checkpoint "
            "(merged pool_B_mag layout) — converting to raw pool_dB_mag "
            "by subtracting the shared B_mag per occupied slot",
            stacklevel=3)
        like = self.state_tree()
        like["meta"]["slot_ranks"] = np.full((self.n_slots + 1,), self.rank,
                                             np.int32)
        for p, pool in self._pools.items():
            legacy = {k: v for k, v in pool.items() if k != "pool_dB_mag"}
            legacy["pool_B_mag"] = jnp.zeros_like(pool["pool_dB_mag"])
            like["pools"][p.replace("/", ".")] = legacy
        try:
            # old checkpoints may predate the shared bgmv_* leaves — the
            # caller's own shared tree is then the only candidate
            tree, step = restore_checkpoint(
                path, like,
                allow_missing=r"^meta/slot_ranks$|/bgmv_")
        except AssertionError as e:
            raise ValueError(
                f"legacy pool_B_mag checkpoint {path} is not convertible "
                f"into this store: pool shape mismatch {e.args[0]!r} — the "
                "merge is non-invertible here; re-register the tenants"
            ) from e
        self._restore_meta(tree["meta"])
        occupied = np.zeros((self.n_slots + 1,), bool)
        for slot in self._tenant_of:
            occupied[slot] = True
        for p, pool in self._pools.items():
            ck = tree["pools"][p.replace("/", ".")]
            b_mag = np.asarray(pool["bgmv_B_mag"])     # (lead, r) shared
            ck_b_mag = np.asarray(ck["bgmv_B_mag"])
            if not np.allclose(ck_b_mag, b_mag, rtol=1e-6, atol=1e-7):
                raise ValueError(
                    f"legacy pool_B_mag checkpoint {path} was written "
                    f"against a different shared B_mag at {p!r} — the merge "
                    "is non-invertible with this store's shared tree; "
                    "re-register the tenants")
            merged = np.asarray(ck["pool_B_mag"])       # (lead, L, r)
            db = merged - ck_b_mag[..., None, :]
            # empty/null slots and rank rows above each slot's own rank
            # carry no delta (the old layout zero-padded them)
            occ = occupied.reshape((-1, 1))
            rows = np.arange(self.rank) < self._slot_ranks[:, None]
            db = db * (occ & rows)
            self._pools[p] = {k: jnp.asarray(v) for k, v in ck.items()
                              if k != "pool_B_mag"}
            self._pools[p]["pool_dB_mag"] = jnp.asarray(db, jnp.float32)
        if obs.enabled():
            obs.event("ckpt_migrate", path=str(path),
                      layout="pool_B_mag->pool_dB_mag",
                      tenants=len(self._tenant_of))
        return step
