"""repro.obs — fleet telemetry: metrics, tracing, structured events.

Global-sink design: exactly one ``Telemetry`` is active per process.
By default it is the **null** telemetry — a ``NullRegistry`` plus a
``NullEventLog`` whose every method is a no-op — so instrumented call
sites cost one attribute lookup when observability is off and the
jitted programs they wrap are byte-identical (locked by
``tests/test_obs.py`` no-op-invariance tests).  ``enable()`` swaps in a
live registry/event log; ``disable()`` swaps the null one back.

    from repro import obs
    tel = obs.enable(event_path="run/telemetry.jsonl")
    ... run engines ...
    obs.emit_snapshot()           # dump metrics into the JSONL epilogue
    obs.disable()

Engines read the sink through ``obs.active()`` (or the module-level
helpers ``inc`` / ``set_gauge`` / ``observe`` / ``event``) at call time,
never caching it across rounds, so enabling mid-process works.
"""
from __future__ import annotations

from repro.obs.events import EventLog, NullEventLog, read_events
from repro.obs.metrics import (DEFAULT_BOUNDS, LATENCY_BOUNDS,
                               MetricsRegistry, NullRegistry, to_prometheus)
from repro.obs.tracing import annotate, named_scope, span

__all__ = [
    "Telemetry", "enable", "disable", "enabled", "active",
    "inc", "set_gauge", "observe", "event", "emit_snapshot",
    "MetricsRegistry", "NullRegistry", "EventLog", "NullEventLog",
    "read_events", "span", "annotate", "named_scope",
    "DEFAULT_BOUNDS", "LATENCY_BOUNDS", "to_prometheus",
]


class Telemetry:
    """A metrics registry paired with an event sink."""

    def __init__(self, metrics, events, *, live: bool):
        self.metrics = metrics
        self.events = events
        self.live = live

    def close(self) -> None:
        self.events.close()


_NULL = Telemetry(NullRegistry(), NullEventLog(), live=False)
_active = _NULL


def enable(event_path: str | None = None, *,
           max_bytes: int = 8 * 1024 * 1024, keep: int = 3) -> Telemetry:
    """Install a live telemetry sink (idempotent: replaces the current
    one, closing its event log).  ``event_path=None`` keeps metrics but
    drops events (useful in tests that only assert on the registry)."""
    global _active
    if _active.live:
        _active.close()
    events = (EventLog(event_path, max_bytes=max_bytes, keep=keep)
              if event_path is not None else NullEventLog())
    _active = Telemetry(MetricsRegistry(), events, live=True)
    return _active


def disable() -> None:
    """Swap the null sink back in (closing the live event log)."""
    global _active
    if _active.live:
        _active.close()
    _active = _NULL


def enabled() -> bool:
    return _active.live


def active() -> Telemetry:
    return _active


# -- call-site helpers -------------------------------------------------------

def inc(name: str, value: float = 1.0, **labels) -> None:
    _active.metrics.counter(name).inc(value, **labels)


def set_gauge(name: str, value: float, **labels) -> None:
    _active.metrics.gauge(name).set(value, **labels)


def observe(name: str, value: float, bounds: tuple | None = None,
            **labels) -> None:
    """Record one histogram observation.  ``bounds`` sets the bucket
    upper bounds on the histogram's *first* creation (latency-class call
    sites pass ``obs.LATENCY_BOUNDS`` for sub-ms resolution); later
    calls — with or without bounds — share the existing instrument, per
    the registry's first-creation-wins contract."""
    h = (_active.metrics.histogram(name, bounds) if bounds is not None
         else _active.metrics.histogram(name))
    h.observe(value, **labels)


def event(kind: str, **fields) -> None:
    _active.events.emit(kind, **fields)


def emit_snapshot() -> dict:
    """Dump the full metrics snapshot as a ``metrics_snapshot`` event
    (the run epilogue that ``telemetry_section`` renders) and return it."""
    snap = _active.metrics.snapshot()
    _active.events.emit("metrics_snapshot", snapshot=snap)
    _active.events.flush()
    return snap
