"""Mamba-2 (SSD — state-space duality) mixer.

TPU adaptation of the CUDA selective-scan: the sequence is split into
chunks; *intra-chunk* terms are batched matmuls (MXU work, fully visible
to the compiler — no while loop), and the *inter-chunk* recurrence is a
log-depth ``jax.lax.associative_scan`` over chunk states.  This keeps the
HLO loop-free so the dry-run cost analysis sees every FLOP, and it is the
same decomposition the Pallas kernel tiles into VMEM (kernels/ssd_scan).

Parameterization (separate projections instead of mamba_ssm's fused
in_proj so tensor-parallel sharding splits cleanly — depthwise convs over
concat(x,B,C) factor into per-segment convs, so the math is unchanged):

  z_proj (D, d_inner)   gate
  x_proj (D, d_inner)
  B_proj (D, G*N)   C_proj (D, G*N)   dt_proj (D, H)
  conv_x (d_inner, k)  conv_B (G*N, k)  conv_C (G*N, k)   [depthwise causal]
  A_log (H,)  D_skip (H,)  dt_bias (H,)  norm_w (d_inner,)
  out_proj (d_inner, D)

with d_inner = expand*D, H = d_inner/headdim heads, G groups, N state dim.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import linear, rms_norm

Params = Any


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x: (B, S, C); w: (C, k).
    state: (B, k-1, C) trailing context (decode) or None (zero-pad)."""
    B, S, C = x.shape
    k = w.shape[-1]
    if state is None:
        pad = jnp.zeros((B, k - 1, C), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                 # (B, S+k-1, C)
    # sum of k shifted elementwise products (avoids an (B,S,k,C) gather)
    y = jnp.zeros((B, S, C), jnp.float32)
    for j in range(k):
        y = y + xp[:, j:j + S, :].astype(jnp.float32) * w[:, j].astype(jnp.float32)
    y = y.astype(x.dtype)
    new_state = jax.lax.dynamic_slice_in_dim(xp, xp.shape[1] - (k - 1), k - 1, 1)
    return y, new_state


def _ssd_chunked(x, dt, A_log, B, C, chunk: int):
    """SSD forward.  x: (b, S, H, P); dt: (b, S, H); B,C: (b, S, G, N).
    Returns y: (b, S, H, P) and final state (b, H, P, N)."""
    b, S, H, Pd = x.shape
    cdt = x.dtype                                          # compute dtype for
    G, N = B.shape[2], B.shape[3]                          # the Q×Q tensors
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(cdt)            # (b,S,H,N)
    Ch = jnp.repeat(C, rep, axis=2).astype(cdt)
    dtf = dt.astype(jnp.float32)
    a = -jnp.exp(A_log.astype(jnp.float32)) * dtf          # (b,S,H) log-decay
    xdt = (x.astype(jnp.float32) * dtf[..., None]).astype(cdt)  # (b,S,H,P)

    nc = S // chunk
    shp = lambda t, *rest: t.reshape(b, nc, chunk, *rest)
    ac, xc = shp(a, H), shp(xdt, H, Pd)
    Bc, Cc = shp(Bh, H, N), shp(Ch, H, N)

    # intra-chunk: cumulative log-decay within chunk
    ld = jnp.cumsum(ac, axis=2)                            # (b,nc,Q,H)
    # L[i,j] = exp(l_i - l_j) for i >= j else 0
    li = ld[:, :, :, None, :]                               # (b,nc,Q,1,H)
    lj = ld[:, :, None, :, :]                               # (b,nc,1,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None],
                      jnp.exp(li - lj), 0.0).astype(cdt)
    cb = jnp.einsum("bnihd,bnjhd->bnijh", Cc, Bc)          # (b,nc,Q,Q,H)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", cb * decay, xc,
                         preferred_element_type=jnp.float32)

    # per-chunk end state: sum_j exp(l_last - l_j) B_j x_j^T
    seg = jnp.exp(ld[:, :, -1:, :] - ld).astype(cdt)         # (b,nc,Q,H)
    states = jnp.einsum("bnjh,bnjhd,bnjhp->bnhdp", seg, Bc, xc,
                        preferred_element_type=jnp.float32)  # (b,nc,H,N,P)
    chunk_decay = jnp.exp(ld[:, :, -1, :])                  # (b,nc,H)

    # inter-chunk recurrence via log-depth associative scan:
    #   S_c = d_c * S_{c-1} + states_c
    def comb(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s2 + d2[..., None, None] * s1

    dcum, scum = jax.lax.associative_scan(
        comb, (chunk_decay, states), axis=1)
    # state entering chunk c = scum[c-1]
    s_in = jnp.concatenate(
        [jnp.zeros_like(scum[:, :1]), scum[:, :-1]], axis=1)   # (b,nc,H,N,P)
    y_inter = jnp.einsum("bnihd,bnih,bnhdp->bnihp",
                         Cc, jnp.exp(ld), s_in)
    y = (y_intra + y_inter).reshape(b, S, H, Pd)
    final_state = scum[:, -1].transpose(0, 1, 3, 2)         # (b,H,P,N)
    return y, final_state


def mamba2_mixer(p: Params, x, cfg, *, cache: Optional[dict] = None,
                 cache_index=None, lora_scale: float = 0.0,
                 dropout_rng=None, return_cache: bool = False):
    """Full Mamba-2 block body (pre-norm applied by caller).

    Adapters (the paper's technique, adapted per DESIGN §8) attach to the
    x_proj ("in") and out_proj projections when the config's lora_targets
    name them.
    """
    B, S, D = x.shape
    H, Pd = cfg.d_model * cfg.ssm_expand // cfg.ssm_headdim, cfg.ssm_headdim
    G, N = cfg.ssm_groups, cfg.ssm_state
    d_inner = H * Pd

    tgt = cfg.lora_targets
    z = linear(p["z_proj"], x)
    xi = linear(p["x_proj"], x,
                lora_scale=lora_scale if "x_proj" in tgt or "in_proj" in tgt else 0.0,
                dropout_rng=dropout_rng, dropout=cfg.lora_dropout)
    Bv = linear(p["B_proj"], x)
    Cv = linear(p["C_proj"], x)
    dt = linear(p["dt_proj"], x)

    if cache is None:
        xi, cx = _causal_conv(xi, p["conv_x"])
        Bv, cB = _causal_conv(Bv, p["conv_B"])
        Cv, cC = _causal_conv(Cv, p["conv_C"])
        new_conv = (cx, cB, cC) if return_cache else None
    else:
        xi, cx = _causal_conv(xi, p["conv_x"], cache["conv_x"])
        Bv, cB = _causal_conv(Bv, p["conv_B"], cache["conv_B"])
        Cv, cC = _causal_conv(Cv, p["conv_C"], cache["conv_C"])
        new_conv = (cx, cB, cC)
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)
    Bv = jax.nn.silu(Bv.astype(jnp.float32)).astype(x.dtype)
    Cv = jax.nn.silu(Cv.astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # (B,S,H)
    xh = xi.reshape(B, S, H, Pd)
    Bh = Bv.reshape(B, S, G, N)
    Ch = Cv.reshape(B, S, G, N)

    if cache is None:
        chunk = min(cfg.ssm_chunk, S)
        if S % chunk:                                       # pad to chunk
            padlen = chunk - S % chunk
            padf = lambda t: jnp.pad(t, [(0, 0), (0, padlen)] + [(0, 0)] * (t.ndim - 2))
            y, st = _ssd_chunked(padf(xh), padf(dt), p["A_log"], padf(Bh),
                                 padf(Ch), chunk)
            y = y[:, :S]
        else:
            y, st = _ssd_chunked(xh, dt, p["A_log"], Bh, Ch, chunk)
        new_cache = None
        if return_cache:
            new_cache = {"state": st.astype(x.dtype), "conv_x": new_conv[0],
                         "conv_B": new_conv[1], "conv_C": new_conv[2]}
    else:
        # one-token recurrent update: state (B,H,P,N)
        st = cache["state"].astype(jnp.float32)
        af = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32)) * dt[:, 0])  # (B,H)
        rep = H // G
        Bt = jnp.repeat(Bh[:, 0], rep, axis=1).astype(jnp.float32)   # (B,H,N)
        Ct = jnp.repeat(Ch[:, 0], rep, axis=1).astype(jnp.float32)
        xt = xh[:, 0].astype(jnp.float32) * dt[:, 0][..., None]      # (B,H,P)
        st = af[..., None, None] * st + jnp.einsum("bhp,bhn->bhpn", xt, Bt)
        yt = jnp.einsum("bhpn,bhn->bhp", st, Ct)
        y = yt[:, None]                                     # (B,1,H,P)
        new_cache = {"state": st.astype(cache["state"].dtype),
                     "conv_x": new_conv[0], "conv_B": new_conv[1],
                     "conv_C": new_conv[2]}

    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z)) * w
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["norm_w"], cfg.norm_eps)
    y = linear(p["out_proj"], y,
               lora_scale=lora_scale if "out_proj" in tgt else 0.0)
    return y, new_cache


def init_ssm_cache(cfg, batch: int, dtype):
    H = cfg.d_model * cfg.ssm_expand // cfg.ssm_headdim
    d_inner = H * cfg.ssm_headdim
    GN = cfg.ssm_groups * cfg.ssm_state
    k = cfg.ssm_conv
    return {
        "state": jnp.zeros((batch, H, cfg.ssm_headdim, cfg.ssm_state), dtype),
        "conv_x": jnp.zeros((batch, k - 1, d_inner), dtype),
        "conv_B": jnp.zeros((batch, k - 1, GN), dtype),
        "conv_C": jnp.zeros((batch, k - 1, GN), dtype),
    }
