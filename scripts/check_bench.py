#!/usr/bin/env python
"""CI benchmark smoke gate.

Reads the JSON the benchmark harness wrote (``python -m benchmarks.run
--only perf,het,dist --fresh`` → experiments/bench/) and fails if the
heterogeneous-round overhead ratio regressed past the bar recorded in
``benchmarks/baselines/het_round.json`` (the PR-3 seed trajectory).

Exit status is the contract: 0 = within the bar, 1 = regression or
missing results.  The CI lane uploads experiments/bench/ as an artifact
either way, so a red run ships the numbers that failed it.
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(ROOT, "benchmarks", "baselines", "het_round.json")
RESULTS = os.path.join(ROOT, "experiments", "bench", "het.json")


def main() -> int:
    with open(BASELINE) as f:
        base = json.load(f)
    if not os.path.exists(RESULTS):
        print(f"[check_bench] FAIL: no benchmark results at {RESULTS} — "
              "run `make bench-smoke` (= `python -m benchmarks.run --only "
              "perf,het,dist --fresh` + this check) first")
        return 1
    with open(RESULTS) as f:
        rows = json.load(f)
    het = [r for r in rows if r.get("arch") == "fed_round/het_masked"]
    if not het:
        print(f"[check_bench] FAIL: no fed_round/het_masked row in {RESULTS}")
        return 1
    ratio = float(het[0]["ratio"])
    bar = float(base["max_ratio"])
    recorded = base["recorded"]
    print(f"[check_bench] het-round ratio {ratio:.2f}x "
          f"(bar {bar:.2f}x; recorded {recorded['ratio']:.2f}x in "
          f"PR {recorded['pr']})")
    if ratio > bar:
        print("[check_bench] FAIL: masked mixed-rank round regressed past "
              "the bar — the het fleet is paying more than rank-mask "
              "elementwise work on top of the uniform round")
        return 1
    print("[check_bench] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
