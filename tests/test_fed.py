"""Federated engine behaviour tests (single device, tiny model)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core import peft
from repro.fed.simulate import FedHyper, FedSim
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.utils import pytree as pt

CFG = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                 dtype="float32", lora_rank=4, lora_dropout=0.0)


def _batches(C, n, seed=0):
    rng = np.random.default_rng(seed)
    return [{"tokens": jnp.asarray(rng.integers(5, 256, size=(C, 4, 32)),
                                   jnp.int32),
             "loss_mask": jnp.ones((C, 4, 32), jnp.float32)}
            for _ in range(n)]


def test_local_training_reduces_loss():
    """LoRA adapters memorize a repeated batch (random tokens are not
    predictable across fresh batches, so repeat one)."""
    hp = FedHyper(method="fedlora_opt", n_clients=2, local_steps=1, lr=1e-2)
    sim = FedSim(CFG, hp)
    b = _batches(2, 1)
    first = sim.local_round(b, jax.random.PRNGKey(0))
    for _ in range(30):
        last = sim.local_round(b, jax.random.PRNGKey(0))
    assert np.mean(last["ce"]) < np.mean(first["ce"]) - 0.05


def test_aggregate_syncs_shared_components_keeps_personal():
    hp = FedHyper(method="fedlora_opt", n_clients=3)
    sim = FedSim(CFG, hp)
    # desynchronize clients artificially
    sim.client_adapters = jax.tree.map(
        lambda x: x + jnp.arange(x.shape[0], dtype=x.dtype).reshape(
            (-1,) + (1,) * (x.ndim - 1)), sim.client_adapters)
    before = sim.client_adapters
    sim.aggregate()
    after = sim.client_adapters
    for path, leaf in zip(pt.tree_paths(after), jax.tree.leaves(after)):
        arr = np.asarray(leaf)
        if path.endswith("dB_mag"):
            np.testing.assert_allclose(
                arr, np.asarray(FedSim._leaf(before, path)))  # personal kept
        else:
            for c in range(1, arr.shape[0]):
                np.testing.assert_allclose(arr[c], arr[0], rtol=1e-5,
                                           err_msg=path)


def test_comm_accounting_counts_adapters_only():
    hp = FedHyper(method="fedlora_opt", n_clients=4)
    sim = FedSim(CFG, hp)
    sim.aggregate()
    # keep-local leaves (dB_mag) never move, so they don't count
    shared = pt.filter_tree(sim.adapter_template,
                            lambda p: not p.endswith("dB_mag"))
    per_client = 2 * pt.tree_bytes(shared)
    assert sim.comm_bytes == 4 * per_client
    assert sim.comm_bytes < pt.tree_bytes(sim.base) / 2   # « backbone


def test_comm_accounting_bills_collective_class():
    """Gather-class methods (lora_exact, lora_trimmed) move
    (C+1)·|adapters| per client per round — each client uplinks its
    factors once and downlinks every client's stack — while the psum
    family moves 2·|adapters|.  The engine must bill the method's true
    comm class, not a flat psum rate."""
    from repro.core.methods import get_method
    assert agg.comm_class(get_method("lora")) == "psum"
    assert agg.comm_class(get_method("fedlora_opt")) == "psum"
    assert agg.comm_class(get_method("lora_exact")) == "all_gather"
    assert agg.comm_class(get_method("lora_trimmed")) == "all_gather"
    with pytest.raises(ValueError, match="n_clients"):
        agg.comm_bytes_per_round({"a": jnp.zeros((2, 2))},
                                 comm="all_gather")

    C = 4
    for method, factor in [("lora", 2), ("lora_exact", C + 1),
                           ("lora_trimmed", C + 1)]:
        sim = FedSim(CFG, FedHyper(method=method, n_clients=C))
        sim.aggregate()
        per_client = pt.tree_bytes(sim.adapter_template)
        assert sim.comm_bytes == C * factor * per_client, method

    # heterogeneous fleet: each client bills its own rank rows, still at
    # the gather rate
    ranks = (2, 4, 4)
    sim = FedSim(CFG, FedHyper(method="lora_exact", n_clients=3,
                               client_ranks=ranks))
    sim.aggregate()
    expect = 0
    for r in ranks:
        for path, leaf in zip(pt.tree_paths(sim.adapter_template),
                              jax.tree.leaves(sim.adapter_template)):
            shape = list(leaf.shape)
            ax = peft.rank_axis(path)
            if ax is not None:
                shape[leaf.ndim + ax] = min(r, shape[leaf.ndim + ax])
            expect += (3 + 1) * int(np.prod(shape)) * leaf.dtype.itemsize
    assert sim.comm_bytes == expect


def test_comm_accounting_bills_compressed_class():
    """COMPRESSED-class methods bill the encoded uplink, not raw f32:
    q8 ships n int8 codes + one f32 scale per leaf up and the f32
    aggregate down; top-k ships k (value, index) pairs up.  The q8
    round must come in strictly under the psum family's 2·|adapters|
    rate — that is the point of the codec."""
    import math
    from repro.core.methods import get_method
    assert agg.comm_class(get_method("lora_fedavg_q8")) == "q8"
    assert agg.comm_class(get_method("lora_fedavg_topk")) == "topk"
    with pytest.raises(ValueError, match="unknown comm class"):
        agg.comm_bytes_per_round({"a": jnp.zeros((2, 2))}, comm="zfp")

    C = 4
    for method, ratio in [("lora_fedavg_q8", None),
                          ("lora_fedavg_topk", 0.05)]:
        sim = FedSim(CFG, FedHyper(method=method, n_clients=C))
        sim.aggregate()
        expect = 0
        for leaf in jax.tree.leaves(sim.adapter_template):
            n, sz = leaf.size, leaf.dtype.itemsize
            if ratio is None:                     # q8: codes + scale + down
                expect += n + 4 + n * sz
            else:                                 # topk: (value, idx) + down
                k = max(1, math.ceil(ratio * n))
                expect += k * (sz + 4) + n * sz
        assert sim.comm_bytes == C * expect, method

    # acceptance: the q8 round moves strictly less than an uncompressed
    # psum round of the same fleet
    sim = FedSim(CFG, FedHyper(method="lora_fedavg_q8", n_clients=C))
    sim.aggregate()
    assert sim.comm_bytes < C * 2 * pt.tree_bytes(sim.adapter_template)


def test_compressed_round_trains_and_tracks_fedavg():
    """A q8 round is a working training round: loss is finite, clients
    sync to a common aggregate, and that aggregate stays within codec
    noise of the exact-FedAvg aggregate of the same trained fleet."""
    hp = FedHyper(method="lora_fedavg_q8", n_clients=3, local_steps=2,
                  lr=1e-2)
    sim = FedSim(CFG, hp)
    mets = sim.local_round(_batches(3, 2), jax.random.PRNGKey(0))
    assert np.isfinite(mets["ce"]).all()
    clients = jax.tree.map(np.asarray, sim.client_adapters)
    exact = agg.fedavg(sim.client_adapters)
    sim.aggregate()
    for path, leaf, pre, ref in zip(pt.tree_paths(sim.client_adapters),
                                    jax.tree.leaves(sim.client_adapters),
                                    jax.tree.leaves(clients),
                                    jax.tree.leaves(exact)):
        arr = np.asarray(leaf)
        for c in range(1, arr.shape[0]):          # all clients synced
            np.testing.assert_array_equal(arr[c], arr[0], err_msg=path)
        err = np.abs(arr[0] - np.asarray(ref)).max()
        # the aggregate error is ≤ the mean of the per-client SR bins
        bins = np.abs(pre).reshape(pre.shape[0], -1).max(1) / 127.0
        assert err <= bins.mean() + 1e-6, path


def test_stage_masks_select_expected_leaves():
    ad = peft.add_lora(M.init_params(jax.random.PRNGKey(0), CFG), CFG,
                       jax.random.PRNGKey(1), decomposed=True)
    mg = peft.mask_stage_global(ad)
    ml = peft.mask_stage_local(ad)
    paths = pt.tree_paths(ad)
    for p, g, lo in zip(paths, jax.tree.leaves(mg), jax.tree.leaves(ml)):
        assert g == p.endswith("dA_dir")
        assert lo == p.endswith("dB_mag")


def test_global_stage_trains_only_dA_dir():
    hp = FedHyper(method="fedlora_opt", n_clients=2, global_steps=2,
                  server_lr=1e-2, lr=1e-2)
    sim = FedSim(CFG, hp)
    # stage-1 first: at the DoRA-faithful init B_mag = 0, so ΔA_D gradients
    # are exactly zero until local training gives B magnitude (by design)
    sim.local_round(_batches(2, 3), jax.random.PRNGKey(1))
    aggregated = sim.aggregate()
    sb = [{k: v[0] for k, v in b.items()} for b in _batches(1, 2, seed=3)]
    new_agg = sim.global_stage(aggregated, sb, jax.random.PRNGKey(0))
    for path in pt.tree_paths(aggregated):
        old = np.asarray(FedSim._leaf(aggregated, path))
        new = np.asarray(FedSim._leaf(new_agg, path))
        if path.endswith("dA_dir"):
            assert np.abs(new - old).max() > 0, path
        else:
            np.testing.assert_allclose(new, old, err_msg=path)


def test_personalize_trains_only_dB_mag():
    hp = FedHyper(method="fedlora_opt", n_clients=2, lam=1e-3)
    sim = FedSim(CFG, hp)
    before = sim.client_adapters
    sim.personalize(_batches(2, 3, seed=5), jax.random.PRNGKey(0))
    after = sim.client_adapters
    for path in pt.tree_paths(before):
        old = np.asarray(FedSim._leaf(before, path))
        new = np.asarray(FedSim._leaf(after, path))
        if path.endswith("dB_mag"):
            assert np.abs(new - old).max() > 0, path
        else:
            np.testing.assert_allclose(new, old, err_msg=path)


@pytest.mark.parametrize("method", ["lora", "ffa_lora", "fedprox", "prompt",
                                    "adapter"])
def test_baseline_methods_step(method):
    hp = FedHyper(method=method, n_clients=2, local_steps=1, prox_mu=0.01)
    sim = FedSim(CFG, hp)
    mets = sim.local_round(_batches(2, 2), jax.random.PRNGKey(0))
    assert np.isfinite(mets["ce"]).all()
    if method == "ffa_lora":
        # A must stay frozen
        for path, leaf in zip(pt.tree_paths(sim.client_adapters),
                              jax.tree.leaves(sim.client_adapters)):
            if path.endswith("lora_A"):
                ref = FedSim._leaf(
                    agg.broadcast_to_clients(sim.adapter_template, 2), path)
                np.testing.assert_allclose(np.asarray(leaf), np.asarray(ref))
