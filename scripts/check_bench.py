#!/usr/bin/env python
"""CI benchmark smoke gate.

Reads the JSON the benchmark harness wrote (``python -m benchmarks.run
--only perf,het,cohort,dist,pipeline,quant,obs,tier --fresh`` →
experiments/bench/) and fails if a gated ratio regressed past its
checked-in bar:

  * ``baselines/het_round.json`` — the masked mixed-rank round must stay
    within ``max_ratio`` of the uniform round (PR-3 trajectory);
  * ``baselines/quant_decode.json`` — the analytic f32/int8 decode byte
    ratio of the quantized backbone must stay above ``min_ratio``
    (PR-6 trajectory; see docs/quantization.md);
  * ``baselines/obs_overhead.json`` — the instrumented (live telemetry
    sink) het round and serve loop must stay within ``max_ratio`` of
    the disabled-sink run (PR-7 trajectory; see docs/observability.md —
    the jitted programs are byte-identical, so anything past the bar is
    host-side leakage into the hot loop);
  * ``baselines/cohort_round.json`` — the sampled-cohort round
    (ClientBank gather/scatter + fault transforms + straggler
    buffering) must stay within ``max_ratio`` of the bare
    full-participation round at equal cohort size (PR-8 trajectory;
    see docs/distributed_training.md — fleet scale-out is host work,
    not a second jitted program);
  * ``baselines/tier_churn.json`` — the tiered adapter pool
    (PR-9 trajectory; see docs/serving.md): warm-T0 lookups through
    the TieredAdapterStore must stay within ``max_warm_ratio`` of the
    flat pool, and Zipf churn over the 10k-tenant registry must keep
    at least ``min_churn_ratio`` of the all-resident throughput —
    promotions must remain a batched between-chunks host epilogue.

Exit status is the contract: 0 = within the bar, 1 = regression or
missing results.  The CI lane uploads experiments/bench/ as an artifact
either way, so a red run ships the numbers that failed it.
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINES = os.path.join(ROOT, "benchmarks", "baselines")
BENCH = os.path.join(ROOT, "experiments", "bench")


def _load(name: str, results: str):
    with open(os.path.join(BASELINES, name)) as f:
        base = json.load(f)
    path = os.path.join(BENCH, results)
    if not os.path.exists(path):
        print(f"[check_bench] FAIL: no benchmark results at {path} — "
              "run `make bench-smoke` (= `python -m benchmarks.run --only "
              "perf,het,cohort,dist,pipeline,quant,obs,tier --fresh` + "
              "this check) first")
        return base, None
    with open(path) as f:
        return base, json.load(f)


def check_het() -> bool:
    base, rows = _load("het_round.json", "het.json")
    if rows is None:
        return False
    het = [r for r in rows if r.get("arch") == "fed_round/het_masked"]
    if not het:
        print("[check_bench] FAIL: no fed_round/het_masked row in het.json")
        return False
    ratio = float(het[0]["ratio"])
    bar = float(base["max_ratio"])
    recorded = base["recorded"]
    print(f"[check_bench] het-round ratio {ratio:.2f}x "
          f"(bar {bar:.2f}x; recorded {recorded['ratio']:.2f}x in "
          f"PR {recorded['pr']})")
    if ratio > bar:
        print("[check_bench] FAIL: masked mixed-rank round regressed past "
              "the bar — the het fleet is paying more than rank-mask "
              "elementwise work on top of the uniform round")
        return False
    return True


def check_quant() -> bool:
    base, rows = _load("quant_decode.json", "quant.json")
    if rows is None:
        return False
    q8 = [r for r in rows if r.get("arch") == "quant/decode_int8"]
    if not q8:
        print("[check_bench] FAIL: no quant/decode_int8 row in quant.json")
        return False
    ratio = float(q8[0]["bytes_ratio"])
    bar = float(base["min_ratio"])
    recorded = base["recorded"]
    print(f"[check_bench] quant decode byte ratio {ratio:.2f}x "
          f"(bar {bar:.2f}x; recorded {recorded['ratio']:.2f}x in "
          f"PR {recorded['pr']})")
    if ratio < bar:
        print("[check_bench] FAIL: the int8 backbone stopped being "
              "materially smaller than f32 — a projection leaf is no "
              "longer quantizing (or scales ballooned), so the "
              "bytes-bound decode win is gone")
        return False
    return True


def check_obs() -> bool:
    base, rows = _load("obs_overhead.json", "obs.json")
    if rows is None:
        return False
    bar = float(base["max_ratio"])
    recorded = base["recorded"]
    ok = True
    for arch in ("obs/het_round_instrumented", "obs/serve_instrumented"):
        row = [r for r in rows if r.get("arch") == arch]
        if not row:
            print(f"[check_bench] FAIL: no {arch} row in obs.json")
            ok = False
            continue
        ratio = float(row[0]["ratio"])
        print(f"[check_bench] {arch} ratio {ratio:.3f}x "
              f"(bar {bar:.2f}x; recorded "
              f"{recorded[arch.split('/')[1]]:.3f}x in PR {recorded['pr']})")
        if ratio > bar:
            print(f"[check_bench] FAIL: {arch} regressed past the bar — "
                  "telemetry is no longer host-epilogue-only on that loop "
                  "(a sync, transfer, or per-step callback leaked into the "
                  "instrumented path)")
            ok = False
    return ok


def check_cohort() -> bool:
    base, rows = _load("cohort_round.json", "cohort.json")
    if rows is None:
        return False
    coh = [r for r in rows if r.get("arch") == "fed_round/sampled_cohort"]
    if not coh:
        print("[check_bench] FAIL: no fed_round/sampled_cohort row in "
              "cohort.json")
        return False
    ratio = float(coh[0]["ratio"])
    bar = float(base["max_ratio"])
    recorded = base["recorded"]
    print(f"[check_bench] cohort-round ratio {ratio:.2f}x "
          f"(bar {bar:.2f}x; recorded {recorded['ratio']:.2f}x in "
          f"PR {recorded['pr']})")
    if ratio > bar:
        print("[check_bench] FAIL: the sampled-cohort round regressed past "
              "the bar — bank gather/scatter, fault transforms, or "
              "straggler buffering is taxing the jitted round beyond "
              "host-epilogue work")
        return False
    return True


def check_tier() -> bool:
    base, rows = _load("tier_churn.json", "tier.json")
    if rows is None:
        return False
    recorded = base["recorded"]
    ok = True
    warm = [r for r in rows if r.get("arch") == "serve/tier_warm"]
    if not warm:
        print("[check_bench] FAIL: no serve/tier_warm row in tier.json")
        ok = False
    else:
        ratio = float(warm[0]["ratio"])
        bar = float(base["max_warm_ratio"])
        print(f"[check_bench] tier warm-T0 ratio {ratio:.3f}x "
              f"(bar {bar:.2f}x; recorded {recorded['warm_ratio']:.2f}x "
              f"in PR {recorded['pr']})")
        if ratio > bar:
            print("[check_bench] FAIL: warm-T0 lookups through the tiered "
                  "store regressed past the bar — tier bookkeeping (dict "
                  "walks, prefetch drains, telemetry) leaked into the "
                  "steady-state decode loop")
            ok = False
    churn = [r for r in rows if r.get("arch") == "serve/tier_churn"]
    if not churn:
        print("[check_bench] FAIL: no serve/tier_churn row in tier.json")
        ok = False
    else:
        ratio = float(churn[0]["ratio"])
        bar = float(base["min_churn_ratio"])
        print(f"[check_bench] tier churn throughput {ratio:.2f}x of "
              f"all-resident (bar {bar:.2f}x; recorded "
              f"{recorded['churn_ratio']:.2f}x in PR {recorded['pr']})")
        if ratio < bar:
            print("[check_bench] FAIL: Zipf churn over the 10k-tenant "
                  "registry fell below the bar — hot-swap stopped being a "
                  "batched between-chunks epilogue (per-request device "
                  "puts, a recompile, or synchronous shard reads on the "
                  "decode path)")
            ok = False
    return ok


def main() -> int:
    ok = check_het()
    ok = check_quant() and ok
    ok = check_obs() and ok
    ok = check_cohort() and ok
    ok = check_tier() and ok
    if not ok:
        return 1
    print("[check_bench] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
