"""Public dispatchers for the batched-LoRA (BGMV) kernels.

``bgmv`` / ``bgmv_mag`` route to the Pallas TPU kernel on TPU backends
and to the vectorized einsum oracle elsewhere.  Unlike ``fused_dora``
(validation-oriented), the CPU default here is the *oracle*, not
interpret mode: these ops sit on the serving hot path and the Pallas
interpreter is orders of magnitude slower than XLA.  Tests force the
kernel body with ``impl="interpret"``.

Inputs accept (B, S, d_in) token blocks or (B, d_in) single-token decode
rows; ``idx`` is the (B,) int32 pool-slot vector from the AdapterStore.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.batched_lora.bgmv import bgmv_matmul, bgmv_mag_matmul
from repro.kernels.batched_lora.ref import bgmv_ref, bgmv_mag_ref
from repro.obs.tracing import named_scope

_BS = 256                       # token-block size for the Pallas grid


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl):
    if impl is None:
        return "pallas" if _on_tpu() else "einsum"
    if impl not in ("pallas", "interpret", "einsum"):
        raise ValueError(f"unknown bgmv impl {impl!r}")
    return impl


def _pad_tokens(x):
    """Pad S up to a block multiple for the Pallas grid (zero token rows
    contribute zero delta and are sliced back off)."""
    S = x.shape[1]
    bs = min(_BS, S)
    pad = -S % bs
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x, S, bs


def bgmv(x, a_pool, b_pool, idx, *, scale: float = 1.0, impl=None,
         ranks=None):
    """y[i] = scale · (x[i] @ a_pool[idx[i]]) @ b_pool[idx[i]].

    ``ranks`` (L,) int32: heterogeneous pool — rank rows ≥ ranks[idx[i]]
    are masked out of row i (see bgmv.py)."""
    impl = _resolve(impl)
    squeeze = x.ndim == 2
    if squeeze:
        x = x[:, None, :]
    with named_scope("kernels/bgmv"):
        if impl == "einsum":
            y = bgmv_ref(x, a_pool, b_pool, idx, scale, ranks=ranks)
        else:
            xp, S, bs = _pad_tokens(x)
            y = bgmv_matmul(xp, a_pool, b_pool, idx, ranks, scale=scale,
                            bs=bs,
                            interpret=(impl == "interpret") or not _on_tpu())
            y = y[:, :S]
    return y[:, 0] if squeeze else y


def bgmv_mag(x, a_dir, a_mag, b_mag, dmag_pool, b_dir, idx, *,
             scale: float = 1.0, impl=None, ranks=None):
    """Decomposed-DoRA magnitude path (raw-delta pool):
    y[i] = scale · (((x[i] ⊙ a_mag) @ a_dir)
                    ⊙ (b_mag + dmag_pool[idx[i]])) @ b_dir.

    ``ranks`` (L,) int32: heterogeneous pool — the magnitude product ≥
    the slot's rank is masked per row (shared b_mag rows included, so a
    rank-0 slot serves the bare backbone)."""
    impl = _resolve(impl)
    squeeze = x.ndim == 2
    if squeeze:
        x = x[:, None, :]
    with named_scope("kernels/bgmv_mag"):
        if impl == "einsum":
            y = bgmv_mag_ref(x, a_dir, a_mag, b_mag, dmag_pool, b_dir, idx,
                             scale, ranks=ranks)
        else:
            xp, S, bs = _pad_tokens(x)
            y = bgmv_mag_matmul(xp, a_dir, a_mag, b_mag, dmag_pool, b_dir,
                                idx, ranks, scale=scale, bs=bs,
                                interpret=(impl == "interpret")
                                or not _on_tpu())
            y = y[:, :S]
    return y[:, 0] if squeeze else y


__all__ = ["bgmv", "bgmv_mag", "bgmv_ref", "bgmv_mag_ref"]
