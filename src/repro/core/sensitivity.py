"""Fig.-1 sensitivity analysis (paper Eqs. 2–3).

Given adapters fine-tuned per downstream task and adapters fine-tuned on
the all-task mixture, measure for each LoRA factor:

  ΔM (Eq. 2):  mean_|columns| |m_task − m_all|      (magnitude shift)
  ΔD (Eq. 3):  mean_columns (1 − cos(dir_task, dir_all))  (direction shift)

averaged over layers/targets.  The paper's observations:
  Obs. 1  ΔD(A) ≈ 1.7 × ΔD(B)
  Obs. 2  ΔM(B) ≈ 41 × ΔM(A)
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.core import dora
from repro.utils import pytree as pt


def _collect_factors(adapters: Any) -> dict[str, list]:
    """Pull raw or decomposed LoRA factors per target: {'A': [...], 'B': [...]}"""
    out: dict[str, dict[str, Any]] = {}
    leaves = jax.tree_util.tree_leaves_with_path(adapters)
    for p, x in leaves:
        path = pt.path_str(p)
        prefix, name = path.rsplit("/", 1)
        out.setdefault(prefix, {})[name] = x
    factors: dict[str, list] = {"A": [], "B": []}
    for prefix, d in out.items():
        if "lora_A" in d:
            factors["A"].append(np.asarray(d["lora_A"], np.float32))
            factors["B"].append(np.asarray(d["lora_B"], np.float32))
        elif "A_dir" in d:
            A, B = dora.recompose_lora_pair(d)
            factors["A"].append(np.asarray(A, np.float32))
            factors["B"].append(np.asarray(B, np.float32))
    return factors


def _delta_m(x_task: np.ndarray, x_all: np.ndarray) -> float:
    m_t = np.linalg.norm(x_task, axis=-1)
    m_a = np.linalg.norm(x_all, axis=-1)
    return float(np.mean(np.abs(m_t - m_a)))            # Eq. 2


def _delta_d(x_task: np.ndarray, x_all: np.ndarray) -> float:
    eps = 1e-12
    n_t = np.linalg.norm(x_task, axis=-1, keepdims=True)
    n_a = np.linalg.norm(x_all, axis=-1, keepdims=True)
    d_t = x_task / (n_t + eps)
    d_a = x_all / (n_a + eps)
    cos = np.sum(d_t * d_a, axis=-1)
    # zero-magnitude columns (B_mag = 0 at the DoRA-faithful init) have no
    # direction — exclude them instead of reporting 1 − cos(0,0) = 1
    valid = ((n_t[..., 0] > 1e-9) & (n_a[..., 0] > 1e-9))
    if not np.any(valid):
        return 0.0
    return float(np.mean((1.0 - cos)[valid]))           # Eq. 3


def sensitivity_report(task_adapters: dict[str, Any],
                       all_adapters: Any) -> dict:
    """task_adapters: {task_name: adapter_tree}; all_adapters: the
    all-task fine-tune.  Returns per-task and mean ΔM/ΔD for A and B plus
    the two observation ratios."""
    ref = _collect_factors(all_adapters)
    rows = {}
    for task, ad in task_adapters.items():
        fac = _collect_factors(ad)
        rows[task] = {
            "dM_A": float(np.mean([_delta_m(t, a) for t, a in zip(fac["A"], ref["A"])])),
            "dM_B": float(np.mean([_delta_m(t, a) for t, a in zip(fac["B"], ref["B"])])),
            "dD_A": float(np.mean([_delta_d(t, a) for t, a in zip(fac["A"], ref["A"])])),
            "dD_B": float(np.mean([_delta_d(t, a) for t, a in zip(fac["B"], ref["B"])])),
        }
    mean = {k: float(np.mean([r[k] for r in rows.values()]))
            for k in ("dM_A", "dM_B", "dD_A", "dD_B")}
    eps = 1e-12
    return {
        "per_task": rows,
        "mean": mean,
        "obs1_dir_ratio_A_over_B": mean["dD_A"] / (mean["dD_B"] + eps),
        "obs2_mag_ratio_B_over_A": mean["dM_B"] / (mean["dM_A"] + eps),
    }
