"""AdapterStore: slot pooling, LRU register/evict, checkpoint roundtrip,
and rejection of rank/target-mismatched adapters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint
from repro.core import peft
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.serve import AdapterStore
from repro.utils import pytree as pt

CFG = ArchConfig(name="store-t", family="dense", n_layers=2, d_model=32,
                 n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                 dtype="float32", lora_rank=4, lora_dropout=0.0)


@pytest.fixture(scope="module")
def base():
    return M.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def shared(base):
    return peft.add_lora(base, CFG, jax.random.PRNGKey(1), decomposed=True)


def _raw_adapter(base, seed, rank=0):
    return peft.add_lora(base, CFG, jax.random.PRNGKey(seed),
                         decomposed=False, rank=rank)


def _mag_overlay(shared, seed):
    key = jax.random.PRNGKey(seed)
    full = pt.tree_map_with_path(
        lambda p, x: x + 0.1 * jax.random.normal(
            jax.random.fold_in(key, hash(p) % 2**30), x.shape)
        if p.endswith("dB_mag") else x, shared)
    return pt.filter_tree(full, lambda p: p.endswith("dB_mag"))


def test_register_assigns_slots_and_pools(base):
    store = AdapterStore(base, CFG, n_slots=3, kind="pairs")
    s0 = store.register("alice", _raw_adapter(base, 2))
    s1 = store.register("bob", _raw_adapter(base, 3))
    assert s0 != s1 and "alice" in store and "bob" in store
    ov = store.overlay()
    leaves = pt.tree_paths(ov)
    assert any(p.endswith("pool_A") for p in leaves)
    # registered slots hold the adapter; the null slot stays zero
    for p, leaf in zip(pt.tree_paths(ov), jax.tree.leaves(ov)):
        if p.endswith("pool_A"):
            slot_axis = leaf.ndim - 3          # lead? + (L, d_in, r)
            null = jnp.take(leaf, store.null_slot, axis=slot_axis)
            assert float(jnp.abs(null).max()) == 0.0
            reg = jnp.take(leaf, s0, axis=slot_axis)
            assert float(jnp.abs(reg).max()) > 0.0


def test_lru_evict_and_slot_reuse(base):
    store = AdapterStore(base, CFG, n_slots=2, kind="pairs")
    store.register("a", _raw_adapter(base, 2))
    s_b = store.register("b", _raw_adapter(base, 3))
    store.slot_of("a")                          # touch a → b becomes LRU
    s_c = store.register("c", _raw_adapter(base, 4))
    assert s_c == s_b                           # b's slot reused
    assert "b" not in store and "a" in store and "c" in store
    # explicit evict zeroes the slot
    store.evict("c")
    ov = store.overlay()
    for p, leaf in zip(pt.tree_paths(ov), jax.tree.leaves(ov)):
        if p.endswith("pool_A"):
            slot_axis = leaf.ndim - 3
            assert float(jnp.abs(jnp.take(leaf, s_c, axis=slot_axis)).max()) \
                == 0.0


def test_reregister_updates_in_place(base):
    store = AdapterStore(base, CFG, n_slots=2, kind="pairs")
    s0 = store.register("a", _raw_adapter(base, 2))
    s1 = store.register("a", _raw_adapter(base, 9))
    assert s0 == s1 and len(store.tenants) == 1


def test_rejects_rank_and_target_mismatch(base, shared):
    store = AdapterStore(base, CFG, n_slots=2, kind="pairs")
    with pytest.raises(ValueError, match="mismatch"):
        store.register("bad-rank", _raw_adapter(base, 2, rank=8))
    with pytest.raises(ValueError, match="missing target"):
        store.register("empty", {})
    # leaves outside the store's targets (e.g. an o_proj adapter when the
    # config targets q/v) are rejected rather than silently dropped
    import dataclasses
    wide_cfg = dataclasses.replace(CFG, lora_targets=("q_proj", "v_proj",
                                                      "o_proj"))
    wide = peft.add_lora(M.init_params(jax.random.PRNGKey(0), wide_cfg),
                         wide_cfg, jax.random.PRNGKey(5))
    with pytest.raises(ValueError, match="outside"):
        store.register("too-wide", wide)
    mag_store = AdapterStore(base, CFG, n_slots=2, kind="dora_mag",
                             shared=shared)
    with pytest.raises(ValueError, match="dB_mag"):
        mag_store.register("no-mags", _raw_adapter(base, 2))


def test_dora_mag_kind_needs_shared(base):
    with pytest.raises(ValueError, match="shared"):
        AdapterStore(base, CFG, n_slots=2, kind="dora_mag")


def test_bytes_per_tenant_is_tiny_for_mag_kind(base, shared):
    mag_store = AdapterStore(base, CFG, n_slots=2, kind="dora_mag",
                             shared=shared)
    pair_store = AdapterStore(base, CFG, n_slots=2, kind="pairs")
    # ΔB_M payload: 4 bytes · r per target per layer — a few hundred bytes
    n_targets = sum(
        (int(np.prod(lead)) if lead else 1)
        for lead, _, _ in mag_store.targets.values())
    assert mag_store.bytes_per_tenant() == 4 * CFG.lora_rank * n_targets
    assert mag_store.bytes_per_tenant() < pair_store.bytes_per_tenant() // 8


def test_server_rank_pool_above_cfg_rank(base):
    """A server-rank fleet (server_rank=16 > cfg.lora_rank=4) must pool
    without truncation: tenants of ranks {2, 4, 8} and the rank-16
    server adapter all register, each at its true rank."""
    store = AdapterStore(base, CFG, n_slots=4, kind="pairs", rank=16)
    assert store.rank == 16
    for t, r in enumerate((2, 4, 8, 16)):
        store.register(f"t{t}", _raw_adapter(base, 10 + t, rank=r))
        assert store.rank_of(f"t{t}") == r
    ov = store.overlay()
    for p, leaf in zip(pt.tree_paths(ov), jax.tree.leaves(ov)):
        if p.endswith("pool_A"):
            assert leaf.shape[-1] == 16, p


def test_register_explicit_rank_for_padded_fleet_adapters(base):
    """A heterogeneous fleet allocates every client's adapters at the
    server rank (rows above the client's own rank are zero) — the shape
    alone over-states the rank, so register(rank=) records the true one
    (and rejects a rank above the leaves' allocation)."""
    store = AdapterStore(base, CFG, n_slots=2, kind="pairs", rank=16)
    ad16 = _raw_adapter(base, 21, rank=16)
    masks = peft.client_rank_masks(ad16, jnp.asarray([4]))
    padded = jax.tree.map(lambda x, m: x * m[0], ad16, masks)
    store.register("fleet4", padded, rank=4)
    assert store.rank_of("fleet4") == 4
    with pytest.raises(ValueError, match="mismatch"):
        store.register("bad", padded, rank=32)


def test_dora_mag_pool_follows_shared_server_rank(base):
    """kind='dora_mag' with a server-rank shared tree must allocate the
    pool at the shared tree's rank (it used to pin to cfg.lora_rank and
    reject the fleet), and tenants below it pad in at their true rank."""
    shared16 = peft.add_lora(base, CFG, jax.random.PRNGKey(7),
                             decomposed=True, rank=16)
    store = AdapterStore(base, CFG, n_slots=3, kind="dora_mag",
                         shared=shared16)
    assert store.rank == 16
    for t, r in enumerate((2, 8, 16)):
        overlay = pt.tree_map_with_path(
            lambda p, x: 0.1 * (t + 1) * jnp.ones(x.shape[:-1] + (r,)),
            pt.filter_tree(shared16, lambda p: p.endswith("dB_mag")))
        store.register(f"m{t}", overlay)
        assert store.rank_of(f"m{t}") == r
    for p, leaf in zip(pt.tree_paths(store.overlay()),
                       jax.tree.leaves(store.overlay())):
        if p.endswith("pool_dB_mag"):
            assert leaf.shape[-1] == 16, p


def test_dora_mag_pool_stores_raw_deltas(base, shared):
    """The magnitude pool holds the RAW ΔB_M (the shared B_mag lives in
    its own bgmv_B_mag leaf) so the kernel's rank mask can cover the
    magnitude rows; evicting zeroes the slot's delta and its rank."""
    store = AdapterStore(base, CFG, n_slots=2, kind="dora_mag",
                         shared=shared)
    overlay = _mag_overlay(shared, 4)
    slot = store.register("alice", overlay)
    ov = store.overlay()
    for prefix in store.targets:
        got = pt.tree_get(ov, f"{prefix}/pool_dB_mag")
        want = pt.tree_get(overlay, f"{prefix}/dB_mag")
        lead, _, _ = store.targets[prefix]
        idx = (slice(None), slot) if lead else (slot,)
        np.testing.assert_array_equal(np.asarray(got[idx]),
                                      np.asarray(want))
        np.testing.assert_array_equal(
            np.asarray(pt.tree_get(ov, f"{prefix}/bgmv_B_mag")),
            np.asarray(pt.tree_get(shared, f"{prefix}/B_mag")))
    assert int(pt.tree_get(ov, f"{list(store.targets)[0]}/pool_ranks"
                           ).reshape(-1)[slot]) == CFG.lora_rank
    store.evict("alice")
    ov = store.overlay()
    for prefix in store.targets:
        got = pt.tree_get(ov, f"{prefix}/pool_dB_mag")
        assert float(jnp.abs(got).max()) == 0.0
    assert store._slot_ranks[slot] == 0


def test_checkpoint_roundtrip(base, shared, tmp_path):
    path = str(tmp_path / "store.msgpack")
    store = AdapterStore(base, CFG, n_slots=3, kind="dora_mag", shared=shared)
    store.register("alice", _mag_overlay(shared, 1))
    store.register("bob", _mag_overlay(shared, 2))
    store.slot_of("alice")
    store.save(path, step=7)

    fresh = AdapterStore(base, CFG, n_slots=3, kind="dora_mag", shared=shared)
    assert fresh.load(path) == 7
    assert fresh.tenants == store.tenants
    assert fresh.slot_of("alice") == store._slot_of["alice"]
    for (pa, la), (pb, lb) in zip(
            zip(pt.tree_paths(store.overlay()),
                jax.tree.leaves(store.overlay())),
            zip(pt.tree_paths(fresh.overlay()),
                jax.tree.leaves(fresh.overlay()))):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # LRU state survives: bob is now least-recently-used, so a register
    # into the full... (3 slots, 2 used) — fill then add one more
    fresh.register("carol", _mag_overlay(shared, 3))
    fresh.register("dave", _mag_overlay(shared, 4))     # evicts bob (LRU)
    assert "bob" not in fresh and "alice" in fresh


def _legacy_b_mag_checkpoint(store, path, step=3, *, b_mag_shift=0.0):
    """Synthesize a pre-raw-delta dora_mag checkpoint from ``store``:
    each pool's raw ``pool_dB_mag`` is replaced by the old MERGED layout
    ``pool_B_mag[slot] = B_mag + ΔB_M`` on occupied slots' rank rows and
    zero elsewhere.  ``b_mag_shift`` perturbs the checkpoint's shared
    magnitude (consistently in both leaves) to fake a checkpoint written
    against a different shared tree."""
    st = store.state_tree()
    occupied = np.zeros((store.n_slots + 1,), bool)
    for slot in store._tenant_of:
        occupied[slot] = True
    mask = (occupied.reshape(-1, 1)
            & (np.arange(store.rank) < store._slot_ranks[:, None]))
    for p, pool in st["pools"].items():
        pool = dict(pool)
        db = np.asarray(pool.pop("pool_dB_mag"))
        b_mag = np.asarray(pool["bgmv_B_mag"]) + b_mag_shift
        pool["bgmv_B_mag"] = jnp.asarray(b_mag)
        pool["pool_B_mag"] = jnp.asarray((db + b_mag[..., None, :]) * mask,
                                         jnp.float32)
        st["pools"][p] = pool
    save_checkpoint(path, st, step=step)


def test_legacy_pool_b_mag_checkpoint_migrates(base, shared, tmp_path):
    """A pre-raw-delta checkpoint (merged pool_B_mag layout) loads with a
    warning and converts back to raw deltas matching the original store
    leaf-for-leaf."""
    path = str(tmp_path / "legacy.msgpack")
    store = AdapterStore(base, CFG, n_slots=3, kind="dora_mag", shared=shared)
    store.register("alice", _mag_overlay(shared, 1))
    store.register("bob", _mag_overlay(shared, 2))
    _legacy_b_mag_checkpoint(store, path, step=5)

    fresh = AdapterStore(base, CFG, n_slots=3, kind="dora_mag", shared=shared)
    with pytest.warns(UserWarning, match="pool_B_mag"):
        assert fresh.load(path) == 5
    assert fresh.tenants == ["alice", "bob"]
    assert fresh.rank_of("alice") == CFG.lora_rank
    for (pa, la), (pb, lb) in zip(
            zip(pt.tree_paths(store.overlay()),
                jax.tree.leaves(store.overlay())),
            zip(pt.tree_paths(fresh.overlay()),
                jax.tree.leaves(fresh.overlay()))):
        assert pa == pb
        # (db + b_mag) - b_mag re-derivation costs one f32 rounding
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-6, atol=1e-6)


def test_legacy_migration_rejects_foreign_b_mag(base, shared, tmp_path):
    """When the legacy checkpoint's shared B_mag disagrees with the
    store's, the merge is non-invertible and load must refuse rather
    than silently corrupt the deltas."""
    path = str(tmp_path / "legacy-foreign.msgpack")
    store = AdapterStore(base, CFG, n_slots=2, kind="dora_mag", shared=shared)
    store.register("alice", _mag_overlay(shared, 1))
    _legacy_b_mag_checkpoint(store, path, b_mag_shift=0.5)

    fresh = AdapterStore(base, CFG, n_slots=2, kind="dora_mag", shared=shared)
    with pytest.warns(UserWarning, match="pool_B_mag"), \
            pytest.raises(ValueError, match="different shared B_mag"):
        fresh.load(path)


def test_legacy_migration_rejects_shape_mismatch(base, shared, tmp_path):
    """A legacy checkpoint for a different slot allocation cannot be
    converted into this store."""
    path = str(tmp_path / "legacy-shape.msgpack")
    store = AdapterStore(base, CFG, n_slots=3, kind="dora_mag", shared=shared)
    store.register("alice", _mag_overlay(shared, 1))
    _legacy_b_mag_checkpoint(store, path)

    fresh = AdapterStore(base, CFG, n_slots=5, kind="dora_mag", shared=shared)
    with pytest.warns(UserWarning, match="pool_B_mag"), \
            pytest.raises(ValueError, match="not convertible"):
        fresh.load(path)
