"""Pallas TPU kernel: dequant-fused weight-only quantized matmul.

Decode is bytes-bound on backbone weights: at serving batch sizes the
MXU idles while HBM streams each (d_in, d_out) f32 kernel.  Storing the
kernel as int8 (or packed int4) plus per-group f32 scales cuts that
stream ~4× (~8×), and this kernel dequantizes INSIDE the matmul tile:
the quantized block and its scales are DMA'd to VMEM, widened and
scaled in-register, and fed straight to the MXU — a full-precision
weight matrix never exists in HBM.

Grid (M/bm, N/bn) with full-K tiles: each step streams one (K, bn)
quantized weight block (the bytes win) against a resident (bm, K)
activation block.  Layouts are ``ref.py``'s: int8 plain; int4 packed
two-nibbles-per-byte along K with a +8 bias (unpacked by interleave in
VMEM); scales (G, bn) per group of K/G input rows.

VMEM working set (bm=bn=256, K=4096): x(256·4096·4) + q(4096·256) +
w(4096·256·4) + out(256·256·4) ≈ 9.6 MB < 16 MB v5e VMEM at int8, and
the packed-int4 block is half again smaller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmm_kernel(x_ref, q_ref, s_ref, o_ref):
    x = x_ref[...]                                        # (bm, K)
    q = q_ref[...]                                        # (K|K/2, bn)
    if q.dtype == jnp.uint8:                              # packed int4
        lo = (q & 0xF).astype(jnp.int8) - 8
        hi = (q >> 4).astype(jnp.int8) - 8
        q = jnp.stack([lo, hi], axis=1).reshape(2 * q.shape[0], q.shape[1])
    G = s_ref.shape[0]
    K, bn = q.shape
    w = (q.astype(jnp.float32).reshape(G, K // G, bn)
         * s_ref[...][:, None, :]).reshape(K, bn)         # dequant in VMEM
    y = jax.lax.dot_general(
        x.astype(jnp.float32), w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bm, bn)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def quant_matmul_kernel(x, q, scale, *, bm: int = 256, bn: int = 256,
                        interpret: bool = False):
    """x (M, d_in) @ dequant(q, scale) → (M, d_out).

    q int8 (d_in, d_out) or packed-int4 uint8 (d_in/2, d_out); scale
    (G, d_out) f32.  M and d_out must be block multiples — the ops.py
    dispatcher pads and slices."""
    M, K = x.shape
    N = q.shape[-1]
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    kq = q.shape[0]                                       # K (int8) or K/2
    G = scale.shape[0]
    return pl.pallas_call(
        _qmm_kernel,
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((kq, bn), lambda i, j: (0, j)),
            pl.BlockSpec((G, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x, q, scale)
