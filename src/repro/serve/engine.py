"""Multi-tenant serving engine: one mixed batch, never-merged adapters.

The engine keeps a persistent batch of ``max_rows`` rows over one frozen
backbone merged (dict-merge, zero copies) with the AdapterStore's pooled
overlay.  Each row carries its own adapter slot (``adapter_idx``) and
its own sequence position, so tenants mix freely in a single forward
pass — the BGMV path in ``layers.linear`` gathers each row's adapter
from the pool instead of folding it into the weights.

Two jitted programs cover the whole serving loop, both with fixed
shapes so nothing recompiles as traffic flows:

  prefill   full-width (R, W) forward over newly admitted rows (idle
            rows compute throwaway work, a masked cache merge keeps
            mid-decode rows untouched) → first greedy token per row
  decode    one ``lax.scan`` of ``decode_chunk`` single-token steps with
            per-row cache positions; retired rows freeze (their writes
            are idempotent) until re-admission overwrites them

Between chunks the host retires finished rows and lets the batcher
admit queued requests into the free rows — continuous batching at
chunk granularity.  Greedy decoding, matching ``launch.serve``'s
reference generator bit-for-bit in float32.
"""
from __future__ import annotations

import os
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.serve.adapter_store import AdapterStore
from repro.serve.batcher import ContinuousBatcher
from repro.utils import pytree as pt

Params = Any


def _merge_cache_rows(old, new, admit_mask):
    """Take `new` cache rows where admit_mask, else keep `old`.  Batch
    sits at axis 1 under the scanned ``blocks`` (leading superblock axis)
    and axis 0 in the unstacked ``tail``."""
    def sel(axis):
        def f(o, n):
            shape = [1] * o.ndim
            shape[axis] = admit_mask.shape[0]
            return jnp.where(admit_mask.reshape(shape), n, o)
        return f
    return {"blocks": jax.tree.map(sel(1), old["blocks"], new["blocks"]),
            "tail": jax.tree.map(sel(0), old["tail"], new["tail"])}


class ServeEngine:
    def __init__(self, base: Params, cfg: ArchConfig, store: AdapterStore, *,
                 max_rows: int = 8, max_prompt_len: int = 32,
                 max_len: int = 64, decode_chunk: int = 8):
        if cfg.family not in ("dense", "moe") or cfg.n_enc_layers:
            raise ValueError(f"ServeEngine supports attention-cache "
                             f"families, got {cfg.family!r}")
        if cfg.sliding_window or cfg.local_global:
            # ring-buffer caches index slots by (position % window); the
            # padded full-width prefill and per-row valid masks here
            # assume linear slot == position — serving a windowed config
            # would silently drop real prefix tokens for short prompts
            raise ValueError("sliding-window (local) attention is not "
                             "supported by ServeEngine yet")
        if cfg.backbone_quant:
            # store the frozen backbone quantized (int8/int4 + per-channel
            # or grouped scales, per cfg.backbone_quant_group); the
            # per-tenant BGMV deltas stay f32 on top, so one quantize
            # pass serves every tenant
            from repro.kernels import quantize_backbone
            base = quantize_backbone(base, cfg.backbone_quant,
                                     group_size=cfg.backbone_quant_group)
        self.base, self.cfg, self.store = base, cfg, store
        self.max_rows = max_rows
        self.max_len = max_len
        self.decode_chunk = decode_chunk
        self.batcher = ContinuousBatcher(max_rows, max_prompt_len, max_len)
        self._tenant_of_rid: dict[int, str] = {}

        def prefill_fn(params, cache, tokens, lens, slots, admit_mask):
            batch = {"tokens": tokens, "adapter_idx": slots}
            hidden, fresh, _ = M.forward(params, batch, cfg,
                                         return_cache=True, cache_len=max_len)
            rows = jnp.arange(tokens.shape[0])
            last = hidden[rows, lens - 1]               # per-row true last
            logits = (last @ M._head_kernel(params, cfg).astype(last.dtype)
                      ).astype(jnp.float32)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return tok, _merge_cache_rows(cache, fresh, admit_mask)

        def chunk_fn(params, cache, tok, pos, slots, active):
            def body(carry, _):
                tok, cache, pos = carry
                logits, cache = M.decode_step(params, tok, cache, pos, cfg,
                                              adapter_idx=slots)
                ntok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                ntok = jnp.where(active, ntok, tok)     # freeze retired rows
                pos = pos + active.astype(jnp.int32)
                return (ntok, cache, pos), ntok
            (tok, cache, pos), toks = jax.lax.scan(
                body, (tok, cache, pos), length=decode_chunk)
            return tok, cache, pos, toks                # toks (chunk, R)

        # obs.annotate names the two jitted programs in profiler traces
        # (host wrapper only — the compiled computations are untouched)
        self._prefill = obs.annotate("serve/prefill")(
            jax.jit(prefill_fn, donate_argnums=(1,)))
        self._chunk = obs.annotate("serve/decode_chunk")(
            jax.jit(chunk_fn, donate_argnums=(1,)))
        self._compiled: set[str] = set()   # compile-event bookkeeping
        self._params_cache: tuple[int, Params] | None = None

    def _merged_params(self) -> Params:
        """Backbone ∪ pool overlay, rebuilt only when the store's pools
        actually changed (keyed on ``store.version`` — the tiered
        store's batched hot-swap bumps it once per install, and the
        donated scatter invalidates the old pool buffers, so a stale
        merge must never be reused)."""
        if (self._params_cache is None
                or self._params_cache[0] != self.store.version):
            self._params_cache = (self.store.version,
                                  pt.merge_trees(self.base,
                                                 self.store.overlay()))
        return self._params_cache[1]

    # ------------------------------------------------------------------

    def submit(self, tenant: str, tokens, n_new: int) -> int:
        """Queue one request.  The tenant must be registered in the store
        (or be the empty-adapter pseudo-tenant None)."""
        if tenant is not None and tenant not in self.store:
            raise KeyError(f"tenant {tenant!r} not registered in the store")
        rid = self.batcher.submit(tenant or "", tokens, n_new)
        self._tenant_of_rid[rid] = tenant
        if obs.enabled():
            obs.inc("serve/requests", tenant=tenant or "<none>")
            obs.set_gauge("serve/queue_depth", self.batcher.pending)
        return rid

    def run(self) -> dict[int, np.ndarray]:
        """Drain the queue, returning {rid: generated tokens (n_new,)}.

        Adapter slots are snapshotted per admission — register/evict
        between ``run`` calls, not during one.  With a tiered store,
        admission promotes each admitted tenant's adapter (T2→T1→T0,
        one batched device scatter), pinning active rows and consulting
        the batcher queue for victims; queued tenants prefetch toward
        the host cache while each decode chunk runs.
        """
        cfg, R = self.cfg, self.max_rows
        params = self._merged_params()
        cache = M.init_cache(cfg, R, self.max_len)

        # telemetry is sampled once per run; everything below is behind
        # ``if enabled`` so the disabled path adds no clock reads and no
        # device syncs beyond the np.asarray pulls it always did
        enabled = obs.enabled()
        t_run0 = time.perf_counter() if enabled else 0.0
        n_chunks = n_prefills = 0

        active = np.zeros((R,), bool)
        pos = jnp.zeros((R,), jnp.int32)
        tok = jnp.zeros((R,), jnp.int32)
        row_slots = np.full((R,), self.store.null_slot, np.int32)
        remaining = np.zeros((R,), np.int64)
        rid_of_row = np.full((R,), -1, np.int64)
        outputs: dict[int, list[int]] = {}
        results: dict[int, np.ndarray] = {}

        def gauges():
            # batch composition only changes at admit/retire — sampling
            # the occupancy gauges there (not per chunk) keeps the
            # per-chunk telemetry down to the two timing observes
            obs.set_gauge("serve/queue_depth", self.batcher.pending)
            obs.set_gauge("serve/slot_occupancy", float(active.mean()))
            obs.set_gauge(
                "serve/null_slot_fraction",
                float((row_slots == self.store.null_slot).mean()))

        def retire(row):
            rid = int(rid_of_row[row])
            results[rid] = np.asarray(outputs.pop(rid), np.int32)
            if enabled:
                tenant = self._tenant_of_rid.get(rid)
                obs.inc("serve/completed", tenant=tenant or "<none>")
            self._tenant_of_rid.pop(rid, None)      # don't leak rid→tenant
            active[row] = False
            row_slots[row] = self.store.null_slot
            if enabled:
                gauges()

        while self.batcher.pending or active.any():
            free = [r for r in range(R) if not active[r]]
            admitted = self.batcher.admit(free)
            if admitted:
                if enabled:
                    now = time.perf_counter()
                    for row, req in admitted:
                        wait = now - req.submit_ts
                        # admission waits on a drained queue are tens of
                        # microseconds — LATENCY_BOUNDS keeps them out
                        # of one collapsed first bucket
                        obs.observe("serve/admission_wait_seconds", wait,
                                    bounds=obs.LATENCY_BOUNDS,
                                    tenant=req.tenant or "<none>")
                        obs.event("serve_admit", rid=req.rid,
                                  tenant=req.tenant or None, row=row,
                                  wait=round(wait, 6),
                                  queue_depth=self.batcher.pending)
                # one batched install covers every admitted tenant:
                # active rows are hard-pinned (their slots are serving)
                # and the near front of the queue informs victim choice
                need = [self._tenant_of_rid[req.rid] for _, req in admitted
                        if self._tenant_of_rid[req.rid] is not None]
                still_active = {self._tenant_of_rid.get(int(rid_of_row[r]))
                                for r in range(R) if active[r]}
                still_active.discard(None)
                installed = self.store.install_batch(
                    need, pinned=still_active,
                    queued=self.batcher.queued_tenants(limit=2 * R))
                slot_of_rid = {
                    req.rid: (self.store.null_slot
                              if self._tenant_of_rid[req.rid] is None else
                              installed[self._tenant_of_rid[req.rid]])
                    for _, req in admitted}
                params = self._merged_params()
                tokens, lens, row_slots = self.batcher.pack_prompts(
                    admitted, slot_of_rid, self.store.null_slot, row_slots)
                admit_mask = np.zeros((R,), bool)
                for row, _ in admitted:
                    admit_mask[row] = True
                t0 = time.perf_counter() if enabled else 0.0
                tok0, cache = self._prefill(
                    params, cache, jnp.asarray(tokens),
                    jnp.asarray(lens), jnp.asarray(row_slots),
                    jnp.asarray(admit_mask))
                tok0_h = np.asarray(tok0)
                if enabled:
                    dt = time.perf_counter() - t0
                    if "prefill" not in self._compiled:
                        self._compiled.add("prefill")
                        obs.event("compile", program="serve/prefill",
                                  wall=round(dt, 6))
                    obs.observe("serve/prefill_seconds", dt,
                                bounds=obs.LATENCY_BOUNDS)
                    obs.observe("span_seconds", dt, span="serve/prefill")
                    n_prefills += 1
                    gauges()
                tok = jnp.where(jnp.asarray(admit_mask), tok0, tok)
                new_pos = np.asarray(pos).copy()
                for row, req in admitted:
                    active[row] = True
                    new_pos[row] = req.tokens.size
                    remaining[row] = req.n_new - 1
                    rid_of_row[row] = req.rid
                    outputs[req.rid] = [int(tok0_h[row])]
                    if remaining[row] == 0:
                        retire(row)
                pos = jnp.asarray(new_pos)

            if active.any():
                n_active = int(active.sum())
                # queued tenants' shards load toward T1 while the scan
                # runs (flat store: no-op); the drain after the chunk
                # folds whatever completed into the host cache
                self.store.prefetch(self.batcher.queued_tenants(limit=2 * R))
                t0 = time.perf_counter() if enabled else 0.0
                tok, cache, pos, toks = self._chunk(
                    params, cache, tok, pos, jnp.asarray(row_slots),
                    jnp.asarray(active))
                toks_h = np.asarray(toks)               # (chunk, R)
                self.store.drain_prefetch()
                if enabled:
                    dt = time.perf_counter() - t0
                    if "decode_chunk" not in self._compiled:
                        self._compiled.add("decode_chunk")
                        obs.event("compile", program="serve/decode_chunk",
                                  wall=round(dt, 6))
                    produced = n_active * self.decode_chunk
                    obs.observe("serve/decode_chunk_seconds", dt,
                                bounds=obs.LATENCY_BOUNDS)
                    obs.observe("span_seconds", dt, span="serve/decode_chunk")
                    obs.observe("serve/chunk_tokens_per_s",
                                produced / max(dt, 1e-9))
                    n_chunks += 1
                for row in range(R):
                    if not active[row]:
                        continue
                    take = int(min(self.decode_chunk, remaining[row]))
                    outputs[int(rid_of_row[row])].extend(
                        toks_h[:take, row].tolist())
                    remaining[row] -= take
                    if remaining[row] == 0:
                        retire(row)
        if enabled:
            wall = time.perf_counter() - t_run0
            total_toks = int(sum(len(v) for v in results.values()))
            gauges()
            obs.event("serve_run", requests=len(results), tokens=total_toks,
                      wall=round(wall, 6),
                      tokens_per_s=round(total_toks / max(wall, 1e-9), 2),
                      chunks=n_chunks, prefills=n_prefills,
                      rows=R, decode_chunk=self.decode_chunk)
            prom_path = os.environ.get("REPRO_PROM_PATH")
            if prom_path:
                # Prometheus textfile-collector hook: dump the registry
                # after each drained run, atomically so a concurrent
                # scrape never reads a torn file
                text = obs.to_prometheus(obs.active().metrics.snapshot())
                tmp = prom_path + ".tmp"
                with open(tmp, "w") as f:
                    f.write(text)
                os.replace(tmp, prom_path)
        return results

    def generate(self, requests, n_new: int = 16) -> list[np.ndarray]:
        """Convenience: ``requests`` is a list of (tenant, prompt_tokens);
        returns generated tokens per request, in order — one mixed batch
        across all tenants."""
        rids = [self.submit(tenant, toks, n_new) for tenant, toks in requests]
        results = self.run()
        return [results[rid] for rid in rids]
