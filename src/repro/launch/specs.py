"""ShapeDtypeStruct input specs + sharding specs for every
(architecture × input-shape × mesh) combination — no device allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import InputShape
from repro.core import peft
from repro.launch.mesh import data_axes, dp_size
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.utils import pytree as pt
from repro.utils.sharding import DEFAULT_PARAM_RULES, spec_for

Params = Any


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _bspec(mesh):
    ax = data_axes(mesh)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


# ---------------------------------------------------------------------------
# abstract param / adapter / cache trees (eval_shape — zero allocation)
# ---------------------------------------------------------------------------

def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def abstract_adapters(cfg: ArchConfig, n_clients: int = 0):
    base = abstract_params(cfg)

    def build():
        # eval_shape can't thread real PRNG use cheaply; adapters are tiny
        # but still built abstractly for uniformity.
        ad = peft.add_lora(base_concrete, cfg, jax.random.PRNGKey(0),
                           decomposed=True)
        return ad

    # peft.add_lora only reads shapes from base leaves; give it structs.
    base_concrete = base
    ad = jax.eval_shape(build)
    if n_clients:
        ad = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((n_clients,) + x.shape, x.dtype), ad)
    return ad


def abstract_cache(cfg: ArchConfig, batch: int, seq_len: int):
    return jax.eval_shape(lambda: M.init_cache(cfg, batch, seq_len))


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def param_specs(cfg: ArchConfig, mesh, tree):
    return pt.tree_map_with_path(
        lambda p, x: NamedSharding(
            mesh, spec_for(p, len(x.shape), DEFAULT_PARAM_RULES, mesh)), tree)


def adapter_specs(mesh, tree, client_axis: bool):
    """Adapters: tiny → replicated, except the leading client axis (if any)
    which is sharded over the data axes (1 client per data shard)."""
    b = _bspec(mesh)

    def fn(p, x):
        if client_axis:
            return NamedSharding(mesh, P(b, *([None] * (len(x.shape) - 1))))
        return NamedSharding(mesh, P())

    return pt.tree_map_with_path(fn, tree)


def cache_specs(cfg: ArchConfig, mesh, tree, batch: int,
                seq_shard_kv: bool = False):
    """KV caches: batch over data axes when batch ≥ dp, else shard the seq
    axis (long-context, batch=1).  Head/state dims over 'model' when they
    divide.

    seq_shard_kv (hillclimb variant): shard the cache SEQ dim over 'model'
    instead of splitting head_dim — when kv_heads < tp the baseline layout
    forces XLA to all-gather the whole cache every layer (measured 68 GB
    per decode step on qwen3-32b); flash-decoding-style sequence sharding
    replaces that with small softmax-stat/partial-output reductions."""
    b = _bspec(mesh)
    dp = dp_size(mesh)
    tp = mesh.shape["model"]

    def fn(path, x):
        shp = x.shape
        if path.endswith("/k") or path.endswith("/v"):
            # (n_sb?, B, S, K, dh)
            B, S, K, dh = shp[-4], shp[-3], shp[-2], shp[-1]
            lead = [None] * (len(shp) - 4)
            if batch >= dp and B % dp == 0:
                if seq_shard_kv and K % tp and S % tp == 0:
                    spec = lead + [b, "model", None, None]
                else:
                    spec = lead + [b, None,
                                   "model" if K % tp == 0 else None,
                                   "model" if (K % tp and dh % tp == 0) else None]
                    if spec[-2] == "model":
                        spec[-1] = None
            else:
                spec = lead + [None, b, None,
                               "model" if dh % tp == 0 else None]
            return NamedSharding(mesh, P(*spec))
        if path.endswith("/state"):
            # (n_sb?, B, H, P, N)
            B, H = shp[-4], shp[-3]
            lead = [None] * (len(shp) - 4)
            spec = lead + [b if (batch >= dp and B % dp == 0) else None,
                           "model" if H % tp == 0 else None, None, None]
            return NamedSharding(mesh, P(*spec))
        if "conv" in path:
            B, C = shp[-3], shp[-1]
            lead = [None] * (len(shp) - 3)
            spec = lead + [b if (batch >= dp and B % dp == 0) else None,
                           None, "model" if C % tp == 0 else None]
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return pt.tree_map_with_path(fn, tree)


# ---------------------------------------------------------------------------
# input specs per shape kind
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepSpec:
    """Everything dryrun needs: fn args as ShapeDtypeStructs + shardings."""
    args: tuple
    in_shardings: tuple
    out_shardings: Any


def train_batch_specs(cfg: ArchConfig, shape: InputShape, mesh,
                      n_clients: int):
    """Stacked federated batch (C, B_c, S) + frontend embeddings."""
    b = _bspec(mesh)
    B_c = shape.global_batch // n_clients
    S = shape.seq_len
    S_tok = S
    extras = {}
    if cfg.frontend and not cfg.n_enc_layers:
        S_mm = min(cfg.frontend_tokens, S // 2)
        S_tok = S - S_mm
        extras["frontend_emb"] = (
            jax.ShapeDtypeStruct((n_clients, B_c, S_mm, cfg.d_model), _dt(cfg)),
            NamedSharding(mesh, P(b, None, None, None)))
    if cfg.n_enc_layers:
        S_tok = S // 2
        extras["frontend_emb"] = (
            jax.ShapeDtypeStruct((n_clients, B_c, S // 2, cfg.d_model), _dt(cfg)),
            NamedSharding(mesh, P(b, None, None, None)))
    batch = {
        "tokens": (jax.ShapeDtypeStruct((n_clients, B_c, S_tok), jnp.int32),
                   NamedSharding(mesh, P(b, None, None))),
        "loss_mask": (jax.ShapeDtypeStruct((n_clients, B_c, S_tok), jnp.float32),
                      NamedSharding(mesh, P(b, None, None))),
        **extras,
    }
    args = {k: v[0] for k, v in batch.items()}
    shardings = {k: v[1] for k, v in batch.items()}
    return args, shardings


def serve_batch_specs(cfg: ArchConfig, shape: InputShape, mesh):
    """Prefill inputs (B, S)."""
    b = _bspec(mesh)
    B, S = shape.global_batch, shape.seq_len
    dp = dp_size(mesh)
    bs = b if B % dp == 0 and B >= dp else None
    S_tok = S
    extras = {}
    if cfg.frontend and not cfg.n_enc_layers:
        S_mm = min(cfg.frontend_tokens, S // 2)
        S_tok = S - S_mm
        extras["frontend_emb"] = (
            jax.ShapeDtypeStruct((B, S_mm, cfg.d_model), _dt(cfg)),
            NamedSharding(mesh, P(bs, None, None)))
    if cfg.n_enc_layers:
        S_tok = S // 2
        extras["frontend_emb"] = (
            jax.ShapeDtypeStruct((B, S // 2, cfg.d_model), _dt(cfg)),
            NamedSharding(mesh, P(bs, None, None)))
    batch = {
        "tokens": (jax.ShapeDtypeStruct((B, S_tok), jnp.int32),
                   NamedSharding(mesh, P(bs, None))),
        **extras,
    }
    args = {k: v[0] for k, v in batch.items()}
    shardings = {k: v[1] for k, v in batch.items()}
    return args, shardings


def decode_specs(cfg: ArchConfig, shape: InputShape, mesh,
                 seq_shard_kv: bool = False):
    """One-token decode: token ids, cache, index (+ enc_out for enc-dec)."""
    b = _bspec(mesh)
    B, S = shape.global_batch, shape.seq_len
    dp = dp_size(mesh)
    bs = b if B % dp == 0 and B >= dp else None
    S_cache = S // 2 if cfg.n_enc_layers else S
    cache = abstract_cache(cfg, B, S_cache)
    cspecs = cache_specs(cfg, mesh, cache, B, seq_shard_kv=seq_shard_kv)
    tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    args = {"new_token": tok, "cache": cache, "cache_index": idx}
    shardings = {"new_token": NamedSharding(mesh, P(bs)),
                 "cache": cspecs,
                 "cache_index": NamedSharding(mesh, P())}
    if cfg.n_enc_layers:
        args["enc_out"] = jax.ShapeDtypeStruct((B, S // 2, cfg.d_model), _dt(cfg))
        shardings["enc_out"] = NamedSharding(mesh, P(bs, None, None))
    return args, shardings
