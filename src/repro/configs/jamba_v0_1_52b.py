"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].  Attention layer every 8 sublayers; MoE replaces the
dense FFN on every 2nd sublayer.  Jamba uses Mamba-1 (d_state 16); our SSM
block is the SSD (Mamba-2) formulation of the same recurrence — noted in
DESIGN.md §7.  Attention is global (no SWA) — long_500k stays feasible
because only 4/32 layers carry a KV cache."""
from repro.models.config import ArchConfig, reduced

ARCH = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536,
    n_experts=16, top_k=2, moe_every=2,
    attn_every=8,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_headdim=64, ssm_groups=1,
    ssm_chunk=128,
    source="arXiv:2403.19887",
)
SMOKE = reduced(ARCH)
