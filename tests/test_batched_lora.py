"""BGMV (batched-LoRA) kernel: Pallas interpret mode vs jnp oracle, plus
the pooled-adapter path through ``layers.linear``."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bgmv, bgmv_mag

RNG = np.random.default_rng(11)


def _pairs(B, S, d, r, o, L, dt=jnp.float32):
    x = jnp.asarray(RNG.normal(size=(B, S, d)), dt)
    ap = jnp.asarray(RNG.normal(size=(L, d, r)) * 0.3, jnp.float32)
    bp = jnp.asarray(RNG.normal(size=(L, r, o)) * 0.3, jnp.float32)
    idx = jnp.asarray(RNG.integers(0, L, size=(B,)), jnp.int32)
    return x, ap, bp, idx


@pytest.mark.parametrize("B,S,d,r,o,L", [
    (4, 16, 64, 8, 96, 5),
    (2, 8, 128, 4, 64, 3),
    (8, 1, 32, 16, 32, 9),       # decode-shaped: one token per row
    (3, 24, 48, 8, 48, 1),       # single-slot pool
])
def test_bgmv_pallas_vs_ref(B, S, d, r, o, L):
    x, ap, bp, idx = _pairs(B, S, d, r, o, L)
    y_ref = bgmv(x, ap, bp, idx, scale=2.0, impl="einsum")
    y_pal = bgmv(x, ap, bp, idx, scale=2.0, impl="interpret")
    assert y_pal.shape == (B, S, o)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,S,d,r,o,L", [
    (4, 16, 64, 8, 96, 5),
    (6, 4, 96, 4, 32, 7),
])
def test_bgmv_mag_pallas_vs_ref(B, S, d, r, o, L):
    x = jnp.asarray(RNG.normal(size=(B, S, d)), jnp.float32)
    ad = jnp.asarray(RNG.normal(size=(d, r)) * 0.3, jnp.float32)
    am = jnp.asarray(RNG.uniform(0.5, 1.5, size=(d,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(r,)), jnp.float32)
    mp = jnp.asarray(RNG.normal(size=(L, r)), jnp.float32)
    bd = jnp.asarray(RNG.normal(size=(r, o)) * 0.3, jnp.float32)
    idx = jnp.asarray(RNG.integers(0, L, size=(B,)), jnp.int32)
    y_ref = bgmv_mag(x, ad, am, bm, mp, bd, idx, scale=4.0, impl="einsum")
    y_pal = bgmv_mag(x, ad, am, bm, mp, bd, idx, scale=4.0,
                     impl="interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_bgmv_gathers_the_right_slot():
    """Row i must use pool slot idx[i] — checked against per-row math."""
    B, S, d, r, o, L = 5, 6, 32, 4, 48, 4
    x, ap, bp, idx = _pairs(B, S, d, r, o, L)
    y = bgmv(x, ap, bp, idx, scale=1.5, impl="einsum")
    for i in range(B):
        s = int(idx[i])
        want = (x[i] @ ap[s]) @ bp[s] * 1.5
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def test_bgmv_decode_shape():
    """(B, d_in) single-token rows round-trip without the S axis."""
    B, S, d, r, o, L = 4, 1, 64, 8, 64, 3
    x, ap, bp, idx = _pairs(B, S, d, r, o, L)
    y2 = bgmv(x[:, 0], ap, bp, idx, impl="einsum")
    y3 = bgmv(x, ap, bp, idx, impl="einsum")
    assert y2.shape == (B, o)
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(y3[:, 0]))


def test_bgmv_pallas_pads_nondivisible_seq():
    """S not a multiple of the 256 token block must pad, not crash (the
    TPU default path hits this for any prompt > 256 tokens)."""
    B, S, d, r, o, L = 2, 300, 32, 4, 32, 3
    x, ap, bp, idx = _pairs(B, S, d, r, o, L)
    y_ref = bgmv(x, ap, bp, idx, scale=1.0, impl="einsum")
    y_pal = bgmv(x, ap, bp, idx, scale=1.0, impl="interpret")
    assert y_pal.shape == (B, S, o)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# heterogeneous pools (mixed per-slot ranks, padded to r_max)
# ---------------------------------------------------------------------------

def test_bgmv_ranked_pallas_vs_ref():
    """Rank-masked kernel (second scalar-prefetch vector) vs the masked
    einsum oracle, pool with ranks {2, 4, 8, 1, 3}."""
    B, S, d, r, o, L = 5, 16, 64, 8, 96, 5
    x, ap, bp, _ = _pairs(B, S, d, r, o, L)
    idx = jnp.arange(B, dtype=jnp.int32)
    ranks = jnp.asarray([2, 4, 8, 1, 3], jnp.int32)
    y_ref = bgmv(x, ap, bp, idx, scale=2.0, impl="einsum", ranks=ranks)
    y_pal = bgmv(x, ap, bp, idx, scale=2.0, impl="interpret", ranks=ranks)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_bgmv_ranked_equals_truncated_adapter():
    """Masking at rank rᵢ must equal running the slot's first rᵢ rank
    rows unpadded — i.e. stale/padded rows above a slot's rank can never
    leak into the output."""
    B, S, d, r, o, L = 4, 6, 32, 8, 48, 4
    x, ap, bp, idx = _pairs(B, S, d, r, o, L)
    ranks = jnp.asarray([1, 2, 4, 8], jnp.int32)
    y = bgmv(x, ap, bp, idx, scale=1.5, impl="einsum", ranks=ranks)
    for i in range(B):
        s = int(idx[i])
        rr = int(ranks[s])
        want = (x[i] @ ap[s, :, :rr]) @ bp[s, :rr] * 1.5
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_bgmv_mag_ranked_pallas_vs_ref():
    B, S, d, r, o, L = 6, 8, 96, 4, 32, 7
    x = jnp.asarray(RNG.normal(size=(B, S, d)), jnp.float32)
    ad = jnp.asarray(RNG.normal(size=(d, r)) * 0.3, jnp.float32)
    am = jnp.asarray(RNG.uniform(0.5, 1.5, size=(d,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(r,)), jnp.float32)
    mp = jnp.asarray(RNG.normal(size=(L, r)), jnp.float32)
    bd = jnp.asarray(RNG.normal(size=(r, o)) * 0.3, jnp.float32)
    idx = jnp.asarray(RNG.integers(0, L, size=(B,)), jnp.int32)
    ranks = jnp.asarray(RNG.integers(0, r + 1, size=(L,)), jnp.int32)
    y_ref = bgmv_mag(x, ad, am, bm, mp, bd, idx, scale=4.0, impl="einsum",
                     ranks=ranks)
    y_pal = bgmv_mag(x, ad, am, bm, mp, bd, idx, scale=4.0,
                     impl="interpret", ranks=ranks)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_bgmv_mag_ranked_masks_shared_rows_too():
    """The raw-delta magnitude path must serve slot rank rᵢ as the first
    rᵢ rows of the SHARED model plus the delta — rows ≥ rᵢ (including
    the shared B_mag contribution) are gone, and a rank-0 slot
    contributes exactly nothing."""
    B, S, d, r, o, L = 4, 6, 32, 8, 48, 4
    x = jnp.asarray(RNG.normal(size=(B, S, d)), jnp.float32)
    ad = jnp.asarray(RNG.normal(size=(d, r)) * 0.3, jnp.float32)
    am = jnp.asarray(RNG.uniform(0.5, 1.5, size=(d,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(r,)), jnp.float32)
    mp = jnp.asarray(RNG.normal(size=(L, r)), jnp.float32)
    bd = jnp.asarray(RNG.normal(size=(r, o)) * 0.3, jnp.float32)
    idx = jnp.arange(B, dtype=jnp.int32)
    ranks = jnp.asarray([0, 2, 4, 8], jnp.int32)
    y = bgmv_mag(x, ad, am, bm, mp, bd, idx, scale=1.5, impl="einsum",
                 ranks=ranks)
    np.testing.assert_array_equal(np.asarray(y[0]), 0.0)   # rank-0 slot
    for i in range(1, B):
        rr = int(ranks[i])
        h = (x[i] * am) @ ad[:, :rr]
        want = (h * (bm + mp[i])[:rr]) @ bd[:rr] * 1.5
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_bgmv_full_rank_table_matches_unranked():
    """ranks ≡ r_max must be a no-op: masked and unmasked paths agree
    exactly (every real column kept, nothing else existed)."""
    B, S, d, r, o, L = 3, 8, 32, 4, 32, 3
    x, ap, bp, idx = _pairs(B, S, d, r, o, L)
    full = jnp.full((L,), r, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(bgmv(x, ap, bp, idx, impl="einsum")),
        np.asarray(bgmv(x, ap, bp, idx, impl="einsum", ranks=full)))


def test_linear_pooled_ranked_matches_truncated_merged():
    """layers.linear with a pool_ranks leaf must equal the merged linear
    of each slot's own-rank (truncated) adapter."""
    from repro.models.layers import linear
    d, r, o, L = 48, 8, 64, 3
    kern = jnp.asarray(RNG.normal(size=(d, o)) * 0.05, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(L, 5, d)), jnp.float32)
    idx = jnp.arange(L, dtype=jnp.int32)
    ap = jnp.asarray(RNG.normal(size=(L, d, r)) * 0.3, jnp.float32)
    bp = jnp.asarray(RNG.normal(size=(L, r, o)) * 0.3, jnp.float32)
    ranks = jnp.asarray([2, 4, 8], jnp.int32)
    y = linear({"kernel": kern, "pool_A": ap, "pool_B": bp,
                "pool_ranks": ranks}, x, lora_scale=2.0, adapter_idx=idx)
    for i in range(L):
        rr = int(ranks[i])
        yi = linear({"kernel": kern, "lora_A": ap[i, :, :rr],
                     "lora_B": bp[i, :rr]}, x[i:i + 1], lora_scale=2.0)
        np.testing.assert_allclose(np.asarray(y[i:i + 1]), np.asarray(yi),
                                   rtol=1e-6, atol=1e-6)


def test_bgmv_bad_impl_rejected():
    x, ap, bp, idx = _pairs(2, 4, 16, 4, 16, 2)
    with pytest.raises(ValueError):
        bgmv(x, ap, bp, idx, impl="cuda")


def test_linear_pooled_matches_per_row_merged():
    """layers.linear with pooled leaves + adapter_idx must equal the
    merged per-tenant linear, row for row, for both pool layouts."""
    from repro.models.layers import linear
    d, r, o, L = 48, 4, 64, 3
    kern = jnp.asarray(RNG.normal(size=(d, o)) * 0.05, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(L, 5, d)), jnp.float32)
    idx = jnp.arange(L, dtype=jnp.int32)

    # pairs layout
    ap = jnp.asarray(RNG.normal(size=(L, d, r)) * 0.3, jnp.float32)
    bp = jnp.asarray(RNG.normal(size=(L, r, o)) * 0.3, jnp.float32)
    y = linear({"kernel": kern, "pool_A": ap, "pool_B": bp}, x,
               lora_scale=2.0, adapter_idx=idx)
    for i in range(L):
        yi = linear({"kernel": kern, "lora_A": ap[i], "lora_B": bp[i]},
                    x[i:i + 1], lora_scale=2.0)
        np.testing.assert_array_equal(np.asarray(y[i:i + 1]), np.asarray(yi))

    # decomposed magnitude layout: shared B_mag + raw per-slot ΔB_M
    ad = jnp.asarray(RNG.normal(size=(d, r)) * 0.3, jnp.float32)
    am = jnp.asarray(RNG.uniform(0.5, 1.5, size=(d,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(r,)), jnp.float32)
    bd = jnp.asarray(RNG.normal(size=(r, o)) * 0.3, jnp.float32)
    dmags = jnp.asarray(RNG.normal(size=(L, r)), jnp.float32)
    y = linear({"kernel": kern, "bgmv_A_dir": ad, "bgmv_A_mag": am,
                "bgmv_B_mag": bm, "bgmv_B_dir": bd, "pool_dB_mag": dmags},
               x, lora_scale=2.0, adapter_idx=idx)
    for i in range(L):
        p = {"kernel": kern, "A_dir": ad, "A_mag": am, "B_dir": bd,
             "B_mag": bm, "dB_mag": dmags[i]}
        yi = linear(p, x[i:i + 1], lora_scale=2.0)
        np.testing.assert_array_equal(np.asarray(y[i:i + 1]), np.asarray(yi))


def test_linear_pooled_inert_without_adapter_idx():
    """Pooled leaves must not perturb linear when no adapter_idx is
    passed (training code never sees the pools)."""
    from repro.models.layers import linear
    d, o = 32, 32
    kern = jnp.asarray(RNG.normal(size=(d, o)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 3, d)), jnp.float32)
    p = {"kernel": kern,
         "pool_A": jnp.ones((2, d, 4), jnp.float32),
         "pool_B": jnp.ones((2, 4, o), jnp.float32)}
    np.testing.assert_array_equal(
        np.asarray(linear(p, x, lora_scale=2.0)),
        np.asarray(linear({"kernel": kern}, x, lora_scale=2.0)))
