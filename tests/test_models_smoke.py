"""Per-assigned-architecture smoke tests: reduced same-family variant,
one forward + one LoRA train step on CPU, asserting shapes + finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core import peft
from repro.models import model as M
from repro.optim import adamw, masked
from repro.optim.optimizers import apply_updates
from repro.utils import pytree as pt

B, S = 2, 32


def _batch(cfg):
    rng = np.random.default_rng(0)
    S_tok = S
    extras = {}
    if cfg.frontend and not cfg.n_enc_layers:
        S_mm = cfg.frontend_tokens
        S_tok = S - S_mm
        extras["frontend_emb"] = jnp.asarray(
            rng.normal(size=(B, S_mm, cfg.d_model)), jnp.float32)
    if cfg.n_enc_layers:
        S_tok = S
        extras["frontend_emb"] = jnp.asarray(
            rng.normal(size=(B, 16, cfg.d_model)), jnp.float32)
    return {
        "tokens": jnp.asarray(
            rng.integers(5, cfg.vocab_size, size=(B, S_tok)), jnp.int32),
        "loss_mask": jnp.ones((B, S_tok), jnp.float32),
        **extras,
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.n_layers <= 8
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    # forward: shapes + finite
    hidden, _, aux = M.forward(params, batch, cfg)
    S_tok = batch["tokens"].shape[1]
    S_exp = S_tok + (batch["frontend_emb"].shape[1]
                     if (cfg.frontend and not cfg.n_enc_layers) else 0)
    assert hidden.shape == (B, S_exp, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))

    # one LoRA train step: loss finite, adapters move, base frozen
    adapters = peft.add_lora(params, cfg, jax.random.PRNGKey(1),
                             decomposed=True)
    assert pt.tree_count_params(adapters) > 0
    mask = peft.mask_stage_local_pretrain(adapters)
    opt = masked(adamw(1e-3), mask)
    ost = opt.init(adapters)

    def loss_fn(ad):
        p = pt.merge_trees(params, ad)
        return M.loss_and_metrics(p, batch, cfg)[0]

    loss, g = jax.value_and_grad(loss_fn)(adapters)
    assert bool(jnp.isfinite(loss)), arch
    upd, _ = opt.update(g, ost, adapters, jnp.zeros((), jnp.int32))
    new_ad = apply_updates(adapters, upd)
    moved = pt.global_norm(pt.tree_sub(new_ad, adapters))
    assert float(moved) > 0, "adapters did not move"
    # pipeline deltas must stay zero during stage-1
    for path, leaf in zip(pt.tree_paths(new_ad), jax.tree.leaves(new_ad)):
        if path.endswith("dA_dir") or path.endswith("dB_mag"):
            assert float(jnp.max(jnp.abs(leaf))) == 0.0


@pytest.mark.parametrize("arch", ["gemma3-1b", "mamba2-2.7b",
                                  "jamba-v0.1-52b", "mixtral-8x22b",
                                  "seamless-m4t-large-v2"])
def test_smoke_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(5, cfg.vocab_size, size=(B, 24)), jnp.int32)
    batch = {"tokens": toks}
    enc_out = None
    if cfg.n_enc_layers:
        batch["frontend_emb"] = jnp.asarray(
            rng.normal(size=(B, 16, cfg.d_model)), jnp.float32)
    hidden, _, _ = M.forward(params, batch, cfg)
    full_logits = hidden[:, -1] @ M._head_kernel(params, cfg)
    pre, cache = M.prefill(params, {**batch, "tokens": toks[:, :-1]}, cfg,
                           cache_len=24)
    if cfg.n_enc_layers:
        from repro.models.layers import rms_norm
        from repro.models.config import SubLayer
        from repro.models.model import _run_blocks
        e_pos = jnp.broadcast_to(jnp.arange(16)[None], (B, 16))
        enc_out, _, _ = _run_blocks(
            params["encoder"]["blocks"], {}, batch["frontend_emb"],
            [SubLayer("attn", "dense", "global")], cfg, positions=e_pos,
            causal=False)
        enc_out = rms_norm(enc_out, params["encoder"]["final_norm"],
                           cfg.norm_eps)
    logits, _ = M.decode_step(params, toks[:, -1], cache,
                              jnp.asarray(23), cfg, enc_out=enc_out)
    err = float(jnp.max(jnp.abs(logits - full_logits)))
    assert err < 5e-4, (arch, err)
