"""R2 — donation-safety.

Historical bug: PR 9's tiered adapter pool.  The batched hot-swap
scatter is a jitted function with ``donate_argnums=(0,)``: the pool
leaf buffer is donated so the scatter aliases it in place.  The caller
kept a reference to the *pre-call* binding and read it after the call —
on CPU that read stale-but-alive memory and "worked"; on TPU it is a
deleted-buffer error.  The workaround (re-keying the engine's merge on
``store.version``) shipped before the root cause was understood.

Detection: within each function, track names bound to a jitted callable
that carries ``donate_argnums=`` (direct ``jax.jit(f, donate_argnums=…)``
assignment, the ``obs.annotate(...)(jax.jit(...))`` wrap, or a
``@partial(jax.jit, donate_argnums=…)`` decorated def).  At every call
of such a callable, any *positional* plain-Name argument at a donated
index is poisoned; a later Name *load* before the name is rebound is a
finding.  Assignments (including the same statement's own target, e.g.
``x = f(x)``) rebind and clear the poison.  Starred args, attribute and
subscript arguments are skipped — the donated buffer there lives behind
a container the analyzer can't track, which is exactly what the
``store.version`` protocol covers at runtime.
"""
from __future__ import annotations

import ast
from typing import Optional

from .base import Finding, FunctionNode, ModuleInfo, Rule, last_seg


def _donated_indices(call: ast.Call) -> Optional[tuple[int, ...]]:
    """donate_argnums of a ``jax.jit(...)`` call expression, unwrapping
    ``annotate(...)(jax.jit(...))``; None if not a donating jit."""
    if not isinstance(call, ast.Call):
        return None
    if isinstance(call.func, ast.Call) and call.args:
        return _donated_indices(call.args[0])
    if last_seg(call.func) != "jit":
        return None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            return _literal_ints(kw.value)
    return None


def _decorator_donated(fn) -> Optional[tuple[int, ...]]:
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call):
            if last_seg(dec.func) == "jit":
                idx = _kw_ints(dec, "donate_argnums")
                if idx is not None:
                    return idx
            if last_seg(dec.func) == "partial" and dec.args and \
                    last_seg(dec.args[0]) == "jit":
                idx = _kw_ints(dec, "donate_argnums")
                if idx is not None:
                    return idx
    return None


def _kw_ints(call: ast.Call, name: str) -> Optional[tuple[int, ...]]:
    for kw in call.keywords:
        if kw.arg == name:
            return _literal_ints(kw.value)
    return None


def _literal_ints(node) -> tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                out.append(elt.value)
        return tuple(out)
    return ()


class DonationSafety(Rule):
    code = "R2"
    name = "donation-safety"
    description = ("argument donated to a jitted function is read again "
                   "after the call (deleted buffer on TPU, stale memory "
                   "on CPU)")

    def check_module(self, mod: ModuleInfo) -> list[Finding]:
        # donating callables visible module-wide: name -> donated indices
        donators: dict[str, tuple[int, ...]] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                idx = _donated_indices(node.value)
                if idx:
                    tgt = node.targets[0]
                    if isinstance(tgt, ast.Name):
                        donators[tgt.id] = idx
                    elif isinstance(tgt, ast.Attribute):
                        donators[f"self.{tgt.attr}"] = idx
            elif isinstance(node, FunctionNode):
                idx = _decorator_donated(node)
                if idx:
                    donators[node.name] = idx
        if not donators:
            return []
        out: list[Finding] = []
        for fn in [n for n in ast.walk(mod.tree)
                   if isinstance(n, FunctionNode)]:
            out.extend(self._check_fn(mod, fn, donators))
        return out

    def _callee_key(self, call: ast.Call) -> str:
        f = call.func
        if isinstance(f, ast.Name):
            return f.id
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id == "self":
            return f"self.{f.attr}"
        return ""

    def _check_fn(self, mod: ModuleInfo, fn, donators) -> list[Finding]:
        """Statement-ordered pass over ``fn``: donated Name args become
        poisoned; a later load fires, a store rebinds.  Within one
        statement loads/donations are processed before stores, so
        ``x = f(x)`` donates and immediately rebinds — no finding."""
        poisoned: dict[str, str] = {}           # name -> donating callee
        out: list[Finding] = []

        def stmt_events(stmt) -> tuple[list, list]:
            loads, stores = [], []
            for node in ast.walk(stmt):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)) and node is not stmt:
                    continue
                if isinstance(node, ast.Call):
                    key = self._callee_key(node)
                    if key in donators and not any(
                            isinstance(a, ast.Starred) for a in node.args):
                        for i in donators[key]:
                            if i < len(node.args) and isinstance(
                                    node.args[i], ast.Name):
                                loads.append((node.lineno, node.col_offset,
                                              "donate",
                                              node.args[i].id, key, node))
                elif isinstance(node, ast.Name):
                    if isinstance(node.ctx, ast.Load):
                        loads.append((node.lineno, node.col_offset, "load",
                                      node.id, None, node))
                    else:
                        stores.append(node.id)
            loads.sort(key=lambda e: (e[0], e[1]))
            return loads, stores

        def fire(name: str, node) -> None:
            out.append(mod.finding(
                "R2", node,
                f"`{name}` was donated to `{poisoned[name]}` and is read "
                f"afterwards — the buffer may be deleted or aliased in "
                f"place; rebind the result instead"))
            poisoned.pop(name)                  # one finding per donation

        def run_stmt(stmt) -> None:
            loads, stores = stmt_events(stmt)
            for _, _, kind, name, key, node in loads:
                if kind == "load" and name in poisoned:
                    fire(name, node)
            for _, _, kind, name, key, node in loads:
                if kind == "donate":
                    poisoned[name] = key
            for name in stores:
                poisoned.pop(name, None)

        def run_header(stmt) -> None:
            """Loads in a compound statement's header (``if x:``,
            ``for i in f(x):``, ``with g(x):``)."""
            exprs = []
            for f in ("test", "iter"):
                v = getattr(stmt, f, None)
                if v is not None:
                    exprs.append(v)
            for item in getattr(stmt, "items", []):
                exprs.append(item.context_expr)
            for e in exprs:
                for sub in ast.walk(e):
                    if isinstance(sub, ast.Name) and isinstance(
                            sub.ctx, ast.Load) and sub.id in poisoned:
                        fire(sub.id, sub)

        def run_block(body) -> None:
            for stmt in body:
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef, ast.ClassDef)):
                    continue
                blocks = [b for b in (getattr(stmt, "body", None),
                                      getattr(stmt, "orelse", None),
                                      getattr(stmt, "finalbody", None)) if b]
                handlers = getattr(stmt, "handlers", [])
                if blocks or handlers:
                    run_header(stmt)
                    for blk in blocks:
                        run_block(blk)
                    for h in handlers:
                        run_block(h.body)
                else:
                    run_stmt(stmt)

        run_block(fn.body)
        return out
