"""CPU micro-benchmarks: wall time of one forward/train/decode step per
reduced architecture, plus the federated round engine — the scanned
``FedSim.local_round`` (one jitted lax.scan over local steps) against the
seed-style per-step loop (``local_round_reference``) at paper-scale
settings (4 clients, 5 local steps).  Real measured numbers on this
container; the TPU numbers live in the roofline table, which is analytic
by necessity."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M
from repro.models.config import ArchConfig

B, S = 2, 64

FED_CFG = ArchConfig(name="fed-bench", family="dense", n_layers=4,
                     d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                     vocab_size=512, dtype="float32", lora_rank=8,
                     lora_dropout=0.0)


def _batch(cfg, rng):
    S_tok = S
    extras = {}
    if cfg.frontend and not cfg.n_enc_layers:
        S_tok = S - cfg.frontend_tokens
        extras["frontend_emb"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)), jnp.float32)
    if cfg.n_enc_layers:
        extras["frontend_emb"] = jnp.asarray(
            rng.normal(size=(B, 16, cfg.d_model)), jnp.float32)
    return {"tokens": jnp.asarray(rng.integers(5, cfg.vocab_size,
                                               size=(B, S_tok)), jnp.int32),
            "loss_mask": jnp.ones((B, S_tok), jnp.float32), **extras}


def _time(fn, *args, reps=5):
    fn(*args)                                  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(log=print):
    rng = np.random.default_rng(0)
    rows = []
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg, rng)
        fwd = jax.jit(lambda p, b: M.loss_and_metrics(p, b, cfg)[0])
        us_f = _time(fwd, params, batch)
        cache = M.init_cache(cfg, B, S)
        dec = jax.jit(lambda p, t, c, i: M.decode_step(
            p, t, c, i, cfg,
            enc_out=jnp.zeros((B, 16, cfg.d_model)) if cfg.n_enc_layers else None)[0])
        us_d = _time(dec, params, jnp.ones((B,), jnp.int32), cache,
                     jnp.asarray(5))
        rows.append({"arch": arch, "fwd_us": us_f, "dec_us": us_d})
        log(f"[perf] {arch:24s} fwd={us_f:9.0f}us decode={us_d:9.0f}us")
    return rows


def run_fed_round(log=print, n_clients: int = 4, local_steps: int = 5,
                  reps: int = 8):
    """Scanned round engine vs the seed per-step loop (paper-scale
    settings: 4 clients × 5 local steps, fedlora_opt).  The scan wins on
    (a) no per-step host sync or Python/jit dispatch, (b) donated adapter
    and optimizer buffers, (c) activation temporaries reused across the
    local steps of one round instead of reallocated per dispatch."""
    from repro.fed.simulate import FedHyper, FedSim

    hp = FedHyper(method="fedlora_opt", n_clients=n_clients,
                  local_steps=local_steps, batch=32, seq_len=64)
    rng = np.random.default_rng(0)
    batches = [{"tokens": jnp.asarray(
                    rng.integers(5, FED_CFG.vocab_size,
                                 size=(n_clients, hp.batch, hp.seq_len)),
                    jnp.int32),
                "loss_mask": jnp.ones((n_clients, hp.batch, hp.seq_len),
                                      jnp.float32)}
               for _ in range(local_steps)]
    key = jax.random.PRNGKey(0)

    def one(round_fn, sim):
        t0 = time.perf_counter()
        round_fn(batches, key)
        jax.block_until_ready(sim.client_adapters)
        return time.perf_counter() - t0

    # warm/compile both, then interleave reps so box noise hits both
    # paths equally; min over reps is the noise-robust estimator on a
    # shared machine (interference only ever adds time).
    sim_scan, sim_ref = FedSim(FED_CFG, hp), FedSim(FED_CFG, hp)
    one(sim_scan.local_round, sim_scan)
    one(sim_ref.local_round_reference, sim_ref)
    ts_scan, ts_ref = [], []
    for _ in range(reps):
        ts_scan.append(one(sim_scan.local_round, sim_scan))
        ts_ref.append(one(sim_ref.local_round_reference, sim_ref))
    us_scan, us_ref = min(ts_scan) * 1e6, min(ts_ref) * 1e6
    speedup = us_ref / us_scan
    log(f"[perf] fed_round/scan     {us_scan:9.0f}us  "
        f"({n_clients} clients x {local_steps} steps)")
    log(f"[perf] fed_round/per_step {us_ref:9.0f}us  speedup={speedup:.2f}x")
    return [{"arch": "fed_round/scan", "us": us_scan},
            {"arch": "fed_round/per_step", "us": us_ref}], speedup


def run_het_round(log=print, n_clients: int = 6, local_steps: int = 5,
                  reps: int = 6):
    """Masked mixed-rank round vs the uniform-rank round (same engine,
    same allocated rank).  The heterogeneous fleet rides the identical
    jitted lax.scan with per-client rank masks multiplied into the
    updates — adapter-sized elementwise work, so the masked round should
    sit within ~1.2× of the uniform one (the acceptance bar for not
    paying a second program for scenario diversity)."""
    from repro.fed.simulate import FedHyper, FedSim

    ranks = tuple([2, 4, 8] * (n_clients // 3 + 1))[:n_clients]
    hp_uni = FedHyper(method="fedlora_opt", n_clients=n_clients,
                      local_steps=local_steps, batch=32, seq_len=64)
    hp_het = FedHyper(method="fedlora_opt", n_clients=n_clients,
                      local_steps=local_steps, batch=32, seq_len=64,
                      client_ranks=ranks)
    rng = np.random.default_rng(0)
    batches = [{"tokens": jnp.asarray(
                    rng.integers(5, FED_CFG.vocab_size,
                                 size=(n_clients, hp_uni.batch,
                                       hp_uni.seq_len)), jnp.int32),
                "loss_mask": jnp.ones((n_clients, hp_uni.batch,
                                       hp_uni.seq_len), jnp.float32)}
               for _ in range(local_steps)]
    key = jax.random.PRNGKey(0)

    def one(sim):
        t0 = time.perf_counter()
        sim.local_round(batches, key)
        jax.block_until_ready(sim.client_adapters)
        return time.perf_counter() - t0

    sim_uni, sim_het = FedSim(FED_CFG, hp_uni), FedSim(FED_CFG, hp_het)
    one(sim_uni), one(sim_het)                  # compile + warm
    ts_uni, ts_het = [], []
    for _ in range(reps):                        # interleave (box noise)
        ts_uni.append(one(sim_uni))
        ts_het.append(one(sim_het))
    us_uni, us_het = min(ts_uni) * 1e6, min(ts_het) * 1e6
    ratio = us_het / us_uni
    log(f"[perf] fed_round/uniform    {us_uni:9.0f}us  "
        f"({n_clients} clients x {local_steps} steps, r=8)")
    log(f"[perf] fed_round/het_masked {us_het:9.0f}us  "
        f"ranks={ranks} ratio={ratio:.2f}x (bar: 1.2x)")
    return [{"arch": "fed_round/uniform", "us": us_uni, "ratio": 1.0},
            {"arch": "fed_round/het_masked", "us": us_het,
             "ratio": ratio}], ratio


def run_cohort(log=print, n_clients: int = 4, local_steps: int = 5,
               n_total: int = 16, reps: int = 6):
    """Sampled-cohort round (ClientBank gather → faulted round →
    masked scatter, stragglers buffered host-side) vs the bare
    full-participation round at the same cohort size.  Everything the
    cross-device layer adds is host work plus adapter-sized elementwise
    fault transforms, so the sampled round must stay within ~1.2× of
    the full-fleet round — the acceptance bar for fleet scale-out not
    taxing the jitted round."""
    from repro.fed import CohortSim, FaultPlan
    from repro.fed.simulate import FedHyper, FedSim

    hp = FedHyper(method="fedlora_opt", n_clients=n_clients,
                  local_steps=local_steps, batch=32, seq_len=64)
    rng = np.random.default_rng(0)
    batches = [{"tokens": jnp.asarray(
                    rng.integers(5, FED_CFG.vocab_size,
                                 size=(n_clients, hp.batch, hp.seq_len)),
                    jnp.int32),
                "loss_mask": jnp.ones((n_clients, hp.batch, hp.seq_len),
                                      jnp.float32)}
               for _ in range(local_steps)]
    key = jax.random.PRNGKey(0)

    sim_full = FedSim(FED_CFG, hp)
    cs = CohortSim(FedSim(FED_CFG, hp), n_total=n_total,
                   faults=FaultPlan(dropout_rate=0.125,
                                    straggler_rate=0.125, seed=0), seed=0)

    def one_full():
        t0 = time.perf_counter()
        sim_full.run_round(batches, key)
        jax.block_until_ready(sim_full.client_adapters)
        return time.perf_counter() - t0

    def one_cohort():
        t0 = time.perf_counter()
        cs.run_round(batches, key)
        jax.block_until_ready(cs.sim.client_adapters)
        return time.perf_counter() - t0

    one_full(), one_cohort()                    # compile + warm (both
    # programs: the faulted round is a distinct jitted specialization)
    ts_full, ts_coh = [], []
    for _ in range(reps):                        # interleave (box noise)
        ts_full.append(one_full())
        ts_coh.append(one_cohort())
    us_full, us_coh = min(ts_full) * 1e6, min(ts_coh) * 1e6
    ratio = us_coh / us_full
    log(f"[perf] fed_round/full_fleet     {us_full:9.0f}us  "
        f"({n_clients} clients x {local_steps} steps)")
    log(f"[perf] fed_round/sampled_cohort {us_coh:9.0f}us  "
        f"(cohort {n_clients} of {n_total}, faults on) "
        f"ratio={ratio:.2f}x (bar: 1.2x)")
    return [{"arch": "fed_round/full_fleet", "us": us_full, "ratio": 1.0},
            {"arch": "fed_round/sampled_cohort", "us": us_coh,
             "ratio": ratio}], ratio


def run_dist_round(log=print, local_steps: int = 5, reps: int = 6):
    """Production shard_map collective round (launch/train) vs the
    single-process FedSim engine round at matched settings, on a
    data-only client mesh over every visible device (1 on a default CPU
    run; under the --dist lane's XLA flag, 8 virtual devices → 8
    clients).  The adapter payload is tiny, so the ratio isolates what
    the move from a vmapped client axis to one-client-per-shard
    collectives costs in dispatch + collective overhead."""
    import jax

    from repro.fed.simulate import FedHyper, FedSim
    from repro.launch.mesh import make_client_mesh
    from repro.launch.train import TrainSettings, make_fed_train_step

    C = jax.device_count()
    hp = FedHyper(method="fedlora_opt", n_clients=C,
                  local_steps=local_steps, batch=8, seq_len=64)
    sim = FedSim(FED_CFG, hp)
    mesh = make_client_mesh(C)
    st = TrainSettings(lr=hp.lr, micro_batches=1, clip=hp.clip, remat=False,
                       method=hp.method, local_steps=local_steps)
    step_fn = jax.jit(make_fed_train_step(FED_CFG, mesh, st)[0])
    rng = np.random.default_rng(0)
    batches = [{"tokens": jnp.asarray(
                    rng.integers(5, FED_CFG.vocab_size,
                                 size=(C, hp.batch, hp.seq_len)), jnp.int32),
                "loss_mask": jnp.ones((C, hp.batch, hp.seq_len),
                                      jnp.float32)}
               for _ in range(local_steps)]
    big = {k: jnp.concatenate([b[k] for b in batches], axis=1)
           for k in batches[0]}
    key = jax.random.PRNGKey(0)

    ad, ost = sim.client_adapters, sim.opt_state
    step0 = jnp.zeros((), jnp.int32)

    def one_prod():
        nonlocal ad, ost, step0
        t0 = time.perf_counter()
        ad, ost, _ = step_fn(sim.base, ad, ost, step0, big)
        jax.block_until_ready(ad)
        step0 = step0 + local_steps
        return time.perf_counter() - t0

    def one_sim():
        t0 = time.perf_counter()
        sim.run_round(batches, key)
        jax.block_until_ready(sim.client_adapters)
        return time.perf_counter() - t0

    one_prod(), one_sim()                       # compile + warm
    ts_prod, ts_sim = [], []
    for _ in range(reps):                        # interleave (box noise)
        ts_prod.append(one_prod())
        ts_sim.append(one_sim())
    us_prod, us_sim = min(ts_prod) * 1e6, min(ts_sim) * 1e6
    ratio = us_prod / us_sim
    log(f"[perf] fed_round/engine    {us_sim:9.0f}us  "
        f"({C} clients x {local_steps} steps)")
    log(f"[perf] fed_round/shardmap  {us_prod:9.0f}us  "
        f"ratio={ratio:.2f}x vs engine ({len(jax.devices())} devices)")
    return [{"arch": "fed_round/engine", "us": us_sim, "ratio": 1.0},
            {"arch": "fed_round/shardmap", "us": us_prod,
             "ratio": ratio}], ratio


def run_pipeline(log=print, local_steps: int = 3, global_steps: int = 2,
                 personal_steps: int = 2, reps: int = 5):
    """Full three-stage paper pipeline: the shard_map pipeline engine
    (launch/train.make_fed_pipeline_step — stage-1 round + collective,
    stage-2 global optimizer on replicated server batches, stage-3
    per-client personalization) vs the FedSim three-stage sequence
    (run_round → global_stage → personalize) at matched settings, on a
    data-only client mesh over every visible device.  At 1 device both
    paths run the same math once, so the ratio isolates the pipeline's
    dispatch + collective overhead — the bar is ~1.00x."""
    import jax

    from repro.fed.simulate import FedHyper, FedSim
    from repro.launch.mesh import make_client_mesh
    from repro.launch.train import TrainSettings, make_fed_pipeline_step

    C = jax.device_count()
    hp = FedHyper(method="fedlora_opt", n_clients=C, local_steps=local_steps,
                  global_steps=global_steps, personal_steps=personal_steps,
                  batch=8, seq_len=64)
    sim = FedSim(FED_CFG, hp)
    mesh = make_client_mesh(C)
    st = TrainSettings(lr=hp.lr, micro_batches=1, clip=hp.clip, remat=False,
                       method=hp.method, local_steps=local_steps,
                       server_lr=hp.server_lr, global_steps=global_steps,
                       personal_steps=personal_steps, lam=hp.lam)
    pipe = make_fed_pipeline_step(FED_CFG, mesh, st)
    rng = np.random.default_rng(0)

    def cbatches(n):
        return [{"tokens": jnp.asarray(
                    rng.integers(5, FED_CFG.vocab_size,
                                 size=(C, hp.batch, hp.seq_len)), jnp.int32),
                 "loss_mask": jnp.ones((C, hp.batch, hp.seq_len),
                                       jnp.float32)}
                for _ in range(n)]

    def sbatches(n):
        return [{"tokens": jnp.asarray(
                    rng.integers(5, FED_CFG.vocab_size,
                                 size=(hp.batch, hp.seq_len)), jnp.int32),
                 "loss_mask": jnp.ones((hp.batch, hp.seq_len), jnp.float32)}
                for _ in range(n)]

    def flat(bs, axis):
        return {k: jnp.concatenate([b[k] for b in bs], axis=axis)
                for k in bs[0]}

    cb, sb, pb = (cbatches(local_steps), sbatches(global_steps),
                  cbatches(personal_steps))
    big_c, big_s, big_p = flat(cb, 1), flat(sb, 0), flat(pb, 1)
    key = jax.random.PRNGKey(0)

    ad, ost = sim.client_adapters, sim.opt_state
    step0 = jnp.zeros((), jnp.int32)

    def one_prod():
        nonlocal ad, ost, step0
        t0 = time.perf_counter()
        ad, ost, _, _, _ = pipe.run_pipeline(sim.base, ad, ost, step0,
                                             big_c, big_s, big_p)
        jax.block_until_ready(ad)
        step0 = step0 + local_steps
        return time.perf_counter() - t0

    def one_sim():
        t0 = time.perf_counter()
        sim.local_round(cb, key)
        agg = sim.aggregate()
        sim.global_stage(agg, sb, key)
        sim.personalize(pb, key)
        jax.block_until_ready(sim.client_adapters)
        return time.perf_counter() - t0

    one_prod(), one_sim()                       # compile + warm
    ts_prod, ts_sim = [], []
    for _ in range(reps):                        # interleave (box noise)
        ts_prod.append(one_prod())
        ts_sim.append(one_sim())
    us_prod, us_sim = min(ts_prod) * 1e6, min(ts_sim) * 1e6
    ratio = us_prod / us_sim
    log(f"[perf] pipeline/engine    {us_sim:9.0f}us  "
        f"({C} clients, {local_steps}+{global_steps}+{personal_steps} steps)")
    log(f"[perf] pipeline/shardmap  {us_prod:9.0f}us  "
        f"ratio={ratio:.2f}x vs engine ({len(jax.devices())} devices, "
        f"bar: 1.00x at 1 device)")
    return [{"arch": "pipeline/engine", "us": us_sim, "ratio": 1.0},
            {"arch": "pipeline/shardmap", "us": us_prod,
             "ratio": ratio}], ratio


def run_quant(log=print, reps: int = 6):
    """Quantized-backbone decode: wall time of one decode step on the
    f32 vs int8 vs int4 backbone, plus the *analytic* decode byte ratio
    — batch-1 decode is weight-bytes-bound, so bytes(f32 tree) /
    bytes(quantized tree) is the roofline speedup on a bandwidth-bound
    accelerator.  CPU wall-clock is reported honestly (this container's
    XLA dequant-fused fallback roughly ties f32; the win is the byte
    ratio, which is what the CI gate checks)."""
    from repro.kernels.quant_matmul.ops import quantize_backbone
    from repro.utils import pytree as pt

    cfg = FED_CFG
    base = M.init_params(jax.random.PRNGKey(0), cfg)
    cache = M.init_cache(cfg, 1, 64)
    tok = jnp.ones((1,), jnp.int32)

    def dec(params):
        f = jax.jit(lambda p, t, c, i: M.decode_step(p, t, c, i, cfg)[0])
        f(params, tok, cache, jnp.asarray(5))            # compile + warm
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            out = f(params, tok, cache, jnp.asarray(5))
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        return min(ts) * 1e6

    us_f32 = dec(base)
    rows = [{"arch": "quant/decode_f32", "us": us_f32,
             "bytes": pt.tree_bytes(base)}]
    log(f"[perf] quant/decode_f32   {us_f32:9.0f}us  "
        f"({pt.tree_bytes(base)} B weights)")
    ratios = {}
    for mode in ("int8", "int4"):
        qtree = quantize_backbone(base, mode)
        us_q = dec(qtree)
        ratios[mode] = pt.tree_bytes(base) / pt.tree_bytes(qtree)
        rows.append({"arch": f"quant/decode_{mode}", "us": us_q,
                     "bytes": pt.tree_bytes(qtree),
                     "wall_ratio": us_f32 / us_q,
                     "bytes_ratio": ratios[mode]})
        log(f"[perf] quant/decode_{mode}  {us_q:9.0f}us  "
            f"bytes_ratio={ratios[mode]:.2f}x "
            f"wall_ratio={us_f32 / us_q:.2f}x (analytic win is bytes)")
    return rows, ratios["int8"]


def run_obs(log=print, n_clients: int = 4, local_steps: int = 5,
            reps: int = 6, serve_reps: int = 24,
            out_path: str = "experiments/bench/obs_telemetry.jsonl"):
    """Telemetry overhead gate: the instrumented loops (live obs sink,
    JSONL events on) vs the same loops with the no-op sink, on the two
    hot paths the observability layer touches — the masked het federated
    round (run_het_round settings) and the multi-tenant serve loop.
    The enabled path pays host-side clocks, dict updates and JSONL
    writes only (the jitted programs are byte-identical either way), so
    the interleaved min-of-reps ratio must stay under the checked-in
    1.05x bar (baselines/obs_overhead.json).  Side effect: ``out_path``
    is left holding the run's events + a metrics snapshot — the CI
    telemetry artifact that ``telemetry_section`` renders."""
    import os

    from benchmarks import serve_multitenant
    from repro import obs
    from repro.fed.simulate import FedHyper, FedSim
    from repro.serve import AdapterStore, ServeEngine
    from repro.utils import pytree as pt

    # fresh artifact: drop the live file and any rotated segments
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    for p in [out_path] + [f"{out_path}.{i}" for i in range(1, 4)]:
        if os.path.exists(p):
            os.remove(p)

    # batch 16 (vs run_het_round's 32): a shorter round is *harder* on
    # this gate — the per-round host epilogue is fixed cost, so its
    # relative weight grows — and buys enough reps for a stable floor
    ranks = tuple([2, 4, 8] * (n_clients // 3 + 1))[:n_clients]
    hp = FedHyper(method="fedlora_opt", n_clients=n_clients,
                  local_steps=local_steps, batch=16, seq_len=64,
                  client_ranks=ranks)
    rng = np.random.default_rng(0)
    batches = [{"tokens": jnp.asarray(
                    rng.integers(5, FED_CFG.vocab_size,
                                 size=(n_clients, hp.batch, hp.seq_len)),
                    jnp.int32),
                "loss_mask": jnp.ones((n_clients, hp.batch, hp.seq_len),
                                      jnp.float32)}
               for _ in range(local_steps)]
    key = jax.random.PRNGKey(0)
    sim = FedSim(FED_CFG, hp)

    def one_round():
        t0 = time.perf_counter()
        sim.run_round(batches, key)
        jax.block_until_ready(sim.client_adapters)
        return time.perf_counter() - t0

    # 8 tenants through 4 rows: long enough for a stable min-of-reps
    # (a ~10ms loop cannot resolve a 5% bar through box noise) and the
    # queue actually queues, so admission/retire telemetry is on the
    # measured path
    n_tenants, n_new = 8, 48
    cfg, base, shared, tenants, prompts = serve_multitenant._setting(
        n_tenants)
    store = AdapterStore(base, cfg, n_slots=n_tenants, kind="dora_mag",
                         shared=shared)
    for name, tree in tenants.items():
        store.register(name, pt.filter_tree(
            tree, lambda p: p.endswith("dB_mag")))
    engine = ServeEngine(base, cfg, store, max_rows=n_tenants // 2,
                         max_prompt_len=prompts.shape[1],
                         max_len=prompts.shape[1] + n_new + 8,
                         decode_chunk=8)
    reqs = [(f"tenant{t}", prompts[t]) for t in range(n_tenants)]

    def one_serve():
        t0 = time.perf_counter()
        engine.generate(reqs, n_new=n_new)
        return time.perf_counter() - t0

    obs.disable()
    one_round(), one_serve()                    # compile + warm, obs off
    ts = {"round_off": [], "round_on": [], "serve_off": [], "serve_on": []}

    def measure(fn, off_key, on_key, n, attempts):
        # interleaved pairs, min as the estimator — but adaptive: box
        # noise only ever *adds* time, so a ratio stuck above the bar
        # after one batch earns more samples (the floors converge to
        # the true ratio), while quiet boxes exit after one batch.  A
        # genuine leak (sync/transfer/per-step callback on the hot
        # path) shifts the floor itself and keeps failing every batch.
        for _ in range(attempts):
            for _ in range(n):
                obs.disable()
                ts[off_key].append(fn())
                obs.enable(out_path)            # append mode: events keep
                ts[on_key].append(fn())
            if min(ts[on_key]) / min(ts[off_key]) <= 1.03:
                break
        return min(ts[on_key]) / min(ts[off_key])

    r_ratio = measure(one_round, "round_off", "round_on", reps, attempts=3)
    # the serve loop is ~100x cheaper than the round, so buy its noise
    # floor down with many more interleaved reps — min-of-few on a
    # tens-of-ms loop cannot resolve a 5% bar on a shared box
    s_ratio = measure(one_serve, "serve_off", "serve_on", serve_reps,
                      attempts=4)
    # still enabled from the last interleaved pair — its registry holds
    # that pair's metrics, which is what the snapshot epilogue dumps
    obs.emit_snapshot()
    obs.disable()

    us = {k: min(v) * 1e6 for k, v in ts.items()}
    log(f"[perf] obs/het_round disabled={us['round_off']:9.0f}us "
        f"instrumented={us['round_on']:9.0f}us ratio={r_ratio:.3f}x")
    log(f"[perf] obs/serve     disabled={us['serve_off']:9.0f}us "
        f"instrumented={us['serve_on']:9.0f}us ratio={s_ratio:.3f}x "
        f"(bar: 1.05x; events -> {out_path})")
    rows = [{"arch": "obs/het_round_disabled", "us": us["round_off"],
             "ratio": 1.0},
            {"arch": "obs/het_round_instrumented", "us": us["round_on"],
             "ratio": r_ratio},
            {"arch": "obs/serve_disabled", "us": us["serve_off"],
             "ratio": 1.0},
            {"arch": "obs/serve_instrumented", "us": us["serve_on"],
             "ratio": s_ratio}]
    return rows, max(r_ratio, s_ratio)


def main():
    rows = run()
    fed_rows, speedup = run_fed_round()
    het_rows, het_ratio = run_het_round()
    cohort_rows, cohort_ratio = run_cohort()
    dist_rows, dist_ratio = run_dist_round()
    pipe_rows, pipe_ratio = run_pipeline()
    quant_rows, quant_ratio = run_quant()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"perf/{r['arch']}/fwd,{r['fwd_us']:.0f},smoke_cpu")
        print(f"perf/{r['arch']}/decode,{r['dec_us']:.0f},smoke_cpu")
    for r in fed_rows:
        print(f"perf/{r['arch']},{r['us']:.0f},smoke_cpu")
    for r in het_rows + cohort_rows + dist_rows + pipe_rows + quant_rows:
        print(f"perf/{r['arch']},{r['us']:.0f},smoke_cpu")
    # ratios, not timings — kept out of the us_per_call column
    print(f"# fed_round speedup (per_step / scan): {speedup:.2f}x")
    print(f"# het_round overhead (het_masked / uniform): {het_ratio:.2f}x")
    print(f"# cohort_round overhead (sampled_cohort / full_fleet): "
          f"{cohort_ratio:.2f}x")
    print(f"# dist_round overhead (shardmap / engine): {dist_ratio:.2f}x")
    print(f"# pipeline overhead (shardmap / engine): {pipe_ratio:.2f}x")
    print(f"# quant decode byte ratio (f32 / int8, analytic): "
          f"{quant_ratio:.2f}x")
    return (rows + fed_rows + het_rows + cohort_rows + dist_rows
            + pipe_rows + quant_rows)


if __name__ == "__main__":
    main()
