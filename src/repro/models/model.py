"""Unified multi-architecture transformer.

One engine covers the 10 assigned architectures via superblock patterns
(config.py).  Layer params are stacked (n_superblocks, ...) and the main
body is a single ``lax.scan``; an unrolled tail handles layer counts that
don't divide the pattern length.

Entry points:
  init_params(rng, cfg)                     → param pytree (no adapters)
  forward(params, batch, cfg, ...)          → (hidden, cache, aux)
  logits_from_hidden / loss_and_metrics     → chunked-CE training loss
  prefill(...) / decode_step(...)           → serving path with caches
  init_cache(cfg, batch, seq_len)           → per-layer cache pytree
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ArchConfig, SubLayer

Params = Any


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_linear(rng, d_in, d_out, scale, dtype):
    return {"kernel": (jax.random.normal(rng, (d_in, d_out), jnp.float32)
                       * scale).astype(dtype)}


def _init_sublayer(rng, cfg: ArchConfig, sub: SubLayer, dtype):
    D, F = cfg.d_model, cfg.d_ff
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 16)
    sc = 0.02
    out_sc = 0.02 / math.sqrt(max(2 * cfg.n_layers, 1))
    p: dict = {"input_norm": jnp.ones((D,), jnp.float32)}
    if sub.mixer in ("attn", "cross_attn"):
        p["attn"] = {
            "q_proj": _init_linear(ks[0], D, H * dh, sc, dtype),
            "k_proj": _init_linear(ks[1], D, K * dh, sc, dtype),
            "v_proj": _init_linear(ks[2], D, K * dh, sc, dtype),
            "o_proj": _init_linear(ks[3], H * dh, D, out_sc, dtype),
        }
        if cfg.qk_norm:
            p["attn"]["q_norm"] = jnp.ones((dh,), jnp.float32)
            p["attn"]["k_norm"] = jnp.ones((dh,), jnp.float32)
    elif sub.mixer == "ssm":
        Hs = D * cfg.ssm_expand // cfg.ssm_headdim
        d_inner = Hs * cfg.ssm_headdim
        GN = cfg.ssm_groups * cfg.ssm_state
        p["ssm"] = {
            "z_proj": _init_linear(ks[0], D, d_inner, sc, dtype),
            "x_proj": _init_linear(ks[1], D, d_inner, sc, dtype),
            "B_proj": _init_linear(ks[2], D, GN, sc, dtype),
            "C_proj": _init_linear(ks[3], D, GN, sc, dtype),
            "dt_proj": _init_linear(ks[4], D, Hs, sc, dtype),
            "conv_x": (jax.random.normal(ks[5], (d_inner, cfg.ssm_conv)) * 0.1).astype(dtype),
            "conv_B": (jax.random.normal(ks[6], (GN, cfg.ssm_conv)) * 0.1).astype(dtype),
            "conv_C": (jax.random.normal(ks[7], (GN, cfg.ssm_conv)) * 0.1).astype(dtype),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, Hs)).astype(jnp.float32),
            "D_skip": jnp.ones((Hs,), jnp.float32),
            "dt_bias": jnp.full((Hs,), -2.0, jnp.float32),
            "norm_w": jnp.ones((d_inner,), jnp.float32),
            "out_proj": _init_linear(ks[8], d_inner, D, out_sc, dtype),
        }
    if sub.ffn == "dense":
        p["ffn_norm"] = jnp.ones((D,), jnp.float32)
        p["mlp"] = {
            "gate_proj": _init_linear(ks[9], D, F, sc, dtype),
            "up_proj": _init_linear(ks[10], D, F, sc, dtype),
            "down_proj": _init_linear(ks[11], F, D, out_sc, dtype),
        }
    elif sub.ffn == "moe":
        E_slots = cfg.n_experts * cfg.ep_fsplit
        F_eff = F // cfg.ep_fsplit
        p["ffn_norm"] = jnp.ones((D,), jnp.float32)
        p["moe"] = {
            "router": {"kernel": (jax.random.normal(ks[12], (D, cfg.n_experts))
                                  * sc).astype(jnp.float32)},
            "experts": {
                "gate": (jax.random.normal(ks[13], (E_slots, D, F_eff)) * sc).astype(dtype),
                "up": (jax.random.normal(ks[14], (E_slots, D, F_eff)) * sc).astype(dtype),
                "down": (jax.random.normal(ks[15], (E_slots, F_eff, D)) * out_sc).astype(dtype),
            },
        }
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _init_block_stack(rng, cfg, pattern, n_sb, tail, dtype):
    """Returns (stacked_blocks, tail_blocks)."""
    def one_superblock(r):
        rs = jax.random.split(r, len(pattern))
        return {f"sub{i}": _init_sublayer(rs[i], cfg, sub, dtype)
                for i, sub in enumerate(pattern)}

    rngs = jax.random.split(rng, n_sb + 1)
    blocks = _stack([one_superblock(rngs[i]) for i in range(n_sb)]) if n_sb else {}
    tail_blocks = {}
    if tail:
        rs = jax.random.split(rngs[-1], tail)
        tail_blocks = {f"sub{i}": _init_sublayer(rs[i], cfg, pattern[i], dtype)
                       for i in range(tail)}
    return blocks, tail_blocks


def init_params(rng, cfg: ArchConfig) -> Params:
    dtype = _dtype(cfg)
    k_embed, k_blocks, k_enc, k_head = jax.random.split(rng, 4)
    n_sb, tail, pattern = cfg.blocks_layout()
    if cfg.n_enc_layers:
        pattern = cfg.dec_pattern()
        n_sb, tail = cfg.n_layers, 0
    params: dict = {
        "embed": {"embedding": (jax.random.normal(
            k_embed, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype)},
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    blocks, tail_blocks = _init_block_stack(k_blocks, cfg, pattern, n_sb,
                                            tail, dtype)
    params["blocks"] = blocks
    if tail_blocks:
        params["tail"] = tail_blocks
    if cfg.n_enc_layers:
        enc_pat = [SubLayer("attn", "dense", "global")]
        enc_blocks, _ = _init_block_stack(k_enc, cfg, enc_pat,
                                          cfg.n_enc_layers, 0, dtype)
        params["encoder"] = {"blocks": enc_blocks,
                             "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}
    if not cfg.tie_embeddings:
        params["lm_head"] = _init_linear(k_head, cfg.d_model, cfg.vocab_size,
                                         0.02, dtype)
    return params


# ---------------------------------------------------------------------------
# sublayer application
# ---------------------------------------------------------------------------

def _apply_sublayer(p, x, sub: SubLayer, cfg, *, positions, cache=None,
                    cache_index=None, enc_out=None, lora_scale=0.0,
                    dropout_rng=None, mesh=None, causal=True,
                    chunk_q=False, return_cache=False, cache_len=0,
                    adapter_idx=None):
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    h = L.rms_norm(x, p["input_norm"], cfg.norm_eps)
    if sub.mixer in ("attn", "cross_attn"):
        kv_src = enc_out if sub.mixer == "cross_attn" else None
        acache = cache.get("attn") if cache else None
        y, nc = L.attention(
            p["attn"], h, positions, cfg, kind=sub.attn_kind,
            causal=causal and sub.mixer != "cross_attn",
            cache=acache, cache_index=cache_index, kv_source=kv_src,
            lora_scale=lora_scale, dropout_rng=dropout_rng, chunk_q=chunk_q,
            return_cache=return_cache, cache_len=cache_len,
            adapter_idx=adapter_idx)
        if nc is not None:
            new_cache["attn"] = nc
        x = x + y
    elif sub.mixer == "ssm":
        scache = cache.get("ssm") if cache else None
        y, nc = S.mamba2_mixer(p["ssm"], h, cfg, cache=scache,
                               cache_index=cache_index,
                               lora_scale=lora_scale, dropout_rng=dropout_rng,
                               return_cache=return_cache)
        if nc is not None:
            new_cache["ssm"] = nc
        x = x + y
    if sub.ffn == "dense":
        h = L.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        x = x + L.dense_ffn(p["mlp"], h, cfg, lora_scale,
                            adapter_idx=adapter_idx)
    elif sub.ffn == "moe":
        h = L.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        if isinstance(mesh, tuple) and mesh[0] == "manual":
            # inside a manual region over the data axes (launch/train.py)
            y, a = L.moe_ffn_manual(p["moe"], h, cfg, mesh[1])
        elif mesh is not None and mesh.devices.size > 1:
            y, a = L.moe_ffn_ep(p["moe"], h, cfg, mesh)
        else:
            y, a = L.moe_ffn_local(p["moe"], h, cfg)
        aux = aux + a
        x = x + y
    return x, new_cache, aux


def _superblock_fn(pattern, cfg, *, causal=True, mesh=None, chunk_q=False,
                   remat=False, return_cache=False, cache_len=0,
                   adapter_idx=None):
    """Returns body(x, p_sb, cache_sb, positions, cache_index, enc_out, rng)."""

    def body(x, p_sb, cache_sb, positions, cache_index, enc_out, rng):
        aux = jnp.zeros((), jnp.float32)
        new_cache = {}
        scale = cfg.lora_alpha / cfg.lora_rank
        for i, sub in enumerate(pattern):
            key = f"sub{i}"
            if key not in p_sb:      # tail shorter than pattern
                continue
            r = None if rng is None else jax.random.fold_in(rng, i)
            c = cache_sb.get(key) if cache_sb else None
            x, nc, a = _apply_sublayer(
                p_sb[key], x, sub, cfg, positions=positions, cache=c,
                cache_index=cache_index, enc_out=enc_out,
                lora_scale=scale, dropout_rng=r, mesh=mesh, causal=causal,
                chunk_q=chunk_q, return_cache=return_cache,
                cache_len=cache_len, adapter_idx=adapter_idx)
            if nc:
                new_cache[key] = nc
            aux = aux + a
        return x, new_cache, aux

    if remat == "dots":
        # save matmul outputs; recompute only cheap elementwise ops in the
        # backward pass (≈2× fwd FLOPs instead of 3×, at higher residency)
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_saveable)
    elif remat:
        body = jax.checkpoint(body)
    return body


# ---------------------------------------------------------------------------
# backbone forward
# ---------------------------------------------------------------------------

def _run_blocks(blocks, tail, x, pattern, cfg, *, positions, cache=None,
                cache_index=None, enc_out=None, rng=None, mesh=None,
                causal=True, chunk_q=False, remat=False, return_cache=False,
                cache_len=0, adapter_idx=None):
    """Scan over stacked superblocks, then unrolled tail."""
    body = _superblock_fn(pattern, cfg, causal=causal, mesh=mesh,
                          chunk_q=chunk_q, remat=remat,
                          return_cache=return_cache, cache_len=cache_len,
                          adapter_idx=adapter_idx)
    n_sb = 0
    if blocks:
        some_leaf = jax.tree.leaves(blocks)[0]
        n_sb = some_leaf.shape[0]

    new_cache = {"blocks": None, "tail": {}}
    aux_total = jnp.zeros((), jnp.float32)

    if n_sb:
        rngs = None if rng is None else jax.random.split(rng, n_sb)

        def scan_body(carry, xs):
            x, aux = carry
            p_sb, cache_sb, r = xs
            x, nc, a = body(x, p_sb, cache_sb, positions, cache_index,
                            enc_out, r)
            return (x, aux + a), nc

        xs = (blocks,
              cache["blocks"] if cache is not None else None,
              rngs)
        # lax.scan needs every xs leaf to have the leading n_sb dim; None
        # subtrees are fine (empty pytrees).
        (x, aux_total), cache_out = jax.lax.scan(
            scan_body, (x, aux_total), xs)
        new_cache["blocks"] = cache_out

    if tail:
        r = None if rng is None else jax.random.fold_in(rng, 999)
        x, nc, a = body(x, tail,
                        cache["tail"] if cache is not None else None,
                        positions, cache_index, enc_out, r)
        new_cache["tail"] = nc
        aux_total = aux_total + a
    return x, new_cache, aux_total


def _embed(params, tokens, cfg, frontend_emb=None):
    emb = jnp.take(params["embed"]["embedding"], tokens, axis=0)
    if cfg.frontend and frontend_emb is not None:
        emb = jnp.concatenate([frontend_emb.astype(emb.dtype), emb], axis=1)
    return emb


def forward(params, batch, cfg: ArchConfig, *, rng=None, mesh=None,
            remat=False, causal=True, return_cache=False, cache_len=0):
    """Training/prefill forward → (hidden (B,S,D), cache, aux)."""
    tokens = batch["tokens"]
    frontend_emb = None if cfg.n_enc_layers else batch.get("frontend_emb")
    x = _embed(params, tokens, cfg, frontend_emb)
    B, Stot = x.shape[0], x.shape[1]

    if "prompt_embed" in params:                      # prompt-tuning baseline
        n_p = params["prompt_embed"].shape[0]
        pe = jnp.broadcast_to(params["prompt_embed"][None].astype(x.dtype),
                              (B, n_p, x.shape[-1]))
        x = jnp.concatenate([pe, x], axis=1)
        Stot = Stot + n_p

    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Stot)[None], (B, Stot))

    enc_out = None
    if cfg.n_enc_layers:
        enc_tokens_emb = batch["frontend_emb"]        # audio frames → encoder
        enc_pat = [SubLayer("attn", "dense", "global")]
        e_pos = jnp.broadcast_to(
            jnp.arange(enc_tokens_emb.shape[1])[None],
            enc_tokens_emb.shape[:2])
        # fold the dropout rng onto a branch of its own: sharing `rng`
        # between the encoder and decoder stacks gives layer i of both
        # the same fold_in(rng, i) key → identical dropout masks (R3)
        enc_rng = None if rng is None else jax.random.fold_in(rng, 998)
        enc_out, _, _ = _run_blocks(
            params["encoder"]["blocks"], {}, enc_tokens_emb.astype(x.dtype),
            enc_pat, cfg, positions=e_pos, rng=enc_rng, mesh=mesh,
            causal=False, chunk_q=True, remat=remat)
        enc_out = L.rms_norm(enc_out, params["encoder"]["final_norm"],
                             cfg.norm_eps)

    n_sb, tail, pattern = cfg.blocks_layout()
    if cfg.n_enc_layers:
        pattern = cfg.dec_pattern()
        n_sb, tail = cfg.n_layers, 0

    x, cache, aux = _run_blocks(
        params["blocks"], params.get("tail", {}), x, pattern, cfg,
        positions=positions, enc_out=enc_out, rng=rng, mesh=mesh,
        causal=causal, chunk_q=True, remat=remat, return_cache=return_cache,
        cache_len=cache_len, adapter_idx=batch.get("adapter_idx"))

    if "prompt_embed" in params:
        x = x[:, params["prompt_embed"].shape[0]:]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, cache, aux


# ---------------------------------------------------------------------------
# loss (chunked cross-entropy — unrolled chunks so the dry-run sees the
# full lm_head FLOPs; memory per chunk = B·Sc·V/n_chunks)
# ---------------------------------------------------------------------------

def _head_kernel(params, cfg):
    if cfg.tie_embeddings or "lm_head" not in params:
        return params["embed"]["embedding"].T
    return params["lm_head"]["kernel"]


def loss_and_metrics(params, batch, cfg, *, rng=None, mesh=None,
                     remat=False, n_loss_chunks: int = 0, aux_weight=0.01):
    hidden, _, aux = forward(params, batch, cfg, rng=rng, mesh=mesh,
                             remat=remat)
    tokens, mask = batch["tokens"], batch["loss_mask"]
    if cfg.frontend and not cfg.n_enc_layers and "frontend_emb" in batch:
        hidden = hidden[:, batch["frontend_emb"].shape[1]:]
    B, Stot, D = hidden.shape
    targets = tokens[:, 1:]
    h = hidden[:, :-1]
    m = mask[:, :-1]
    Sl = Stot - 1
    kern = _head_kernel(params, cfg)
    V = kern.shape[-1]
    if n_loss_chunks <= 0:
        n_loss_chunks = max(1, min(32, (B * Sl * V) // (1 << 26)))
    while Sl % n_loss_chunks:
        n_loss_chunks -= 1
    Sc = Sl // n_loss_chunks

    # CE over vocab in seq chunks via lax.scan with a rematerialized body:
    # scan serializes the per-chunk backward (an unrolled loop lets XLA keep
    # every chunk's (B,Sc,V) softmax grads alive at once — measured 17 GB on
    # gemma3 train_4k), and remat keeps only the (B,Sc,D) chunk inputs as
    # residuals, recomputing logits in the backward sweep.
    @jax.checkpoint
    def _ce_chunk(kern, hb, tb, mb):
        logits = hb @ kern.astype(hb.dtype)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        tgt = jnp.take_along_axis(
            logits, tb[..., None], axis=-1)[..., 0].astype(jnp.float32)
        loss = jnp.sum((lse - tgt) * mb)
        pred = jnp.argmax(logits, axis=-1)
        # accuracy counts only full-weight (answer) positions; fractional
        # mask weights are auxiliary LM signal
        amb = (mb >= 0.999).astype(jnp.float32)
        correct = jnp.sum((pred == tb) * amb)
        return loss, correct, jnp.sum(amb)

    hc = h.reshape(B, n_loss_chunks, Sc, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n_loss_chunks, Sc).transpose(1, 0, 2)
    mc = m.reshape(B, n_loss_chunks, Sc).transpose(1, 0, 2)

    def _ce_scan(carry, xs):
        hb, tb, mb = xs
        l_c, a_c, n_c = _ce_chunk(kern, hb, tb, mb)
        return (carry[0] + l_c, carry[1] + a_c, carry[2] + n_c), None

    (tot_loss, tot_correct, tot_ans), _ = jax.lax.scan(
        _ce_scan, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
                   jnp.zeros((), jnp.float32)),
        (hc, tc, mc))

    denom = jnp.maximum(jnp.sum(m), 1.0)
    loss = tot_loss / denom + aux_weight * aux
    return loss, {"ce": tot_loss / denom,
                  "acc": tot_correct / jnp.maximum(tot_ans, 1.0),
                  "aux": aux, "n_tok": denom}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, seq_len: int):
    dtype = _dtype(cfg)
    n_sb, tail, pattern = cfg.blocks_layout()
    if cfg.n_enc_layers:
        pattern = cfg.dec_pattern()
        n_sb, tail = cfg.n_layers, 0

    def one(sub: SubLayer):
        if sub.mixer == "attn":
            return {"attn": L.init_attn_cache(cfg, batch, seq_len,
                                              sub.attn_kind, dtype)}
        if sub.mixer == "ssm":
            return {"ssm": S.init_ssm_cache(cfg, batch, dtype)}
        return {}

    def stack_n(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape),
                            tree)

    blocks = {}
    if n_sb:
        per_sb = {f"sub{i}": one(s) for i, s in enumerate(pattern)}
        per_sb = {k: v for k, v in per_sb.items() if v}
        blocks = stack_n(per_sb, n_sb)
    tail_c = {f"sub{i}": one(pattern[i]) for i in range(tail)}
    tail_c = {k: v for k, v in tail_c.items() if v}
    return {"blocks": blocks, "tail": tail_c}


def decode_step(params, new_token, cache, cache_index, cfg: ArchConfig, *,
                mesh=None, enc_out=None, adapter_idx=None):
    """One-token decode.  new_token: (B,) int32; cache_index: () int32
    shared position or (B,) int32 per-row positions (mixed batching).
    adapter_idx: optional (B,) pool slots for batched-LoRA serving.
    Returns (logits (B,V), new_cache)."""
    x = jnp.take(params["embed"]["embedding"], new_token[:, None], axis=0)
    B = x.shape[0]
    if jnp.ndim(cache_index) == 1:
        positions = cache_index[:, None].astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(cache_index[None, None],
                                     (B, 1)).astype(jnp.int32)

    n_sb, tail, pattern = cfg.blocks_layout()
    if cfg.n_enc_layers:
        pattern = cfg.dec_pattern()
        n_sb, tail = cfg.n_layers, 0

    x, new_cache, _ = _run_blocks(
        params["blocks"], params.get("tail", {}), x, pattern, cfg,
        positions=positions, cache=cache, cache_index=cache_index,
        enc_out=enc_out, mesh=mesh, adapter_idx=adapter_idx)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ _head_kernel(params, cfg).astype(x.dtype)).astype(jnp.float32)
    return logits, new_cache


def prefill(params, batch, cfg: ArchConfig, *, mesh=None, cache_len=0):
    """Process a prompt, returning (last_logits, cache).  cache_len pads
    full-attention caches with headroom for subsequent decode steps."""
    hidden, cache, _ = forward(params, batch, cfg, mesh=mesh,
                               return_cache=True, cache_len=cache_len)
    logits = (hidden[:, -1] @ _head_kernel(params, cfg).astype(hidden.dtype)
              ).astype(jnp.float32)
    return logits, cache
