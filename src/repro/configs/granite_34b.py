"""Granite 34B code model — llama-arch dense, MQA (kv=1), 88 layers
[arXiv:2405.04324]."""
from repro.models.config import ArchConfig, reduced

ARCH = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab_size=49152,
    source="arXiv:2405.04324",
)
SMOKE = reduced(ARCH)
