"""Non-IID client partitioning.

Heterogeneity is induced the way the paper does it (clients specialize in
different downstream task types): a Dirichlet(alpha) draw over task types
per client.  alpha → 0 gives one-task clients (the paper's setting: each
client = one downstream task); alpha → inf gives IID clients.
"""
from __future__ import annotations

import numpy as np


def dirichlet_task_partition(n_clients: int, n_tasks: int, alpha: float,
                             seed: int = 0) -> np.ndarray:
    """Returns (n_clients, n_tasks) row-stochastic mixture matrix."""
    rng = np.random.default_rng(seed)
    if alpha <= 0:  # degenerate: one task per client, round-robin
        probs = np.zeros((n_clients, n_tasks))
        for c in range(n_clients):
            probs[c, c % n_tasks] = 1.0
        return probs
    return rng.dirichlet([alpha] * n_tasks, size=n_clients)


def specialist_partition(n_clients: int, n_tasks: int) -> np.ndarray:
    """Paper setting: client i trains task (i mod n_tasks) exclusively."""
    return dirichlet_task_partition(n_clients, n_tasks, alpha=0.0)
