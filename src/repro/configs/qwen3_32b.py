"""Qwen3-32B — dense GQA kv=8 with qk-norm, head_dim 128
[hf:Qwen/Qwen3-8B family card]."""
from repro.models.config import ArchConfig, reduced

ARCH = ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_ff=25600,
    vocab_size=151936, d_head=128, qk_norm=True,
    source="hf:Qwen/Qwen3-8B",
)
SMOKE = reduced(ARCH)
