"""Table I — FedLoRA-Optimizer vs baselines on two dataset families.

Paper: LLaMA2-7B / DeepSeek-7B on Dolly-15k & Natural-Instructions;
here: reduced llama-family backbone on the two synthetic families
(DESIGN.md §9 — we validate the *ordering* ours > LoRA on global AND
local, not absolute accuracies).  FFA-LoRA added from related work.
"""
from __future__ import annotations

import time

from benchmarks.common import BENCH_CFG, bench_base, build_setting
from repro.core.fedlora import run_federated
from repro.fed.simulate import FedHyper

METHODS = ("fedlora_opt", "lora", "ffa_lora", "prompt", "adapter")
DATASETS = ("dolly", "ni")


def run(rounds: int = 6, log=print) -> list[dict]:
    rows = []
    for ds_name in DATASETS:
        base = bench_base(ds_name, log=lambda s: log(f"  {s}"))
        cds, sds, eg, el = build_setting(ds_name)
        for method in METHODS:
            hp = FedHyper(method=method, n_clients=len(cds), rounds=rounds,
                          local_steps=3, batch=8, seq_len=48, lr=3e-3,
                          server_lr=5e-4, global_steps=2, personal_steps=10,
                          lam=1e-3, prox_mu=0.0, seed=0)
            t0 = time.time()
            res = run_federated(BENCH_CFG, hp, cds, sds, eg, el, base=base)
            row = {"dataset": ds_name, "method": method,
                   "global_acc": res.global_acc, "local_acc": res.local_acc,
                   "comm_mb": res.comm_bytes / 1e6,
                   "wall_s": time.time() - t0}
            rows.append(row)
            log(f"[table1] {ds_name:6s} {method:12s} "
                f"global={res.global_acc:.3f} local={res.local_acc:.3f} "
                f"comm={row['comm_mb']:.2f}MB ({row['wall_s']:.0f}s)")
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"table1/{r['dataset']}/{r['method']},"
              f"{r['wall_s']*1e6:.0f},"
              f"global_acc={r['global_acc']:.4f};local_acc={r['local_acc']:.4f}")
    return rows


if __name__ == "__main__":
    main()
