"""repro.lint rule fixtures: one true-positive and one true-negative
per rule (R1–R5), each TP cross-checked against the *other* rules so it
provably fails if its rule is disabled; plus runner-level tests for
suppression comments, the justified-baseline contract, and a live-repo
run asserting the checked-in baseline is respected.
"""
import json
import os
import textwrap

import jax
import numpy as np
import pytest

from repro.lint import runner as LR
from repro.lint.rules import available_rules, get_rule
from repro.lint.rules.base import ModuleInfo
from repro.lint.rules.dead_mask import evaluate_registry
from repro.lint.sanitize import (KeyReuseError, NonFiniteError, nan_guard,
                                 tracked)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_src(source: str, codes, rel: str = "mod.py", extra_mods=()):
    """Run the given rules over one in-memory module (plus optional
    companion modules for project rules)."""
    mod = ModuleInfo(path=rel, rel=rel, source=textwrap.dedent(source))
    mods = [mod] + [ModuleInfo(path=r, rel=r, source=textwrap.dedent(s))
                    for r, s in extra_mods]
    return LR.run_rules(mods, root=".", codes=list(codes))


def other_rules(code: str) -> list[str]:
    # R5 needs the live registry — exclude it from cross-checks
    return [c for c in available_rules() if c not in (code, "R5")]


# ---------------------------------------------------------------------------
# R1 host-sync-in-jit
# ---------------------------------------------------------------------------

R1_TP = """
    import jax
    import numpy as np

    def step(x):
        y = x * 2
        np.asarray(y)           # host materialization inside jit
        return y

    run = jax.jit(step)
"""

R1_TN = """
    import jax
    import numpy as np

    def step(x):
        return x * 2

    run = jax.jit(step)

    def host_loop(x):
        out = run(x)
        return np.asarray(out)  # outside the traced body: fine
"""


def test_r1_true_positive_and_negative():
    hits = lint_src(R1_TP, ["R1"])
    assert len(hits) == 1 and hits[0].rule == "R1"
    assert "np.asarray" in hits[0].message and "step" in hits[0].message
    assert lint_src(R1_TP, other_rules("R1")) == []   # only R1 sees it
    assert lint_src(R1_TN, ["R1"]) == []


def test_r1_catches_obs_emits_scan_bodies_and_tracer_float():
    src = """
        import jax
        from repro import obs

        def body(carry, x):
            obs.inc("steps")            # telemetry emit in a scan body
            lr = float(x)               # concretizes the traced operand
            return carry + lr, None

        def outer(xs):
            return jax.lax.scan(body, 0.0, xs)
    """
    rules = {f.message.split("`")[1] for f in lint_src(src, ["R1"])}
    assert "obs.inc" in rules and "float()" in " ".join(
        f.message for f in lint_src(src, ["R1"]))
    # obs.annotate is a host-side wrapper, not an emit
    assert lint_src("""
        import jax
        from repro import obs

        def f(x):
            return x + 1

        g = obs.annotate("serve/prefill")(jax.jit(f))
    """, ["R1"]) == []


# ---------------------------------------------------------------------------
# R2 donation-safety
# ---------------------------------------------------------------------------

R2_TP = """
    import jax

    def scatter(pool, rows):
        return pool.at[0].set(rows)

    scatter_jit = jax.jit(scatter, donate_argnums=(0,))

    def swap(pool, rows):
        new = scatter_jit(pool, rows)
        stale = pool.sum()      # read after donation
        return new, stale
"""

R2_TN = """
    import jax

    def scatter(pool, rows):
        return pool.at[0].set(rows)

    scatter_jit = jax.jit(scatter, donate_argnums=(0,))

    def swap(pool, rows):
        pool = scatter_jit(pool, rows)   # rebinds: donation is safe
        return pool.sum()
"""


def test_r2_true_positive_and_negative():
    hits = lint_src(R2_TP, ["R2"])
    assert len(hits) == 1 and hits[0].rule == "R2"
    assert "`pool`" in hits[0].message and "donated" in hits[0].message
    assert lint_src(R2_TP, other_rules("R2")) == []
    assert lint_src(R2_TN, ["R2"]) == []


def test_r2_decorated_defs_and_annotate_wrap():
    src = """
        import jax
        from functools import partial
        from repro import obs

        @partial(jax.jit, donate_argnums=(1,))
        def merge(base, overlay):
            return base, overlay

        wrapped = obs.annotate("x")(jax.jit(merge, donate_argnums=(1,)))

        def caller(b, ov):
            out = merge(b, ov)
            return ov
    """
    hits = lint_src(src, ["R2"])
    assert len(hits) == 1 and "`ov`" in hits[0].message


# ---------------------------------------------------------------------------
# R3 PRNG hygiene
# ---------------------------------------------------------------------------

R3_TP = """
    import jax

    def init(rng, shape):
        a = jax.random.normal(rng, shape)
        b = jax.random.normal(rng, shape)   # same key, same draw
        return a, b
"""

R3_TN = """
    import jax

    def init(rng, shape):
        k1, k2 = jax.random.split(rng)
        a = jax.random.normal(k1, shape)
        b = jax.random.normal(k2, shape)
        r2 = jax.random.fold_in(rng, 1)
        c = jax.random.normal(r2, shape)
        return a, b, c
"""


def test_r3_true_positive_and_negative():
    hits = lint_src(R3_TP, ["R3"])
    assert len(hits) == 1 and hits[0].rule == "R3"
    assert "`rng`" in hits[0].message
    assert lint_src(R3_TP, other_rules("R3")) == []
    assert lint_src(R3_TN, ["R3"]) == []


def test_r3_branches_are_exclusive_but_loops_reuse():
    # if/else branches never both run → no reuse
    assert lint_src("""
        import jax

        def f(rng, flag):
            if flag:
                return jax.random.normal(rng, (2,))
            else:
                return jax.random.uniform(rng, (2,))
    """, ["R3"]) == []
    # a loop body consuming an outer key reuses it every iteration
    hits = lint_src("""
        import jax

        def f(rng, xs):
            out = []
            for x in xs:
                out.append(jax.random.normal(rng, (2,)))
            return out
    """, ["R3"])
    assert len(hits) == 1
    # numpy Generators are stateful — reuse is their API
    assert lint_src("""
        import numpy as np

        def f(rng: np.random.Generator, n):
            a = rng.integers(0, 9, n)
            draw = consume(rng)
            draw2 = consume(rng)
            return a, draw, draw2
    """, ["R3"]) == []


def test_r3_fold_offset_contract_between_engine_files():
    train = """
        import jax

        def train_scan(rng, *, rng_fold=0):
            return jax.random.fold_in(rng, rng_fold)

        def personal(rng):
            return train_scan(rng, rng_fold=31)
    """
    sim_ok = """
        import jax

        def make_scan(fold_offset):
            def body(rng, step):
                return jax.random.fold_in(rng, fold_offset + step)
            return body

        s1 = make_scan(0)
        s3 = make_scan(31)
    """
    sim_drift = sim_ok.replace("make_scan(31)", "make_scan(17)")
    ok = lint_src(train, ["R3"], rel="launch/train.py",
                  extra_mods=[("fed/simulate.py", sim_ok)])
    assert [f for f in ok if "drift" in f.message] == []
    drift = lint_src(train, ["R3"], rel="launch/train.py",
                     extra_mods=[("fed/simulate.py", sim_drift)])
    msgs = [f for f in drift if "drift" in f.message]
    assert len(msgs) == 1 and "[0, 31]" in msgs[0].message \
        and "[0, 17]" in msgs[0].message


# ---------------------------------------------------------------------------
# R4 recompile hazards
# ---------------------------------------------------------------------------

R4_TP = """
    import jax

    def make_step():
        scale = 1.0

        def step(x):
            return x * scale    # closes over a mutated python scalar

        stepj = jax.jit(step)
        scale += 0.5            # mutation → retrace or stale constant
        return stepj
"""

R4_TN = """
    import jax

    def make_step(scale):
        def step(x, s):
            return x * s        # dynamic arg: no closure hazard
        return jax.jit(step)
"""


def test_r4_true_positive_and_negative():
    hits = lint_src(R4_TP, ["R4"])
    assert len(hits) == 1 and hits[0].rule == "R4"
    assert "`scale`" in hits[0].message
    assert lint_src(R4_TP, other_rules("R4")) == []
    assert lint_src(R4_TN, ["R4"]) == []


def test_r4_unhashable_static_literal():
    hits = lint_src("""
        import jax

        def f(x, opts):
            return x

        fj = jax.jit(f, static_argnums=(1,))

        def call(x):
            return fj(x, {"mode": "fast"})   # dict literal as static
    """, ["R4"])
    assert len(hits) == 1 and "static_argnums" in hits[0].message


# ---------------------------------------------------------------------------
# R5 dead-mask (live registry)
# ---------------------------------------------------------------------------

LLAMA_ONLY = (("llama2_7b", "repro.configs.llama2_7b"),)


def test_r5_live_registry_has_no_dead_masks():
    assert evaluate_registry() == []


def test_r5_flags_a_dead_keep_local_regex():
    from repro.core import methods as M
    from repro.core import peft
    from functools import partial
    dead = M.FedMethod(
        name="_lint_dead_fixture",
        make_adapter=partial(peft.add_lora, decomposed=False),
        train_mask=peft.mask_all,
        keep_local=r"no_such_leaf_anywhere$")
    M.register(dead)
    try:
        problems = evaluate_registry(configs=LLAMA_ONLY)
    finally:
        M._REGISTRY.pop("_lint_dead_fixture")
    assert any(p["method"] == "_lint_dead_fixture"
               and p["field"] == "keep_local" for p in problems)
    # and the registry is clean again once the fixture is gone
    assert evaluate_registry(configs=LLAMA_ONLY) == []


def test_r5_flags_a_dead_stage_mask():
    from repro.core import methods as M
    from repro.core import peft
    from repro.utils import pytree as pt
    from functools import partial
    dead = M.FedMethod(
        name="_lint_dead_stage",
        make_adapter=partial(peft.add_lora, decomposed=False),
        train_mask=peft.mask_all,
        global_mask=lambda ad: pt.path_mask(ad, lambda p: False))
    M.register(dead)
    try:
        problems = evaluate_registry(configs=LLAMA_ONLY)
    finally:
        M._REGISTRY.pop("_lint_dead_stage")
    assert any(p["method"] == "_lint_dead_stage"
               and p["field"] == "stage_mask[global]" for p in problems)


# ---------------------------------------------------------------------------
# runner: suppression, baseline, live repo
# ---------------------------------------------------------------------------

def _project(tmp_path, source: str):
    (tmp_path / "pyproject.toml").write_text("[tool.none]\n")
    f = tmp_path / "mod.py"
    f.write_text(textwrap.dedent(source))
    return f


BAD = """
    import jax

    def init(rng, shape):
        a = jax.random.normal(rng, shape)
        b = jax.random.normal(rng, shape)
        return a, b
"""


def test_runner_exit_codes_and_json(tmp_path, capsys):
    f = _project(tmp_path, BAD)
    assert LR.main([str(f), "--rules", "R3", "--json"]) == 1
    rep = json.loads(capsys.readouterr().out)
    assert len(rep["findings"]) == 1
    assert rep["findings"][0]["rule"] == "R3"
    # clean file exits 0
    f.write_text("x = 1\n")
    assert LR.main([str(f), "--rules", "R3"]) == 0


def test_suppression_requires_a_reason(tmp_path, capsys):
    src = BAD.replace(
        "b = jax.random.normal(rng, shape)",
        "b = jax.random.normal(rng, shape)  # lint: ok[R3] twin draw is "
        "intentional here")
    f = _project(tmp_path, src)
    assert LR.main([str(f), "--rules", "R3"]) == 0
    # a bare ok[R3] with no justification does NOT suppress
    bare = BAD.replace("b = jax.random.normal(rng, shape)",
                       "b = jax.random.normal(rng, shape)  # lint: ok[R3]")
    f.write_text(textwrap.dedent(bare))
    assert LR.main([str(f), "--rules", "R3"]) == 1
    # the wrong rule code does not suppress either
    wrong = BAD.replace("b = jax.random.normal(rng, shape)",
                        "b = jax.random.normal(rng, shape)  "
                        "# lint: ok[R1] not the rule that fires")
    f.write_text(textwrap.dedent(wrong))
    assert LR.main([str(f), "--rules", "R3"]) == 1


def test_baseline_needs_notes_and_matches_on_content(tmp_path, capsys):
    f = _project(tmp_path, BAD)
    bl = tmp_path / ".lint-baseline.json"
    assert LR.main([str(f), "--rules", "R3", "--write-baseline"]) == 0
    entries = json.loads(bl.read_text())
    assert len(entries) == 1 and entries[0]["note"].startswith("TODO")
    # TODO notes are a config error — justification is mandatory
    assert LR.main([str(f), "--rules", "R3"]) == 2
    entries[0]["note"] = "known twin draw, tracked in #123"
    bl.write_text(json.dumps(entries))
    assert LR.main([str(f), "--rules", "R3"]) == 0
    # content-matched: an unrelated line added above does not break it
    f.write_text("# a new comment line\n" + f.read_text())
    assert LR.main([str(f), "--rules", "R3"]) == 0
    # fixing the bug makes the entry stale (warned, still exit 0)
    f.write_text(textwrap.dedent(BAD).replace(
        "b = jax.random.normal(rng, shape)",
        "b = jax.random.normal(jax.random.fold_in(rng, 1), shape)"))
    assert LR.main([str(f), "--rules", "R3"]) == 0
    assert "stale" in capsys.readouterr().out


@pytest.mark.slow
def test_live_repo_is_clean_under_checked_in_baseline():
    """The merged tree lints green: zero unsuppressed findings beyond
    the justified baseline (the ISSUE acceptance criterion)."""
    src = os.path.join(ROOT, "src", "repro")
    assert LR.main([src]) == 0


# ---------------------------------------------------------------------------
# sanitize: nan_guard + tracked keys
# ---------------------------------------------------------------------------

def test_nan_guard_names_offending_paths():
    tree = {"a": np.ones(3), "b": {"c": np.array([1.0, np.nan])},
            "label": "not-an-array"}
    with pytest.raises(NonFiniteError) as e:
        nan_guard(tree, "grads")
    assert "b/c" in str(e.value) and "grads" in str(e.value)
    clean = {"a": np.ones(3), "n": 7}
    assert nan_guard(clean, "ok") is clean


def test_tracked_key_raises_on_second_consumption():
    k = tracked(jax.random.PRNGKey(0), "root")
    k1, k2 = k.split(2)
    a = jax.random.normal(k1.use(), (2,))
    with pytest.raises(KeyReuseError, match="consumed twice"):
        k1.use()
    # deriving never consumes; each child is fresh
    b = jax.random.normal(k2.fold_in(3).use(), (2,))
    c = jax.random.normal(k2.fold_in(4).use(), (2,))
    assert np.isfinite(a).all() and not np.allclose(b, c)


def test_rule_registry_mirrors_method_registry():
    assert available_rules() == ["R1", "R2", "R3", "R4", "R5"]
    with pytest.raises(ValueError, match="unknown lint rule"):
        get_rule("R9")
