"""Fig. 1 — sensitivity of LoRA factor direction/magnitude (Eqs. 2-3).

Fine-tune decomposed-LoRA per downstream task and on the all-task mixture
from the same pretrained base, then measure ΔM/ΔD of A and B between each
task fine-tune and the all-task fine-tune.  Paper observations to verify
qualitatively: ΔD(A) > ΔD(B)  (≈1.7×)  and  ΔM(B) ≫ ΔM(A)  (≈41×).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_CFG, bench_base, PAPER_TASKS, task_probs, mixture_probs
from repro.core import peft
from repro.core.sensitivity import sensitivity_report
from repro.data.synthetic import SyntheticInstructionDataset, make_dataset_family
from repro.models import model as M
from repro.optim import adamw, masked, chain_clip
from repro.optim.optimizers import apply_updates
from repro.utils import pytree as pt


def _finetune_lora(base, cfg, dataset, steps=80, lr=3e-3, seed=0):
    adapters = peft.add_lora(base, cfg, jax.random.PRNGKey(seed),
                             decomposed=True)
    mask = peft.mask_stage_local_pretrain(adapters)
    opt = chain_clip(masked(adamw(lr), mask), 1.0)
    ost = opt.init(adapters)

    @jax.jit
    def step(ad, ost, b, i):
        def loss(ad):
            return M.loss_and_metrics(pt.merge_trees(base, ad), b, cfg)[0]
        g = jax.grad(loss)(ad)
        upd, ost = opt.update(g, ost, ad, i)
        return apply_updates(ad, upd), ost

    rng = np.random.default_rng(seed)
    for i in range(steps):
        b = {k: jnp.asarray(v)
             for k, v in dataset.sample_batch(rng, 16, 48).items()}
        adapters, ost = step(adapters, ost, b, jnp.asarray(i))
    return adapters


def run(steps: int = 60, log=print) -> dict:
    t0 = time.time()
    base = bench_base("dolly", log=lambda s: log(f"  {s}"))
    fam = make_dataset_family("dolly")
    task_ads = {}
    for t in PAPER_TASKS:
        ds = SyntheticInstructionDataset(fam, task_probs(t), client_seed=0)
        task_ads[t] = _finetune_lora(base, BENCH_CFG, ds, steps=steps)
        log(f"[fig1] fine-tuned task {t}")
    mix = SyntheticInstructionDataset(fam, mixture_probs(), client_seed=0)
    all_ad = _finetune_lora(base, BENCH_CFG, mix, steps=steps)
    rep = sensitivity_report(task_ads, all_ad)
    rep["wall_s"] = time.time() - t0
    log(f"[fig1] mean ΔD_A={rep['mean']['dD_A']:.4f} ΔD_B={rep['mean']['dD_B']:.4f} "
        f"ratio={rep['obs1_dir_ratio_A_over_B']:.2f}  (paper: 1.7)")
    log(f"[fig1] mean ΔM_A={rep['mean']['dM_A']:.4f} ΔM_B={rep['mean']['dM_B']:.4f} "
        f"ratio={rep['obs2_mag_ratio_B_over_A']:.2f}  (paper: 41)")
    return rep


def main():
    rep = run()
    print("name,us_per_call,derived")
    print(f"fig1/sensitivity,{rep['wall_s']*1e6:.0f},"
          f"dirA_over_dirB={rep['obs1_dir_ratio_A_over_B']:.3f};"
          f"magB_over_magA={rep['obs2_mag_ratio_B_over_A']:.3f}")
    return rep


if __name__ == "__main__":
    main()
