"""Sharding rules: map param paths → PartitionSpec via ordered regex rules.

Rules are (regex, spec-template) pairs.  A spec template is a tuple whose
entries are either None, a mesh-axis name, or a tuple of axis names.  Axis
names that do not exist in the mesh are dropped (so the same rule table
works for the single-pod ("data","model") mesh and the multi-pod
("pod","data","model") mesh).
"""
from __future__ import annotations

import re
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Sequence[tuple[str, tuple]]


def _filter_axes(entry, mesh_axes: set[str]):
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in mesh_axes else None
    # tuple of axes: keep only present ones
    kept = tuple(a for a in entry if a in mesh_axes)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def spec_for(path: str, ndim: int, rules: Rules, mesh: Mesh) -> P:
    mesh_axes = set(mesh.axis_names)
    for rx, template in rules:
        if re.search(rx, path):
            entries = [_filter_axes(e, mesh_axes) for e in template]
            # pad/trim template to the array rank (templates are written
            # for the unstacked rank; scan-stacking prepends dims).
            if len(entries) < ndim:
                entries = [None] * (ndim - len(entries)) + entries
            elif len(entries) > ndim:
                entries = entries[len(entries) - ndim:]
            return P(*entries)
    return P()  # replicated


def tree_shardings(tree, rules: Rules, mesh: Mesh):
    """NamedSharding pytree for a pytree of arrays/ShapeDtypeStructs."""
    from repro.utils.pytree import tree_map_with_path

    def fn(path, x):
        return NamedSharding(mesh, spec_for(path, len(x.shape), rules, mesh))

    return tree_map_with_path(fn, tree)


def tree_specs(tree, rules: Rules, mesh: Mesh):
    from repro.utils.pytree import tree_map_with_path

    return tree_map_with_path(
        lambda p, x: spec_for(p, len(x.shape), rules, mesh), tree
    )


def data_axis_names(mesh: Mesh) -> tuple[str, ...]:
    """The mesh axes that enumerate data/clients, in collective order —
    the one source of truth for how federated clients map onto
    ('pod','data') (launch/mesh.data_axes delegates here)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def client_axis(mesh: Mesh):
    """The PartitionSpec entry that shards a leading client/batch axis
    over every data-like mesh axis."""
    axes = data_axis_names(mesh)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def client_specs(tree, mesh: Mesh):
    """PartitionSpec pytree placing one client per data shard: every leaf
    is sharded on its leading client axis (adapters, optimizer state, and
    per-client aggregation weights/masks in the fed train step all use
    this layout)."""
    ax = client_axis(mesh)
    return jax.tree.map(lambda _: P(ax), tree)


def client_vector_spec(mesh: Mesh) -> P:
    """PartitionSpec for a per-client (C,) vector — aggregation weights,
    participation flags, staleness counters, update scales: one scalar
    per data shard, the layout the fed round's fault/weight inputs ride
    (launch/train.py)."""
    return P(client_axis(mesh))


def replicated_specs(tree):
    """PartitionSpec pytree replicating every leaf — the layout of the
    federated pipeline's stage-2 state (the aggregated server model and
    the server batch mixture carry no client axis and are identical on
    every shard)."""
    return jax.tree.map(lambda _: P(), tree)


def batch_spec(mesh: Mesh, ndim: int, batch_axis: int = 0) -> P:
    """Shard the batch dim over every data-like axis present in the mesh."""
    entries: list[Any] = [None] * ndim
    entries[batch_axis] = client_axis(mesh)
    return P(*entries)


def local_device_count_for(mesh: Mesh) -> int:
    return mesh.devices.size


# ---------------------------------------------------------------------------
# Default rule table for the model zoo.  Paths look like:
#   embed/embedding                         (vocab, d)
#   blocks/<i>/attn/{q,k,v,o}_proj/kernel   (d, heads*dh) stacked → (L, d, H*dh)
#   blocks/<i>/mlp/{up,gate}_proj/kernel    (d, ff)
#   blocks/<i>/mlp/down_proj/kernel         (ff, d)
#   blocks/<i>/moe/experts/{up,gate}        (E, d, ff)
#   blocks/<i>/moe/experts/down             (E, ff, d)
#   blocks/<i>/moe/router/kernel            (d, E)
#   blocks/<i>/ssm/...                      mamba mixer params
#   lm_head/kernel                          (d, vocab)
#   .../lora_A  (r, d_in) — replicated (tiny) ; .../lora_B (d_out, r)
# ---------------------------------------------------------------------------

DEFAULT_PARAM_RULES: Rules = (
    # adapters: tiny, replicated (may carry a leading per-client axis which
    # is sharded by the fed runtime, not these rules)
    (r"lora_|prompt_|adapter_|_mag$|_dir$", ()),
    # MoE experts: expert-parallel over data axis, d_ff tensor-parallel
    (r"moe/experts/(up|gate)", ("data", None, "model")),
    (r"moe/experts/down", ("data", "model", None)),
    (r"moe/router", (None, None)),
    # attention projections: head dim tensor-parallel
    (r"attn/(q_proj|k_proj|v_proj)/kernel", (None, "model")),
    (r"attn/o_proj/kernel", ("model", None)),
    # dense mlp
    (r"mlp/(up_proj|gate_proj)/kernel", (None, "model")),
    (r"mlp/down_proj/kernel", ("model", None)),
    # mamba mixer: inner dim tensor-parallel
    (r"ssm/in_proj/kernel", (None, "model")),
    (r"ssm/out_proj/kernel", ("model", None)),
    (r"ssm/(conv_w|A_log|D|dt_bias|norm_w)", ("model",)),
    # embeddings / unembedding: vocab tensor-parallel
    (r"embed/embedding", ("model", None)),
    (r"lm_head/kernel", (None, "model")),
    # norms etc: replicated
    (r".*", ()),
)

# FSDP overlay: additionally shard the *frozen* big tensors over the data
# axis (ZeRO-3 style) for archs that do not fit with pure tensor-parallel.
FSDP_PARAM_RULES: Rules = (
    (r"lora_|prompt_|adapter_|_mag$|_dir$", ()),
    (r"moe/experts/(up|gate)", ("data", None, "model")),
    (r"moe/experts/down", ("data", "model", None)),
    (r"moe/router", (None, None)),
    (r"attn/(q_proj|k_proj|v_proj)/kernel", ("data", "model")),
    (r"attn/o_proj/kernel", (("data", "model"), None)),
    (r"mlp/(up_proj|gate_proj)/kernel", ("data", "model")),
    (r"mlp/down_proj/kernel", (("data", "model"), None)),
    (r"ssm/in_proj/kernel", ("data", "model")),
    (r"ssm/out_proj/kernel", (("data", "model"), None)),
    (r"ssm/(conv_w|A_log|D|dt_bias|norm_w)", ("model",)),
    (r"embed/embedding", (("data", "model"), None)),
    (r"lm_head/kernel", ("data", "model")),
    (r".*", ()),
)
