"""Core layers for the multi-arch transformer zoo.

Everything is a pure function over nested-dict params.  Linear layers
understand adapter params living alongside their kernel:

  {kernel}                                  — plain frozen projection
  {kernel_q, kernel_scale}                  — weight-only quantized frozen
                                              projection (int8 / packed
                                              int4 + per-group f32 scales;
                                              see kernels/quant_matmul) —
                                              adapters ride alongside in
                                              full precision
  {kernel, lora_A, lora_B}                  — raw LoRA (baseline)
  {kernel, A_dir, A_mag, B_dir, B_mag,
   dA_dir, dB_mag}                          — DoRA-decomposed LoRA
                                              (the paper's representation;
                                              dA_dir is the global-stage
                                              delta, dB_mag the local-stage
                                              delta)

Kernels use (d_in, d_out) layout; per-column magnitude in the DoRA sense
is the norm over the *output* axis for each input feature — A_mag:(d_in,),
B_mag:(r,).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def head_rms_norm(x, w, eps: float = 1e-6):
    """qk-norm: normalize over the head dim (..., dh)."""
    return rms_norm(x, w, eps)


# ---------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def _rope_freqs(dh: int, theta: float):
    return theta ** (-jnp.arange(0, dh // 2, dtype=jnp.float32) / (dh // 2))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, S, H, dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    freqs = _rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float = 1e4,
                sections=(0.25, 0.375, 0.375)):
    """Qwen2-VL multimodal rotary: positions3 (B, S, 3) = (t, h, w) ids.

    The dh/2 frequency bands are split into three sections, each rotated by
    its own position component.  For text-only inputs all three components
    are equal and this degrades exactly to standard RoPE.
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = _rope_freqs(dh, theta)
    n0 = int(half * sections[0])
    n1 = int(half * sections[1])
    sel = jnp.concatenate([
        jnp.zeros((n0,), jnp.int32),
        jnp.ones((n1,), jnp.int32),
        jnp.full((half - n0 - n1,), 2, jnp.int32),
    ])                                                    # (dh/2,)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                   # (B,S,3)
        jnp.broadcast_to(sel, positions3.shape[:2] + (half,)).astype(jnp.int32) * 0
        + sel[None, None, :], axis=-1)                    # (B,S,dh/2)
    ang = pos * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# adapter-aware linear
# ---------------------------------------------------------------------------

def lora_delta(p: Params, x, scale: float, dropout_rng=None,
               dropout: float = 0.0):
    """Low-rank adapter contribution for input x (..., d_in)."""
    if dropout_rng is not None and dropout > 0.0:
        keep = jax.random.bernoulli(dropout_rng, 1.0 - dropout, x.shape)
        x = jnp.where(keep, x / (1.0 - dropout), 0.0).astype(x.dtype)
    if "lora_A" in p:                                    # raw LoRA
        h = x @ p["lora_A"].astype(x.dtype)
        y = (h @ p["lora_B"].astype(x.dtype)) * scale
        if "local_A" in p:                               # FedALT dual pair
            hl = x @ p["local_A"].astype(x.dtype)
            y = y + (hl @ p["local_B"].astype(x.dtype)) * scale
        return y
    # DoRA-decomposed LoRA (the paper's form):
    #   A = (A_dir + dA_dir) * A_mag[:, None]
    #   B = B_dir * (B_mag + dB_mag)[:, None]
    a_dir = p["A_dir"] + p.get("dA_dir", 0.0)
    h = (x * p["A_mag"].astype(x.dtype)) @ a_dir.astype(x.dtype)
    b_mag = p["B_mag"] + p.get("dB_mag", 0.0)
    return ((h * b_mag.astype(x.dtype)) @ p["B_dir"].astype(x.dtype)) * scale


def lora_delta_batched(p: Params, x, adapter_idx, scale: float):
    """Mixed-tenant adapter contribution: row i of x (B, ..., d_in) uses
    the adapter in pool slot adapter_idx[i] (BGMV — see
    kernels/batched_lora and serve/adapter_store).  Pooled leaves:

      {pool_A, pool_B}                        — per-slot LoRA pairs
      {bgmv_A_dir, bgmv_A_mag, bgmv_B_mag,
       bgmv_B_dir, pool_dB_mag}               — decomposed-DoRA: shared
                                                direction/magnitude
                                                factors, per-slot RAW
                                                ΔB_M deltas (the paper's
                                                deployment shape; the
                                                kernel forms
                                                B_mag + ΔB_M itself)

    An optional {pool_ranks} leaf ((L,) int32) marks a heterogeneous
    pool: slots are padded to r_max and the kernel masks each row's
    intermediate at its slot's own rank — on the magnitude layout that
    mask covers the shared B_mag rows too, so each tenant gets its own
    rank-slice of the shared model and a rank-0 slot gets none of it.
    """
    from repro.kernels import bgmv, bgmv_mag
    ranks = p.get("pool_ranks")
    if "pool_A" in p:
        return bgmv(x, p["pool_A"], p["pool_B"], adapter_idx, scale=scale,
                    ranks=ranks)
    return bgmv_mag(x, p["bgmv_A_dir"], p["bgmv_A_mag"], p["bgmv_B_mag"],
                    p["pool_dB_mag"], p["bgmv_B_dir"], adapter_idx,
                    scale=scale, ranks=ranks)


def _has_pooled(p: Params) -> bool:
    return "pool_A" in p or "pool_dB_mag" in p


def linear(p: Params, x, *, lora_scale: float = 0.0, dropout_rng=None,
           dropout: float = 0.0, fused: bool = False, adapter_idx=None):
    if (fused and "A_dir" in p and lora_scale
            and (adapter_idx is None or not _has_pooled(p))
            and (dropout_rng is None or dropout == 0.0)
            and "bias" not in p and "kernel" in p
            and p["kernel"].ndim == 2):
        # (pooled per-row routing outranks the fused single-adapter path:
        # taking the fused branch here would silently serve every tenant
        # the shared adapter)
        # fused base+adapter matmul (Pallas; interpret mode off-TPU).
        # Forward/serving only: pallas_call has no VJP here, so training
        # paths keep fused=False.
        from repro.kernels import fused_dora
        return fused_dora(x, p["kernel"], p["A_dir"], p["A_mag"],
                          p["B_dir"], p["B_mag"], p.get("dA_dir"),
                          p.get("dB_mag"), scale=lora_scale)
    if "kernel_q" in p:
        # quantized frozen backbone: dequant-fused matmul (Pallas on TPU,
        # XLA oracle elsewhere); all adapter deltas below stay f32 on top
        from repro.kernels import quant_matmul
        y = quant_matmul(x, p["kernel_q"], p["kernel_scale"])
    else:
        y = x @ p["kernel"].astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    if adapter_idx is not None and lora_scale and _has_pooled(p):
        y = y + lora_delta_batched(p, x, adapter_idx, lora_scale)
    elif ("lora_A" in p or "A_dir" in p) and lora_scale:
        y = y + lora_delta(p, x, lora_scale, dropout_rng, dropout)
    return y


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _causal_window_mask(S_q, S_k, q_offset, window: Optional[int],
                        causal: bool):
    """(S_q, S_k) boolean mask; q position i attends k position j."""
    qi = jnp.arange(S_q)[:, None] + q_offset
    kj = jnp.arange(S_k)[None, :]
    m = jnp.ones((S_q, S_k), bool)
    if causal:
        m &= kj <= qi
    if window is not None:
        m &= kj > qi - window
    return m


def _sdpa(q, k, v, mask, softmax_scale):
    """q:(B,Sq,H,dh) k,v:(B,Sk,K,dh) GQA; mask (..., Sq,Sk) or None.

    Grouped-head einsums instead of jnp.repeat (a repeated 32k KV cache
    materializes H/K× the cache bytes), and bf16 operands with f32
    accumulation instead of .astype(f32) casts (XLA hoists a full-cache
    f32 copy out of the layer scan otherwise — measured 8.6 GB on
    qwen3-32b decode)."""
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    rep = H // K
    qg = q.reshape(B, Sq, K, rep, dh)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, k,
                        preferred_element_type=jnp.float32) * softmax_scale
    if mask is not None:
        m = mask
        if m.ndim == 4:                       # (B?,1,Sq,Sk) → (B?,1,1,Sq,Sk)
            m = m[:, :, None]
        scores = jnp.where(m, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


def _sdpa_chunked(q, k, v, softmax_scale, window, causal, q_block: int = 512):
    """Flash-style online-softmax over query blocks in pure JAX (lax.scan)
    — bounds activation memory for 32k-token prefill in the dry-run the
    same way the Pallas kernel does on TPU."""
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    K = k.shape[2]
    rep = H // K
    nb = Sq // q_block
    qb = q.reshape(B, nb, q_block, K, rep, dh).transpose(1, 0, 2, 3, 4, 5)

    @jax.checkpoint
    def _block(qi, idx):
        # remat: the (bq × Sk) score/weight tensors are recomputed in the
        # backward pass — without this every q-block's softmax weights stay
        # live as scan residuals (measured ~2 GB/layer on 4k×1152 trains).
        # Grouped-head bf16 einsums w/ f32 accumulation (see _sdpa).
        scores = jnp.einsum("bqkrd,bskd->bkrqs", qi, k,
                            preferred_element_type=jnp.float32)
        scores = scores * softmax_scale
        mask = _causal_window_mask(q_block, Sk, idx * q_block, window, causal)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkrqs,bskd->bqkrd", w.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, q_block, H, dh).astype(q.dtype)

    def body(_, qi_and_idx):
        qi, idx = qi_and_idx
        return None, _block(qi, idx)

    _, outs = jax.lax.scan(body, None, (qb, jnp.arange(nb)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, dh)


def attention(p: Params, x, positions, cfg, *, kind: str = "global",
              causal: bool = True, cache=None, cache_index=None,
              kv_source=None, lora_scale: float = 0.0, dropout_rng=None,
              chunk_q: bool = False, return_cache: bool = False,
              cache_len: int = 0, adapter_idx=None):
    """Full attention sublayer (pre-norm outside).  Returns (y, new_cache).

    cache: dict(k=(B,Sc,K,dh), v=...) — decode ring/linear buffer.
    cache_index: () int32 shared write position, or (B,) int32 per-row
    positions (mixed-tenant serving: rows admitted at different times).
    kv_source: encoder output for cross-attention (keys/values from there).
    adapter_idx: (B,) int32 pool-slot per row for batched-LoRA serving.
    """
    B, S, D = x.shape
    H, Kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    window = cfg.sliding_window if kind == "local" else None
    scale = 1.0 / math.sqrt(dh)

    # one dropout key per projection: sharing dropout_rng across q/k/v
    # makes the adapter-dropout masks identical (q and k/v see the same
    # input tensor in self-attention) — lint rule R3
    if dropout_rng is None:
        q_rng = k_rng = v_rng = None
    else:
        q_rng, k_rng, v_rng = jax.random.split(dropout_rng, 3)
    q = linear(p["q_proj"], x, lora_scale=lora_scale if "q_proj" in cfg.lora_targets else 0.0,
               dropout_rng=q_rng, dropout=cfg.lora_dropout,
               fused=cfg.use_fused_dora, adapter_idx=adapter_idx)
    kv_in = x if kv_source is None else kv_source
    k = linear(p["k_proj"], kv_in, lora_scale=lora_scale if "k_proj" in cfg.lora_targets else 0.0,
               dropout_rng=k_rng, dropout=cfg.lora_dropout,
               fused=cfg.use_fused_dora, adapter_idx=adapter_idx)
    v = linear(p["v_proj"], kv_in, lora_scale=lora_scale if "v_proj" in cfg.lora_targets else 0.0,
               dropout_rng=v_rng, dropout=cfg.lora_dropout,
               fused=cfg.use_fused_dora, adapter_idx=adapter_idx)
    Skv = kv_in.shape[1]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, Skv, Kh, dh)
    v = v.reshape(B, Skv, Kh, dh)

    if "q_norm" in p:                                      # qwen3 qk-norm
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)

    if kv_source is None:                                  # self-attn: rope
        if cfg.mrope:
            pos3 = positions if positions.ndim == 3 else jnp.repeat(
                positions[..., None], 3, axis=-1)
            q = apply_mrope(q, pos3, cfg.rope_theta)
            k = apply_mrope(k, pos3, cfg.rope_theta)
        else:
            pos = positions if positions.ndim == 2 else positions[..., 0]
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)

    new_cache = None
    if cache is not None and kv_source is None:
        # decode: write the new token's k/v into the buffer.
        Sc = cache["k"].shape[1]
        if window is not None and Sc == window:
            slot = cache_index % window                    # ring buffer
        else:
            slot = cache_index
        if jnp.ndim(cache_index) == 1:
            # per-row write positions (continuous batching: each row is
            # at its own sequence offset) — scatter one slot per row.
            rows = jnp.arange(B)
            ck = cache["k"].at[rows, slot].set(k[:, 0])
            cv = cache["v"].at[rows, slot].set(v[:, 0])
            valid = (jnp.arange(Sc)[None, :]
                     < jnp.minimum(cache_index + 1, Sc)[:, None])
            mask = valid[:, None, None, :]                 # (B,1,1,Sc)
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
            valid = jnp.arange(Sc) < jnp.minimum(cache_index + 1, Sc)
            mask = valid[None, None, None, :]              # (1,1,1,Sc)
        new_cache = {"k": ck, "v": cv}
        out = _sdpa(q, ck, cv, mask, scale)
    elif cache is not None and kv_source is not None:
        # cross-attention during decode: kv from the (static) encoder output.
        out = _sdpa(q, k, v, None, scale)
        new_cache = cache
    else:
        if chunk_q and S >= 2048 and S % 512 == 0:
            out = _sdpa_chunked(q, k, v, scale, window, causal)
        else:
            mask = None
            if causal or window is not None:
                mask = _causal_window_mask(S, Skv, 0, window, causal)[None, None]
            out = _sdpa(q, k, v, mask, scale)
        if return_cache and kv_source is None:
            if window is not None:
                if S > window:
                    # keep last `window` kv, rotated so pos p sits at slot
                    # p % window (ring layout the decode path expects)
                    kk = jnp.roll(k[:, -window:], S % window, axis=1)
                    vv = jnp.roll(v[:, -window:], S % window, axis=1)
                else:                       # pad up to the ring size
                    pad = [(0, 0), (0, window - S), (0, 0), (0, 0)]
                    kk, vv = jnp.pad(k, pad), jnp.pad(v, pad)
            else:
                tgt = max(cache_len, S)
                pad = [(0, 0), (0, tgt - S), (0, 0), (0, 0)]
                kk, vv = jnp.pad(k, pad), jnp.pad(v, pad)
            new_cache = {"k": kk, "v": vv}

    y = linear(p["o_proj"], out.reshape(B, S, H * dh),
               lora_scale=lora_scale if "o_proj" in cfg.lora_targets else 0.0,
               fused=cfg.use_fused_dora, adapter_idx=adapter_idx)
    return y, new_cache


def init_attn_cache(cfg, batch: int, seq_len: int, kind: str, dtype):
    window = cfg.sliding_window if kind == "local" else None
    Sc = min(seq_len, window) if window is not None else seq_len
    shape = (batch, Sc, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# dense FFN (SwiGLU)
# ---------------------------------------------------------------------------

def dense_ffn(p: Params, x, cfg, lora_scale: float = 0.0, adapter_idx=None):
    g = linear(p["gate_proj"], x,
               lora_scale=lora_scale if "gate_proj" in cfg.lora_targets else 0.0,
               fused=cfg.use_fused_dora, adapter_idx=adapter_idx)
    u = linear(p["up_proj"], x,
               lora_scale=lora_scale if "up_proj" in cfg.lora_targets else 0.0,
               fused=cfg.use_fused_dora, adapter_idx=adapter_idx)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = linear(p["down_proj"], h,
               lora_scale=lora_scale if "down_proj" in cfg.lora_targets else 0.0,
               fused=cfg.use_fused_dora, adapter_idx=adapter_idx)
    if "adapter_down" in p:                                # Houlsby adapter
        a = jax.nn.gelu((y @ p["adapter_down"]).astype(jnp.float32)).astype(y.dtype)
        y = y + a @ p["adapter_up"]
    return y


# ---------------------------------------------------------------------------
# MoE FFN — sort+capacity grouped matmul, optional expert-parallel a2a
# ---------------------------------------------------------------------------

def _group_by_expert(xt, top_i, top_w, E_slots: int, C: int, fsplit: int):
    """Token grouping: returns (xg (E_slots*C, D), combine info).

    Tokens routed to logical expert e are duplicated onto the fsplit
    physical slots [e*fsplit, (e+1)*fsplit) — each slot holds a 1/fsplit
    slice of d_ff, and the down-projection partial sums recombine in the
    weighted scatter-add (expert tensor-parallel trick for E < EP-degree).
    """
    T, k = top_i.shape
    if fsplit > 1:
        top_i = (top_i[..., None] * fsplit
                 + jnp.arange(fsplit)[None, None, :]).reshape(T, k * fsplit)
        top_w = jnp.repeat(top_w, fsplit, axis=-1)
        k = k * fsplit
    flat_e = top_i.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(T * k) - first
    keep = pos < C
    dest = jnp.where(keep, se * C + pos, E_slots * C)      # overflow → dump row
    xg = jnp.zeros((E_slots * C + 1, xt.shape[-1]), xt.dtype)
    xg = xg.at[dest].add(xt[st])
    return xg[:-1], (st, sw, dest, keep)


def _combine_from_expert(yg, combine, T: int):
    st, sw, dest, keep = combine
    D = yg.shape[-1]
    yg1 = jnp.concatenate([yg, jnp.zeros((1, D), yg.dtype)], axis=0)
    vals = yg1[jnp.where(keep, dest, yg.shape[0])] * (sw * keep)[:, None].astype(yg.dtype)
    return jnp.zeros((T, D), yg.dtype).at[st].add(vals)


def _expert_mlp(xg, wg, wu, wd):
    """xg: (E_loc, C, D); weights (E_loc, D, F_loc)/(E_loc, F_loc, D)."""
    g = jnp.einsum("ecd,edf->ecf", xg, wg.astype(xg.dtype))
    u = jnp.einsum("ecd,edf->ecf", xg, wu.astype(xg.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xg.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(xg.dtype))


def moe_router(p, xt, cfg, fsplit: int):
    logits = (xt @ p["router"]["kernel"].astype(xt.dtype)).astype(jnp.float32)
    top_w, top_i = jax.lax.top_k(logits, cfg.top_k)
    top_w = jax.nn.softmax(top_w, axis=-1).astype(xt.dtype)
    # load-balance aux (Switch): E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    counts = jnp.zeros((cfg.n_experts,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    aux = cfg.n_experts * jnp.sum(f * probs.mean(0))
    return top_i, top_w, aux


def moe_ffn_local(p: Params, x, cfg):
    """Single-shard sort+capacity grouped-matmul MoE.

    Expert weights are stored in *slot layout* ``(E·fsplit, D, F/fsplit)``
    (see ArchConfig.ep_fsplit); for fsplit == 1 this is the plain layout.
    Also serves as the math oracle target for the expert-parallel path.
    """
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    fsplit = cfg.ep_fsplit
    E_slots = cfg.n_experts * fsplit
    C = max(1, int(math.ceil(cfg.top_k * T * cfg.capacity_factor / cfg.n_experts)))
    C = min(C, T)
    top_i, top_w, aux = moe_router(p, xt, cfg, fsplit)
    xg, combine = _group_by_expert(xt, top_i, top_w, E_slots, C, fsplit)
    wg, wu, wd = p["experts"]["gate"], p["experts"]["up"], p["experts"]["down"]
    yg = _expert_mlp(xg.reshape(E_slots, C, D), wg, wu, wd).reshape(E_slots * C, D)
    y = _combine_from_expert(yg, combine, T)
    return y.reshape(B, S, D), aux


def moe_ffn_manual(p: Params, x, cfg, dp: int, ep_axis: str = "data"):
    """MoE body for code already running inside a manual region over the
    data axes (launch/train.py's client shard_map).  Tokens are per-shard;
    expert slots are manual-sharded over ``ep_axis`` (E_loc per shard); the
    'model' axis stays auto — XLA inserts the F-partial all-reduce.
    """
    B_l, S, D = x.shape
    T = B_l * S
    xt = x.reshape(T, D)
    fsplit = cfg.ep_fsplit
    E_slots = cfg.n_experts * fsplit
    E_loc = E_slots // dp
    top_i, top_w, aux = moe_router(p, xt, cfg, fsplit)
    C = max(1, int(math.ceil(cfg.top_k * T * cfg.capacity_factor / cfg.n_experts)))
    C = min(C, T)
    xg, combine = _group_by_expert(xt, top_i, top_w, E_slots, C, fsplit)
    xg = xg.reshape(dp, E_loc, C, D)
    xr = jax.lax.all_to_all(xg, ep_axis, split_axis=0, concat_axis=0)
    xr = xr.transpose(1, 0, 2, 3).reshape(E_loc, dp * C, D)
    wg, wu, wd = p["experts"]["gate"], p["experts"]["up"], p["experts"]["down"]
    yr = _expert_mlp(xr, wg, wu, wd)
    yr = yr.reshape(E_loc, dp, C, D).transpose(1, 0, 2, 3)
    yg = jax.lax.all_to_all(yr, ep_axis, split_axis=0, concat_axis=0)
    y = _combine_from_expert(yg.reshape(E_slots * C, D), combine, T)
    return y.reshape(B_l, S, D), aux


def moe_ffn_ep(p: Params, x, cfg, mesh, ep_axis: str = "data"):
    """Expert-parallel MoE via shard_map + all_to_all over ``ep_axis``.

    Layout: expert slots sharded ``P(ep_axis, None, 'model')``; tokens
    sharded over the batch axes.  Per shard: local routing → group by slot
    → a2a (dispatch) → local grouped matmul on resident slots → a2a
    (return) → weighted combine → psum over 'model' (deferred from the
    down-projection partial sums — cheaper after combine).
    This is the GShard/Switch communication pattern expressed TPU-natively.
    """
    dp = mesh.shape[ep_axis]
    fsplit = cfg.ep_fsplit
    E_slots = cfg.n_experts * fsplit
    assert E_slots % dp == 0, (E_slots, dp)
    E_loc = E_slots // dp
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_total = 1
    for a in batch_axes:
        dp_total *= mesh.shape[a]

    if x.shape[0] % dp_total:
        # Small-batch (decode) path: activations replicated, experts stay
        # parallel — each shard computes its resident slots and the token
        # outputs are summed with a psum over the EP axis.
        def small_fn(x_l, router, wg, wu, wd):
            B_l, S, D = x_l.shape
            T = B_l * S
            xt = x_l.reshape(T, D)
            top_i, top_w, aux = moe_router({"router": {"kernel": router}},
                                           xt, cfg, fsplit)
            C = max(1, int(math.ceil(
                cfg.top_k * T * cfg.capacity_factor / cfg.n_experts)))
            C = min(C, T)
            xg, combine = _group_by_expert(xt, top_i, top_w, E_slots, C,
                                           fsplit)
            idx = jax.lax.axis_index(ep_axis)
            x_loc = jax.lax.dynamic_slice_in_dim(
                xg.reshape(E_slots, C, D), idx * E_loc, E_loc, 0)
            y_loc = _expert_mlp(x_loc, wg, wu, wd)
            yg = jnp.zeros((E_slots, C, D), y_loc.dtype)
            yg = jax.lax.dynamic_update_slice_in_dim(yg, y_loc, idx * E_loc, 0)
            y = _combine_from_expert(yg.reshape(E_slots * C, D), combine, T)
            y = jax.lax.psum(y, (ep_axis, "model"))
            aux = jax.lax.pmean(aux, batch_axes)
            return y.reshape(B_l, S, D), aux

        out = jax.shard_map(
            small_fn, mesh=mesh,
            in_specs=(P(None, None, None), P(None, None),
                      P(ep_axis, None, "model"), P(ep_axis, None, "model"),
                      P(ep_axis, "model", None)),
            out_specs=(P(None, None, None), P()),
            check_vma=False,
        )(x, p["router"]["kernel"], p["experts"]["gate"],
          p["experts"]["up"], p["experts"]["down"])
        return out

    def local_fn(x_l, router, wg, wu, wd):
        B_l, S, D = x_l.shape
        T = B_l * S
        xt = x_l.reshape(T, D)
        top_i, top_w, aux = moe_router({"router": {"kernel": router}}, xt,
                                       cfg, fsplit)
        C = max(1, int(math.ceil(
            cfg.top_k * T * cfg.capacity_factor / cfg.n_experts)))
        C = min(C, T)
        xg, combine = _group_by_expert(xt, top_i, top_w, E_slots, C, fsplit)
        xg = xg.reshape(dp, E_loc, C, D)
        # dispatch: swap device axis <-> slot-owner axis
        xr = jax.lax.all_to_all(xg, ep_axis, split_axis=0, concat_axis=0)
        xr = xr.transpose(1, 0, 2, 3).reshape(E_loc, dp * C, D)
        yr = _expert_mlp(xr, wg, wu, wd)                   # partial over F_loc
        yr = yr.reshape(E_loc, dp, C, D).transpose(1, 0, 2, 3)
        yg = jax.lax.all_to_all(yr, ep_axis, split_axis=0, concat_axis=0)
        y = _combine_from_expert(yg.reshape(E_slots * C, D), combine, T)
        y = jax.lax.psum(y, "model")                       # F_loc partials
        aux = jax.lax.pmean(aux, batch_axes)
        return y.reshape(B_l, S, D), aux

    x_spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0],
               None, None)
    out = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), P(ep_axis, None, "model"),
                  P(ep_axis, None, "model"), P(ep_axis, "model", None)),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"]["kernel"], p["experts"]["gate"], p["experts"]["up"],
      p["experts"]["down"])
    return out


def moe_ffn_dense_ref(p: Params, x, cfg):
    """Oracle: compute every expert for every token, mask by router top-k.
    O(E·T·D·F) — tiny models only (tests)."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    top_i, top_w, aux = moe_router(p, xt, cfg, 1)
    wg, wu, wd = p["experts"]["gate"], p["experts"]["up"], p["experts"]["down"]
    g = jnp.einsum("td,edf->tef", xt, wg.astype(xt.dtype))
    u = jnp.einsum("td,edf->tef", xt, wu.astype(xt.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
    y_all = jnp.einsum("tef,efd->ted", h, wd.astype(xt.dtype))   # (T,E,D)
    gates = jnp.zeros((xt.shape[0], cfg.n_experts), xt.dtype).at[
        jnp.arange(xt.shape[0])[:, None], top_i].add(top_w)
    y = jnp.einsum("ted,te->td", y_all, gates.astype(y_all.dtype))
    return y.reshape(B, S, D), aux
