"""Batch iterators bridging numpy generation → jnp device arrays."""
from __future__ import annotations

from typing import Iterator, Sequence

import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import SyntheticInstructionDataset


def batch_iterator(dataset: SyntheticInstructionDataset, batch: int,
                   seq_len: int, steps: int, seed: int = 0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        b = dataset.sample_batch(rng, batch, seq_len)
        yield {k: jnp.asarray(v) for k, v in b.items()}


def eval_batches(dataset: SyntheticInstructionDataset, batch: int,
                 seq_len: int, n_batches: int, task: str | None = None,
                 seed: int = 10_000) -> list[dict]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        if task is None:
            b = dataset.sample_batch(rng, batch, seq_len)
        else:
            b = dataset.sample_task_batch(rng, batch, seq_len, task)
        out.append({k: jnp.asarray(v) for k, v in b.items()})
    return out


def client_batch(datasets: Sequence[SyntheticInstructionDataset],
                 rng: np.random.Generator, per_client_batch: int,
                 seq_len: int) -> dict:
    """Stacked (C, B, S) batch across clients for the vmapped fed step."""
    outs = [d.sample_batch(rng, per_client_batch, seq_len) for d in datasets]
    return {
        k: jnp.asarray(np.stack([o[k] for o in outs]))
        for k in outs[0]
    }
